# Dev/CI entry points. CI runs `make ci`.
#
# XLA_FLAGS stays UNSET for the pytest run on purpose: smoke tests must see
# the single real CPU device; tests/test_multidevice.py spawns subprocesses
# that set --xla_force_host_platform_device_count=8 themselves. The `smoke`
# target DOES force 8 host devices so every model family is exercised on a
# multi-device CPU mesh in CI.

PY ?= python
PYTHONPATH := src

.PHONY: test smoke serve-demo bench-slo bench-smoke bench-check ci

test:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest -q

smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	PYTHONPATH=$(PYTHONPATH) $(PY) scripts/dev_smoke.py

serve-demo:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m repro.launch.serve --arch qwen3-8b \
	    --n-requests 6 --prompt-len 24 --max-new 8 \
	    --policy round_robin --tpot-budget-ms 9 --admission shed --trace

bench-slo:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.run --only tpot_slo

# Live-smoke perf rows only (no dry-run compiles); writes BENCH_decode.json
# and BENCH_prefill.json at the repo root for PR-over-PR tracking.
# bench_mtp runs after bench_decode_throughput: it merges the MTP section
# (acceptance rate + fused-MTP speedup) into the same BENCH_decode.json.
# bench-check (its own CI step, and part of `make ci`) asserts the decode
# artifact is schema 7: the pool autoscale section (engine-count timeline
# + scale-event counts), the continuous_batching section (dead-slot rate
# before/after, mid-scan refill counts, token identity, zero TPOT budget
# violations), the fault_tolerance section (crash fired, every lost
# request recovered by replay, recovery-TTFT percentiles present, faulted
# tokens bit-identical to the fault-free reference) AND the slo_classes
# section (interactive TPOT p99 held with class-aware control / violated
# without on the identical burst, >= 1 mid-decode batch preemption, and
# preempted-then-resumed tokens bit-identical to the uncontended run).
# The prefill artifact is schema 9: the handoff_overlap section (pipelined
# chunked KV streaming strictly lowers virtual-clock TTFT vs the
# synchronous whole-request handoff, hides transfer time behind prefill,
# and stays token-identical) AND the ems section (multi-turn session hit
# rate growing across turns through the shared EMS tier, promote/demote
# byte conservation against the RDMA-plane transfer books, TTFT split by
# hit depth, analytic UB-vs-VPC reuse gain, and the hit-aware admission
# demo: the suffix-blind gate waits where the hit-aware gate admits).
bench-smoke:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.bench_decode_throughput --smoke
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.bench_mtp --smoke
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.bench_prefill_throughput --smoke
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.bench_context_caching --smoke

bench-check:
	$(PY) -c "import json; d = json.load(open('BENCH_decode.json')); \
	assert d['schema'] == 7, f'BENCH_decode.json schema {d[\"schema\"]} != 7'; \
	a = d['pool']['autoscale']; \
	assert a['engine_count_timeline'] and 'scale_grows' in a \
	and 'scale_shrinks' in a, 'autoscale section incomplete'; \
	assert a['tokens_identical_to_fixed_pool'] is True, \
	'autoscaled tokens diverged from the fixed-size pool'; \
	cb = d['continuous_batching']; \
	assert cb['tokens_identical_to_per_step'] is True, \
	'continuous-batching tokens diverged from per-step decode'; \
	assert cb['after']['dead_slot_rate'] < cb['before']['dead_slot_rate'], \
	'continuous batching did not lower the dead-slot rate'; \
	assert cb['after']['mid_scan_refills'] >= 0 \
	and 'mid_scan_refills' in cb['before'], 'refill counts missing'; \
	assert cb['tpot_budget_violations'] == 0, \
	f\"TPOT gate violated {cb['tpot_budget_violations']}x under CB\"; \
	ft = d['fault_tolerance']; \
	assert ft['engine_failures'] >= 1 and ft['recoveries'] >= 1, \
	'fault plan fired no mid-decode crash/recovery'; \
	assert ft['tokens_replayed'] >= 1 and ft['retries'] >= 1, \
	'replay/retry counters missing or zero'; \
	assert ft['recovery_ttft_p50_s'] is not None \
	and ft['recovery_ttft_p99_s'] is not None, \
	'recovery-TTFT percentiles missing'; \
	assert ft['completed'] == ft['completed_fault_free'], \
	'faulted run lost requests vs fault-free reference'; \
	assert ft['tokens_identical_to_fault_free'] is True, \
	'recovered tokens diverged from the fault-free run'; \
	sc = d['slo_classes']; \
	assert sc['held_with_control'] is True, \
	'class-aware control failed to hold interactive TPOT p99'; \
	assert sc['violated_without_control'] is True, \
	'class-blind baseline did not violate the budget (burst too mild)'; \
	assert sc['preemptions'] >= 1, 'no batch-tier preemption fired'; \
	assert sc['tokens_identical_after_preemption'] is True, \
	'preempted-then-resumed tokens diverged from the uncontended run'; \
	print('BENCH_decode.json schema 7 OK:', \
	f\"{a['scale_grows']} grows, {a['scale_shrinks']} shrinks, \" \
	f\"peak {a['peak_engines']} engines; dead_slot_rate \" \
	f\"{cb['before']['dead_slot_rate']} -> {cb['after']['dead_slot_rate']} \" \
	f\"({cb['after']['mid_scan_refills']} mid-scan refills); \" \
	f\"{ft['engine_failures']} failures -> {ft['recoveries']} recoveries, \" \
	f\"{ft['tokens_replayed']} tokens replayed, {ft['retries']} retries; \" \
	f\"SLO held {sc['interactive_tpot_p99_ms_controlled']:.1f}ms <= \" \
	f\"{sc['budget_ms']:g}ms < \" \
	f\"{sc['interactive_tpot_p99_ms_uncontrolled']:.1f}ms blind, \" \
	f\"{sc['preemptions']} preemptions, \" \
	f\"brownout peak L{sc['brownout_peak_level']}\")"
	$(PY) -c "import json; p = json.load(open('BENCH_prefill.json')); \
	assert p['schema'] == 9, f'BENCH_prefill.json schema {p[\"schema\"]} != 9'; \
	h = p['handoff_overlap']; \
	assert h['tokens_identical'] is True, \
	'streamed handoff tokens diverged from the synchronous path'; \
	assert h['streamed_ttft_p50_s'] < h['sync_ttft_p50_s'], \
	'pipelined streaming did not lower median TTFT vs synchronous'; \
	assert h['streamed_ttft_mean_s'] < h['sync_ttft_mean_s'], \
	'pipelined streaming did not lower mean TTFT vs synchronous'; \
	assert h['overlap_hidden_s'] > 0, 'no transfer time was hidden'; \
	assert h['stream_chunks'] > h['requests'], \
	'streaming did not actually chunk the handoff'; \
	assert h['stream_bytes'] > 0 and h['max_chunk_bytes_in_flight'] > 0, \
	'transfer-bytes-in-flight accounting missing'; \
	e = p['ems']; hr = e['hit_rate_by_turn']; \
	assert hr[0] == 0 and hr[-1] > hr[0], \
	f'EMS hit rate did not grow across session turns: {hr}'; \
	assert e['hit_rate'] > 0, 'EMS served no hits on the session trace'; \
	assert e['demote_bytes'] > 0, 'EMS write-back moved no bytes'; \
	assert e['demote_bytes'] == e['transfer_bytes_demoted'], \
	'EMS demote bytes diverged from the RDMA-plane transfer books'; \
	assert e['promote_bytes'] == e['transfer_bytes_promoted'], \
	'EMS promote bytes diverged from the RDMA-plane transfer books'; \
	t = e['ttft_by_hit_depth']; \
	assert t['cold']['n'] > 0 and t['cold']['ttft_ms'] is not None, \
	'TTFT-by-hit-depth cold bucket empty'; \
	assert t['deep']['n'] > 0 and t['deep']['ttft_ms'] is not None, \
	'TTFT-by-hit-depth deep bucket empty (sessions never reused deeply)'; \
	assert e['ub_vs_vpc_reuse90_gain'] > 1, \
	'UB plane showed no TTFT gain over VPC at 90% reuse'; \
	d = e['hit_aware_admission']; \
	assert d['suffix_blind_decision'] == 'wait', \
	'demo gate was not saturated: blind gate admitted'; \
	assert d['hit_aware_decision'] == 'admit', \
	'hit-aware gate failed to admit the mostly-cached request'; \
	print('BENCH_prefill.json schema 9 OK:', \
	f\"streamed TTFT p50 {h['streamed_ttft_p50_s']*1e3:.3f}ms < \" \
	f\"sync {h['sync_ttft_p50_s']*1e3:.3f}ms, \" \
	f\"{h['overlap_hidden_s']*1e3:.3f}ms hidden over \" \
	f\"{h['stream_chunks']} chunks; \" \
	f\"ems hit rate {hr[0]} -> {hr[-1]} over {e['turns']} turns, \" \
	f\"{e['demote_bytes']} B demoted / {e['promote_bytes']} B promoted, \" \
	f\"hit-aware {d['suffix_blind_decision']} -> \" \
	f\"{d['hit_aware_decision']} at charge {d['suffix_charge']}\")"

ci: smoke test bench-smoke bench-check
