# Dev/CI entry points. CI runs `make ci`.
#
# XLA_FLAGS stays UNSET for the pytest run on purpose: smoke tests must see
# the single real CPU device; tests/test_multidevice.py spawns subprocesses
# that set --xla_force_host_platform_device_count=8 themselves. The `smoke`
# target DOES force 8 host devices so every model family is exercised on a
# multi-device CPU mesh in CI.

PY ?= python
PYTHONPATH := src

.PHONY: test smoke serve-demo bench-slo bench-smoke ci

test:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest -q

smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	PYTHONPATH=$(PYTHONPATH) $(PY) scripts/dev_smoke.py

serve-demo:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m repro.launch.serve --arch qwen3-8b \
	    --n-requests 6 --prompt-len 24 --max-new 8 \
	    --policy round_robin --tpot-budget-ms 9 --admission shed --trace

bench-slo:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.run --only tpot_slo

# Live-smoke perf rows only (no dry-run compiles); writes BENCH_decode.json
# and BENCH_prefill.json at the repo root for PR-over-PR tracking.
# bench_mtp runs after bench_decode_throughput: it merges the MTP section
# (acceptance rate + fused-MTP speedup) into the same BENCH_decode.json.
bench-smoke:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.bench_decode_throughput --smoke
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.bench_mtp --smoke
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.bench_prefill_throughput --smoke

ci: smoke test bench-smoke
