"""§Roofline deliverable: the full (arch × shape) roofline table from the
dry-run artifacts — compute/memory/collective terms, dominant bottleneck,
MODEL_FLOPS ratio — printed as CSV (and consumed by EXPERIMENTS.md)."""
from __future__ import annotations

import json
import os

from benchmarks.common import DRYRUN_DIR, emit


def main() -> None:
    print("name,metric,value,derived")
    if not os.path.isdir(DRYRUN_DIR):
        emit("roofline", "status", "NA", "run_repro.launch.dryrun_--all_first")
        return
    rows = []
    for fn in sorted(os.listdir(DRYRUN_DIR)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(DRYRUN_DIR, fn)) as f:
            rec = json.load(f)
        key = f"{rec['arch']}|{rec['shape']}|{rec['mesh']}"
        if rec.get("status") == "skipped":
            emit("roofline", key, "skipped", rec["reason"].replace(",", ";"))
            continue
        if rec.get("status") != "ok":
            emit("roofline", key, "error",
                 rec.get("error", "?").replace(",", ";")[:80])
            continue
        emit("roofline", key,
             rec["dominant"],
             f"compute_ms={rec['compute_s']*1e3:.2f};"
             f"memory_ms={rec['memory_s']*1e3:.2f};"
             f"collective_ms={rec['collective_s']*1e3:.2f};"
             f"useful={rec.get('useful_ratio') and round(rec['useful_ratio'], 3)}")


if __name__ == "__main__":
    main()
