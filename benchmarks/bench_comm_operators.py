"""Paper Table 7: Dispatch / Combine latency & per-rank bandwidth vs EP degree.

Model: per-rank payload from our LEP static buffers (batch 128/rank, paper's
message sizes — 7.5 KB/token dispatch after early INT8 quantization, 14 KB
combine in BF16) over the flat UB-analogue fabric (ICI) with per-message
startup cost; contrasted with the RDMA-plane constants DeepEP reports on
H800. Latencies bound the fused-operator design of §4.2.1.
"""
from __future__ import annotations

from benchmarks.common import ICI_BW, ICI_LINKS, emit

BATCH_PER_RANK = 128
TOPK = 8
HIDDEN = 7168
DISPATCH_MSG = 7.5 * 1024       # int8 payload + aligned scale (paper §4.2.1)
COMBINE_MSG = 14 * 1024         # bf16 combine payload (paper Fig. 12)
STARTUP_UB = 1.3e-6             # paper Table 1 intra write latency
STARTUP_PER_PEER = 0.35e-6      # AIV-direct per-peer issue cost (modeled)


def op_latency(ep: int, msg: int) -> float:
    """One rank sends BATCH×TOPK messages spread over (ep-1) peers."""
    n_msgs = BATCH_PER_RANK * min(TOPK, ep)
    bytes_out = n_msgs * msg
    bw = ICI_BW * ICI_LINKS
    return STARTUP_UB + (ep - 1) * STARTUP_PER_PEER + bytes_out / bw


def main() -> None:
    print("name,metric,value,derived")
    for ep in (8, 16, 32, 64, 128, 256):
        for op, msg in (("dispatch", DISPATCH_MSG), ("combine", COMBINE_MSG)):
            lat = op_latency(ep, msg)
            n_msgs = BATCH_PER_RANK * min(TOPK, ep)
            bw = n_msgs * msg / lat / 1e9
            emit("comm_ops", f"{op}_ep{ep}_latency_us", round(lat * 1e6, 1),
                 f"bw={bw:.0f}GB/s_per_rank")
    # paper reference points (CANN EP on CM384, Table 7) for comparison
    emit("comm_ops", "paper_dispatch_ep256_latency_us", 152, "CM384_reference")
    emit("comm_ops", "paper_combine_ep256_latency_us", 149, "CM384_reference")


if __name__ == "__main__":
    main()
