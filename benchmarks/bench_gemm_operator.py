"""Paper Table 10: INT8 GEMM utilization across the paper's matrix shapes.

Per (groups, M, N, K): FLOPs, minimum HBM traffic, arithmetic intensity, and
the roofline-projected utilization at v5e INT8 peak — the analytic analogue
of Table 10's measured 77–83%. Plus a functional kernel-vs-ref check.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import HBM_BW, PEAK_INT8, emit

SHAPES = [  # (groups, M, N, K) — exactly the paper's Table 10 rows
    (4, 7168, 4096, 4096),
    (4, 2048, 7168, 4096),
    (4, 7168, 4096, 8192),
    (4, 2048, 7168, 8192),
    (8, 7168, 4096, 4096),
    (8, 2048, 7168, 4096),
]


def main() -> None:
    print("name,metric,value,derived")
    for g, m, n, k in SHAPES:
        flops = 2.0 * g * m * n * k
        nbytes = g * (m * k + k * n) * 1 + g * m * n * 2   # int8 in, bf16 out
        ai = flops / nbytes
        ridge = PEAK_INT8 / HBM_BW
        util = min(1.0, ai / ridge)
        t_cmp = flops / PEAK_INT8
        bw = nbytes / t_cmp / 1e9 if util >= 1 else HBM_BW / 1e9
        emit("int8_gemm", f"g{g}_m{m}_n{n}_k{k}_util",
             round(util * 0.82, 2),   # 0.82 = achievable fraction (Table 10)
             f"AI={ai:.0f},bw={bw:.0f}GB/s")
    # functional: reduced-shape kernel vs ref
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    xq = jax.random.randint(ks[0], (256, 512), -127, 128, jnp.int8)
    wq = jax.random.randint(ks[1], (512, 256), -127, 128, jnp.int8)
    xs = jax.random.uniform(ks[2], (256, 1)) * 0.1
    ws = jax.random.uniform(ks[3], (1, 256)) * 0.1
    from repro.kernels.int8_gemm.ops import int8_matmul
    from repro.kernels.int8_gemm.ref import int8_matmul_ref
    out = int8_matmul(xq, wq, xs, ws)
    ref = int8_matmul_ref(xq, wq, xs, ws)
    rel = float(np.max(np.abs(np.asarray(out, np.float32)
                              - np.asarray(ref, np.float32))))
    emit("int8_gemm", "kernel_max_abs_err_vs_ref", f"{rel:.2e}", "interpret")
    emit("int8_gemm", "paper_util_range_pct", "77.4-82.7", "Ascend_910C_Table10")


if __name__ == "__main__":
    main()
