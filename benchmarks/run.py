# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver — one module per paper table/figure:

  Table 2  -> bench_model_caching       Table 7  -> bench_comm_operators
  Table 3  -> bench_prefill_throughput  Table 8/9-> bench_mla_operator
  Table 4  -> bench_decode_throughput   Table 10 -> bench_gemm_operator
  Table 5  -> bench_tpot_slo            Fig 20/21-> bench_microbatch
  Table 6  -> bench_quant_accuracy      Fig 22   -> bench_mtp
  Fig 23   -> bench_context_caching     §Roofline-> bench_roofline

Run: PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "bench_model_caching",
    "bench_comm_operators",
    "bench_mla_operator",
    "bench_gemm_operator",
    "bench_quant_accuracy",
    "bench_microbatch",
    "bench_mtp",
    "bench_context_caching",
    "bench_prefill_throughput",
    "bench_decode_throughput",
    "bench_tpot_slo",
    "bench_roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    mods = [m for m in MODULES if args.only is None or args.only in m]
    failures = []
    for name in mods:
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)), flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()[-1500:]}",
                  flush=True)
    if failures:
        print(f"\n# FAILURES: {failures}")
        sys.exit(1)
    print("\n# all benchmarks completed")


if __name__ == "__main__":
    main()
