"""Paper Figs. 20/21: microbatch-based pipeline ablation.

Decode (Fig. 20): per-layer latency with vs without two-stream overlap. With
the pipeline, the attention path of µb0 overlaps the MoE path (dispatch +
expert FFN + combine) of µb1: per-layer latency = max(path0, path1) instead
of their sum. Paths are derived from the compiled decode dry-run's roofline
terms (collectives = MoE path communication; compute+memory split between
attention and MoE by FLOP share).

Prefill (Fig. 21): same construction from the prefill dry-run — collective
(all_to_all) time overlaps AIC-analogue compute.
"""
from __future__ import annotations

from benchmarks.common import emit, ensure_dryrun

ARCH = "deepseek-r1"
MOE_FLOP_SHARE = 0.55   # MoE FFN share of decode FLOPs for R1 (37B active;
                        # attention+heads ≈ 45% at 4K context)


def ablate(rec, phase: str) -> None:
    c, m, k = rec["compute_s"], rec["memory_s"], rec["collective_s"]
    serial = max(c, m) + k
    attn_path = max(c, m) * (1 - MOE_FLOP_SHARE)
    moe_path = max(c, m) * MOE_FLOP_SHARE + k
    overlapped = max(attn_path, moe_path)
    gain = serial / overlapped - 1
    emit("microbatch", f"{phase}_serial_ms", round(serial * 1e3, 2), "no_pipeline")
    emit("microbatch", f"{phase}_overlapped_ms", round(overlapped * 1e3, 2),
         f"two_stream (paths {attn_path*1e3:.2f}/{moe_path*1e3:.2f})")
    emit("microbatch", f"{phase}_gain_pct", round(gain * 100, 1),
         "paper_decode:+5.8-9.4%, paper_prefill:+23-31%")


def main() -> None:
    print("name,metric,value,derived")
    rec_d = ensure_dryrun(ARCH, "decode_32k")
    if rec_d:
        ablate(rec_d, "decode")
    rec_p = ensure_dryrun(ARCH, "prefill_32k")
    if rec_p:
        ablate(rec_p, "prefill")
    # functional: microbatched step == plain step (correctness of the split)
    import jax, jax.numpy as jnp, numpy as np  # noqa: E401
    from repro.configs import get_config, smoke_variant
    from repro.core.microbatch import microbatched
    from repro.models import decode_step, init_params, prefill
    cfg = smoke_variant(get_config("qwen3-8b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0, cfg.vocab_size)
    _, caches = prefill(params, cfg, {"tokens": toks}, capacity=20,
                        cache_dtype=jnp.float32)
    step = lambda t, c: decode_step(params, cfg, t, c, jnp.int32(12))
    t1 = jnp.ones((4, 1), jnp.int32)
    o_plain, _ = step(t1, caches)
    o_mb, _ = microbatched(step, 2)(t1, caches)
    err = float(np.max(np.abs(np.asarray(o_plain) - np.asarray(o_mb))))
    emit("microbatch", "split_equivalence_max_err", f"{err:.2e}", "must_be~0")


if __name__ == "__main__":
    main()
