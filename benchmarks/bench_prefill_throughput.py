"""Paper Table 3: prefill throughput per accelerator (tokens/s and
tokens/s/TFLOPS).

Derived from the compiled dry-run of prefill_32k: step time = roofline of
the compiled program (per-device FLOPs / bytes / collectives), throughput =
global tokens / step time / devices. The paper's DeepSeek-R1 row is computed
from the deepseek-r1 config (the paper's own model); assigned archs reported
alongside.
"""
from __future__ import annotations

from benchmarks.common import (PEAK_FLOPS, emit, ensure_dryrun,
                               step_time_from_record)

ARCHS = ["qwen3-8b", "granite-3-2b", "olmoe-1b-7b", "deepseek-r1"]
SHAPE = "prefill_32k"
TOKENS = 32 * 32768


def main() -> None:
    print("name,metric,value,derived")
    for arch in ARCHS:
        rec = ensure_dryrun(arch, SHAPE)
        if rec is None:
            emit("prefill_tput", f"{arch}_tokens_per_s_per_chip", "NA",
                 "dryrun_missing")
            continue
        t = step_time_from_record(rec)
        tput = TOKENS / t / rec["n_devices"]
        per_tflops = tput / (PEAK_FLOPS / 1e12)
        emit("prefill_tput", f"{arch}_tokens_per_s_per_chip", round(tput),
             f"dom={rec['dominant']}")
        emit("prefill_tput", f"{arch}_tokens_per_s_per_TFLOPS",
             round(per_tflops, 2), f"step_ms={t*1e3:.0f}")
    emit("prefill_tput", "paper_deepseek_r1_per_NPU", 6688,
         "CloudMatrix-Infer_perfect_EPLB (4.45 tok/s/TFLOPS)")


if __name__ == "__main__":
    main()
