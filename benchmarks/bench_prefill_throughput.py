"""Paper Table 3: prefill throughput per accelerator (tokens/s and
tokens/s/TFLOPS).

Derived from the compiled dry-run of prefill_32k: step time = roofline of
the compiled program (per-device FLOPs / bytes / collectives), throughput =
global tokens / step time / devices. The paper's DeepSeek-R1 row is computed
from the deepseek-r1 config (the paper's own model); assigned archs reported
alongside.
"""
from __future__ import annotations

import time

from benchmarks.common import (PEAK_FLOPS, emit, ensure_dryrun,
                               step_time_from_record, write_bench_artifact)

ARCHS = ["qwen3-8b", "granite-3-2b", "olmoe-1b-7b", "deepseek-r1"]
SHAPE = "prefill_32k"
TOKENS = 32 * 32768

# live smoke measurement (chunked suffix prefill vs full prefill)
LIVE_PROMPT = 24
LIVE_SHARED = 16
LIVE_REQS = 6
LIVE_REPEATS = 3


def main(smoke: bool = False) -> None:
    print("name,metric,value,derived")
    if not smoke:
        for arch in ARCHS:
            rec = ensure_dryrun(arch, SHAPE)
            if rec is None:
                emit("prefill_tput", f"{arch}_tokens_per_s_per_chip", "NA",
                     "dryrun_missing")
                continue
            t = step_time_from_record(rec)
            tput = TOKENS / t / rec["n_devices"]
            per_tflops = tput / (PEAK_FLOPS / 1e12)
            emit("prefill_tput", f"{arch}_tokens_per_s_per_chip", round(tput),
                 f"dom={rec['dominant']}")
            emit("prefill_tput", f"{arch}_tokens_per_s_per_TFLOPS",
                 round(per_tflops, 2), f"step_ms={t*1e3:.0f}")
        emit("prefill_tput", "paper_deepseek_r1_per_NPU", 6688,
             "CloudMatrix-Infer_perfect_EPLB (4.45 tok/s/TFLOPS)")
    _live_rows()


def _live_rows() -> None:
    """Wall-clock prefill throughput of the live engine at smoke scale —
    fresh prompts vs EMS prefix reuse (chunked suffix fast path) — persisted
    to BENCH_prefill.json."""
    import numpy as np

    from benchmarks.common import LIVE_ARCH, live_model
    from repro.mempool import ContextCache, MemoryPool
    from repro.serving import PrefillEngine, Request

    cfg, params = live_model()
    pool = MemoryPool(n_nodes=4)
    cc = ContextCache(pool, block_tokens=8, model_tag=cfg.name)
    eng = PrefillEngine(params, cfg, capacity=LIVE_PROMPT + 8,
                        context_cache=cc)
    rng = np.random.RandomState(0)
    shared = list(rng.randint(0, cfg.vocab_size, LIVE_SHARED))
    reqs = [Request(i, shared + list(rng.randint(0, cfg.vocab_size,
                                                 LIVE_PROMPT - LIVE_SHARED)),
                    1) for i in range(LIVE_REQS)]
    eng.run(reqs[0])                       # warm: compile + seed the cache
    t0 = time.perf_counter()
    reused = computed = 0
    for _ in range(LIVE_REPEATS):
        for r in reqs:
            _, _, res = eng.run(r)
            reused += res.reused_tokens
            computed += res.computed_tokens
    wall = time.perf_counter() - t0
    tput = (reused + computed) / wall
    emit("prefill_tput", "live_smoke_tokens_per_wall_s", round(tput, 1),
         f"reused={reused};computed={computed};wall_s={wall:.3f}")
    artifact = {
        "config": {"arch": LIVE_ARCH, "prompt_len": LIVE_PROMPT,
                   "shared_prefix": LIVE_SHARED, "requests": LIVE_REQS,
                   "repeats": LIVE_REPEATS,
                   "suffix_chunk": eng.suffix_chunk},
        "tokens_per_s": tput,
        "wall_s": wall,
        "reused_tokens": reused,
        "computed_tokens": computed,
        "tpot_p50_ms": None,               # prefill-side bench: no decode
        "tpot_p99_ms": None,
        "decode_chunk": None,
    }
    path = write_bench_artifact("prefill", artifact)
    emit("prefill_tput", "artifact", path, "")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="live smoke rows + BENCH artifact only")
    main(smoke=ap.parse_args().smoke)
