"""Paper Table 3: prefill throughput per accelerator (tokens/s and
tokens/s/TFLOPS).

Derived from the compiled dry-run of prefill_32k: step time = roofline of
the compiled program (per-device FLOPs / bytes / collectives), throughput =
global tokens / step time / devices. The paper's DeepSeek-R1 row is computed
from the deepseek-r1 config (the paper's own model); assigned archs reported
alongside.
"""
from __future__ import annotations

import time

from benchmarks.common import (PEAK_FLOPS, emit, ensure_dryrun,
                               step_time_from_record, write_bench_artifact)

ARCHS = ["qwen3-8b", "granite-3-2b", "olmoe-1b-7b", "deepseek-r1"]
SHAPE = "prefill_32k"
TOKENS = 32 * 32768

# live smoke measurement (chunked suffix prefill vs full prefill)
LIVE_PROMPT = 24
LIVE_SHARED = 16
LIVE_REQS = 6
LIVE_REPEATS = 3
FRESH_CHUNK = 8      # fresh-prompt chunked prefill width (bounded shapes)


def main(smoke: bool = False) -> None:
    print("name,metric,value,derived")
    if not smoke:
        for arch in ARCHS:
            rec = ensure_dryrun(arch, SHAPE)
            if rec is None:
                emit("prefill_tput", f"{arch}_tokens_per_s_per_chip", "NA",
                     "dryrun_missing")
                continue
            t = step_time_from_record(rec)
            tput = TOKENS / t / rec["n_devices"]
            per_tflops = tput / (PEAK_FLOPS / 1e12)
            emit("prefill_tput", f"{arch}_tokens_per_s_per_chip", round(tput),
                 f"dom={rec['dominant']}")
            emit("prefill_tput", f"{arch}_tokens_per_s_per_TFLOPS",
                 round(per_tflops, 2), f"step_ms={t*1e3:.0f}")
        emit("prefill_tput", "paper_deepseek_r1_per_NPU", 6688,
             "CloudMatrix-Infer_perfect_EPLB (4.45 tok/s/TFLOPS)")
    _live_rows()


def _live_rows() -> None:
    """Wall-clock prefill throughput of the live engine at smoke scale —
    fresh prompts vs EMS prefix reuse (chunked suffix fast path), plus the
    bounded-compile-shape fresh-prompt chunked path with its compile-cache
    hit rate — persisted to BENCH_prefill.json."""
    import numpy as np

    from benchmarks.common import LIVE_ARCH, live_model
    from repro.mempool import ContextCache, MemoryPool
    from repro.serving import PrefillEngine, Request

    cfg, params = live_model()
    pool = MemoryPool(n_nodes=4)
    cc = ContextCache(pool, block_tokens=8, model_tag=cfg.name)
    eng = PrefillEngine(params, cfg, capacity=LIVE_PROMPT + 8,
                        context_cache=cc)
    rng = np.random.RandomState(0)
    shared = list(rng.randint(0, cfg.vocab_size, LIVE_SHARED))
    reqs = [Request(i, shared + list(rng.randint(0, cfg.vocab_size,
                                                 LIVE_PROMPT - LIVE_SHARED)),
                    1) for i in range(LIVE_REQS)]
    eng.run(reqs[0])                       # warm: compile + seed the cache
    t0 = time.perf_counter()
    reused = computed = 0
    for _ in range(LIVE_REPEATS):
        for r in reqs:
            _, _, res = eng.run(r)
            reused += res.reused_tokens
            computed += res.computed_tokens
    wall = time.perf_counter() - t0
    tput = (reused + computed) / wall
    emit("prefill_tput", "live_smoke_tokens_per_wall_s", round(tput, 1),
         f"reused={reused};computed={computed};wall_s={wall:.3f}")

    # --- fresh long prompts through chunked prefill_continue ------------
    # One compiled program per chunk width serves EVERY prompt length:
    # varied lengths stop exploding the jit cache (bounded compile shapes).
    eng_c = PrefillEngine(params, cfg, capacity=2 * LIVE_PROMPT + 8,
                          prefill_chunk=FRESH_CHUNK)
    fresh = [Request(100 + i,
                     list(rng.randint(0, cfg.vocab_size,
                                      LIVE_PROMPT + (i % 4))), 1)
             for i in range(LIVE_REQS)]    # varied lengths on purpose
    eng_c.run(fresh[0])                    # warm: compile the chunk program
    t0 = time.perf_counter()
    fresh_tokens = 0
    for _ in range(LIVE_REPEATS):
        for r in fresh:
            _, _, res = eng_c.run(r)
            fresh_tokens += res.computed_tokens
    fresh_wall = time.perf_counter() - t0
    fresh_tput = fresh_tokens / fresh_wall
    hit = eng_c.continue_cache_hit_rate
    emit("prefill_tput", "live_fresh_chunked_tokens_per_wall_s",
         round(fresh_tput, 1),
         f"chunk={FRESH_CHUNK};wall_s={fresh_wall:.3f}")
    emit("prefill_tput", "live_fresh_chunked_compile_cache_hit",
         round(hit, 3),
         f"{len(eng_c.continue_widths)}_programs_over_"
         f"{eng_c.continue_calls}_dispatches")

    handoff = _handoff_overlap_section()

    artifact = {
        "config": {"arch": LIVE_ARCH, "prompt_len": LIVE_PROMPT,
                   "shared_prefix": LIVE_SHARED, "requests": LIVE_REQS,
                   "repeats": LIVE_REPEATS,
                   "suffix_chunk": eng.suffix_chunk,
                   "fresh_prefill_chunk": FRESH_CHUNK},
        "tokens_per_s": tput,
        "wall_s": wall,
        "reused_tokens": reused,
        "computed_tokens": computed,
        "fresh_chunked": {
            "tokens_per_s": fresh_tput,
            "wall_s": fresh_wall,
            "computed_tokens": fresh_tokens,
            "compile_cache_hit_rate": hit,
            "compiled_widths": sorted(eng_c.continue_widths),
            "dispatches": eng_c.continue_calls,
        },
        "handoff_overlap": handoff,
        "tpot_p50_ms": None,               # prefill-side bench: no decode
        "tpot_p99_ms": None,
        "decode_chunk": None,
    }
    path = write_bench_artifact("prefill", artifact, schema=9)
    emit("prefill_tput", "artifact", path, "")


def _handoff_overlap_section() -> dict:
    """Pipelined chunked KV streaming vs the synchronous whole-request
    handoff on the identical open-loop burst: virtual-clock TTFT split
    (streamed must be strictly lower — the transfer is hidden behind the
    remaining prefill compute except the last chunk's wire time), bytes in
    flight, and emitted-token identity. The section is asserted by
    ``make bench-check``."""
    import numpy as np

    from benchmarks.common import STREAM_CHUNK, live_stream_serve

    sync_res, sync_sched = live_stream_serve(streamed=False)
    sync_ttft = {r.rid: sync_sched.traces[r.rid].ttft
                 for r in sync_res if not r.shed}
    sync_tokens = {r.rid: list(r.tokens) for r in sync_res}
    strm_res, strm_sched = live_stream_serve(streamed=True)
    strm_ttft = {r.rid: strm_sched.traces[r.rid].ttft
                 for r in strm_res if not r.shed}
    strm_tokens = {r.rid: list(r.tokens) for r in strm_res}
    s = strm_sched.summary()
    identical = sync_tokens == strm_tokens
    sync_vals = [sync_ttft[r] for r in sorted(sync_ttft)]
    strm_vals = [strm_ttft[r] for r in sorted(strm_ttft)]
    emit("prefill_tput", "handoff_streamed_ttft_p50_ms",
         round(float(np.percentile(strm_vals, 50)) * 1e3, 4),
         f"sync_p50_ms={float(np.percentile(sync_vals, 50))*1e3:.4f}")
    emit("prefill_tput", "handoff_overlap_hidden_ms",
         round(s["stream_overlap_s"] * 1e3, 4),
         f"chunks={s['stream_chunks']};tokens_identical={identical}")
    return {
        "stream_chunk": STREAM_CHUNK,
        "requests": len(strm_vals),
        "streamed_ttft_p50_s": float(np.percentile(strm_vals, 50)),
        "streamed_ttft_p99_s": float(np.percentile(strm_vals, 99)),
        "sync_ttft_p50_s": float(np.percentile(sync_vals, 50)),
        "sync_ttft_p99_s": float(np.percentile(sync_vals, 99)),
        "streamed_ttft_mean_s": float(np.mean(strm_vals)),
        "sync_ttft_mean_s": float(np.mean(sync_vals)),
        "overlap_hidden_s": s["stream_overlap_s"],
        "stream_chunks": s["stream_chunks"],
        "stream_bytes": s["stream_bytes"],
        "max_chunk_bytes_in_flight": s["stream_max_chunk_bytes"],
        "tokens_identical": identical,
    }


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="live smoke rows + BENCH artifact only")
    main(smoke=ap.parse_args().smoke)
