"""Paper Table 4: decode throughput per accelerator under a ~50 ms TPOT SLO.

serve_step roofline from the compiled decode_32k dry-run gives TPOT; decode
throughput per chip = (batch/chips) / TPOT, with the paper's MTP accounting
(1 speculative token at 70% acceptance ⇒ ×1.7 tokens per iteration at ×~1.4
iteration cost — §5.4.2 measured +44% per-layer latency).

A functional layer runs the real PDC system (``serving/scheduler.py``) at
smoke scale and reports decode throughput on the scheduler's virtual clock
straight from the structured per-request trace — batching amortizes the
fixed per-step cost, so throughput rises with the decode batch while TPOT
rises linearly (the Table 4 ↔ Table 5 tension, observed end-to-end)."""
from __future__ import annotations

import time

from benchmarks.common import (PEAK_FLOPS, emit, ensure_dryrun,
                               step_time_from_record, write_bench_artifact)

ARCHS = ["qwen3-8b", "granite-3-2b", "olmoe-1b-7b", "kimi-k2-1t-a32b",
         "deepseek-r1"]
SHAPE = "decode_32k"
BATCH = 128
MTP_ACCEPT = 0.70
MTP_COST = 1.44      # paper Fig. 22b: ~44% per-iteration latency increase

# device-resident fast-path comparison (wall clock, smoke config)
FAST_CHUNK = 4
FAST_MAX_NEW = 16
FAST_REPEATS = 3

# decode pool smoke (per-engine utilization per routing policy)
POOL_ENGINES = 2
POOL_BATCH = 2
POOL_REBALANCE_EVERY = 2

# autoscale smoke: Poisson burst through a 1..AUTOSCALE_MAX pool
AUTOSCALE_MAX = 3


def main(smoke: bool = False) -> None:
    print("name,metric,value,derived")
    if not smoke:
        for arch in ARCHS:
            rec = ensure_dryrun(arch, SHAPE)
            if rec is None:
                emit("decode_tput", f"{arch}_tokens_per_s_per_chip", "NA",
                     "dryrun_missing_or_skipped")
                continue
            tpot = step_time_from_record(rec)
            tput = (BATCH / rec["n_devices"]) / tpot
            emit("decode_tput", f"{arch}_TPOT_ms", round(tpot * 1e3, 2),
                 f"dom={rec['dominant']}")
            emit("decode_tput", f"{arch}_tokens_per_s_per_chip", round(tput, 1),
                 f"batch_per_chip={BATCH/rec['n_devices']:.2f}")
            tput_mtp = tput * (1 + MTP_ACCEPT) / MTP_COST
            emit("decode_tput", f"{arch}_tokens_per_s_per_chip_mtp",
                 round(tput_mtp, 1), f"accept={MTP_ACCEPT}")
            _optimized_row(arch, rec)
        emit("decode_tput", "paper_deepseek_r1_per_NPU", 1943,
             "CloudMatrix-Infer@TPOT<50ms (1.29 tok/s/TFLOPS)")
    _live_rows()


def _live_rows() -> None:
    """Trace-derived decode throughput from the live scheduler subsystem,
    plus the decode fast-path wall-clock comparison — persisted to
    BENCH_decode.json so the perf trajectory is tracked PR-over-PR."""
    from benchmarks.common import (LIVE_ARCH, LIVE_PROMPT_LEN, LIVE_REQUESTS,
                                   live_smoke_serve)

    artifact = {"config": {"arch": LIVE_ARCH, "requests": LIVE_REQUESTS,
                           "prompt_len": LIVE_PROMPT_LEN,
                           "max_new": FAST_MAX_NEW,
                           "repeats": FAST_REPEATS},
                "runs": []}
    for batch in (2, 8):
        results, scheduler = live_smoke_serve(decode_batch=batch)
        s = scheduler.summary()
        decode_tokens = sum(t.decode_iters for t in scheduler.tracker.finished)
        tput = decode_tokens / max(s["decode_virtual_s"], 1e-12)
        emit("decode_tput", f"live_smoke_b{batch}_tokens_per_virtual_s",
             round(tput, 1),
             f"tpot_p50_ms={s['tpot_p50_s']*1e3:.2f};n={len(results)}")

    # --- device-resident fast path: decode_chunk=1 vs FAST_CHUNK ---------
    walls = {}
    for chunk in (1, FAST_CHUNK):
        # warm (compile), then time repeated serve waves
        live_smoke_serve(decode_batch=4, decode_chunk=chunk,
                         max_new=FAST_MAX_NEW)
        t0 = time.perf_counter()
        for _ in range(FAST_REPEATS):
            results, scheduler = live_smoke_serve(
                decode_batch=4, decode_chunk=chunk, max_new=FAST_MAX_NEW)
        wall = (time.perf_counter() - t0) / FAST_REPEATS
        s = scheduler.summary()
        decode_tokens = sum(len(r.tokens) - 1 for r in results if not r.shed)
        walls[chunk] = wall
        emit("decode_tput", f"fastpath_chunk{chunk}_tokens_per_wall_s",
             round(decode_tokens / wall, 1), f"wall_s={wall:.3f}")
        artifact["runs"].append({
            "decode_chunk": chunk,
            "decode_batch": 4,
            "tokens_per_s": decode_tokens / wall,
            "wall_s": wall,
            "tpot_p50_ms": s["tpot_p50_s"] * 1e3,
            "tpot_p99_ms": s["tpot_p99_s"] * 1e3,
            "completed": s["completed"],
        })
    speedup = walls[1] / walls[FAST_CHUNK]
    emit("decode_tput", f"fastpath_chunk{FAST_CHUNK}_speedup",
         round(speedup, 2), "wall_chunk1/wall_chunkN")
    artifact["fastpath_speedup"] = speedup
    artifact["continuous_batching"] = _continuous_rows()
    artifact["pool"] = _pool_rows()
    artifact["pool"]["autoscale"] = _autoscale_rows()
    artifact["fault_tolerance"] = _fault_rows()
    artifact["slo_classes"] = _slo_class_rows()
    path = write_bench_artifact("decode", artifact)
    emit("decode_tput", "artifact", path, "")


def _continuous_rows() -> dict:
    """Continuous-batching open-loop comparison (schema 5): the identical
    Poisson arrival trace through the chunked fast path with continuous
    batching off (wave-shaped: the before section) and on (adaptive scan
    widths + mid-scan refill: the after section), plus a per-step
    reference. Asserted downstream by ``make bench-check``: dead-slot
    rate measurably lower with CB on, zero TPOT-budget violations, and
    emitted tokens bit-identical to per-step decode for every request."""
    from benchmarks.common import (CB_CHUNK, CB_MAX_NEW, continuous_burst,
                                   live_continuous_serve)
    from repro.serving import Request

    budget_ms = 9.0
    reqs = continuous_burst()       # ONE arrival trace for all three runs
    clone = lambda: [Request(r.rid, list(r.prompt), r.max_new_tokens,  # noqa: E731
                             r.arrival) for r in reqs]
    runs = {}
    for label, chunk, continuous in (("per_step", 1, False),
                                     ("before", CB_CHUNK, False),
                                     ("after", CB_CHUNK, True)):
        results, scheduler = live_continuous_serve(
            continuous=continuous, decode_chunk=chunk,
            tpot_budget_ms=budget_ms, requests=clone())
        s = scheduler.summary()
        tokens = {r.rid: list(r.tokens) for r in results}
        violations = sum(1 for t in scheduler.tracker.finished
                         if t.decode_iters > 0
                         and t.tpot > budget_ms * 1e-3 + 1e-12)
        runs[label] = {"tokens": tokens, "violations": violations,
                       "summary": s}
        if label != "per_step":
            emit("decode_tput", f"continuous_{label}_dead_slot_rate",
                 round(s["dead_slot_rate"], 4),
                 f"masked={s['masked_slot_iters']};"
                 f"live={s['live_slot_iters']};"
                 f"refills={s['mid_scan_refills']}")

    identical = (runs["after"]["tokens"] == runs["per_step"]["tokens"]
                 and runs["before"]["tokens"] == runs["per_step"]["tokens"])
    section = {
        "decode_chunk": CB_CHUNK, "max_new": CB_MAX_NEW,
        "tpot_budget_ms": budget_ms,
        "requests": len(reqs),
        "before": {k: runs["before"]["summary"][k] for k in
                   ("dead_slot_rate", "masked_slot_iters",
                    "live_slot_iters", "mid_scan_refills", "completed")},
        "after": {k: runs["after"]["summary"][k] for k in
                  ("dead_slot_rate", "masked_slot_iters",
                   "live_slot_iters", "mid_scan_refills", "completed")},
        "tpot_budget_violations": sum(r["violations"]
                                      for r in runs.values()),
        "tokens_identical_to_per_step": identical,
    }
    emit("decode_tput", "continuous_tokens_identical_to_per_step",
         identical, f"chunk={CB_CHUNK};max_new={CB_MAX_NEW}")
    emit("decode_tput", "continuous_mid_scan_refills",
         section["after"]["mid_scan_refills"],
         f"tpot_violations={section['tpot_budget_violations']}")
    return section


def _pool_rows() -> dict:
    """2-engine decode-pool smoke per routing policy: per-engine virtual
    throughput/utilization + migration counts, persisted into the decode
    artifact (schema 3) so pool balance is tracked PR-over-PR."""
    from benchmarks.common import live_pool_serve

    section = {"engines": POOL_ENGINES, "decode_batch": POOL_BATCH,
               "policies": []}
    for policy in ("round_robin", "least_loaded_slots", "cache_affinity"):
        results, scheduler, system = live_pool_serve(
            policy=policy, decode_engines=POOL_ENGINES,
            decode_batch=POOL_BATCH, rebalance_every=POOL_REBALANCE_EVERY)
        s = scheduler.summary()
        busy = s["engine_busy_s"]
        toks = s["engine_decode_tokens"]
        per_engine = [
            {"engine": e,
             "decode_tokens": toks[e],
             "tokens_per_virtual_s": round(toks[e] / max(busy[e], 1e-12), 1),
             "util": s["engine_util"][e]}
            for e in range(POOL_ENGINES)]
        section["policies"].append({
            "policy": policy, "completed": s["completed"],
            "migrations": s["migrations"], "per_engine": per_engine})
        emit("decode_tput", f"pool_{policy}_tokens_per_virtual_s",
             round(s["decode_tokens"] / max(s["decode_virtual_s"], 1e-12), 1),
             f"per_engine={[p['decode_tokens'] for p in per_engine]};"
             f"migrations={s['migrations']}")
        emit("decode_tput", f"pool_{policy}_engine_util",
             "|".join(str(u) for u in s["engine_util"]),
             f"completed={s['completed']}")
    return section


def _autoscale_rows() -> dict:
    """Decode-pool autoscaling smoke (schema 4): an open-loop Poisson burst
    through a ``--autoscale``-style pool (min 1, max AUTOSCALE_MAX) — the
    engine-count timeline, scale-event counts, and the token-identity check
    against a fixed pool at the max size, persisted so the controller's
    behaviour on the canonical burst is tracked PR-over-PR."""
    from benchmarks.common import (AUTOSCALE_MAX_NEW, LIVE_PROMPT_LEN,
                                   autoscale_burst, live_autoscale_serve,
                                   live_model)
    from repro.serving import Request, ServingSystem

    reqs = autoscale_burst()        # ONE stream for both runs
    results, scheduler, system = live_autoscale_serve(
        requests=[Request(r.rid, list(r.prompt), r.max_new_tokens,
                          r.arrival) for r in reqs],
        max_engines=AUTOSCALE_MAX)
    s = scheduler.summary()
    timeline = s.get("engine_count_timeline", [])
    # fixed-size reference at the max engine count: autoscaling must not
    # change a single emitted token
    cfg, params = live_model()
    fixed = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                          capacity=LIVE_PROMPT_LEN + AUTOSCALE_MAX_NEW + 16,
                          decode_engines=AUTOSCALE_MAX)
    ref = {r.rid: r.tokens for r in fixed.serve(
        [Request(r.rid, list(r.prompt), r.max_new_tokens, r.arrival)
         for r in reqs], open_loop=True)}
    identical = {r.rid: r.tokens for r in results} == ref
    section = {
        "min_engines": 1, "max_engines": AUTOSCALE_MAX,
        "completed": s["completed"],
        "scale_grows": s.get("scale_grows", 0),
        "scale_shrinks": s.get("scale_shrinks", 0),
        "engine_count_timeline": timeline,
        "peak_engines": max((n for _, n in timeline), default=1),
        "migrations": s.get("migrations", 0),
        "tokens_identical_to_fixed_pool": identical,
    }
    emit("decode_tput", "autoscale_scale_events",
         f"{section['scale_grows']}grow/{section['scale_shrinks']}shrink",
         f"peak_engines={section['peak_engines']};"
         f"final={system.pool.n_live}")
    emit("decode_tput", "autoscale_engine_count_timeline",
         "|".join(f"{n}@{t*1e3:.1f}ms" for t, n in timeline),
         f"migrations={section['migrations']}")
    emit("decode_tput", "autoscale_tokens_identical_to_fixed_pool",
         identical, f"fixed_engines={AUTOSCALE_MAX}")
    return section


def _fault_rows() -> dict:
    """Fault-tolerance smoke (schema 6): the canonical autoscale burst
    through a 2-engine pool under the canonical fault plan (mid-decode
    engine crash + consecutive transfer timeouts + a straggler window),
    against the identical system run fault-free. Asserted downstream by
    ``make bench-check``: the crash fires, every lost request is recovered
    by replay re-prefill, recovery-TTFT percentiles are reported, and the
    faulted run's emitted tokens are bit-identical to the fault-free
    reference (greedy determinism survives failure)."""
    from benchmarks.common import FAULT_PLAN_EVENTS, live_fault_serve

    ref_results, ref_sched, _, _ = live_fault_serve(events=None)
    results, scheduler, system, injector = live_fault_serve()
    s = scheduler.summary()
    ref_tokens = {r.rid: list(r.tokens) for r in ref_results if not r.shed}
    tokens = {r.rid: list(r.tokens) for r in results if not r.shed}
    identical = tokens == ref_tokens
    section = {
        "plan": [dict(e) for e in FAULT_PLAN_EVENTS],
        "injected": injector.summary(),
        "engine_failures": s["engine_failures"],
        "recoveries": s["recoveries"],
        "tokens_replayed": s["tokens_replayed"],
        "retries": s["retries"],
        "transfer_timeouts": s["transfer_timeouts"],
        "transfer_corruptions": s["transfer_corruptions"],
        "recovery_ttft_p50_s": s.get("recovery_ttft_p50_s"),
        "recovery_ttft_p99_s": s.get("recovery_ttft_p99_s"),
        "completed": s["completed"],
        "shed": s["shed"],
        "completed_fault_free": ref_sched.summary()["completed"],
        "engines_respawned": sum(
            1 for e in scheduler.scale_events if e["action"] == "grow"),
        "tokens_identical_to_fault_free": identical,
    }
    emit("decode_tput", "fault_recoveries", s["recoveries"],
         f"failures={s['engine_failures']};replayed={s['tokens_replayed']}")
    emit("decode_tput", "fault_transfer_retries", s["retries"],
         f"timeouts={s['transfer_timeouts']};"
         f"corruptions={s['transfer_corruptions']}")
    emit("decode_tput", "fault_recovery_ttft_p99_ms",
         round((s.get("recovery_ttft_p99_s") or 0.0) * 1e3, 3),
         f"p50_ms={round((s.get('recovery_ttft_p50_s') or 0.0) * 1e3, 3)}")
    emit("decode_tput", "fault_tokens_identical_to_fault_free", identical,
         f"completed={s['completed']}/{section['completed_fault_free']}")
    return section


def _slo_class_rows() -> dict:
    """SLO-class overload control (schema 7): the canonical mixed-class
    overload burst (batch flood first, interactive trickle mid-decode)
    through three runs — class-blind baseline, class-aware control
    (per-class budgets + strict priority + batch preemption), and the
    brownout-ladder variant. Asserted downstream by ``make bench-check``:
    the controlled run holds interactive TPOT p99 inside the budget the
    baseline provably violates on the identical stream, at least one batch
    request is preempted mid-decode, and every preempted-then-resumed
    request's emitted tokens are bit-identical to the uncontended
    baseline's (replay re-prefill is exact)."""
    from benchmarks.common import OVERLOAD_BUDGET_MS, live_overload_serve

    base_results, base_sched, _ = live_overload_serve(class_aware=False)
    ctrl_results, ctrl_sched, _ = live_overload_serve(class_aware=True)
    base, ctrl = base_sched.summary(), ctrl_sched.summary()

    def inter_p99_ms(s):
        cls = s.get("classes", {}).get("interactive", s)
        return cls["tpot_p99_s"] * 1e3

    budget, eps = OVERLOAD_BUDGET_MS, 1e-6
    b_ms, c_ms = inter_p99_ms(base), inter_p99_ms(ctrl)
    base_tokens = {r.rid: list(r.tokens) for r in base_results if not r.shed}
    ctrl_tokens = {r.rid: list(r.tokens) for r in ctrl_results if not r.shed}
    preempted = sorted(t.rid for t in ctrl_sched.traces.values()
                       if t.preemptions)
    identical = all(ctrl_tokens.get(rid) == base_tokens.get(rid)
                    for rid in preempted) and ctrl_tokens == base_tokens

    _, brown_sched, _ = live_overload_serve(class_aware=True, brownout=True)
    brown = brown_sched.summary()
    section = {
        "budget_ms": budget,
        "interactive_tpot_p99_ms_controlled": c_ms,
        "interactive_tpot_p99_ms_uncontrolled": b_ms,
        "held_with_control": bool(c_ms <= budget + eps),
        "violated_without_control": bool(b_ms > budget + eps),
        "preemptions": ctrl["preemptions"],
        "preempted_rids": preempted,
        "preempt_tokens_replayed": ctrl["preempt_tokens_replayed"],
        "tokens_identical_after_preemption": bool(identical),
        "classes": {
            name: {"completed": c["completed"], "shed": c["shed"]}
            for name, c in ctrl.get("classes", {}).items()},
        "brownout_peak_level": brown.get("brownout_peak_level", 0),
        "brownout_transitions": brown.get("brownout_transitions", 0),
        "brownout_timeline": brown.get("brownout_timeline", []),
    }
    emit("decode_tput", "slo_interactive_p99_ms_controlled", round(c_ms, 3),
         f"budget_ms={budget:g};held={section['held_with_control']}")
    emit("decode_tput", "slo_interactive_p99_ms_class_blind", round(b_ms, 3),
         f"budget_ms={budget:g};"
         f"violated={section['violated_without_control']}")
    emit("decode_tput", "slo_batch_preemptions", ctrl["preemptions"],
         f"rids={preempted};tokens_identical={identical}")
    emit("decode_tput", "slo_brownout_peak_level",
         section["brownout_peak_level"],
         f"transitions={section['brownout_transitions']}")
    return section


def _optimized_row(arch: str, base_rec) -> None:
    """Report the best §Perf hillclimb variant alongside the baseline."""
    import glob
    import json
    import os
    hc = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "hillclimb")
    best, best_name = None, None
    for fn in glob.glob(os.path.join(hc, f"{arch}__{SHAPE}__*.json")):
        with open(fn) as f:
            rec = json.load(f)
        if rec.get("status") == "ok" and (best is None
                                          or rec["step_s"] < best["step_s"]):
            best, best_name = rec, rec["variant"]
    if best is None:
        return
    tput = (BATCH / best_rec_devices(base_rec)) / best["step_s"]
    emit("decode_tput", f"{arch}_optimized_tokens_per_s_per_chip",
         round(tput, 1), f"variant={best_name};TPOT_ms={best['step_s']*1e3:.1f}")


def best_rec_devices(rec) -> int:
    return rec.get("n_devices", 256)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="live smoke rows + BENCH artifact only (no "
                         "dry-run-derived tables)")
    main(smoke=ap.parse_args().smoke)
