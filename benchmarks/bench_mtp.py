"""Paper Fig. 22: MTP ablation — decode throughput with/without MTP.

Quantitative layer (full mode): throughput model at DeepSeek-R1 scale — MTP
processes base + speculative tokens per iteration (+44% iteration latency
per paper Fig. 22b) and emits 1+α tokens (α = 70% paper acceptance),
evaluated across batch sizes like Fig. 22a.

Functional layer (always, ``--smoke`` for CI): the fused scanned MTP fast
path (``model.decode_loop_mtp`` with the one-forward base+draft verify) on
the live smoke system, with a draft head distilled against the base model's
own greedy continuations so acceptance is real rather than chance. Measures
the acceptance rate and wall-clock tokens/s vs the decode_chunk-only fast
path, and merges both into BENCH_decode.json (schema 4) so the MTP
trajectory is tracked PR-over-PR."""
from __future__ import annotations

import time

from benchmarks.common import (emit, ensure_dryrun, live_model,
                               live_mtp_params, live_smoke_serve,
                               step_time_from_record, update_bench_artifact)

ACCEPT = 0.70
LAT_FACTOR = 1.44

# fused-path smoke measurement (wall clock, live smoke system)
MTP_CHUNK = 4
MTP_MAX_NEW = 16
MTP_REPEATS = 5          # median-of-N: the CI container is noisy


def roofline_rows() -> None:
    rec = ensure_dryrun("deepseek-r1", "decode_32k")
    if rec:
        t_base = step_time_from_record(rec)
        n = rec["n_devices"]
        for batch in (32, 64, 96, 128):
            # fixed weight-read amortizes with batch: smaller batches gain more
            frac_fixed = 0.7 * (128 / batch) / (0.7 * 128 / batch + 0.3)
            t_b = t_base * (0.3 + 0.7 * batch / 128)
            t_mtp = t_b * (1 + (LAT_FACTOR - 1) * (1 - frac_fixed * 0.5))
            tput0 = batch / n / t_b
            tput1 = batch / n / t_mtp * (1 + ACCEPT)
            emit("mtp", f"batch{batch}_speedup_pct",
                 round((tput1 / tput0 - 1) * 100, 1),
                 f"paper_Fig22a:+6-49% (smaller batch => larger gain)")


def _one_serve(kw):
    t0 = time.perf_counter()
    results, scheduler = live_smoke_serve(
        decode_batch=4, decode_chunk=MTP_CHUNK, max_new=MTP_MAX_NEW, **kw)
    return time.perf_counter() - t0, results, scheduler


def fused_rows() -> None:
    """Measured acceptance + MTP speedup of the fused scanned path over the
    decode_chunk-only fast path.

    Two speedup rows, both against the identical request stream:

    * **virtual** — trace-derived tokens per virtual second: each MTP
      iteration is charged the paper's 1.44x verification cost while
      crediting 1 + measured-acceptance tokens. Deterministic, and the
      faithful projection of the memory-bound NPU regime the paper's MTP
      win lives in (the repo's virtual clock exists precisely because CPU
      smoke wall time is orders of magnitude off NPU latencies).
    * **wall** — end-to-end wall clock, median over interleaved A/B pairs
      (robust to the shared CI box drifting mid-run). At smoke scale decode
      is op-dispatch-bound rather than memory-bound, so the wall margin is
      structurally thin; recorded as measured.
    """
    live_mtp_params()        # distill the draft head up front (memoized)

    modes = {"chunk": {}, "mtp": {"use_mtp": True, "mtp_fused": True}}
    for kw in modes.values():
        _one_serve(kw)                  # warm: compile both systems
    walls = {"chunk": [], "mtp": []}
    stats = {}
    for _ in range(MTP_REPEATS):        # interleaved A/B pairs
        for name, kw in modes.items():
            w, results, scheduler = _one_serve(kw)
            walls[name].append(w)
            s = scheduler.summary()
            stats[name] = {
                "decode_tokens": sum(len(r.tokens) - 1 for r in results
                                     if not r.shed),
                "virtual_tput": s["decode_tokens"] / s["decode_virtual_s"],
                "tpot_p50_ms": s["tpot_p50_s"] * 1e3,
                "iters": sum(t.decode_iters
                             for t in scheduler.tracker.finished),
                "tokens": sum(t.decode_tokens
                              for t in scheduler.tracker.finished),
            }
    # Acceptance straight from the trace: tokens credited per decode
    # iteration minus the guaranteed base token.
    accept_rate = (stats["mtp"]["tokens"] / stats["mtp"]["iters"] - 1
                   if stats["mtp"]["iters"] else 0.0)
    emit("mtp", "smoke_acceptance_rate", round(accept_rate, 2),
         "draft head distilled on the serving distribution "
         "(paper: 0.70 for the trained MTP module)")
    emit("mtp", "smoke_tokens_per_iter", round(1 + accept_rate, 2), "")

    tps = {name: stats[name]["decode_tokens"]
           / sorted(ws)[len(ws) // 2] for name, ws in walls.items()}
    vtps = {name: stats[name]["virtual_tput"] for name in modes}
    for name in modes:
        emit("mtp", f"fused_{name}_tokens_per_wall_s", round(tps[name], 1),
             f"decode_chunk={MTP_CHUNK}")
        emit("mtp", f"fused_{name}_tokens_per_virtual_s",
             round(vtps[name], 1), "trace-derived (1.44x MTP iteration)")
    wall_speedup = sorted(c / m for c, m in
                          zip(walls["chunk"], walls["mtp"]))[MTP_REPEATS // 2]
    virtual_speedup = vtps["mtp"] / vtps["chunk"]
    emit("mtp", "mtp_speedup_vs_chunk_virtual", round(virtual_speedup, 3),
         "(1+accept)/1.44 — the paper's memory-bound arithmetic, "
         "measured acceptance")
    emit("mtp", "mtp_speedup_vs_chunk_wall", round(wall_speedup, 2),
         "median of interleaved A/B pair ratios")
    path = update_bench_artifact("decode", {"mtp": {
        "decode_chunk": MTP_CHUNK,
        "max_new": MTP_MAX_NEW,
        "acceptance_rate": accept_rate,
        "tokens_per_iter": 1 + accept_rate,
        "tokens_per_virtual_s": vtps["mtp"],
        "baseline_chunk_tokens_per_virtual_s": vtps["chunk"],
        "mtp_speedup_vs_chunk_virtual": virtual_speedup,
        "tokens_per_wall_s": tps["mtp"],
        "baseline_chunk_tokens_per_wall_s": tps["chunk"],
        "mtp_speedup_vs_chunk_wall": wall_speedup,
        "tpot_p50_ms": stats["mtp"]["tpot_p50_ms"],
        "fused_verify": True,
        "draft_head": "distilled",
    }})
    emit("mtp", "artifact", path, "")


def main(smoke: bool = False) -> None:
    print("name,metric,value,derived")
    if not smoke:
        roofline_rows()
    fused_rows()


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fused-path live rows + BENCH_decode.json merge "
                         "only (no dry-run-derived tables)")
    main(smoke=ap.parse_args().smoke)
