"""Paper Fig. 22: MTP ablation — decode throughput with/without MTP.

Functional layer: the real mtp_step on a smoke model measures actual
acceptance and tokens/iteration. Quantitative layer: throughput model at
DeepSeek-R1 scale — MTP processes base + speculative tokens per iteration
(+44% iteration latency per paper Fig. 22b) and emits 1+α tokens (α = 70%
paper acceptance), evaluated across batch sizes like Fig. 22a."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, ensure_dryrun, step_time_from_record

ACCEPT = 0.70
LAT_FACTOR = 1.44


def main() -> None:
    print("name,metric,value,derived")
    rec = ensure_dryrun("deepseek-r1", "decode_32k")
    if rec:
        t_base = step_time_from_record(rec)
        n = rec["n_devices"]
        for batch in (32, 64, 96, 128):
            # fixed weight-read amortizes with batch: smaller batches gain more
            frac_fixed = 0.7 * (128 / batch) / (0.7 * 128 / batch + 0.3)
            t_b = t_base * (0.3 + 0.7 * batch / 128)
            t_mtp = t_b * (1 + (LAT_FACTOR - 1) * (1 - frac_fixed * 0.5))
            tput0 = batch / n / t_b
            tput1 = batch / n / t_mtp * (1 + ACCEPT)
            emit("mtp", f"batch{batch}_speedup_pct",
                 round((tput1 / tput0 - 1) * 100, 1),
                 f"paper_Fig22a:+6-49% (smaller batch => larger gain)")
    # functional acceptance measurement on the smoke model
    from repro.configs import get_config, smoke_variant
    from repro.core import init_mtp_params
    from repro.core.mtp import mtp_step, propose_draft
    from repro.models import init_params, prefill
    cfg = smoke_variant(get_config("qwen3-8b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    mtp = init_mtp_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size)
    logits, caches = prefill(params, cfg, {"tokens": toks}, capacity=64,
                             cache_dtype=jnp.float32)
    x = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    d = propose_draft(params, mtp, cfg, x)
    cl = jnp.full((2,), 16, jnp.int32)
    key = jax.random.PRNGKey(3)
    accepts, iters = 0, 10
    for _ in range(iters):
        key, sub = jax.random.split(key)
        em, acc, x, d, caches, cl = mtp_step(params, mtp, cfg, x, d, caches,
                                             cl, sub)
        accepts += int(np.sum(np.asarray(acc)))
    emit("mtp", "smoke_acceptance_rate", round(accepts / (iters * 2), 2),
         "untrained_draft_head (paper assumes 0.70 for a trained MTP module)")
    emit("mtp", "smoke_tokens_per_iter", round(1 + accepts / (iters * 2), 2), "")


if __name__ == "__main__":
    main()
