"""Paper Table 5: decode throughput vs TPOT SLO (dynamic batch adjustment).

Decode step-time model decomposed from the compiled dry-run record:
t(B) = t_fixed + B·t_per_req, where t_fixed ≈ weight-read time (invariant in
batch) and t_per_req ≈ per-request cache traffic. For each SLO we pick the
largest batch meeting it — the paper's batch-size/latency trade (Table 5:
96→24→8 for 50/30/15 ms)."""
from __future__ import annotations

from benchmarks.common import HBM_BW, emit, ensure_dryrun, step_time_from_record

ARCH = "deepseek-r1"
SHAPE = "decode_32k"
BATCH0 = 128
SLOS_MS = (50, 30, 15)


def main() -> None:
    print("name,metric,value,derived")
    rec = ensure_dryrun(ARCH, SHAPE)
    if rec is None:
        emit("tpot_slo", "status", "NA", "dryrun_missing")
        return
    n = rec["n_devices"]
    # decompose: per-request bytes = latent cache row; fixed = the rest
    cfg_cache_bytes_per_req = 61 * 32768 * (512 + 64) * 2 / n    # bf16 latent
    t_per_req = cfg_cache_bytes_per_req / HBM_BW
    t_total = step_time_from_record(rec)
    t_fixed = max(t_total - (BATCH0 / n) * t_per_req * n, t_total * 0.2)

    def t_of(batch: int) -> float:
        return t_fixed + batch * t_per_req

    for slo in SLOS_MS:
        best_b, best_t = 0, None
        for b in (8, 16, 24, 32, 48, 64, 96, 128, 192, 256):
            t = t_of(b)
            if t * 1e3 <= slo:
                best_b, best_t = b, t
        if best_b:
            tput = best_b / n / best_t * n  # tokens/s per chip × chips / chips
            emit("tpot_slo", f"slo{slo}ms_batch", best_b,
                 f"achieved_tpot_ms={best_t*1e3:.1f}")
            emit("tpot_slo", f"slo{slo}ms_tokens_per_s_per_chip",
                 round(best_b / best_t / n, 1), "")
        else:
            emit("tpot_slo", f"slo{slo}ms_batch", 0, "SLO_unreachable")
    emit("tpot_slo", "paper_slo50_batch", 96, "1943tok/s; slo15: batch8 538tok/s")


if __name__ == "__main__":
    main()
