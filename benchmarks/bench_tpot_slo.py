"""Paper Table 5: decode throughput vs TPOT SLO (dynamic batch adjustment).

Two layers, mirroring the repo's methodology:

1. **Roofline layer** — decode step-time model decomposed from the compiled
   dry-run record: t(B) = t_fixed + B·t_per_req, where t_fixed ≈ weight-read
   time (invariant in batch) and t_per_req ≈ per-request cache traffic. For
   each SLO we pick the largest batch meeting it — the paper's
   batch-size/latency trade (Table 5: 96→24→8 for 50/30/15 ms).
2. **Functional layer** — the *real* scheduler subsystem
   (``serving/scheduler.py``) serving live requests at smoke scale under a
   sweep of TPOT budgets with a shedding admission gate. p50/p99 TPOT come
   from the structured per-request trace; tightening the budget shrinks the
   gate's admitted batch cap and sheds load — the same Table 5 trade-off
   observed end-to-end rather than projected.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (HBM_BW, emit, ensure_dryrun,
                               live_autoscale_serve, live_poisson_serve,
                               live_pool_serve, live_smoke_serve,
                               step_time_from_record)

ARCH = "deepseek-r1"
SHAPE = "decode_32k"
BATCH0 = 128
SLOS_MS = (50, 30, 15)

LIVE_BUDGETS_MS = (None, 15.0, 9.0, 6.0)
LIVE_DECODE_BATCH = 8

# Open-loop Poisson burst (virtual req/s): high rate => the whole wave
# lands inside a few decode steps and queues against the admission gate.
POISSON_RATE_RPS = 400.0
POISSON_REQUESTS = 16
# 6 ms sheds demonstrably at this rate (9 ms admits the whole burst), so
# the shed-inclusive queue-percentile assertion actually exercises.
POISSON_BUDGETS = ((None, "queue"), (9.0, "queue"), (9.0, "shed"),
                   (6.0, "shed"))

# Decode-pool sweep: 2 engines, per-engine admission gate under this budget.
POOL_BUDGET_MS = 9.0

# Autoscale: Poisson burst through a 1..AUTOSCALE_MAX pool, with and
# without a TPOT budget (the budget shrinks the per-engine batch cap the
# controller sizes against, so it scales out earlier).
AUTOSCALE_MAX = 3
AUTOSCALE_BUDGET_MS = 9.0


def roofline_rows() -> None:
    rec = ensure_dryrun(ARCH, SHAPE)
    if rec is None:
        emit("tpot_slo", "status", "NA", "dryrun_missing")
        return
    n = rec["n_devices"]
    # decompose: per-request bytes = latent cache row; fixed = the rest
    cfg_cache_bytes_per_req = 61 * 32768 * (512 + 64) * 2 / n    # bf16 latent
    t_per_req = cfg_cache_bytes_per_req / HBM_BW
    t_total = step_time_from_record(rec)
    t_fixed = max(t_total - (BATCH0 / n) * t_per_req * n, t_total * 0.2)

    def t_of(batch: int) -> float:
        return t_fixed + batch * t_per_req

    for slo in SLOS_MS:
        best_b, best_t = 0, None
        for b in (8, 16, 24, 32, 48, 64, 96, 128, 192, 256):
            t = t_of(b)
            if t * 1e3 <= slo:
                best_b, best_t = b, t
        if best_b:
            emit("tpot_slo", f"slo{slo}ms_batch", best_b,
                 f"achieved_tpot_ms={best_t*1e3:.1f}")
            emit("tpot_slo", f"slo{slo}ms_tokens_per_s_per_chip",
                 round(best_b / best_t / n, 1), "")
        else:
            emit("tpot_slo", f"slo{slo}ms_batch", 0, "SLO_unreachable")
    emit("tpot_slo", "paper_slo50_batch", 96, "1943tok/s; slo15: batch8 538tok/s")


def live_scheduler_rows() -> None:
    """Serve real requests through the SLO-aware scheduler per budget."""
    for budget in LIVE_BUDGETS_MS:
        _, scheduler = live_smoke_serve(decode_batch=LIVE_DECODE_BATCH,
                                        tpot_budget_ms=budget,
                                        admission="shed")
        s = scheduler.summary()
        tag = "none" if budget is None else f"{budget:g}ms"
        cap = s.get("admitted_batch_cap", "inf")
        emit("tpot_slo", f"live_{tag}_tpot_p50_ms",
             round(s["tpot_p50_s"] * 1e3, 3),
             f"p99_ms={s['tpot_p99_s']*1e3:.3f};max_ms={s['tpot_max_s']*1e3:.3f}")
        emit("tpot_slo", f"live_{tag}_completed", s["completed"],
             f"shed={s['shed']};batch_cap={cap}")
        if budget is not None:
            ok = s["completed"] == 0 or s["tpot_max_s"] * 1e3 <= budget + 1e-9
            emit("tpot_slo", f"live_{tag}_budget_respected", ok,
                 "max_trace_tpot<=budget")


def open_loop_rows() -> None:
    """Poisson arrival burst served open-loop on the virtual clock: the
    queue-mode admission gate under genuine queueing pressure (requests
    become visible at their arrival, not batched up front)."""
    for budget, admission in POISSON_BUDGETS:
        results, scheduler = live_poisson_serve(
            rate_rps=POISSON_RATE_RPS, tpot_budget_ms=budget,
            admission=admission, n_requests=POISSON_REQUESTS,
            decode_batch=4)
        s = scheduler.summary()
        tag = ("none" if budget is None else f"{budget:g}ms") + f"_{admission}"
        emit("tpot_slo", f"poisson_{tag}_completed", s["completed"],
             f"shed={s['shed']};rate_rps={POISSON_RATE_RPS:g}")
        emit("tpot_slo", f"poisson_{tag}_queue_p99_s",
             round(s["queue_p99_s"], 5),
             f"tpot_p50_ms={s['tpot_p50_s']*1e3:.3f}")
        if budget is not None and s["completed"]:
            ok = s["tpot_max_s"] * 1e3 <= budget + 1e-9
            emit("tpot_slo", f"poisson_{tag}_budget_respected", ok,
                 "max_trace_tpot<=budget")
        if admission == "shed" and s["shed"]:
            # queue_p99_s must see shed traces: a request that queued and
            # was then gate-rejected is queueing pressure, not a
            # statistical ghost. Recompute the percentile over
            # finished+shed independently and assert the summary matches
            # the pooled population, not the finished-only one.
            tr = scheduler.tracker
            pooled = [t.queue_seconds for t in tr.finished + tr.shed]
            assert abs(s["queue_p99_s"] - np.percentile(pooled, 99)) \
                < 1e-12, "queue_p99_s ignores shed traces"
            emit("tpot_slo", f"poisson_{tag}_queue_p99_shed_s",
                 round(s["queue_p99_shed_s"], 5),
                 f"shed={s['shed']};queue_p99_covers_{len(pooled)}_traces")


def pool_rows() -> None:
    """2-engine decode pool under a TPOT budget, per routing policy: the
    admission gate now caps each *engine's* batch (TPOT is a per-engine
    property — projected step time depends on the batch the request
    joins), so per-engine utilization + the budget guarantee are reported
    side by side; a rebalancing run surfaces migration counts."""
    for policy in ("round_robin", "least_loaded_slots", "cache_affinity"):
        _, scheduler, _ = live_pool_serve(policy=policy,
                                          tpot_budget_ms=POOL_BUDGET_MS)
        s = scheduler.summary()
        emit("tpot_slo", f"pool_{policy}_completed", s["completed"],
             f"shed={s['shed']};engines={s['decode_engines']};"
             f"batch_cap_per_engine={s.get('admitted_batch_cap', 'inf')}")
        emit("tpot_slo", f"pool_{policy}_engine_util",
             "|".join(str(u) for u in s["engine_util"]),
             f"tpot_p50_ms={s['tpot_p50_s']*1e3:.3f}")
        if s["completed"]:
            ok = s["tpot_max_s"] * 1e3 <= POOL_BUDGET_MS + 1e-9
            emit("tpot_slo", f"pool_{policy}_budget_respected", ok,
                 "max_trace_tpot<=budget (per-engine gate)")
    # cache_affinity piles shared-prefix requests on the resident engine,
    # so this run demonstrably rebalances (migration counts > 0).
    _, scheduler, system = live_pool_serve(policy="cache_affinity",
                                           rebalance_every=1)
    s = scheduler.summary()
    emit("tpot_slo", "pool_rebalance_migrations", s["migrations"],
         f"engine_util={'|'.join(str(u) for u in s['engine_util'])};"
         f"bytes={system.pool.migrated_bytes}")


def autoscale_rows() -> None:
    """SLO-driven decode-pool autoscaling under an open-loop Poisson burst:
    the engine-count timeline the controller drives, the scale-event
    counts, and — with a TPOT budget — the per-engine gate guarantee
    holding across every dynamically spawned engine."""
    for budget in (None, AUTOSCALE_BUDGET_MS):
        # decode_batch=4: the 9 ms budget caps each engine's batch at 2
        # (calibrated cost), so the budgeted run scales out earlier than
        # the slot-limited one — the SLO buying engines, not batch.
        _, scheduler, system = live_autoscale_serve(
            max_engines=AUTOSCALE_MAX, tpot_budget_ms=budget,
            decode_batch=4)
        s = scheduler.summary()
        tag = "none" if budget is None else f"{budget:g}ms"
        timeline = s.get("engine_count_timeline", [])
        emit("tpot_slo", f"autoscale_{tag}_scale_events",
             f"{s.get('scale_grows', 0)}grow/{s.get('scale_shrinks', 0)}"
             "shrink",
             f"peak_engines={max((n for _, n in timeline), default=1)};"
             f"final_live={system.pool.n_live}")
        emit("tpot_slo", f"autoscale_{tag}_engine_count_timeline",
             "|".join(f"{n}@{t*1e3:.1f}ms" for t, n in timeline),
             f"completed={s['completed']};migrations={s.get('migrations', 0)}")
        if budget is not None and s["completed"]:
            ok = s["tpot_max_s"] * 1e3 <= budget + 1e-9
            emit("tpot_slo", f"autoscale_{tag}_budget_respected", ok,
                 "max_trace_tpot<=budget across spawned engines")


def joint_rows() -> None:
    """Joint P/D autoscaling on the canonical phase-skewed burst: the
    prefill-heavy opening must pull an engine decode->prefill (shift_d2p),
    the decode-heavy tail must push it back (shift_p2d), and the served
    tokens must match a fixed-roster reference on the identical stream —
    the capacity see-saw is pure scheduling, never a token change."""
    from benchmarks.common import live_joint_serve

    ref_res, _, _ = live_joint_serve(joint=False)
    res, scheduler, system = live_joint_serve(joint=True)
    s = scheduler.summary()
    ref_tokens = {r.rid: list(r.tokens) for r in ref_res}
    tokens = {r.rid: list(r.tokens) for r in res}
    timeline = s.get("prefill_count_timeline", [])
    emit("tpot_slo", "joint_shifts",
         f"{s.get('shifts_d2p', 0)}d2p/{s.get('shifts_p2d', 0)}p2d",
         f"tokens_identical={tokens == ref_tokens};"
         f"completed={s['completed']}")
    emit("tpot_slo", "joint_prefill_count_timeline",
         "|".join(f"{n}@{t*1e3:.1f}ms" for t, n in timeline),
         f"final_prefill_live={system.prefill_pool.n_live};"
         f"final_decode_live={system.pool.n_live}")
    emit("tpot_slo", "joint_engine_count_timeline",
         "|".join(f"{n}@{t*1e3:.1f}ms"
                  for t, n in s.get("engine_count_timeline", [])),
         "decode-side view of the same shift events")


def fault_rows() -> None:
    """Fault-tolerant serving under the canonical fault plan: SLO impact of
    a mid-decode engine crash (recovery-TTFT percentiles, the latency the
    replay re-prefill charges to recovered requests) and of graceful
    degradation (a shed threshold bounding the backlog on the shrunken
    pool), next to the fault-free reference on the same burst."""
    from benchmarks.common import live_fault_serve

    _, ref_sched, _, _ = live_fault_serve(events=None)
    _, scheduler, system, injector = live_fault_serve()
    s, ref = scheduler.summary(), ref_sched.summary()
    emit("tpot_slo", "fault_recovery_ttft_p50_ms",
         round((s.get("recovery_ttft_p50_s") or 0.0) * 1e3, 3),
         f"p99_ms={round((s.get('recovery_ttft_p99_s') or 0.0) * 1e3, 3)};"
         f"recoveries={s['recoveries']}")
    emit("tpot_slo", "fault_tpot_p99_ms", round(s["tpot_p99_s"] * 1e3, 3),
         f"fault_free_p99_ms={ref['tpot_p99_s']*1e3:.3f};"
         f"failures={s['engine_failures']};retries={s['retries']}")
    emit("tpot_slo", "fault_completed", s["completed"],
         f"fault_free={ref['completed']};shed={s['shed']};"
         f"final_live={system.pool.n_live}")
    # Graceful degradation: same faulted burst with a shed threshold — the
    # queue stays bounded (anything held longer than the threshold sheds
    # instead of waiting out the capacity dip).
    _, dsched, _, _ = live_fault_serve(degrade_shed_queue_s=0.004)
    d = dsched.summary()
    emit("tpot_slo", "fault_degraded_completed", d["completed"],
         f"shed={d['shed']};threshold_ms=4")
    emit("tpot_slo", "fault_degraded_queue_p99_s",
         round(d["queue_p99_s"], 5),
         f"undegraded_queue_p99_s={round(s['queue_p99_s'], 5)}")


def slo_class_rows() -> None:
    """SLO-class overload control on the canonical mixed-class burst: the
    batch flood lands first, the interactive trickle follows mid-decode.
    The controlled run (per-class budgets + strict priority + batch
    preemption) must hold interactive TPOT p99 inside the 6 ms budget; the
    class-blind baseline on the identical stream must violate it — that
    delta is the whole point of the subsystem. A brownout variant reports
    the ladder's transition timeline."""
    from benchmarks.common import (OVERLOAD_BUDGET_MS, live_overload_serve)

    _, base_sched, _ = live_overload_serve(class_aware=False)
    _, ctrl_sched, _ = live_overload_serve(class_aware=True)
    base, ctrl = base_sched.summary(), ctrl_sched.summary()
    budget = OVERLOAD_BUDGET_MS

    def inter_p99_ms(s):
        cls = s.get("classes", {}).get("interactive", s)
        return cls["tpot_p99_s"] * 1e3

    b_ms, c_ms = inter_p99_ms(base), inter_p99_ms(ctrl)
    eps = 1e-6  # a batch exactly at the budget holds it (float dust aside)
    emit("tpot_slo", "slo_class_interactive_p99_ms_controlled",
         round(c_ms, 3), f"budget_ms={budget:g};held={c_ms <= budget + eps}")
    emit("tpot_slo", "slo_class_interactive_p99_ms_class_blind",
         round(b_ms, 3),
         f"budget_ms={budget:g};violated={b_ms > budget + eps}")
    emit("tpot_slo", "slo_class_batch_preemptions", ctrl["preemptions"],
         f"tokens_replayed={ctrl['preempt_tokens_replayed']};"
         f"preempt_p99_ms="
         f"{round(ctrl.get('preempt_p99_s', 0.0) * 1e3, 3)}")
    cls = ctrl.get("classes", {})
    for name in ("interactive", "batch"):
        c = cls.get(name)
        if c:
            emit("tpot_slo", f"slo_class_{name}_completed", c["completed"],
                 f"shed={c['shed']};"
                 f"queue_p99_s={round(c['queue_p99_s'], 5)}")
    _, brown_sched, _ = live_overload_serve(class_aware=True, brownout=True)
    brown = brown_sched.summary()
    timeline = brown.get("brownout_timeline", [])
    emit("tpot_slo", "slo_class_brownout_peak_level",
         brown.get("brownout_peak_level", 0),
         f"transitions={brown.get('brownout_transitions', 0)}")
    emit("tpot_slo", "slo_class_brownout_timeline",
         "|".join(f"{to}@{t*1e3:.1f}ms" for t, _, to in timeline),
         f"completed={brown['completed']};shed={brown['shed']}")


def main() -> None:
    print("name,metric,value,derived")
    roofline_rows()
    live_scheduler_rows()
    open_loop_rows()
    pool_rows()
    autoscale_rows()
    joint_rows()
    fault_rows()
    slo_class_rows()


if __name__ == "__main__":
    main()
