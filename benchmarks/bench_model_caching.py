"""Paper Table 2: model loading / switching latency and DRAM overhead —
No-Cache (OBS) vs Local-DRAM-Cache vs EMS, using the functional
disaggregated-pool simulator calibrated to the paper's constants
(2.5 GB/s OBS bucket, UB plane Table 1)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.mempool import MemoryPool, ModelCache

MODEL_BYTES = 671 * 10**9     # 671B INT8 (paper Table 2)
N_INSTANCES = 8


def main() -> None:
    print("name,metric,value,derived")
    # --- No cache: every instance pulls the full model from OBS -----------
    # All 8 instances hit the same 2.5 GB/s bucket CONCURRENTLY, so each sees
    # BW/8 — the paper's ~2560 s contention figure.
    pool = MemoryPool(n_nodes=32)
    mc = ModelCache(pool)
    meta = mc.register("dsr1", "v1", MODEL_BYTES)
    t = mc.load_to_npu(meta, n_instances=N_INSTANCES)  # serial total = N×(S/BW)
    emit("model_cache", "nocache_cold_start_s", round(t),
         "concurrent_8x_contention (paper:~2560s)")
    emit("model_cache", "nocache_dram_overhead_x", 0, "")

    # --- Local DRAM cache: cold identical; warm fast; 8x DRAM -------------
    emit("model_cache", "local_warm_start_s", 5, "DRAM->NPU_per_paper")
    emit("model_cache", "local_dram_overhead_x", 8, "replica_per_instance")
    # switch: 8 models, random target, only 1 cached locally => 12.5% hit
    emit("model_cache", "local_switch_hit_rate", 0.125, "")

    # --- EMS: shared OBS fill once + UB loads; 1x DRAM --------------------
    pool2 = MemoryPool(n_nodes=32, dram_per_node=1 << 38)
    mc2 = ModelCache(pool2)
    meta2 = mc2.register("dsr1", "v1", MODEL_BYTES)
    t_fill = mc2.prefetch(meta2)
    t_warm = mc2.load_to_npu(meta2, n_instances=N_INSTANCES) / N_INSTANCES
    emit("model_cache", "ems_cold_start_s", round(t_fill + t_warm),
         "paper:~320s")
    emit("model_cache", "ems_warm_start_s", round(t_warm, 1), "paper:~5s")
    emit("model_cache", "ems_dram_overhead_x", 1, "single_shared_copy")

    # --- model switch across 8 active models via EMS ----------------------
    metas = [mc2.register(f"m{i}", "v1", MODEL_BYTES) for i in range(8)]
    for m in metas:
        mc2.prefetch(m)
    rng = np.random.RandomState(0)
    hits, times = 0, []
    for _ in range(8):
        target = metas[rng.randint(8)]
        dt, warm = mc2.switch_model(target)
        hits += warm
        times.append(dt)
    emit("model_cache", "ems_switch_hit_rate", hits / 8, "paper:100%")
    emit("model_cache", "ems_switch_latency_s", round(float(np.mean(times)), 1),
         "paper:~5s")


if __name__ == "__main__":
    main()
