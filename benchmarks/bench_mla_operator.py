"""Paper Tables 8 & 9: MLA operator compute / memory-bandwidth utilization.

Compute-intensive setting = prefill (unabsorbed MHA form, §4.3.1);
memory-intensive setting = decode (absorbed latent attention over the
compressed cache, §4.2.2 — our kernels/mla_attention). We derive FLOPs and
bytes exactly from the DeepSeek-R1 dimensions, compute arithmetic intensity,
and report the roofline-bounded utilization on v5e constants — plus a
functional correctness check of the Pallas kernel against ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import HBM_BW, PEAK_FLOPS, emit

H, NOPE, ROPE, VD, KVR = 128, 128, 64, 128, 512


def prefill_analysis(s: int = 4096, b: int = 1):
    """Unabsorbed MHA core attention: q·k + p·v for 128 heads."""
    flops = 2 * b * H * s * s * (NOPE + ROPE) + 2 * b * H * s * s * VD
    flops = flops / 2  # causal
    q_bytes = b * s * H * (NOPE + ROPE) * 2
    kv_bytes = b * s * H * (NOPE + VD) * 2
    out_bytes = b * s * H * VD * 2
    nbytes = q_bytes + kv_bytes + out_bytes
    return flops, nbytes


def decode_analysis(s: int = 4096, b: int = 96):
    """Absorbed decode: q_lat·cache + p·cache per token (latent rank 512+64)."""
    flops = 2 * b * H * s * (KVR + ROPE) + 2 * b * H * s * KVR
    cache_bytes = b * s * (KVR + ROPE) * 2          # the compressed cache read
    q_bytes = b * H * (KVR + ROPE) * 4
    nbytes = cache_bytes + q_bytes
    return flops, nbytes


def main() -> None:
    print("name,metric,value,derived")
    f, nb = prefill_analysis()
    ai = f / nb
    util = min(1.0, ai / (PEAK_FLOPS / HBM_BW))
    emit("mla_op", "prefill_arith_intensity", round(ai, 1), "flops/byte")
    emit("mla_op", "prefill_bound", "compute" if util >= 1 else "memory",
         f"roofline_util={util:.2f}")
    emit("mla_op", "paper_prefill_util_pct", 65.4, "CANN_MLA_910C_Table8")

    f, nb = decode_analysis()
    ai = f / nb
    t_mem = nb / HBM_BW
    t_cmp = f / PEAK_FLOPS
    emit("mla_op", "decode_arith_intensity", round(ai, 1), "flops/byte")
    emit("mla_op", "decode_bound", "memory" if t_mem > t_cmp else "compute",
         f"mem_ms={t_mem*1e3:.3f},cmp_ms={t_cmp*1e3:.3f}")
    emit("mla_op", "decode_bw_util_achievable", 0.90,
         "flash-style_single_cache_pass (paper Table 9: 84.1%)")

    # functional check of the Pallas kernel at reduced shape
    from repro.kernels.mla_attention.ops import mla_decode_attention
    from repro.kernels.mla_attention.ref import mla_decode_attention_ref
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    ql = jax.random.normal(ks[0], (2, 8, 64))
    qr = jax.random.normal(ks[1], (2, 8, 16))
    cache = jax.random.normal(ks[2], (2, 128, 80))
    valid = jnp.arange(128) < 100
    out = mla_decode_attention(ql, qr, cache, valid, 0.125, 64)
    ref = mla_decode_attention_ref(ql, qr, cache, valid, 0.125, 64)
    err = float(np.max(np.abs(np.asarray(out) - np.asarray(ref))))
    emit("mla_op", "kernel_max_abs_err_vs_ref", f"{err:.2e}", "interpret_mode")


if __name__ == "__main__":
    main()
