"""Paper Table 6 proxy: INT8 quantization accuracy preservation.

No eval benchmarks exist offline, so the accuracy proxy is distributional:
BF16-reference vs INT8-quantized model logits on held-out synthetic batches —
top-1 agreement, top-8 overlap, mean KL. The paper's claim (Table 6) is that
INT8 matches the FP baseline within noise across 16 benchmarks; the proxy
asserts the same at the logit level for every architecture family."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, smoke_variant
from repro.models import forward, init_params
from repro.quant import quantize_param_tree

ARCHS = ["qwen3-8b", "olmoe-1b-7b", "mamba2-780m", "deepseek-r1"]


def dequantized(tree):
    def walk(t):
        if isinstance(t, dict):
            if "__q__" in t:
                return (t["__q__"].astype(jnp.float32)
                        * t["__scale__"]).astype(jnp.float32)
            return {k: walk(v) for k, v in t.items()}
        return t
    return walk(tree)


def main() -> None:
    print("name,metric,value,derived")
    for arch in ARCHS:
        cfg = smoke_variant(get_config(arch))
        params = init_params(jax.random.PRNGKey(0), cfg)
        qp, stats = quantize_param_tree(params)
        params_q = dequantized(qp)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                  cfg.vocab_size)
        ref, _ = forward(params, cfg, {"tokens": toks})
        out, _ = forward(params_q, cfg, {"tokens": toks})
        ref_f = np.asarray(ref, np.float32).reshape(-1, cfg.vocab_size)
        out_f = np.asarray(out, np.float32).reshape(-1, cfg.vocab_size)
        top1 = float((ref_f.argmax(-1) == out_f.argmax(-1)).mean())
        p = jax.nn.softmax(jnp.asarray(ref_f), -1)
        q = jax.nn.softmax(jnp.asarray(out_f), -1)
        kl = float(jnp.mean(jnp.sum(p * (jnp.log(p + 1e-9) - jnp.log(q + 1e-9)),
                                    -1)))
        k = 8
        ref_top = np.argsort(-ref_f, -1)[:, :k]
        out_top = np.argsort(-out_f, -1)[:, :k]
        overlap = float(np.mean([len(set(a) & set(b)) / k
                                 for a, b in zip(ref_top, out_top)]))
        emit("quant_acc", f"{arch}_top1_agreement", round(top1, 3),
             f"quantized={stats['quantized']}tensors")
        emit("quant_acc", f"{arch}_top8_overlap", round(overlap, 3), "")
        emit("quant_acc", f"{arch}_mean_KL", f"{kl:.4f}", "")
    emit("quant_acc", "paper_claim", "INT8≈FP_api",
         "Table6: 16 benchmarks within noise")


if __name__ == "__main__":
    main()
