"""Shared helpers for the per-table benchmarks.

CPU container ⇒ no wall-clock TPU numbers. Each benchmark derives its table
from (a) functional runs of the real system at smoke scale, and (b) the
compiled dry-run artifacts (experiments/dryrun/*.json) + the v5e roofline
constants — the methodology mandated by the assignment (§Roofline).
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

PEAK_FLOPS = 197e12       # bf16/chip (v5e-class)
PEAK_INT8 = 394e12        # int8 ≈ 2× bf16 on MXU
HBM_BW = 819e9
ICI_BW = 50e9             # per link
ICI_LINKS = 4

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_dryrun(arch: str, shape: str, mesh: str = "16x16") -> Optional[Dict]:
    fn = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(fn):
        return None
    with open(fn) as f:
        rec = json.load(f)
    return rec if rec.get("status") == "ok" else None


def ensure_dryrun(arch: str, shape: str, mesh: str = "16x16") -> Optional[Dict]:
    """Load a dry-run record, running it on demand (subprocess: needs 512
    placeholder devices, which this process must not claim)."""
    rec = load_dryrun(arch, shape, mesh)
    if rec is not None:
        return rec
    import subprocess
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape]
    if mesh == "2x16x16":
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH=src)
    subprocess.run(cmd, env=env, capture_output=True, timeout=580)
    return load_dryrun(arch, shape, mesh)


def step_time_from_record(rec: Dict, overlap_collectives: bool = False) -> float:
    """Roofline step time: serial sum or max-overlap of the three terms."""
    c, m, k = rec["compute_s"], rec["memory_s"], rec["collective_s"]
    if overlap_collectives:
        return max(c + m, k)
    return max(c, m) + k


def emit(name: str, metric: str, value, derived: str = "") -> None:
    print(f"{name},{metric},{value},{derived}")


# ---------------------------------------------------------------------------
# Live-scheduler smoke harness (shared by bench_tpot_slo and
# bench_decode_throughput so their request streams stay comparable).
# ---------------------------------------------------------------------------

LIVE_ARCH = "granite-3-2b"
LIVE_REQUESTS = 10
LIVE_PROMPT_LEN = 12
LIVE_MAX_NEW = 4

_live_model = None
_live_systems: Dict[int, object] = {}


def live_smoke_serve(*, decode_batch: int, tpot_budget_ms=None,
                     admission: str = "shed"):
    """Serve the canonical smoke request stream; returns (results,
    scheduler). The ServingSystem (and its jitted prefill/decode steps) is
    cached per decode_batch — only the scheduler, which traces no
    computation, is rebuilt per sweep point."""
    global _live_model
    import jax
    import numpy as np

    from repro.configs import get_config, smoke_variant
    from repro.models import init_params
    from repro.serving import Request, SchedulerConfig, ServingSystem

    if _live_model is None:
        cfg = smoke_variant(get_config(LIVE_ARCH))
        _live_model = (cfg, init_params(jax.random.PRNGKey(0), cfg))
    cfg, params = _live_model
    rng = np.random.RandomState(0)
    reqs = [Request(i, list(rng.randint(0, cfg.vocab_size, LIVE_PROMPT_LEN)),
                    LIVE_MAX_NEW) for i in range(LIVE_REQUESTS)]
    system = _live_systems.get(decode_batch)
    if system is None:
        system = ServingSystem(params, cfg, n_prefill=2,
                               decode_batch=decode_batch,
                               capacity=LIVE_PROMPT_LEN + LIVE_MAX_NEW + 16)
        _live_systems[decode_batch] = system
    system.reconfigure_scheduler(
        SchedulerConfig(tpot_budget_ms=tpot_budget_ms, admission=admission))
    results = system.serve(reqs)
    return results, system.scheduler
