"""Shared helpers for the per-table benchmarks.

CPU container ⇒ no wall-clock TPU numbers. Each benchmark derives its table
from (a) functional runs of the real system at smoke scale, and (b) the
compiled dry-run artifacts (experiments/dryrun/*.json) + the v5e roofline
constants — the methodology mandated by the assignment (§Roofline).
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

PEAK_FLOPS = 197e12       # bf16/chip (v5e-class)
PEAK_INT8 = 394e12        # int8 ≈ 2× bf16 on MXU
HBM_BW = 819e9
ICI_BW = 50e9             # per link
ICI_LINKS = 4

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_dryrun(arch: str, shape: str, mesh: str = "16x16") -> Optional[Dict]:
    fn = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(fn):
        return None
    with open(fn) as f:
        rec = json.load(f)
    return rec if rec.get("status") == "ok" else None


def ensure_dryrun(arch: str, shape: str, mesh: str = "16x16") -> Optional[Dict]:
    """Load a dry-run record, running it on demand (subprocess: needs 512
    placeholder devices, which this process must not claim)."""
    rec = load_dryrun(arch, shape, mesh)
    if rec is not None:
        return rec
    import subprocess
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape]
    if mesh == "2x16x16":
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH=src)
    subprocess.run(cmd, env=env, capture_output=True, timeout=580)
    return load_dryrun(arch, shape, mesh)


def step_time_from_record(rec: Dict, overlap_collectives: bool = False) -> float:
    """Roofline step time: serial sum or max-overlap of the three terms."""
    c, m, k = rec["compute_s"], rec["memory_s"], rec["collective_s"]
    if overlap_collectives:
        return max(c + m, k)
    return max(c, m) + k


def emit(name: str, metric: str, value, derived: str = "") -> None:
    print(f"{name},{metric},{value},{derived}")


REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def write_bench_artifact(name: str, payload: Dict, schema: int = 7) -> str:
    """Persist a benchmark record as BENCH_<name>.json at the repo root so
    the perf trajectory is trackable PR-over-PR. Schema 2 added the MTP
    section (acceptance rate + speedup) to the decode artifact; schema 3
    added the decode-pool section (per-engine throughput + routing policy +
    migration counts); schema 4 added the pool autoscale section
    (engine-count timeline + scale-event counts + fixed-pool token
    identity); schema 5 added the continuous-batching section
    (dead_slot_rate before/after, mid-scan refill counts, per-step token
    identity); schema 6 added the fault-tolerance section (engine failures,
    replay recoveries, transfer retries, recovery-TTFT percentiles, and
    token identity of the faulted run against its fault-free reference);
    schema 7 adds the slo_classes section (per-class TPOT under a mixed
    overload burst with vs without class-aware control, batch preemption
    counts, preempt-resume token identity, brownout transitions); schema 8
    (prefill artifact) adds the handoff_overlap section (streamed vs
    synchronous TTFT split under pipelined chunked KV streaming, transfer
    bytes in flight, token identity of the two paths); schema 9 (prefill
    artifact) adds the ems section (multi-turn session hit rate by turn,
    promote/demote bytes through the shared EMS tier, TTFT split by hit
    depth, analytic UB-vs-VPC reuse gain, and the hit-aware admission
    demo: a mostly-cached request admitted where the suffix-blind gate
    waits)."""
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump({"schema": schema, "bench": name, **payload}, f, indent=1,
                  sort_keys=True)
        f.write("\n")
    return path


def update_bench_artifact(name: str, extra: Dict, schema: int = 7) -> str:
    """Merge ``extra`` into an existing BENCH_<name>.json (or start a fresh
    one) — benches that contribute sections to a shared artifact (bench_mtp
    -> BENCH_decode.json) use this instead of clobbering it."""
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    payload: Dict = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload.update(extra)
    payload.pop("schema", None)
    payload.pop("bench", None)
    return write_bench_artifact(name, payload, schema)


# ---------------------------------------------------------------------------
# DecodeCostModel calibration from the dry-run roofline records
# (ROADMAP open item — placeholder defaults only when no record exists).
# ---------------------------------------------------------------------------


def kv_bytes_per_request(cfg, context: int = 32768) -> float:
    """Per-request KV/latent cache bytes at `context` (bf16) — the strictly
    batch-proportional HBM traffic of one decode step."""
    if cfg.attention_kind == "mla":
        return cfg.num_layers * context * (cfg.kv_lora_rank
                                           + cfg.qk_rope_head_dim) * 2
    if cfg.attention_kind in ("causal", "bidirectional") and cfg.num_kv_heads:
        return cfg.num_layers * context * 2 * cfg.num_kv_heads \
            * cfg.head_dim * 2
    return 0.0


_calibrated_costs: Dict = {}


def calibrated_decode_cost(arch: str, shape: str = "decode_32k",
                           batch: int = 128):
    """DecodeCostModel from the arch's compiled dry-run record; falls back
    to the placeholder defaults when no record (or no KV traffic) exists.
    Memoized: live_smoke_serve calls this inside timed benchmark loops."""
    from repro.configs import get_config
    from repro.serving.scheduler import decode_cost_from_roofline

    key = (arch, shape, batch)
    if key not in _calibrated_costs:
        rec = load_dryrun(arch, shape)
        if rec is None:
            _calibrated_costs[key] = decode_cost_from_roofline(None, 0.0, 0.0)
        else:
            cfg = get_config(arch)
            _calibrated_costs[key] = decode_cost_from_roofline(
                rec, kv_bytes_per_request(cfg), batch / rec["n_devices"],
                HBM_BW)
    return _calibrated_costs[key]


# ---------------------------------------------------------------------------
# Live-scheduler smoke harness (shared by bench_tpot_slo and
# bench_decode_throughput so their request streams stay comparable).
# ---------------------------------------------------------------------------

LIVE_ARCH = "granite-3-2b"
LIVE_REQUESTS = 10
LIVE_PROMPT_LEN = 12
LIVE_MAX_NEW = 4

_live_model = None
_live_systems: Dict[int, object] = {}


def live_model():
    global _live_model
    import jax

    from repro.configs import get_config, smoke_variant
    from repro.models import init_params

    if _live_model is None:
        cfg = smoke_variant(get_config(LIVE_ARCH))
        _live_model = (cfg, init_params(jax.random.PRNGKey(0), cfg))
    return _live_model


_live_mtp_params = None


def live_mtp_params():
    """Draft-head params for the live smoke arch — distilled against the
    base model's greedy continuations of the *live serving prompts*
    (memoized), the smoke-scale analogue of the paper's trained MTP module
    (train distribution == serve distribution), so live MTP rows measure a
    realistic acceptance rate instead of chance."""
    global _live_mtp_params
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import fit_draft_head, init_mtp_params

    if _live_mtp_params is None:
        cfg, params = live_model()
        mtp = init_mtp_params(jax.random.PRNGKey(1), cfg)
        rng = np.random.RandomState(0)          # == live_smoke_serve stream
        prompts = jnp.asarray(
            [rng.randint(0, cfg.vocab_size, LIVE_PROMPT_LEN)
             for _ in range(LIVE_REQUESTS)], jnp.int32)
        mtp = fit_draft_head(params, cfg, mtp, jax.random.PRNGKey(2),
                             prompts=prompts, gen_len=32, steps=400)
        _live_mtp_params = mtp
    return _live_mtp_params


def live_smoke_serve(*, decode_batch: int, tpot_budget_ms=None,
                     admission: str = "shed", decode_chunk: int = 1,
                     max_new: int = LIVE_MAX_NEW, use_mtp: bool = False,
                     mtp_fused: bool = False):
    """Serve the canonical smoke request stream; returns (results,
    scheduler). The ServingSystem (and its jitted prefill/decode steps) is
    cached per (decode_batch, decode_chunk, mtp mode) — only the scheduler,
    which traces no computation, is rebuilt per sweep point. The decode
    cost model is calibrated from the arch's dry-run roofline record when
    one exists (placeholder defaults otherwise); MTP runs use the distilled
    draft head from :func:`live_mtp_params`."""
    import numpy as np

    from repro.serving import Request, SchedulerConfig, ServingSystem

    cfg, params = live_model()
    rng = np.random.RandomState(0)
    reqs = [Request(i, list(rng.randint(0, cfg.vocab_size, LIVE_PROMPT_LEN)),
                    max_new) for i in range(LIVE_REQUESTS)]
    key = (decode_batch, decode_chunk, max_new, use_mtp, mtp_fused)
    system = _live_systems.get(key)
    if system is None:
        system = ServingSystem(
            params, cfg, n_prefill=2, decode_batch=decode_batch,
            capacity=LIVE_PROMPT_LEN + max_new + 16,
            decode_chunk=decode_chunk, use_mtp=use_mtp,
            mtp_params=live_mtp_params() if use_mtp else None,
            mtp_fused=mtp_fused)
        _live_systems[key] = system
    system.reconfigure_scheduler(
        SchedulerConfig(tpot_budget_ms=tpot_budget_ms, admission=admission,
                        decode_chunk=decode_chunk, use_mtp=use_mtp,
                        decode_cost=calibrated_decode_cost(LIVE_ARCH)))
    results = system.serve(reqs)
    return results, system.scheduler


def live_pool_serve(*, policy: str = "least_loaded_slots",
                    decode_engines: int = 2, decode_batch: int = 2,
                    tpot_budget_ms=None, admission: str = "shed",
                    rebalance_every: int = 0, max_new: int = LIVE_MAX_NEW,
                    shared_prefix: int = 8):
    """Serve a shared-prefix smoke stream through a decode pool; returns
    (results, scheduler, system). The pooled ServingSystem (one jit per
    engine) is cached per shape key; the routing policy and rebalance
    cadence are control-plane and swap via ``reconfigure_scheduler``, so a
    policy sweep reuses one compiled pool. Prompts share a prefix and the
    system carries an EMS context cache, so ``cache_affinity`` has real
    block keys to route on."""
    import numpy as np

    from repro.mempool import ContextCache, MemoryPool
    from repro.serving import Request, SchedulerConfig, ServingSystem

    cfg, params = live_model()
    rng = np.random.RandomState(0)
    prefix = list(rng.randint(0, cfg.vocab_size, shared_prefix))
    reqs = [Request(i, prefix + list(rng.randint(
                0, cfg.vocab_size, LIVE_PROMPT_LEN - shared_prefix)),
                    max_new) for i in range(LIVE_REQUESTS)]
    key = ("pool", decode_engines, decode_batch, max_new)
    system = _live_systems.get(key)
    if system is None:
        cc = ContextCache(MemoryPool(n_nodes=4), block_tokens=4,
                          model_tag=cfg.name)
        system = ServingSystem(
            params, cfg, n_prefill=2, decode_batch=decode_batch,
            capacity=LIVE_PROMPT_LEN + max_new + 16,
            decode_engines=decode_engines, context_cache=cc)
        # Warm the EMS context cache (and the jit caches) on the same
        # stream before any measured run: otherwise the first policy in a
        # sweep pays cold-prefix prefill while later ones reuse it, and
        # the per-policy rows would compare cache warmth, not routing.
        system.serve([Request(r.rid, list(r.prompt), r.max_new_tokens)
                      for r in reqs])
        _live_systems[key] = system
    system.reconfigure_scheduler(
        SchedulerConfig(tpot_budget_ms=tpot_budget_ms, admission=admission,
                        decode_policy=policy,
                        decode_rebalance_every=rebalance_every,
                        decode_cost=calibrated_decode_cost(LIVE_ARCH)))
    results = system.serve(reqs)
    return results, system.scheduler, system


AUTOSCALE_MAX_NEW = 8


def autoscale_burst(n_requests: int = 12, rate_rps: float = 400.0,
                    max_new: int = AUTOSCALE_MAX_NEW, seed: int = 5):
    """The canonical autoscale bench burst. One definition, shared by the
    autoscaling run and its fixed-pool token-identity reference, so the
    two provably serve the same stream."""
    from repro.serving.workload import poisson_requests

    cfg, _ = live_model()
    return poisson_requests(n_requests, rate_rps, LIVE_PROMPT_LEN, max_new,
                            cfg.vocab_size, seed=seed)


def live_autoscale_serve(*, requests=None, min_engines: int = 1,
                         max_engines: int = 3, decode_batch: int = 2,
                         max_new: int = AUTOSCALE_MAX_NEW,
                         tpot_budget_ms=None):
    """Open-loop burst (default: :func:`autoscale_burst`) through an
    *autoscaling* decode pool; returns (results, scheduler, system). Not
    cached: autoscaling mutates the pool's engine roster, so every call
    builds a fresh system (smoke engines are cheap) — determinism of the
    scale-event sequence is part of what the benches report."""
    from repro.serving import SchedulerConfig, ServingSystem

    cfg, params = live_model()
    reqs = autoscale_burst(max_new=max_new) if requests is None else requests
    system = ServingSystem(
        params, cfg, n_prefill=2, decode_batch=decode_batch,
        capacity=LIVE_PROMPT_LEN + max_new + 16,
        decode_engines=min_engines, autoscale=True,
        min_engines=min_engines, max_engines=max_engines,
        tpot_budget_ms=tpot_budget_ms,
        scheduler_config=SchedulerConfig(
            decode_cost=calibrated_decode_cost(LIVE_ARCH)))
    results = system.serve(reqs, open_loop=True)
    return results, system.scheduler, system


#: The canonical bench fault plan: one mid-decode crash (engine 1), two
#: consecutive transfer timeouts (exercises backoff + retry), and a 2×
#: straggler window on engine 0. Shared by bench_decode_throughput and
#: bench_tpot_slo so both report the same failure sequence.
FAULT_PLAN_EVENTS = (
    {"kind": "engine_crash", "engine": 1, "at": 0.02},
    {"kind": "transfer_timeout", "op": "transfer", "after": 2, "count": 2},
    {"kind": "slow_engine", "engine": 0, "at": 0.01, "factor": 2.0,
     "duration": 0.01},
)


def live_fault_serve(*, events=FAULT_PLAN_EVENTS, requests=None,
                     min_engines: int = 2, max_engines: int = 3,
                     decode_batch: int = 2, max_new: int = AUTOSCALE_MAX_NEW,
                     degrade_shed_queue_s=None):
    """Open-loop burst (default: the autoscale bench burst, so the
    fault-free reference is the same stream) through a 2-engine autoscaling
    pool under a deterministic fault plan; returns (results, scheduler,
    system, injector). ``events=None`` runs the identical system fault-free
    — the token-identity reference. Not cached: crashes mutate the engine
    roster. ``min_engines=2`` guarantees the crash drops the pool below the
    floor, so the bench provably exercises the respawn path."""
    from repro.serving import SchedulerConfig, ServingSystem
    from repro.serving.faults import FaultEvent, FaultInjector, FaultPlan

    cfg, params = live_model()
    reqs = autoscale_burst(max_new=max_new) if requests is None else requests
    injector = None
    if events is not None:
        injector = FaultInjector(
            FaultPlan([FaultEvent(**dict(e)) for e in events]))
    system = ServingSystem(
        params, cfg, n_prefill=2, decode_batch=decode_batch,
        capacity=LIVE_PROMPT_LEN + max_new + 16,
        decode_engines=2, autoscale=True,
        min_engines=min_engines, max_engines=max_engines,
        degrade_shed_queue_s=degrade_shed_queue_s,
        fault_injector=injector,
        scheduler_config=SchedulerConfig(
            decode_cost=calibrated_decode_cost(LIVE_ARCH)))
    results = system.serve(reqs, open_loop=True)
    return results, system.scheduler, system, injector


CB_CHUNK = 4       # scan width for the continuous-batching comparison
CB_MAX_NEW = 6     # != 1 (mod CB_CHUNK): every request ends mid-chunk, so
#                    the wave-shaped loop provably burns masked iterations


def continuous_burst(n_requests: int = 12, rate_rps: float = 300.0,
                     max_new: int = CB_MAX_NEW, seed: int = 7):
    """The canonical continuous-batching bench burst: one definition shared
    by the CB-on, CB-off, and per-step reference runs, so all three
    provably serve the identical arrival trace."""
    from repro.serving.workload import poisson_requests

    cfg, _ = live_model()
    return poisson_requests(n_requests, rate_rps, LIVE_PROMPT_LEN, max_new,
                            cfg.vocab_size, seed=seed)


def live_continuous_serve(*, continuous: bool, decode_chunk: int = CB_CHUNK,
                          tpot_budget_ms=9.0, admission: str = "queue",
                          decode_batch: int = 3, max_new: int = CB_MAX_NEW,
                          requests=None):
    """Open-loop burst (default: :func:`continuous_burst`) through the
    chunked decode fast path with continuous batching on or off; returns
    (results, scheduler). The system is cached per (chunk, batch) shape —
    ``continuous_batching`` is control-plane and flips via
    ``reconfigure_scheduler``, so the on/off comparison reuses one
    compiled system (adaptive widths jit lazily on the first CB-on run).
    ``decode_chunk=1`` gives the per-step token-identity reference."""
    from repro.serving import SchedulerConfig, ServingSystem

    cfg, params = live_model()
    reqs = continuous_burst(max_new=max_new) if requests is None \
        else requests
    key = ("cb", decode_chunk, decode_batch, max_new)
    system = _live_systems.get(key)
    if system is None:
        system = ServingSystem(
            params, cfg, n_prefill=2, decode_batch=decode_batch,
            capacity=LIVE_PROMPT_LEN + max_new + 16,
            decode_chunk=decode_chunk)
        _live_systems[key] = system
    system.reconfigure_scheduler(
        SchedulerConfig(tpot_budget_ms=tpot_budget_ms, admission=admission,
                        decode_chunk=decode_chunk,
                        continuous_batching=continuous,
                        decode_cost=calibrated_decode_cost(LIVE_ARCH)))
    results = system.serve(reqs, open_loop=True)
    return results, system.scheduler


OVERLOAD_BUDGET_MS = 6.0        # interactive TPOT budget. Under the
#                                 placeholder cost model (4 ms fixed +
#                                 1 ms/req) this caps the batch at 2 while
#                                 a class-blind batch-of-3 steps at 7 ms —
#                                 so the baseline provably violates what
#                                 the controlled run holds. The overload
#                                 section pins the placeholder cost on
#                                 purpose: its acceptance property
#                                 (held-with vs violated-without control)
#                                 must be stable across containers, not a
#                                 function of whichever dry-run record
#                                 happens to exist.
OVERLOAD_BATCH_BUDGET_MS = 30.0
OVERLOAD_MAX_NEW = 6


def overload_burst(n_batch: int = 6, n_interactive: int = 4, seed: int = 5):
    """The canonical mixed-class overload burst: a batch-tier flood arrives
    first and fills the decode slots, then an interactive trickle lands
    mid-decode. One definition, shared by bench_tpot_slo's per-class rows
    and bench_decode_throughput's slo_classes section (controlled and
    class-blind runs), so every variant provably serves the same stream."""
    import numpy as np

    from repro.serving import Request

    cfg, _ = live_model()
    rng = np.random.RandomState(seed)
    reqs = [Request(i, list(rng.randint(0, cfg.vocab_size, LIVE_PROMPT_LEN)),
                    OVERLOAD_MAX_NEW, arrival=5e-4 * i, slo_class="batch")
            for i in range(n_batch)]
    reqs += [Request(100 + i,
                     list(rng.randint(0, cfg.vocab_size, LIVE_PROMPT_LEN)),
                     LIVE_MAX_NEW, arrival=4e-3 + 2e-3 * i,
                     slo_class="interactive")
             for i in range(n_interactive)]
    return reqs


def live_overload_serve(*, class_aware: bool, brownout: bool = False,
                        requests=None, decode_batch: int = 3):
    """Serve the mixed-class overload burst with or without SLO-class
    control; returns (results, scheduler, system). The controlled run gives
    interactive the 6 ms budget (queue mode), batch a relaxed 30 ms budget,
    and enables batch preemption; the brownout variant instead lets the
    ladder escalate (preemption arrives at level 2, so the ladder itself is
    what's measured); the class-blind baseline serves the identical stream
    gate-open. Not cached: preemption replays through the prefill plane and
    the comparison wants a clean per-run trace, so each call builds a fresh
    system. Uses the placeholder decode cost (see OVERLOAD_BUDGET_MS)."""
    from repro.serving import ServingSystem

    cfg, params = live_model()
    reqs = overload_burst() if requests is None else requests
    kw = {}
    if class_aware:
        kw = dict(tpot_budget_ms=OVERLOAD_BUDGET_MS,
                  batch_tpot_budget_ms=OVERLOAD_BATCH_BUDGET_MS)
        if brownout:
            kw.update(brownout=True)
        else:
            kw.update(preempt_batch=True)
    system = ServingSystem(
        params, cfg, n_prefill=2, decode_batch=decode_batch,
        capacity=LIVE_PROMPT_LEN + OVERLOAD_MAX_NEW + 16, **kw)
    results = system.serve(reqs, open_loop=True)
    return results, system.scheduler, system


STREAM_CHUNK = 4          # streamed-handoff chunk width for the bench
STREAM_PROMPT_LEN = 24    # long enough for several chunks per request
STREAM_RATE_RPS = 500.0


def stream_burst(n_requests: int = 10, seed: int = 11):
    """The canonical pipelined-handoff bench burst: one definition shared
    by the streamed and synchronous runs, so the TTFT split and the
    token-identity check provably compare the same stream."""
    from repro.serving.workload import poisson_requests

    cfg, _ = live_model()
    return poisson_requests(n_requests, STREAM_RATE_RPS, STREAM_PROMPT_LEN,
                            LIVE_MAX_NEW, cfg.vocab_size, seed=seed)


def live_stream_serve(*, streamed: bool, requests=None,
                      stream_chunk: int = STREAM_CHUNK,
                      decode_batch: int = 4):
    """Open-loop burst (default: :func:`stream_burst`) with the KV handoff
    either synchronous (whole-request, on the TTFT critical path) or
    pipelined (chunked streaming overlapped behind prefill compute);
    returns (results, scheduler). ``stream_handoff`` is control-plane, so
    both runs share one cached compiled system and flip the handoff mode
    via ``reconfigure_scheduler`` — the decode path is bit-identical by
    construction of the comparison, and the bench asserts it."""
    from repro.serving import SchedulerConfig, ServingSystem

    cfg, params = live_model()
    reqs = stream_burst() if requests is None else requests
    key = ("stream", decode_batch)
    system = _live_systems.get(key)
    if system is None:
        system = ServingSystem(
            params, cfg, n_prefill=2, decode_batch=decode_batch,
            capacity=STREAM_PROMPT_LEN + LIVE_MAX_NEW + 16)
        _live_systems[key] = system
    system.reconfigure_scheduler(
        SchedulerConfig(stream_handoff=streamed, stream_chunk=stream_chunk,
                        decode_cost=calibrated_decode_cost(LIVE_ARCH)))
    results = system.serve(reqs, open_loop=True)
    return results, system.scheduler


JOINT_TTFT_BUDGET_MS = 2.0
JOINT_TPOT_BUDGET_MS = 6.0


def joint_burst(seed: int = 3):
    """The canonical phase-skewed joint-autoscale burst: a prefill-heavy
    opening phase (long prompts, 2-token generations, tight arrivals)
    followed by a decode-heavy phase (short prompts, long generations), so
    a correct joint controller must shift an engine decode->prefill and
    then back."""
    import numpy as np

    from repro.serving import Request

    cfg, _ = live_model()
    rng = np.random.RandomState(seed)
    reqs = [Request(i, list(rng.randint(0, cfg.vocab_size, 48)), 2,
                    arrival=5e-4 * i) for i in range(8)]
    reqs += [Request(100 + i, list(rng.randint(0, cfg.vocab_size, 6)), 24,
                     arrival=0.15 + 2e-4 * i) for i in range(8)]
    return reqs


def live_joint_serve(*, joint: bool = True, requests=None,
                     decode_batch: int = 2):
    """The phase-skewed burst through a joint P/D-autoscaling system
    (1 prefill + 2 decode engines initially, clamps 1..3 per role);
    returns (results, scheduler, system). ``joint=False`` serves the
    identical stream with the roster fixed — the token-identity reference.
    Not cached: the controller mutates both engine rosters."""
    from repro.serving import SchedulerConfig, ServingSystem

    cfg, params = live_model()
    reqs = joint_burst() if requests is None else requests
    kw = dict(joint_autoscale=True, min_prefill=1, max_prefill=3,
              min_engines=1, max_engines=3,
              ttft_budget_ms=JOINT_TTFT_BUDGET_MS,
              tpot_budget_ms=JOINT_TPOT_BUDGET_MS,
              admission="queue") if joint else {}
    system = ServingSystem(
        params, cfg, prefill_engines=1, decode_batch=decode_batch,
        capacity=96, decode_engines=2, **kw)
    results = system.serve(reqs, open_loop=True)
    return results, system.scheduler, system


EMS_SESSIONS = 3
EMS_TURNS = 3


def live_ems_serve(*, n_sessions: int = EMS_SESSIONS, turns: int = EMS_TURNS,
                   hit_aware: bool = False, seed: int = 13,
                   decode_batch: int = 4, tpot_budget_ms=None):
    """Multi-turn session trace through a ServingSystem backed by the
    shared :class:`~repro.mempool.EMSService` tier with ``cache_affinity``
    routing; returns (results, scheduler, system, reqs). Not cached: the
    EMS hit-rate trajectory across turns (cold first turns, grown-prefix
    reuse on later ones) is exactly what callers measure, so every run
    starts from an empty tier. Utterance/reply lengths are clipped tight
    to bound the set of compiled prefill shapes at smoke scale."""
    from repro.mempool import EMSService, MemoryPool
    from repro.serving import SchedulerConfig, ServingSystem
    from repro.serving.workload import multi_turn_sessions

    cfg, params = live_model()
    reqs = multi_turn_sessions(
        n_sessions, seed=seed, vocab_size=cfg.vocab_size,
        session_rate_rps=200.0, turns=turns, turn_tokens_median=8,
        turn_tokens_sigma=0.4, turn_tokens_max=12,
        max_new_median=3, max_new_sigma=0.3, max_new_max=4)
    cap = max(len(r.prompt) + r.max_new_tokens for r in reqs) + 8
    ems = EMSService(MemoryPool(n_nodes=4), block_tokens=4,
                     model_tag=cfg.name)
    system = ServingSystem(
        params, cfg, n_prefill=2, decode_batch=decode_batch,
        capacity=cap, decode_engines=2, decode_router="cache_affinity",
        context_cache=ems, tpot_budget_ms=tpot_budget_ms,
        hit_aware_admission=True if hit_aware else None,
        scheduler_config=SchedulerConfig(
            decode_cost=calibrated_decode_cost(LIVE_ARCH)))
    results = system.serve(reqs, open_loop=True)
    return results, system.scheduler, system, reqs


def live_poisson_serve(*, rate_rps: float, tpot_budget_ms=None,
                       admission: str = "queue", n_requests: int = 16,
                       decode_batch: int = 4, max_new: int = LIVE_MAX_NEW,
                       seed: int = 0):
    """Open-loop Poisson wave through the cached live system — the
    admission gate under bursts. Returns (results, scheduler)."""
    from repro.serving import SchedulerConfig, ServingSystem
    from repro.serving.workload import poisson_requests

    cfg, params = live_model()
    reqs = poisson_requests(n_requests, rate_rps, LIVE_PROMPT_LEN, max_new,
                            cfg.vocab_size, seed=seed)
    key = (decode_batch, 1, max_new, False, False)
    system = _live_systems.get(key)
    if system is None:
        system = ServingSystem(
            params, cfg, n_prefill=2, decode_batch=decode_batch,
            capacity=LIVE_PROMPT_LEN + max_new + 16)
        _live_systems[key] = system
    system.reconfigure_scheduler(
        SchedulerConfig(tpot_budget_ms=tpot_budget_ms, admission=admission,
                        decode_cost=calibrated_decode_cost(LIVE_ARCH)))
    results = system.serve(reqs, open_loop=True)
    return results, system.scheduler
