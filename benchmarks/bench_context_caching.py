"""Paper Fig. 23: prefill throughput & TTFT vs token reuse rate, UB vs VPC.

Functional layer (``--smoke``): a multi-turn session trace through the live
:class:`~repro.mempool.EMSService` tier (ServingSystem + cache_affinity
routing) — hit rate growing across turns, promote/demote bytes over the
RDMA plane, TTFT split by hit depth, and the hit-aware admission demo (a
mostly-cached request admitted where the suffix-blind gate waits). The ems
section lands in BENCH_prefill.json (schema 9) for ``make bench-check``.

Quantitative layer: DeepSeek-R1-scale TTFT model — compute time for the
non-reused suffix (from the prefill dry-run roofline when one exists, the
scheduler's virtual prefill cost otherwise) + cache-fetch time for the
reused prefix over UB vs VPC plane constants.
"""
from __future__ import annotations

import argparse

from benchmarks.common import (EMS_TURNS, emit, ensure_dryrun,
                               live_ems_serve, step_time_from_record,
                               update_bench_artifact)
from repro.mempool.pool import UB_PLANE, VPC_PLANE

PROMPT = 4096
BATCH_TOKENS = 16384          # paper: 16K tokens per NPU batch
LATENT_BYTES_PER_TOK = 61 * (512 + 64) * 2   # deepseek-r1 latent KV
REUSE_RATES = (0.0, 0.125, 0.25, 0.5, 0.75, 0.9)
# Scheduler virtual prefill cost (s/token) — the analytic fallback when no
# compiled dry-run record exists in the container (CI smoke).
VIRTUAL_PER_TOK_COMPUTE = 2e-4


def _analytic_rows(per_tok_compute: float, derived: str) -> float:
    """UB vs VPC reuse sweep; returns the UB-vs-VPC TTFT gain at 90%
    reuse (the paper's headline plane comparison, Fig. 23a)."""
    ttft_at_90 = {}
    for plane, pname in ((UB_PLANE, "ub"), (VPC_PLANE, "vpc")):
        for r in REUSE_RATES:
            reused = int(PROMPT * r)
            fetch = plane.cost(reused * LATENT_BYTES_PER_TOK)
            compute = (PROMPT - reused) * per_tok_compute
            ttft = fetch + compute
            if r == 0.9:
                ttft_at_90[pname] = ttft
            # effective prefill throughput counts all prompt tokens
            tput = PROMPT / ttft
            emit("context_cache", f"{pname}_reuse{int(r*100)}_ttft_ms",
                 round(ttft * 1e3, 1), f"fetch_ms={fetch*1e3:.1f}")
            emit("context_cache", f"{pname}_reuse{int(r*100)}_speedup",
                 round(tput * per_tok_compute, 2), derived)
    emit("context_cache", "paper_ub_reuse90_speedup", 2.28, "Fig23a")
    emit("context_cache", "paper_ub_vs_vpc_gain", 1.52, "Fig23a")
    return ttft_at_90["vpc"] / ttft_at_90["ub"]


def _hit_aware_demo(system, reqs) -> dict:
    """The acceptance demo: at a cap-saturated gate (placeholder decode
    cost + 6 ms budget => cap 2, two residents), the suffix-blind gate
    holds the deepest-reuse session turn while the hit-aware gate admits
    it on its EMS-probed suffix charge."""
    from repro.serving.scheduler import AdmissionGate, DecodeCostModel

    ems = system.cc
    req = max(reqs, key=lambda r: ems.probe_prefix(r.prompt))
    probe = ems.probe_prefix(req.prompt)
    pt = len(req.prompt)
    charge = max(1.0 - min(probe, pt - 1) / pt, 1.0 / pt)
    cost = DecodeCostModel()            # placeholder: cap = 2 at 6 ms
    blind = AdmissionGate(cost, 6e-3, "queue").decide(2, True)
    aware = AdmissionGate(cost, 6e-3, "queue", hit_aware=True).decide(
        2, True, load=2 * charge, charge=charge)
    return {"probe_cached_tokens": int(probe), "prompt_tokens": pt,
            "suffix_charge": round(charge, 4),
            "suffix_blind_decision": blind, "hit_aware_decision": aware}


def _ems_section() -> dict:
    results, sched, system, reqs = live_ems_serve()
    ems = system.cc
    xfer = ems.transfer                 # the tier's own RDMA-plane books
    ems.flush()                         # drain the write-back queue
    stats = ems.ems_stats()
    served = sorted((r for r in results if not r.shed), key=lambda r: r.rid)
    by_turn = {t: [] for t in range(EMS_TURNS)}
    for r in served:
        prompt = len(next(q.prompt for q in reqs if q.rid == r.rid))
        by_turn[r.rid % EMS_TURNS].append(r.reused_tokens / max(1, prompt))
    hit_rate_by_turn = [round(sum(v) / max(1, len(v)), 4)
                        for _, v in sorted(by_turn.items())]
    buckets = {"cold": [], "partial": [], "deep": []}
    for r in served:
        tr = sched.traces[r.rid]
        frac = r.reused_tokens / max(1, tr.prompt_tokens)
        key = "cold" if frac == 0 else "partial" if frac < 0.5 else "deep"
        buckets[key].append(tr.ttft)
    ttft_by_hit_depth = {
        k: {"n": len(v),
            "ttft_ms": round(1e3 * sum(v) / len(v), 4) if v else None}
        for k, v in buckets.items()}
    return {
        "arch": system.cfg.name,
        "sessions": len(reqs) // EMS_TURNS, "turns": EMS_TURNS,
        "hit_rate_by_turn": hit_rate_by_turn,
        "hit_rate": stats["hit_rate"],
        "hbm_hits": stats["hbm_hits"], "pool_hits": stats["pool_hits"],
        "fetch_misses": stats["fetch_misses"],
        "dedup_skipped": stats["dedup_skipped"],
        "promote_bytes": stats["promote_bytes"],
        "demote_bytes": stats["demote_bytes"],
        "transfer_bytes_promoted": xfer.bytes_promoted,
        "transfer_bytes_demoted": xfer.bytes_demoted,
        "ttft_by_hit_depth": ttft_by_hit_depth,
        "hit_aware_admission": _hit_aware_demo(system, reqs),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="live EMS multi-turn run + BENCH_prefill ems "
                         "section (CI scale)")
    args = ap.parse_args()
    print("name,metric,value,derived")

    rec = None if args.smoke else ensure_dryrun("deepseek-r1", "prefill_32k")
    if rec is not None:
        tokens_total = 32 * 32768
        t_step = step_time_from_record(rec)
        per_tok = t_step * rec["n_devices"] / tokens_total  # s/token/chip
        gain = _analytic_rows(per_tok, "vs_no_cache")
    else:
        gain = _analytic_rows(VIRTUAL_PER_TOK_COMPUTE, "virtual_clock")
    emit("context_cache", "ub_vs_vpc_reuse90_gain", round(gain, 2),
         "model" if rec is not None else "virtual_clock")
    if not args.smoke:
        return

    ems = _ems_section()
    ems["ub_vs_vpc_reuse90_gain"] = round(gain, 2)
    for t, hr in enumerate(ems["hit_rate_by_turn"]):
        emit("ems", f"turn{t}_hit_rate", hr, "reused/prompt")
    emit("ems", "hit_rate", round(ems["hit_rate"], 4),
         f"hbm={ems['hbm_hits']} pool={ems['pool_hits']} "
         f"miss={ems['fetch_misses']}")
    emit("ems", "promote_bytes", ems["promote_bytes"], "pool->hbm")
    emit("ems", "demote_bytes", ems["demote_bytes"], "hbm->pool writeback")
    for k, row in ems["ttft_by_hit_depth"].items():
        if row["ttft_ms"] is not None:
            emit("ems", f"ttft_{k}_ms", row["ttft_ms"], f"n={row['n']}")
    demo = ems["hit_aware_admission"]
    emit("ems", "hit_aware_admission",
         f"{demo['suffix_blind_decision']}->{demo['hit_aware_decision']}",
         f"charge={demo['suffix_charge']}")
    path = update_bench_artifact("prefill", {"ems": ems}, schema=9)
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
