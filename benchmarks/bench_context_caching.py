"""Paper Fig. 23: prefill throughput & TTFT vs token reuse rate, UB vs VPC.

Functional layer: the real ContextCache + ServingSystem at smoke scale
verifies reuse mechanics (exactness is covered in tests). Quantitative
layer: DeepSeek-R1-scale TTFT model — compute time for the non-reused suffix
(from the prefill dry-run roofline) + cache-fetch time for the reused prefix
over UB vs VPC plane constants."""
from __future__ import annotations

from benchmarks.common import emit, ensure_dryrun, step_time_from_record
from repro.mempool.pool import UB_PLANE, VPC_PLANE

PROMPT = 4096
BATCH_TOKENS = 16384          # paper: 16K tokens per NPU batch
LATENT_BYTES_PER_TOK = 61 * (512 + 64) * 2   # deepseek-r1 latent KV
REUSE_RATES = (0.0, 0.125, 0.25, 0.5, 0.75, 0.9)


def main() -> None:
    print("name,metric,value,derived")
    rec = ensure_dryrun("deepseek-r1", "prefill_32k")
    if rec is None:
        emit("context_cache", "status", "NA", "dryrun_missing")
        return
    tokens_total = 32 * 32768
    t_step = step_time_from_record(rec)
    per_tok_compute = t_step * rec["n_devices"] / tokens_total  # s/token/chip

    base_ttft = PROMPT * per_tok_compute
    base_tput = 1.0 / per_tok_compute
    for plane, pname in ((UB_PLANE, "ub"), (VPC_PLANE, "vpc")):
        for r in REUSE_RATES:
            reused = int(PROMPT * r)
            fetch = plane.cost(reused * LATENT_BYTES_PER_TOK)
            compute = (PROMPT - reused) * per_tok_compute
            ttft = fetch + compute
            # effective prefill throughput counts all prompt tokens
            tput = PROMPT / ttft
            emit("context_cache", f"{pname}_reuse{int(r*100)}_ttft_ms",
                 round(ttft * 1e3, 1), f"fetch_ms={fetch*1e3:.1f}")
            emit("context_cache", f"{pname}_reuse{int(r*100)}_speedup",
                 round(tput * per_tok_compute, 2), "vs_no_cache")
    emit("context_cache", "paper_ub_reuse90_speedup", 2.28, "Fig23a")
    emit("context_cache", "paper_ub_vs_vpc_gain", 1.52, "Fig23a")


if __name__ == "__main__":
    main()
