"""MTP speculative decoding (paper §4.2.4) step by step, showing the greedy-
equivalence property and per-iteration acceptance.

    PYTHONPATH=src python examples/mtp_speculative.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core import init_mtp_params
from repro.core.mtp import mtp_step, propose_draft
from repro.models import decode_step, init_params, prefill

cfg = smoke_variant(get_config("qwen3-8b"))
params = init_params(jax.random.PRNGKey(0), cfg)
mtp = init_mtp_params(jax.random.PRNGKey(1), cfg)

prompt = list(np.random.RandomState(0).randint(0, cfg.vocab_size, 20))
N_NEW = 10

# --- reference: plain greedy decode -----------------------------------------
logits, caches = prefill(params, cfg, {"tokens": jnp.asarray([prompt])},
                         capacity=64, cache_dtype=jnp.float32)
ref = [int(jnp.argmax(logits[0, -1]))]
cl = jnp.int32(len(prompt))
for _ in range(N_NEW - 1):
    lg, caches = decode_step(params, cfg, jnp.asarray([[ref[-1]]]), caches, cl)
    ref.append(int(jnp.argmax(lg[0])))
    cl = cl + 1
print("plain greedy :", ref, f"({N_NEW} iterations)")

# --- MTP: draft + validate, 1+accept tokens per iteration -------------------
logits, caches = prefill(params, cfg, {"tokens": jnp.asarray([prompt])},
                         capacity=64, cache_dtype=jnp.float32)
x = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
d = propose_draft(params, mtp, cfg, x)
cl = jnp.full((1,), len(prompt), jnp.int32)
got, iters, accepts = [int(x[0])], 0, 0
key = jax.random.PRNGKey(2)
while len(got) < N_NEW:
    key, sub = jax.random.split(key)
    em, acc, x, d, caches, cl = mtp_step(params, mtp, cfg, x, d, caches, cl,
                                         sub, greedy=True)
    iters += 1
    got.append(int(em[0, 0]))
    if bool(acc[0]) and len(got) < N_NEW:
        got.append(int(em[0, 1]))
        accepts += 1
print("MTP greedy   :", got[:N_NEW], f"({iters} iterations, "
      f"{accepts} accepted drafts)")
assert got[:N_NEW] == ref, "speculative decoding must preserve greedy output"
print(f"tokens/iteration: {len(got[:N_NEW])/iters:.2f} "
      f"(untrained draft head; paper's trained MTP reaches ~1.7)")

# --- fused fast path: N scanned MTP iterations, one host sync ---------------
from repro.models.model import decode_loop_mtp  # noqa: E402

logits, caches = prefill(params, cfg, {"tokens": jnp.asarray([prompt])},
                         capacity=64, cache_dtype=jnp.float32)
x = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
d = propose_draft(params, mtp, cfg, x)
# n_iters = N_NEW - 1 guarantees enough iterations to emit the full run
# whatever the acceptance pattern; steps_left stops emission at N_NEW - 1.
em, acc, lv, *_ = decode_loop_mtp(
    params, mtp, cfg, x, d, caches, jnp.full((1,), len(prompt), jnp.int32),
    n_iters=N_NEW - 1, key=jax.random.PRNGKey(2), fused_verify=True,
    steps_left=jnp.full((1,), N_NEW - 1, jnp.int32))
fused = [int(x[0])]
for j in range(N_NEW - 1):
    if not bool(lv[0, j]):
        break
    fused.append(int(em[0, j, 0]))
    if bool(acc[0, j]) and len(fused) < N_NEW:
        fused.append(int(em[0, j, 1]))
print("fused scan   :", fused[:N_NEW],
      "(decode_loop_mtp: draft+verify+sample+accept all on-device,"
      " one host sync)")
assert fused[:N_NEW] == ref
