"""End-to-end training driver: a ~100M-param OLMoE-style MoE trained for a
few hundred steps on the synthetic packed corpus, with checkpointing.

Full-scale equivalent:
    python -m repro.launch.train --arch olmoe-1b-7b --full ...   (on TPU)

Here (CPU container): a 110M-param config, 300 steps, loss curve printed.

    PYTHONPATH=src python examples/train_moe_100m.py [--steps 300]
"""
import argparse
import dataclasses

import jax

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data import make_batch_iter
from repro.models import init_params
from repro.train import OptConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_moe_100m")
    args = ap.parse_args()

    # ~100M-param MoE in the OLMoE family (8 experts, top-2)
    cfg = dataclasses.replace(
        get_config("olmoe-1b-7b"),
        name="olmoe-100m", num_layers=4, d_model=256, num_heads=4,
        num_kv_heads=4, head_dim=64, d_ff=512, vocab_size=50304,
        num_experts=8, num_experts_per_tok=2, dtype="float32",
    )
    print(f"{cfg.name}: total={cfg.param_count()/1e6:.1f}M "
          f"active={cfg.param_count(True)/1e6:.1f}M")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batches = make_batch_iter(cfg.vocab_size, seq_len=128, global_batch=8,
                              seed=0)
    opt = OptConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps)
    params, hist = train(params, cfg, batches, args.steps, opt, log_every=20)
    assert hist[-1]["loss"] < hist[0]["loss"]
    save_checkpoint(args.ckpt, params, args.steps, meta={"arch": cfg.name})
    print(f"final loss {hist[-1]['loss']:.3f} "
          f"(from {hist[0]['loss']:.3f}); checkpoint at {args.ckpt}")


if __name__ == "__main__":
    main()
