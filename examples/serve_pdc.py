"""The paper's full serving pipeline on batched requests: PDC disaggregation
with EMS context caching, stateless scheduling, RDMA-plane KV handoff, and
continuous-batched decode (optionally MTP).

    PYTHONPATH=src python examples/serve_pdc.py [--mtp]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core import init_mtp_params
from repro.mempool import ContextCache, MemoryPool
from repro.models import init_params
from repro.serving import Request, ServingSystem


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mtp", action="store_true")
    ap.add_argument("--arch", default="qwen3-8b")
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    pool = MemoryPool(n_nodes=16)                      # disaggregated DRAM pool
    cc = ContextCache(pool, block_tokens=8, model_tag=cfg.name)
    mtp = init_mtp_params(jax.random.PRNGKey(1), cfg) if args.mtp else None

    # multi-turn style workload: shared system prefix + per-user suffixes
    rng = np.random.RandomState(0)
    system_prompt = list(rng.randint(0, cfg.vocab_size, 24))
    requests = [Request(i, system_prompt
                        + list(rng.randint(0, cfg.vocab_size, 8)), 6)
                for i in range(6)]

    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=3,
                           capacity=64, context_cache=cc,
                           use_mtp=args.mtp, mtp_params=mtp)
    results = system.serve(requests)

    print(f"{'rid':>3} {'inst':>4} {'reuse':>5} {'comp':>5} tokens")
    for r in sorted(results, key=lambda r: r.rid):
        print(f"{r.rid:>3} {r.prefill_instance:>4} {r.reused_tokens:>5} "
              f"{r.computed_tokens:>5} {r.tokens}")
    s = pool.stats()
    print(f"\npool: hit_rate={s['hit_rate']:.2f} "
          f"dram={s['dram_used']/2**20:.0f}MiB balance={s['load_balance']:.2f}")
    print(f"KV handoffs: {system.transfer.transfers} "
          f"({system.transfer.bytes_moved/2**20:.1f} MiB over RDMA plane)")
    comp = sum(r.computed_tokens for r in results)
    tot = sum(len(rq.prompt) for rq in requests)
    print(f"prefill compute saved by context cache: {100*(1-comp/tot):.0f}%")


if __name__ == "__main__":
    main()
