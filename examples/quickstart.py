"""Quickstart: the public API in ~60 lines.

Builds a reduced Qwen3, runs a forward pass, prefill+decode, LEP-style MoE
on OLMoE, and INT8 quantization — everything the paper's serving stack is
made of, at CPU smoke scale.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.models import decode_step, forward, init_params, prefill
from repro.quant import calibrate_linear, quantized_matmul

# --- 1. a dense GQA model (Qwen3 family, reduced) --------------------------
cfg = smoke_variant(get_config("qwen3-8b"))
params = init_params(jax.random.PRNGKey(0), cfg)
print(f"{cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
      f"params={sum(x.size for x in jax.tree.leaves(params))/1e6:.1f}M")

tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab_size)
logits, aux = forward(params, cfg, {"tokens": tokens})
print("forward:", logits.shape)

# --- 2. prefill + autoregressive decode (the serving path) -----------------
pl_logits, caches = prefill(params, cfg, {"tokens": tokens}, capacity=40,
                            cache_dtype=jnp.float32)
tok = jnp.argmax(pl_logits[:, -1], -1)[:, None].astype(jnp.int32)
out = [int(tok[0, 0])]
cache_len = jnp.int32(24)
for _ in range(8):
    dlogits, caches = decode_step(params, cfg, tok, caches, cache_len)
    tok = jnp.argmax(dlogits, -1)[:, None].astype(jnp.int32)
    out.append(int(tok[0, 0]))
    cache_len = cache_len + 1
print("greedy continuation:", out)

# --- 3. MoE with the paper's capacity-bounded dispatch ----------------------
moe_cfg = smoke_variant(get_config("olmoe-1b-7b"))
moe_params = init_params(jax.random.PRNGKey(2), moe_cfg)
ml, maux = forward(moe_params, moe_cfg,
                   {"tokens": tokens % moe_cfg.vocab_size})
print(f"MoE forward: {ml.shape}, aux loss {float(maux['aux_loss']):.3f}")

# --- 4. INT8 quantization (paper §4.5) --------------------------------------
w = jax.random.normal(jax.random.PRNGKey(3), (64, 32)) * 0.1
x = jax.random.normal(jax.random.PRNGKey(4), (16, 64))
ql = calibrate_linear(w, x)
err = jnp.linalg.norm(quantized_matmul(x, ql) - x @ w) / jnp.linalg.norm(x @ w)
print(f"INT8 linear rel-error: {float(err):.4f}")
print("quickstart OK")
