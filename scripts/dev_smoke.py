"""Dev sanity: every family forward + prefill/decode agreement on smoke configs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_configs, smoke_variant
from repro.models import decode_step, forward, init_params, lm_loss, prefill


def batch_for(cfg, b=2, s=16, key=None):
    key = key or jax.random.PRNGKey(0)
    batch = {}
    if cfg.frontend == "audio_frames":
        batch["frames"] = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.frontend == "vision_patches":
        p = cfg.num_prefix_embeddings
        batch["prefix_emb"] = jax.random.normal(key, (b, p, cfg.d_model), jnp.float32)
        batch["tokens"] = batch["tokens"][:, : s - p]
    batch["labels"] = jax.random.randint(jax.random.PRNGKey(1), (b, s if cfg.frontend != "vision_patches" else s - p), 0, cfg.vocab_size)
    return batch


def main():
    for name in list_configs():
        cfg = smoke_variant(get_config(name))
        key = jax.random.PRNGKey(42)
        params = init_params(key, cfg)
        n_par = sum(np.prod(x.shape) for x in jax.tree.leaves(params))
        b, s = 2, 16
        batch = batch_for(cfg, b, s)
        logits, aux = forward(params, cfg, batch)
        assert not bool(jnp.any(jnp.isnan(logits))), f"{name}: NaN logits"
        loss, metrics = lm_loss(params, cfg, batch)
        msg = f"{name:22s} params={n_par/1e6:6.2f}M fwd={logits.shape} loss={float(loss):.3f}"
        if cfg.supports_decode:
            pl_logits, caches = prefill(params, cfg, batch, capacity=s + 8,
                                        cache_dtype=jnp.float32)
            tok = jnp.argmax(pl_logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            dl, caches = decode_step(params, cfg, tok, caches, jnp.int32(s))
            assert not bool(jnp.any(jnp.isnan(dl))), f"{name}: NaN decode"
            # prefill logits at last pos should match forward logits
            np.testing.assert_allclose(np.asarray(pl_logits), np.asarray(logits),
                                       rtol=2e-3, atol=2e-3)
            msg += f" decode={dl.shape}"
        print(msg, flush=True)


if __name__ == "__main__":
    main()
