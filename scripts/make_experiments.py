"""Render EXPERIMENTS.md §Dry-run + §Roofline tables from dryrun JSONs.

Usage: PYTHONPATH=src python scripts/make_experiments.py > /tmp/tables.md
(The curated EXPERIMENTS.md embeds this output plus the §Perf log.)
"""
import glob
import json
import os
import sys

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def main():
    recs = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))

    for mesh in ("16x16", "2x16x16"):
        sub = [r for r in recs if r["mesh"] == mesh]
        if not sub:
            continue
        print(f"\n### Mesh {mesh} ({256 if mesh=='16x16' else 512} chips)\n")
        print("| arch | shape | status | dom | compute ms | memory ms "
              "| collective ms | HLO-mem ms | useful | args GiB/dev | temps GiB/dev |")
        print("|---|---|---|---|---:|---:|---:|---:|---:|---:|---:|")
        for r in sorted(sub, key=lambda r: (r["arch"], r["shape"])):
            if r["status"] == "skipped":
                print(f"| {r['arch']} | {r['shape']} | skipped — "
                      f"{r['reason'][:60]} | | | | | | | | |")
                continue
            if r["status"] != "ok":
                print(f"| {r['arch']} | {r['shape']} | ERROR "
                      f"{r.get('error','')[:60]} | | | | | | | | |")
                continue
            u = r.get("useful_ratio")
            print(f"| {r['arch']} | {r['shape']} | ok | {r['dominant']} "
                  f"| {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
                  f"| {r['collective_s']*1e3:.1f} "
                  f"| {r.get('memory_hlo_s', 0)*1e3:.0f} "
                  f"| {u and round(u,3)} "
                  f"| {fmt_bytes(r['argument_bytes'])} "
                  f"| {fmt_bytes(r['temp_bytes'])} |")

    # collective schedule summary
    print("\n### Collective mix (per-device bytes, 16x16)\n")
    print("| arch | shape | all-gather | all-reduce | reduce-scatter "
          "| all-to-all | permute | #ops |")
    print("|---|---|---:|---:|---:|---:|---:|---:|")
    for r in sorted((r for r in recs if r["mesh"] == "16x16"
                     and r["status"] == "ok"),
                    key=lambda r: (r["arch"], r["shape"])):
        c = r["collectives"]
        mb = lambda x: f"{x/2**20:.1f}M" if x else "0"
        print(f"| {r['arch']} | {r['shape']} | {mb(c['all-gather'])} "
              f"| {mb(c['all-reduce'])} | {mb(c['reduce-scatter'])} "
              f"| {mb(c['all-to-all'])} | {mb(c['collective-permute'])} "
              f"| {c['count']} |")


if __name__ == "__main__":
    main()
