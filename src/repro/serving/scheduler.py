"""SLO-aware PDC scheduling subsystem (paper §4.1, Table 5).

The paper's headline number is a *trade-off*: 538 tokens/s per NPU **under a
15 ms TPOT budget**, reached by independently scaling prefill, decode, and
caching pools and by sizing the decode batch to the SLO (Table 5: batch
96→24→8 for 50/30/15 ms). This module extracts every scheduling decision out
of ``serving/engine.py`` into small, separately testable pieces:

* :class:`PrefillRouter`      — pluggable prefill routing policy (by name:
  ``least_loaded``, ``round_robin``, ``queue_depth``). All are *stateless
  with respect to data placement* — no cache-affinity term, the paper's
  central contrast with KVCache-centric scheduling.
* :class:`DecodeSlotManager`  — owns decode slot allocation/eviction with
  per-request ``cache_len`` accounting; raises on double assignment or
  capacity overflow instead of silently corrupting batch state.
* :class:`AdmissionGate`      — projects the TPOT of the next decode batch
  from a linear step-time model (t(B) = t_fixed + B·t_per_req, the same
  decomposition ``bench_tpot_slo`` uses) and refuses admissions that would
  push projected TPOT over the configured budget. ``mode="queue"`` holds the
  request until the batch drains; ``mode="shed"`` rejects it immediately.
* :class:`SLOTracker`         — records per-request TTFT/TPOT and exposes
  p50/p99 summaries plus shed accounting.
* :class:`MicrobatchInterleaver` — pairs two decode microbatches through
  ``core/microbatch.py`` so one stream's MoE dispatch/combine communication
  can overlap the other's attention compute (paper §4.2.3).
* :class:`RequestTrace` / :class:`Scheduler` — a structured per-request
  trace (arrival, prefill start/end, transfer seconds, decode iterations and
  seconds) on a deterministic virtual timeline, consumable by benchmarks.

Time model
----------
CPU smoke runs are orders of magnitude off real NPU latencies, so SLO
decisions run on a *virtual* clock: prefill costs ``prefill_token_cost_s``
per **computed** token (EMS-reused prefix tokens are free — context caching
directly buys TTFT), KV handoff is charged by the RDMA-plane
:class:`~repro.serving.transfer.KVTransferEngine`, and each decode iteration
costs ``t_fixed + B·t_per_req`` for the currently active batch ``B``. The
timeline is deterministic given a request stream, which makes SLO behaviour
assertable in tests; on real hardware the same trace schema is stamped from
measured timestamps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.microbatch import microbatched


# ---------------------------------------------------------------------------
# Structured per-request trace
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RequestTrace:
    """Per-request lifecycle record on the scheduler's virtual timeline."""

    rid: int
    arrival: float = 0.0
    prompt_tokens: int = 0
    slo_class: str = "interactive"   # SLO tier: "interactive" | "batch"
    prefill_instance: int = -1
    prefill_start: float = 0.0
    prefill_end: float = 0.0
    reused_tokens: int = 0
    computed_tokens: int = 0
    cached_tokens: int = 0   # EMS hit-probe at enqueue (hit-aware admission)
    transfer_seconds: float = 0.0
    transfer_chunks: int = 0   # pipelined handoff: chunks shipped (0 = sync)
    overlap_seconds: float = 0.0   # transfer time hidden behind prefill
    decode_admit: float = 0.0
    decode_end: float = 0.0
    decode_iters: int = 0
    decode_tokens: int = 0   # committed decode tokens (MTP: 1+accepted/iter)
    masked_iters: int = 0    # device iterations burned while slot-resident
    #                          but masked (lv[i, j] false): dead slot time
    decode_seconds: float = 0.0
    decode_engine: int = -1  # pool engine currently decoding the request
    migrations: int = 0      # cross-engine KV migrations mid-decode
    migration_seconds: float = 0.0
    recoveries: int = 0      # engine-failure recoveries (replay re-prefill)
    tokens_replayed: int = 0  # already-emitted tokens teacher-forced back
    recovery_seconds: float = 0.0  # failure detection -> KV re-ready
    preemptions: int = 0     # batch-tier evictions under interactive pressure
    preempt_seconds: float = 0.0   # eviction -> replay KV re-ready
    tokens_out: int = 0
    shed: bool = False

    @property
    def ready_at(self) -> float:
        """When the first token + KV could reach the decode pool."""
        return self.prefill_end + self.transfer_seconds

    @property
    def ttft(self) -> float:
        """Time to first token: prefill completion + KV handoff — arrival."""
        return self.ready_at - self.arrival

    @property
    def tpot(self) -> float:
        """Mean time per output *token* over the decode residency.

        Per-token, not per-iteration: an MTP iteration that commits an
        accepted draft token counts twice in the denominator
        (``decode_tokens``, credited per decode iteration by the
        scheduler). Falls back to output tokens minus the prefill-produced
        first token, then to iterations, for traces recorded before the
        per-iteration credit existed.
        """
        denom = self.decode_tokens or (
            self.tokens_out - 1 if self.tokens_out > 1 else self.decode_iters)
        return self.decode_seconds / max(1, denom)

    @property
    def queue_seconds(self) -> float:
        """Time spent waiting between KV-ready and decode admission."""
        return max(0.0, self.decode_admit - self.ready_at)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d.update(ttft=self.ttft, tpot=self.tpot,
                 queue_seconds=self.queue_seconds)
        return d


# ---------------------------------------------------------------------------
# Prefill routing policies
# ---------------------------------------------------------------------------


class PrefillRouter:
    """Chooses a prefill instance for the next request.

    Policies see only instance-level load signals (live in-flight tokens
    plus the scheduler's virtual-backlog token equivalents) — never the
    request content or cache placement (the paper's peer-to-peer,
    locality-free scheduling property). ``select`` must be deterministic
    for a fixed request stream.
    """

    name = "base"

    def __init__(self, n_instances: int):
        if n_instances < 1:
            raise ValueError("need at least one prefill instance")
        self.n = n_instances

    def resize(self, n_instances: int) -> None:
        """The prefill pool spawned instances: ids ``[old_n, n_instances)``
        now exist. Instance ids never disappear (retired instances are
        parked, not removed — the same stable-id rule the decode pool
        enforces), so shrinking is an error."""
        if n_instances < self.n:
            raise ValueError(
                "prefill instance ids never disappear (retired instances "
                f"are parked, not removed): cannot resize {self.n} -> "
                f"{n_instances}")
        self.n = n_instances

    def _candidates(self,
                    candidates: Optional[Sequence[int]]) -> List[int]:
        cands = list(range(self.n)) if candidates is None else list(candidates)
        if not cands:
            raise ValueError("no live prefill instance to route to")
        return cands

    def select(self, loads: Sequence[float],
               candidates: Optional[Sequence[int]] = None) -> int:
        raise NotImplementedError

    def on_complete(self, instance: int) -> None:  # pragma: no cover - hook
        """Notification that a routed request finished its prefill."""


class LeastLoadedRouter(PrefillRouter):
    """Instance with the fewest in-flight prompt tokens (ties → lowest id)."""

    name = "least_loaded"

    def select(self, loads: Sequence[int],
               candidates: Optional[Sequence[int]] = None) -> int:
        return min(self._candidates(candidates), key=lambda i: (loads[i], i))


class RoundRobinRouter(PrefillRouter):
    """Cache-affinity-free cyclic assignment — the purest stateless policy.
    With parked instances the cycle runs over the live ids (first live id
    at or after the cursor)."""

    name = "round_robin"

    def __init__(self, n_instances: int):
        super().__init__(n_instances)
        self._next = 0

    def select(self, loads: Sequence[int],
               candidates: Optional[Sequence[int]] = None) -> int:
        cands = self._candidates(candidates)
        i = next((c for c in cands if c >= self._next), cands[0])
        self._next = (i + 1) % self.n
        return i


class QueueDepthRouter(PrefillRouter):
    """Fewest outstanding *requests* routed-but-not-finished (ties → id).

    Unlike ``least_loaded`` (token-weighted, instantaneous) this balances
    request counts across the routing horizon, which is the better signal
    when prompt lengths are uniform but completion is asynchronous. The
    scheduler reports completion when the request *finishes* (decode end or
    shed), so depth spans the whole PDC residency.
    """

    name = "queue_depth"

    def __init__(self, n_instances: int):
        super().__init__(n_instances)
        self.depth = [0] * n_instances

    def resize(self, n_instances: int) -> None:
        super().resize(n_instances)
        self.depth.extend([0] * (n_instances - len(self.depth)))

    def select(self, loads: Sequence[int],
               candidates: Optional[Sequence[int]] = None) -> int:
        i = min(self._candidates(candidates),
                key=lambda j: (self.depth[j], j))
        self.depth[i] += 1
        return i

    def on_complete(self, instance: int) -> None:
        self.depth[instance] -= 1


ROUTERS = {r.name: r for r in
           (LeastLoadedRouter, RoundRobinRouter, QueueDepthRouter)}


def make_router(policy: str, n_instances: int) -> PrefillRouter:
    try:
        return ROUTERS[policy](n_instances)
    except KeyError:
        raise ValueError(
            f"unknown prefill routing policy {policy!r}; "
            f"available: {sorted(ROUTERS)}") from None


# ---------------------------------------------------------------------------
# Decode slot management
# ---------------------------------------------------------------------------


class SlotError(RuntimeError):
    """Slot bookkeeping invariant violated (double assign / overflow)."""


@dataclasses.dataclass
class SlotInfo:
    rid: int
    cache_len: int
    payload: Any = None   # engine-side per-request state (result, remaining)


class DecodeSlotManager:
    """Owns decode slot allocation/eviction and per-request cache lengths.

    Invariants (enforced, not assumed):
      * a slot is never double-assigned;
      * ``cache_len`` never exceeds the engine's static KV capacity;
      * release of an empty slot is an error.
    """

    def __init__(self, n_slots: int, capacity: int):
        if n_slots < 1 or capacity < 1:
            raise ValueError("n_slots and capacity must be positive")
        self.n_slots = n_slots
        self.capacity = capacity
        self._slots: List[Optional[SlotInfo]] = [None] * n_slots
        # Lifetime conservation counters (pool invariant: acquired ==
        # released + active, per engine and summed across a pool).
        self.acquired = 0
        self.released = 0

    # -- queries -----------------------------------------------------------
    @property
    def active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def free(self) -> int:
        return self.n_slots - self.active

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def get(self, slot: int) -> Optional[SlotInfo]:
        return self._slots[slot]

    def active_slots(self) -> Iterator[Tuple[int, SlotInfo]]:
        for i, s in enumerate(self._slots):
            if s is not None:
                yield i, s

    # -- transitions -------------------------------------------------------
    def allocate(self, rid: int, cache_len: int, payload: Any = None,
                 slot: Optional[int] = None) -> int:
        """Claim a slot (lowest free index unless ``slot`` given)."""
        if slot is None:
            slot = self.free_slot()
            if slot is None:
                raise SlotError("no free decode slot")
        if self._slots[slot] is not None:
            raise SlotError(
                f"slot {slot} already holds rid={self._slots[slot].rid}")
        if cache_len > self.capacity:
            raise SlotError(
                f"rid={rid} needs cache_len={cache_len} > capacity="
                f"{self.capacity}")
        self._slots[slot] = SlotInfo(rid, cache_len, payload)
        self.acquired += 1
        return slot

    def advance(self, slot: int, n: int = 1) -> int:
        info = self._slots[slot]
        if info is None:
            raise SlotError(f"advance on empty slot {slot}")
        if info.cache_len + n > self.capacity:
            raise SlotError(
                f"rid={info.rid} cache_len {info.cache_len}+{n} would exceed "
                f"capacity {self.capacity}")
        info.cache_len += n
        return info.cache_len

    def release(self, slot: int) -> SlotInfo:
        info = self._slots[slot]
        if info is None:
            raise SlotError(f"release of empty slot {slot}")
        self._slots[slot] = None
        self.released += 1
        return info


# ---------------------------------------------------------------------------
# Decode step-time model + admission control
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DecodeCostModel:
    """t(B) = t_fixed + B · t_per_req — the Table 5 decomposition.

    ``t_fixed`` ≈ weight-read time (batch-invariant), ``t_per_req`` ≈ per-
    request KV-cache traffic. Defaults are paper-shaped placeholders tuned so
    the interesting SLO regimes (15–50 ms) exercise batch caps of a few to a
    few dozen requests at smoke scale.

    MTP speculative decoding adds an acceptance-rate term: each iteration
    costs ``mtp_iter_factor`` × the plain step (the base+draft verification
    shares one weight stream — paper Fig. 22b measures ~+44%) while
    emitting ``1 + mtp_accept`` tokens (paper α ≈ 0.70 for the trained
    draft head). ``step_time`` charges the per-iteration cost; the
    admission gate projects the *per-token* SLO from both terms.
    """

    #: paper Fig. 22b: ~44% per-iteration latency increase under MTP
    MTP_ITER_FACTOR = 1.44
    #: paper §5.4.2: single-token acceptance of the trained draft head
    MTP_ACCEPT = 0.70

    fixed_s: float = 4e-3
    per_req_s: float = 1e-3
    mtp_iter_factor: float = 1.0   # per-iteration latency multiplier
    mtp_accept: float = 0.0        # expected draft acceptance rate α

    def with_mtp(self, iter_factor: Optional[float] = None,
                 accept: Optional[float] = None) -> "DecodeCostModel":
        """This cost model under MTP speculative decoding (paper defaults,
        or a measured acceptance rate from the bench harness)."""
        return dataclasses.replace(
            self,
            mtp_iter_factor=self.MTP_ITER_FACTOR if iter_factor is None
            else iter_factor,
            mtp_accept=self.MTP_ACCEPT if accept is None else accept)

    @property
    def tokens_per_iter(self) -> float:
        return 1.0 + self.mtp_accept

    @classmethod
    def from_roofline(cls, step_s: float, batch_per_chip: float,
                      kv_read_s: float) -> "DecodeCostModel":
        """Calibrate t(B) = fixed + B·per_req from one roofline point.

        The per-request term is the per-request KV-cache read time (the only
        strictly batch-proportional HBM traffic at decode) and the fixed term
        absorbs the remainder (weight reads + collectives), floored at 20% of
        the recorded step so a KV-dominated record cannot degenerate to
        fixed≈0."""
        per = max(kv_read_s, 1e-9)
        fixed = max(step_s - batch_per_chip * per, 0.2 * step_s)
        return cls(fixed_s=fixed, per_req_s=per)

    def step_time(self, batch: int) -> float:
        """Cost of one decode *iteration* for the active batch."""
        return (self.fixed_s + batch * self.per_req_s) * self.mtp_iter_factor

    def token_time(self, batch: int) -> float:
        """Projected time per committed *token* (TPOT): iteration cost over
        the 1+α tokens an iteration is expected to emit."""
        return self.step_time(batch) / self.tokens_per_iter

    def max_batch_for(self, tpot_budget_s: float) -> int:
        """Largest batch whose projected per-token TPOT meets the budget
        (0 = none). Under MTP the budget buys more batch: the iteration is
        ``mtp_iter_factor`` slower but credits ``1+mtp_accept`` tokens.

        The float quotient is nudged before truncation so budgets that land
        exactly on a step time (t(B) == budget) admit batch B instead of
        B-1."""
        eff = tpot_budget_s * self.tokens_per_iter / self.mtp_iter_factor
        b = int((eff - self.fixed_s) / self.per_req_s + 1e-9)
        return max(0, b)


def decode_cost_from_roofline(record: Optional[Dict[str, Any]],
                              kv_bytes_per_req: float,
                              batch_per_chip: float,
                              hbm_bw: float = 819e9) -> DecodeCostModel:
    """DecodeCostModel calibrated from a compiled dry-run roofline record
    (``experiments/dryrun/*.json``) instead of placeholder defaults.

    ``record`` carries ``compute_s`` / ``memory_s`` / ``collective_s`` as
    written by ``launch/dryrun.py``; the serial roofline step time is
    ``max(compute, memory) + collective`` (same formula as
    ``benchmarks.common.step_time_from_record``). Falls back to the
    placeholder defaults when no record exists or the arch has no
    per-request KV traffic to decompose by."""
    if not record or kv_bytes_per_req <= 0 or batch_per_chip <= 0:
        return DecodeCostModel()
    step_s = max(record["compute_s"], record["memory_s"]) \
        + record["collective_s"]
    return DecodeCostModel.from_roofline(step_s, batch_per_chip,
                                         kv_bytes_per_req / hbm_bw)


class AdmissionGate:
    """Sheds or queues prefill→decode admissions that would break the SLO.

    With budget ``None`` the gate is wide open (slot-limited only). With a
    budget, admission keeps the active decode batch at or below the largest
    B with ``t(B) <= budget``; projected TPOT therefore never exceeds the
    budget for any admitted request.

    The gate is class-indexed: ``class_budgets``/``class_modes`` map an SLO
    class (e.g. ``"batch"``) to its own TPOT budget and queue/shed mode;
    classes without an entry fall back to the base budget/mode, so the
    default two-argument construction is exactly the pre-class gate. Batch
    step time is a property of the *whole* batch, not of the joining
    request, so the effective cap for an admission is the strictest cap
    over the joining class AND every class already resident on the target
    engine — a relaxed-budget batch request may not inflate the batch past
    what a co-resident interactive request's budget allows.

    With ``hit_aware=True`` (EMS hit-aware admission) the gate weighs each
    request by its *suffix* charge — the fraction of its prompt the EMS
    probe could not serve from cache — instead of a flat 1.0: the caller
    passes the summed resident ``load`` and the joining request's
    ``charge``, and admissibility becomes ``load + charge <= cap``. A
    mostly-cached request is nearly free, so it can join a batch the
    suffix-blind count-based gate would have held at the cap. With every
    charge at the default 1.0 the rule is exactly ``active < cap`` — the
    hit-aware gate degrades bit-identically to the blind one on cold
    traffic.
    """

    def __init__(self, cost: DecodeCostModel,
                 tpot_budget_s: Optional[float] = None,
                 mode: str = "queue", *,
                 class_budgets: Optional[Dict[str, Optional[float]]] = None,
                 class_modes: Optional[Dict[str, str]] = None,
                 hit_aware: bool = False):
        if mode not in ("queue", "shed"):
            raise ValueError(f"admission mode must be queue|shed, got {mode!r}")
        self.cost = cost
        self.budget_s = tpot_budget_s
        self.mode = mode
        self.hit_aware = hit_aware
        self.class_budgets = dict(class_budgets or {})
        self.class_modes = dict(class_modes or {})
        for cls, m in self.class_modes.items():
            if m not in ("queue", "shed"):
                raise ValueError(
                    f"admission mode for class {cls!r} must be queue|shed, "
                    f"got {m!r}")
        self.max_batch: Optional[int] = None
        if tpot_budget_s is not None:
            self.max_batch = cost.max_batch_for(tpot_budget_s)
            if self.max_batch == 0 and mode == "queue":
                raise ValueError(
                    f"TPOT budget {tpot_budget_s*1e3:.1f} ms is below the "
                    f"fixed decode cost {cost.fixed_s*1e3:.1f} ms — no batch "
                    "size can meet it (use mode='shed' to reject instead)")
        self.class_caps: Dict[str, Optional[int]] = {}
        for cls, budget in self.class_budgets.items():
            cap = None if budget is None else cost.max_batch_for(budget)
            if cap == 0 and self.mode_for(cls) == "queue":
                raise ValueError(
                    f"TPOT budget {budget*1e3:.1f} ms for class {cls!r} is "
                    f"below the fixed decode cost {cost.fixed_s*1e3:.1f} ms "
                    "— no batch size can meet it (use mode='shed' to reject "
                    "instead)")
            self.class_caps[cls] = cap

    def cap_for(self, slo_class: str = "interactive") -> Optional[int]:
        """Largest admissible batch for one class (None = slot-limited)."""
        if slo_class in self.class_caps:
            return self.class_caps[slo_class]
        return self.max_batch

    def mode_for(self, slo_class: str = "interactive") -> str:
        return self.class_modes.get(slo_class, self.mode)

    def admissible(self, active: int, slo_class: str = "interactive",
                   resident_classes: Sequence[str] = (), *,
                   load: Optional[float] = None,
                   charge: float = 1.0) -> bool:
        """May one more request join a batch currently ``active`` deep?

        Hit-aware gates compare ``load + charge`` (suffix-weighted
        occupancy) against the cap; ``load`` defaults to ``active`` so a
        caller that passes no EMS charges gets the blind rule exactly
        (``active + 1.0 <= cap`` ⇔ ``active < cap`` for integer caps)."""
        caps = [self.cap_for(c) for c in {slo_class, *resident_classes}]
        caps = [c for c in caps if c is not None]
        if not caps:
            return True
        cap = min(caps)
        if self.hit_aware:
            base = float(active) if load is None else load
            return base + charge <= cap + 1e-9
        return active < cap

    def decide(self, active: int, has_free_slot: bool,
               slo_class: str = "interactive",
               resident_classes: Sequence[str] = (),
               mode_override: Optional[str] = None, *,
               load: Optional[float] = None,
               charge: float = 1.0) -> str:
        """'admit' | 'wait' | 'shed' for the head-of-queue request.

        ``mode_override`` forces the queue/shed decision regardless of the
        class's configured mode (the brownout ladder sheds whole classes
        this way) — it does not widen admissibility, only what happens to
        an inadmissible request.
        """
        mode = mode_override if mode_override is not None \
            else self.mode_for(slo_class)
        if mode == "shed" and mode_override is not None:
            # Brownout-level shed rejects the class outright: a browned-out
            # class must not trickle in through free slots.
            return "shed"
        if not has_free_slot:
            return "wait"
        if self.admissible(active, slo_class, resident_classes,
                           load=load, charge=charge):
            return "admit"
        return "shed" if mode == "shed" else "wait"


# ---------------------------------------------------------------------------
# SLO tracking
# ---------------------------------------------------------------------------


class SLOTracker:
    """Aggregates finished (and shed) request traces into SLO statistics."""

    def __init__(self) -> None:
        self.finished: List[RequestTrace] = []
        self.shed: List[RequestTrace] = []

    def record(self, trace: RequestTrace) -> None:
        (self.shed if trace.shed else self.finished).append(trace)

    @staticmethod
    def _pct(values: List[float], q: float) -> float:
        if not values:
            return float("nan")
        return float(np.percentile(np.asarray(values), q))

    def _stats(self, finished: List[RequestTrace],
               shed: List[RequestTrace]) -> Dict[str, float]:
        ttfts = [t.ttft for t in finished]
        tpots = [t.tpot for t in finished if t.decode_iters > 0]
        # Queue statistics span finished AND shed traces: a request that
        # queued long and was then shed is exactly the queueing pressure
        # the percentile must not hide (shed traces stamp their queue time
        # at the shed instant).
        queues = [t.queue_seconds for t in finished + shed]
        return {
            "completed": len(finished),
            "shed": len(shed),
            "ttft_p50_s": self._pct(ttfts, 50),
            "ttft_p99_s": self._pct(ttfts, 99),
            "tpot_p50_s": self._pct(tpots, 50),
            "tpot_p99_s": self._pct(tpots, 99),
            "tpot_max_s": max(tpots) if tpots else float("nan"),
            "queue_p99_s": self._pct(queues, 99),
            "queue_p99_shed_s": self._pct([t.queue_seconds
                                           for t in shed], 99),
        }

    def summary(self) -> Dict[str, float]:
        s = self._stats(self.finished, self.shed)
        # Per-class breakdown only when the wave actually carried more than
        # the default class: single-class summaries stay flat (and older
        # consumers that iterate the summary see no nested dict).
        classes = sorted({t.slo_class for t in self.finished + self.shed})
        if classes and classes != ["interactive"]:
            s["classes"] = {
                cls: self._stats(
                    [t for t in self.finished if t.slo_class == cls],
                    [t for t in self.shed if t.slo_class == cls])
                for cls in classes}
        return s


# ---------------------------------------------------------------------------
# Microbatch interleaving (decode two-stream pipeline, paper §4.2.3)
# ---------------------------------------------------------------------------


class MicrobatchInterleaver:
    """Pairs decode microbatches through :func:`core.microbatch.microbatched`.

    Wraps a ``(tokens(B,1), caches, cache_len(B,)) -> (logits, caches)`` step
    into ``n_micro`` data-independent half-batch computations inside one
    jitted step, so XLA's latency-hiding scheduler may overlap µb0's MoE
    dispatch/combine collectives with µb1's attention compute. ``cache_len``
    rides in the token bundle so it is split along batch like the rest.
    """

    def __init__(self, n_micro: int = 2):
        if n_micro < 1:
            raise ValueError("n_micro must be >= 1")
        self.n_micro = n_micro

    def applicable(self, batch: int) -> bool:
        return self.n_micro > 1 and batch % self.n_micro == 0

    def wrap(self, step_fn: Callable, batch: int) -> Callable:
        if not self.applicable(batch):
            return step_fn

        def core(bundle, caches):
            return step_fn(bundle["tok"], caches, bundle["len"])

        mb = microbatched(core, self.n_micro)

        def wrapped(tokens, caches, cache_len):
            return mb({"tok": tokens, "len": cache_len}, caches)

        return wrapped


# ---------------------------------------------------------------------------
# Brownout ladder (deterministic overload degradation)
# ---------------------------------------------------------------------------


class BrownoutLadder:
    """Deterministic overload ladder the scheduler climbs under sustained
    interactive pressure, one rung per ``patience`` consecutive pressured
    turns, and descends one rung per ``cooldown`` consecutive calm turns:

      level 0  healthy — class budgets/modes as configured
      level 1  shed new batch-tier admissions
      level 2  ... and preempt batch-tier decode slots for interactive
      level 3  ... and queue-age-shed queued batch older than the brownout
               threshold
      level 4  ... and shed interactive admissions too (last resort)

    Pure hysteresis state machine on the virtual clock — no randomness, so
    identical pressure sequences produce identical ladders.
    """

    MAX_LEVEL = 4

    def __init__(self, patience: int = 2, cooldown: int = 2):
        if patience < 1 or cooldown < 1:
            raise ValueError("brownout patience/cooldown must be >= 1")
        self.patience = patience
        self.cooldown = cooldown
        self.level = 0
        self._pressured_turns = 0
        self._calm_turns = 0

    def observe(self, pressured: bool) -> Optional[Dict[str, int]]:
        """Feed one turn's pressure signal; returns a transition event
        ``{"from": .., "to": ..}`` when the level changes, else None."""
        if pressured:
            self._pressured_turns += 1
            self._calm_turns = 0
            if (self._pressured_turns >= self.patience
                    and self.level < self.MAX_LEVEL):
                self._pressured_turns = 0
                self.level += 1
                return {"from": self.level - 1, "to": self.level}
        else:
            self._calm_turns += 1
            self._pressured_turns = 0
            if self._calm_turns >= self.cooldown and self.level > 0:
                self._calm_turns = 0
                self.level -= 1
                return {"from": self.level + 1, "to": self.level}
        return None


# ---------------------------------------------------------------------------
# Scheduler: composition + virtual timeline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SchedulerConfig:
    policy: str = "least_loaded"
    tpot_budget_ms: Optional[float] = None
    admission: str = "queue"                 # "queue" | "shed"
    prefill_token_cost_s: float = 2e-4
    # Pipelined chunked KV streaming (peer-to-peer PDC handoff): each
    # prefill chunk's KV blocks ship over the RDMA plane while the next
    # chunk computes, so TTFT charges max(prefill, transfer) + the last
    # chunk's wire time instead of prefill + transfer. Token-identical to
    # the synchronous handoff (the decode-side cache is rebuilt from the
    # streamed chunks); archs whose caches are not token-sliceable (SSM /
    # hybrid) fall back to the synchronous path. stream_chunk is the chunk
    # width in tokens (None = 8).
    stream_handoff: bool = False
    stream_chunk: Optional[int] = None
    decode_cost: DecodeCostModel = dataclasses.field(
        default_factory=DecodeCostModel)
    interleave_microbatches: bool = False
    n_micro: int = 2
    # Decode iterations per host sync (model.decode_loop scan length).
    # 1 = per-step decode; >1 trades admission/trace granularity (requests
    # join and the clock is reconciled only at chunk boundaries) for host
    # round-trips amortized over `decode_chunk` tokens.
    decode_chunk: int = 1
    # Continuous batching on the chunked fast path: before each device
    # dispatch the engine shrinks the effective scan width (to a pre-jitted
    # width <= decode_chunk) when min(remaining) across active slots is
    # below the chunk or a gate-held admission could land in a free slot,
    # and the serve loop refills freed slots immediately after each
    # engine's chunk drains (mid-scan refill) instead of once per wave
    # boundary. Token-identical to the wave-shaped loop; admissions land
    # strictly earlier. Control-plane only (no re-jit), so it may be
    # flipped between waves via reconfigure_scheduler.
    continuous_batching: bool = False
    # MTP speculative decoding: charge the virtual clock the paper's ~1.44x
    # per-iteration verification cost while the admission gate credits
    # 1+accept tokens per iteration (a decode_cost with explicit MTP terms
    # overrides the paper defaults).
    use_mtp: bool = False
    # Decode-pool routing policy (serving/pool.py registry). Unlike the
    # prefill policy this MAY be cache-affine: the UB plane makes any
    # engine reachable from the shared KV store, so routing to the engine
    # already holding a request's reusable prefix blocks is pure win.
    decode_policy: str = "least_loaded_slots"
    # When > 0, every N pool decode turns the hottest engine drains one
    # slot's KV to the coldest (cross-engine migration over the RDMA
    # plane) if the active-slot imbalance is >= 2. 0 disables rebalancing.
    decode_rebalance_every: int = 0
    # Decode-pool autoscaling (serving/pool.py PoolAutoscaler): between
    # decode turns a deterministic controller grows the pool (engine spawn)
    # when demand exceeds what the live engines can carry at the SLO batch
    # cap, and shrinks it (migration-backed retirement) when N-1 engines
    # could absorb the load. min/max clamp the live engine count; the
    # patience/cooldown knobs are the hysteresis (turns a condition must
    # hold / turns to sit out after any scale event).
    autoscale: bool = False
    min_engines: int = 1
    max_engines: int = 4
    autoscale_grow_patience: int = 1
    autoscale_shrink_patience: int = 3
    autoscale_cooldown: int = 2
    # Joint P/D autoscaling (serving/pool.py JointAutoscaler): a capacity-
    # conserving controller that SHIFTS engines between the prefill and
    # decode roles under one SLO budget — TTFT pressure (virtual prefill
    # backlog past ttft_budget_ms) moves a drained decode engine into the
    # prefill pool, TPOT pressure (decode demand past the per-engine SLO
    # batch cap) moves an idle prefill instance into the decode pool.
    # min/max_prefill clamp the prefill roster the same way min/max_engines
    # clamp decode; patience/cooldown are per-direction hysteresis.
    joint_autoscale: bool = False
    min_prefill: int = 1
    max_prefill: int = 4
    ttft_budget_ms: Optional[float] = None
    joint_patience: int = 1
    joint_cooldown: int = 2
    # Graceful degradation under capacity loss: when set, a queued (not
    # yet admitted) request whose wait since KV-ready exceeds this many
    # virtual seconds is shed even in queue mode — after an engine failure
    # the shrunken pool sheds its backlog instead of growing an unbounded
    # queue. None keeps queue mode unconditional (the pre-fault behavior).
    # Class-ordered: at equal queue age, batch-tier backlog sheds before
    # any interactive request does.
    degrade_shed_queue_s: Optional[float] = None
    # --- SLO classes (overload control) -----------------------------------
    # Batch-tier overrides for the admission gate. tpot_budget_ms/admission
    # above are the base (interactive) budget/mode; None here means the
    # batch tier shares them (the pre-class behavior). A relaxed batch
    # budget lets batch fill deep batches on its own, but the gate still
    # caps any batch that an interactive request is resident in at the
    # interactive cap (see AdmissionGate).
    batch_tpot_budget_ms: Optional[float] = None
    batch_admission: Optional[str] = None    # "queue" | "shed" | None=base
    # Preempt batch-tier decode slots when a gate-ready interactive request
    # would otherwise wait: the youngest batch slot is evicted (KV parked
    # as prompt + emitted tokens), replay re-prefilled, and re-admitted
    # later — token-identical to the unpreempted run, latency charged to
    # the victim's trace (preempt_seconds).
    preempt_batch: bool = False
    # Brownout ladder: under sustained overload the scheduler climbs a
    # deterministic degradation ladder (shed batch admissions → preempt
    # batch → queue-age-shed batch → shed interactive); transitions are
    # recorded as trace events. Patience/cooldown are the hysteresis in
    # decode turns; brownout_queue_age_s is the level-3 batch queue-age
    # shed threshold.
    brownout: bool = False
    brownout_patience: int = 2
    brownout_cooldown: int = 2
    brownout_queue_age_s: float = 0.05
    # EMS hit-aware admission: charge the gate only the *suffix* cost of a
    # request — (prompt − cached) / prompt, from the EMS match_prefix probe
    # stamped on the trace at enqueue (cached_tokens) — and weigh resident
    # requests the same way. A mostly-cached request is nearly free, so it
    # can join a batch a suffix-blind gate would hold at the cap. Composes
    # with SLO classes (strictest cap still wins) and brownout (overrides
    # still short-circuit). Off = bit-identical to the blind gate.
    hit_aware_admission: bool = False


class Scheduler:
    """Control plane for the PDC serving loop.

    Owns the router, admission gate, SLO tracker, and the virtual timeline;
    the :class:`~repro.serving.engine.ServingSystem` calls the ``on_*`` hooks
    as requests move through prefill → transfer → decode and reads decisions
    back. Compute stays in the engines; every *decision* lives here.
    """

    def __init__(self, n_prefill: int, slot_mgr, config: Optional[SchedulerConfig] = None):
        """``slot_mgr`` is one :class:`DecodeSlotManager` (single decode
        engine) or a sequence of them (one per decode-pool engine); every
        engine gets its own virtual clock and admission view, reconciled
        into a single tracker/trace."""
        self.config = config or SchedulerConfig()
        self.n_prefill = n_prefill
        if isinstance(slot_mgr, DecodeSlotManager):
            self.slot_mgrs = [slot_mgr]
        else:
            self.slot_mgrs = list(slot_mgr)
            if not self.slot_mgrs:
                raise ValueError("need at least one decode slot manager")
        self.slot_mgr = self.slot_mgrs[0]      # single-engine compatibility
        self.n_decode = len(self.slot_mgrs)
        # Liveness mask over decode engines (autoscaling parks retired
        # engines in place). Persists across epochs — engine lifecycle is
        # pool state, not per-wave state. Prefill instances get the same
        # treatment (the joint autoscaler parks/revives them mid-wave).
        self._live = [True] * self.n_decode
        self._prefill_live = [True] * n_prefill
        cost = self.config.decode_cost
        if (self.config.use_mtp and cost.mtp_iter_factor == 1.0
                and cost.mtp_accept == 0.0):
            cost = cost.with_mtp()      # paper defaults unless calibrated
        self.cost = cost
        budget_s = (None if self.config.tpot_budget_ms is None
                    else self.config.tpot_budget_ms * 1e-3)
        self.gate = AdmissionGate(self.cost, budget_s, self.config.admission,
                                  class_budgets=self._class_budgets(),
                                  class_modes=self._class_modes(),
                                  hit_aware=self.config.hit_aware_admission)
        self.begin_epoch()

    def _class_budgets(self) -> Optional[Dict[str, Optional[float]]]:
        if self.config.batch_tpot_budget_ms is None:
            return None
        return {"batch": self.config.batch_tpot_budget_ms * 1e-3}

    def _class_modes(self) -> Optional[Dict[str, str]]:
        if self.config.batch_admission is None:
            return None
        return {"batch": self.config.batch_admission}

    def begin_epoch(self) -> None:
        """Start a fresh scheduling epoch (one ``serve()`` call).

        Router state, traces, SLO statistics, and the virtual timeline are
        all per-epoch, so a ServingSystem can serve successive request waves
        (rids may repeat across waves); ``summary()``/``trace_records()``
        reflect the most recent wave.
        """
        self.router = make_router(self.config.policy, self.n_prefill)
        self.tracker = SLOTracker()
        self.traces: Dict[int, RequestTrace] = {}
        self._instance_free_at = [0.0] * self.n_prefill
        # Token-weighted in-flight prefill load, committed at routing time
        # and released on EVERY completion path (decode finish, prefill-only
        # finish, gate shed, fault loss → recovery → finish/shed). Keyed by
        # rid so a release is idempotent — the pre-fix accounting leaked
        # the load of shed/faulted requests and skewed least_loaded routing
        # toward instances that never served them.
        self._prefill_inflight = [0.0] * self.n_prefill
        self._routed_load: Dict[int, Tuple[int, int]] = {}
        # One virtual clock per decode engine (engines step concurrently in
        # reality; each clock advances by its own batch's step cost).
        self._decode_now = [0.0] * self.n_decode
        self.decode_busy = 0.0      # sum of step costs (excludes idle gaps)
        self.decode_steps = 0
        self.decode_token_count = 0
        self._eng_busy = [0.0] * self.n_decode
        self._eng_steps = [0] * self.n_decode
        self._eng_tokens = [0] * self.n_decode
        # Dead-slot observability: slot-iterations that did work vs slot-
        # iterations burned masked (resident at dispatch, lv false), plus
        # the number of admissions that landed mid-scan (continuous
        # batching refills between engine chunks within one decode turn).
        self.live_slot_iters = 0
        self.masked_slot_iters = 0
        self._eng_masked = [0] * self.n_decode
        self.mid_scan_refills = 0
        self.migrations = 0
        self.migration_seconds = 0.0
        # Autoscale bookkeeping: scale events + the live-engine-count
        # timeline, both on the virtual clock (per-epoch like the trace).
        self.scale_events: List[Dict[str, Any]] = []
        self.engine_count_timeline: List[Tuple[float, int]] = [
            (0.0, sum(self._live))]
        self.prefill_count_timeline: List[Tuple[float, int]] = [
            (0.0, sum(self._prefill_live))]
        # Pipelined-handoff observability (per-epoch): chunks streamed,
        # transfer seconds hidden behind prefill, bytes on the wire, and
        # the largest single chunk in flight.
        self.stream_requests = 0
        self.stream_chunks = 0
        self.stream_overlap_s = 0.0
        self.stream_bytes = 0
        self.stream_max_chunk_bytes = 0
        # Fault-tolerance bookkeeping (per-epoch like everything above).
        # _slowdown persists per-engine straggler factors only within the
        # epoch; the injector re-asserts them every turn anyway.
        self._slowdown = [1.0] * self.n_decode
        self.engine_failures = 0
        self.recoveries = 0
        self.tokens_replayed = 0
        self.recovery_ttfts: List[float] = []
        # SLO-class overload control (per-epoch like the trace): preemption
        # totals plus the brownout ladder and its transition event log.
        self.preemptions = 0
        self.preempt_tokens_replayed = 0
        self.preempt_latencies: List[float] = []
        self._ladder = (BrownoutLadder(self.config.brownout_patience,
                                       self.config.brownout_cooldown)
                        if self.config.brownout else None)
        self.brownout_events: List[Dict[str, Any]] = []
        # RDMA-plane retry counters, synced from the KVTransferEngine by
        # the ServingSystem (the transfer engine's counters are lifetime,
        # the summary's are per-epoch deltas).
        self.transfer_retries = 0
        self.transfer_timeouts = 0
        self.transfer_corruptions = 0

    @property
    def decode_now(self) -> float:
        """Pool frontier: the earliest virtual time any *live* decode
        engine can take new work (single-engine: the engine clock). Parked
        engines' stale clocks must not drag the frontier backwards."""
        clocks = [c for c, live in zip(self._decode_now, self._live) if live]
        return min(clocks) if clocks else min(self._decode_now)

    # -- prefill side ------------------------------------------------------
    def on_arrival(self, rid: int, arrival: float, prompt_tokens: int,
                   slo_class: str = "interactive") -> RequestTrace:
        if rid in self.traces:
            raise ValueError(f"duplicate rid {rid}")
        tr = RequestTrace(rid=rid, arrival=arrival,
                          prompt_tokens=prompt_tokens, slo_class=slo_class)
        self.traces[rid] = tr
        return tr

    def route_prefill(self, trace: RequestTrace, loads: Sequence[int],
                      candidates: Optional[Sequence[int]] = None) -> int:
        """Pick a prefill instance for ``trace``.

        Live engine loads are augmented with each instance's *virtual*
        backlog (queued prefill seconds not yet elapsed at the request's
        arrival, in prompt-token equivalents) plus the scheduler-held
        token-weighted in-flight load (requests routed but not yet finished
        or shed) — in the sequential CPU model live loads are always zero
        by the time the decision is made, so the virtual signals are what
        actually spread load across instances. ``candidates`` restricts
        routing to the live roster (parked/failed instances excluded);
        omitted means every live instance.
        """
        cost = self.config.prefill_token_cost_s
        backlog = [max(0.0, free - trace.arrival) / cost
                   for free in self._instance_free_at]
        effective = [loads[i] + backlog[i] + self._prefill_inflight[i]
                     for i in range(len(loads))]
        if candidates is None:
            candidates = self.live_prefill_ids
        i = self.router.select(effective, candidates=candidates)
        # Commit the token-weighted load; released via _release_prefill on
        # every terminal path (finish / shed / prefill-only).
        self._prefill_inflight[i] += trace.prompt_tokens
        self._routed_load[trace.rid] = (i, trace.prompt_tokens)
        return i

    def _release_prefill(self, rid: int) -> None:
        """Release a routed request's token-weighted in-flight load.
        Idempotent (keyed by rid), so a request that is shed after a fault
        recovery cannot double-decrement."""
        entry = self._routed_load.pop(rid, None)
        if entry is not None:
            instance, tokens = entry
            self._prefill_inflight[instance] -= tokens

    @property
    def prefill_inflight_tokens(self) -> List[float]:
        """Per-instance token-weighted in-flight routed load (the
        least_loaded signal; must return to all-zero when a wave drains)."""
        return list(self._prefill_inflight)

    @property
    def live_prefill_ids(self) -> List[int]:
        return [i for i, live in enumerate(self._prefill_live) if live]

    def on_prefill_done(self, trace: RequestTrace, instance: int,
                        computed_tokens: int, reused_tokens: int) -> None:
        start = max(trace.arrival, self._instance_free_at[instance])
        dur = computed_tokens * self.config.prefill_token_cost_s
        trace.prefill_instance = instance
        trace.prefill_start = start
        trace.prefill_end = start + dur
        trace.computed_tokens = computed_tokens
        trace.reused_tokens = reused_tokens
        self._instance_free_at[instance] = trace.prefill_end

    def on_transfer(self, trace: RequestTrace, seconds: float) -> None:
        trace.transfer_seconds = seconds

    def on_stream_transfer(self, trace: RequestTrace, seconds: float,
                           chunks: int, overlap_s: float, nbytes: int,
                           max_chunk_bytes: int) -> None:
        """Pipelined chunked handoff: ``seconds`` is the tail of the
        transfer pipeline past prefill completion (the only part TTFT
        still pays — ``ready_at`` stays ``prefill_end + transfer_seconds``)
        and ``overlap_s`` the wire time hidden behind prefill compute."""
        trace.transfer_seconds = seconds
        trace.transfer_chunks = chunks
        trace.overlap_seconds = overlap_s
        self.stream_requests += 1
        self.stream_chunks += chunks
        self.stream_overlap_s += overlap_s
        self.stream_bytes += nbytes
        self.stream_max_chunk_bytes = max(self.stream_max_chunk_bytes,
                                          max_chunk_bytes)

    # -- decode side -------------------------------------------------------
    def admission_decision(self, trace: RequestTrace, engine: int = 0,
                           recovered: bool = False) -> str:
        """Gate decision against one engine's batch: projected TPOT depends
        on the batch the request would *join*, which under a pool is the
        target engine's, not the pool-wide count. The decision is class-
        indexed: the strictest cap over the joining class and the classes
        already resident on the engine applies, and the brownout ladder may
        override the class's queue/shed mode. Recovered/preempted
        re-admissions bypass the brownout override (never its caps): they
        already streamed tokens, so shedding them would break replay token
        identity — and a browned-out ladder must not deadlock on them."""
        mgr = self.slot_mgrs[engine]
        resident = {self.traces[info.rid].slo_class
                    for _, info in mgr.active_slots()
                    if info.rid in self.traces}
        override = None if recovered \
            else self.brownout_mode_override(trace.slo_class)
        load = charge = None
        if self.config.hit_aware_admission:
            charge = self.suffix_charge(trace)
            load = sum(self.suffix_charge(self.traces[info.rid])
                       for _, info in mgr.active_slots()
                       if info.rid in self.traces)
        return self.gate.decide(mgr.active, mgr.free > 0, trace.slo_class,
                                resident_classes=resident,
                                mode_override=override,
                                load=load,
                                charge=1.0 if charge is None else charge)

    def suffix_charge(self, trace: RequestTrace) -> float:
        """Hit-aware admission weight: the fraction of the prompt the EMS
        could not serve — ``(prompt − cached) / prompt`` — floored at one
        token's worth (even a fully-cached request recomputes its last
        token and occupies a decode slot). Uses the measured reuse once
        prefill ran, else the enqueue-time probe."""
        pt = max(1, trace.prompt_tokens)
        cached = min(max(trace.reused_tokens, trace.cached_tokens), pt - 1)
        return max(1.0 - cached / pt, 1.0 / pt)

    # -- SLO-class overload control ----------------------------------------
    @property
    def brownout_level(self) -> int:
        """Current brownout ladder rung (0 when brownout is off)."""
        return self._ladder.level if self._ladder is not None else 0

    def brownout_mode_override(self, slo_class: str) -> Optional[str]:
        """Forced admission mode for a class at the current brownout level
        (level >= 1 sheds batch admissions, level >= 4 sheds interactive
        too), or None when the configured mode applies."""
        lvl = self.brownout_level
        if lvl >= 1 and slo_class == "batch":
            return "shed"
        if lvl >= 4 and slo_class == "interactive":
            return "shed"
        return None

    @property
    def preemption_enabled(self) -> bool:
        """Batch-tier preemption is on when configured explicitly or when
        the brownout ladder has climbed to its preemption rung."""
        return self.config.preempt_batch or self.brownout_level >= 2

    def note_overload(self, pressured: bool) -> None:
        """Feed the brownout ladder one decode turn's pressure signal
        (``pressured`` = a gate-ready interactive request is still blocked
        after admission ran). Transitions are stamped on the virtual clock
        and recorded as trace events."""
        if self._ladder is None:
            return
        ev = self._ladder.observe(pressured)
        if ev is not None:
            self.brownout_events.append(
                {"t": self.decode_now, "from": ev["from"], "to": ev["to"]})

    def on_preempt(self, trace: RequestTrace, at: float,
                   tokens_replayed: int, ready_at: float) -> None:
        """A batch-tier request was evicted mid-decode for interactive
        pressure and rebuilt by replay re-prefill; it re-enters the
        admission queue at ``ready_at``. The latency is charged to the
        trace (``preempt_seconds``), separate from decode/recovery time —
        TPOT keeps meaning pure decode residency."""
        dt = ready_at - at
        trace.preemptions += 1
        trace.preempt_seconds += dt
        self.preemptions += 1
        self.preempt_tokens_replayed += tokens_replayed
        self.preempt_latencies.append(dt)

    def on_admit(self, trace: RequestTrace, slot: int, engine: int = 0) -> None:
        trace.decode_admit = max(self._decode_now[engine], trace.ready_at)
        trace.decode_engine = engine
        # Decode idles until the admitted KV arrives; without this bump a
        # long prefill could yield decode_end < decode_admit in the trace.
        self._decode_now[engine] = max(self._decode_now[engine],
                                       trace.decode_admit)

    def on_prefill_only_finish(self, trace: RequestTrace) -> None:
        """Request fully answered by prefill (max_new <= 1): its single
        token is the prefill output, so it never occupies a decode slot."""
        trace.decode_admit = trace.decode_end = trace.ready_at
        self.tracker.record(trace)
        self.router.on_complete(trace.prefill_instance)
        self._release_prefill(trace.rid)

    def on_shed(self, trace: RequestTrace) -> None:
        trace.shed = True
        # Stamp the shed instant so queue statistics see the time this
        # request spent waiting before the gate gave up on it (a gate shed
        # happens at the pool frontier; an up-front capacity reject never
        # prefilled, so its queue time is legitimately zero).
        if trace.prefill_instance >= 0:
            t = max(trace.ready_at, self.decode_now)
        else:
            t = trace.ready_at
        trace.decode_admit = trace.decode_end = t
        self.tracker.record(trace)
        if trace.prefill_instance >= 0:     # capacity rejects never prefill
            self.router.on_complete(trace.prefill_instance)
        # A shed request's routed load must come off its instance too —
        # leaking it here left the engine looking permanently busy and
        # skewed every later least_loaded decision (idempotent: an
        # up-front capacity reject was never routed, so there is nothing
        # to release).
        self._release_prefill(trace.rid)

    def on_decode_step(self, active_rids: Sequence[int],
                       finished_rids: Sequence[int],
                       tokens_by_rid: Optional[Dict[int, int]] = None,
                       masked_rids: Sequence[int] = (),
                       engine: int = 0) -> float:
        """Advance one engine's virtual clock by one decode iteration.

        The clock is charged per *iteration* (MTP: ×``mtp_iter_factor``)
        for the **live** batch — ``active_rids`` are the slots whose
        ``lv[i, j]`` was true — while each request is credited the tokens
        it actually committed — ``tokens_by_rid`` from the engine (MTP:
        1+accepted; omitted: 1 per active request) — so TPOT traces
        honestly reflect speculation. ``masked_rids`` are slots that were
        resident at dispatch but masked this iteration (left-exhausted or
        capacity-frozen): they burned a device iteration without doing
        work, so they count toward ``dead_slot_rate`` but are *not*
        charged batch occupancy on the clock or the trace. An iteration
        whose live set is empty (pure dead tail of a chunk) advances
        nothing but the dead-slot counters.
        """
        if active_rids:
            # Straggler factor 1.0 is the healthy default; multiplying by
            # it is exact in IEEE float, so fault-free timelines are
            # bit-identical to the pre-fault scheduler.
            dt = self.cost.step_time(len(active_rids)) \
                * self._slowdown[engine]
            self._decode_now[engine] += dt
            self.decode_busy += dt
            self.decode_steps += 1
            self._eng_busy[engine] += dt
            self._eng_steps[engine] += 1
        else:
            dt = 0.0
        self.live_slot_iters += len(active_rids)
        self.masked_slot_iters += len(masked_rids)
        self._eng_masked[engine] += len(masked_rids)
        for rid in masked_rids:
            tr = self.traces.get(rid)
            if tr is not None:
                tr.masked_iters += 1
        for rid in active_rids:
            tr = self.traces[rid]
            tr.decode_iters += 1
            tr.decode_seconds += dt
            toks = 1 if tokens_by_rid is None else tokens_by_rid.get(rid, 0)
            tr.decode_tokens += toks
            self.decode_token_count += toks
            self._eng_tokens[engine] += toks
        for rid in finished_rids:
            tr = self.traces[rid]
            tr.decode_end = self._decode_now[engine]
            self.tracker.record(tr)
            self.router.on_complete(tr.prefill_instance)
            self._release_prefill(rid)
        return dt

    def on_migrate(self, trace: RequestTrace, src: int, dst: int,
                   seconds: float) -> None:
        """Cross-engine KV migration: the destination engine cannot resume
        the request before the source clock plus the drain time, so the
        destination clock is bumped (per-request timelines stay monotone —
        ``decode_end`` never precedes ``decode_admit``). The drain charge
        is recorded on the trace (``migration_seconds``), separate from
        ``decode_seconds``, so TPOT keeps meaning pure decode residency."""
        self._decode_now[dst] = max(self._decode_now[dst],
                                    self._decode_now[src] + seconds)
        trace.decode_engine = dst
        trace.migrations += 1
        trace.migration_seconds += seconds
        self.migrations += 1
        self.migration_seconds += seconds

    def engine_clock(self, engine: int) -> float:
        """One engine's virtual clock (the pool frontier is their min)."""
        return self._decode_now[engine]

    def note_mid_scan_refill(self) -> None:
        """An admission landed between engine chunks within one decode
        turn (continuous batching) rather than at a wave boundary."""
        self.mid_scan_refills += 1

    def advance_clock(self, t: float) -> None:
        """Open-loop serving: fast-forward the idle decode pool to the next
        arrival/KV-ready event (never rewinds)."""
        self._decode_now = [max(c, t) for c in self._decode_now]

    def sync_idle_clocks(self, stepped: Sequence[int]) -> None:
        """Engines that sat idle while peers decoded are idle *now*, not at
        their last event: pull their clocks up to the busy frontier (the
        least-advanced stepped engine). Without this, open-loop arrival
        visibility — gated on ``decode_now = min(clocks)`` — would freeze
        at an idle engine's stale clock and serialize the pool into
        bulk-synchronous waves (the idle engine never sees new arrivals
        until the whole pool drains)."""
        busy = [self._decode_now[e] for e in stepped]
        if not busy:
            return
        t = min(busy)
        for e in range(self.n_decode):
            if e not in stepped and self._live[e]:
                self._decode_now[e] = max(self._decode_now[e], t)

    # -- dynamic engine lifecycle (decode-pool autoscaling) ----------------
    def register_engine(self, slot_mgr) -> int:
        """A fresh decode engine joined the pool mid-wave: append its
        admission view and per-engine counters, and warm its virtual clock
        to the busy frontier (the same point ``sync_idle_clocks`` pulls
        idle peers to) — a zero clock would re-serialize open-loop arrival
        visibility onto an engine that did not exist yet."""
        frontier = self.decode_now
        e = self.n_decode
        self.slot_mgrs.append(slot_mgr)
        self.n_decode += 1
        self._live.append(True)
        self._decode_now.append(frontier)
        self._eng_busy.append(0.0)
        self._eng_steps.append(0)
        self._eng_tokens.append(0)
        self._eng_masked.append(0)
        self._slowdown.append(1.0)
        return e

    def set_engine_live(self, engine: int, live: bool) -> None:
        """Park (retired) or revive an existing engine's views. A revived
        engine's clock is warmed to the busy frontier: it comes back *now*,
        not at the stale instant it was parked."""
        if live and not self._live[engine]:
            frontier = self.decode_now
            self._live[engine] = True
            self._decode_now[engine] = max(self._decode_now[engine], frontier)
        else:
            self._live[engine] = live

    # -- dynamic prefill lifecycle (prefill pool / joint autoscaling) ------
    def register_prefill_instance(self) -> int:
        """A fresh prefill instance joined the pool mid-wave: extend its
        virtual clock, in-flight accounting, and the router's id space.
        The new clock starts at the live prefill frontier — a spawned
        instance cannot have been free in the past, and warming it there
        keeps routed TTFTs monotone on the virtual timeline."""
        live_free = [f for f, live in zip(self._instance_free_at,
                                          self._prefill_live) if live]
        frontier = min(live_free) if live_free else 0.0
        i = self.n_prefill
        self.n_prefill += 1
        self._prefill_live.append(True)
        self._instance_free_at.append(frontier)
        self._prefill_inflight.append(0.0)
        self.router.resize(self.n_prefill)
        return i

    def set_prefill_live(self, instance: int, live: bool) -> None:
        """Park (retired) or revive a prefill instance. A revived
        instance's clock is pulled to the live frontier: it comes back
        *now*, not at the stale instant it was parked."""
        if live and not self._prefill_live[instance]:
            live_free = [f for f, on in zip(self._instance_free_at,
                                            self._prefill_live) if on]
            frontier = min(live_free) if live_free else 0.0
            self._prefill_live[instance] = True
            self._instance_free_at[instance] = max(
                self._instance_free_at[instance], frontier)
        else:
            self._prefill_live[instance] = live

    def prefill_backlog_s(self, now: float) -> float:
        """TTFT pressure signal: the worst live instance's queued prefill
        seconds not yet elapsed at ``now`` (0.0 = every live instance is
        free). This is exactly the backlog ``route_prefill`` spreads, so
        the joint autoscaler and the router act on one number."""
        lags = [max(0.0, free - now)
                for free, live in zip(self._instance_free_at,
                                      self._prefill_live) if live]
        return max(lags) if lags else 0.0

    # -- fault tolerance ---------------------------------------------------
    def set_engine_slowdown(self, engine: int, factor: float) -> None:
        """Apply a straggler factor to ``engine``'s step-time charging
        (1.0 = healthy). Asserted by the fault injector every turn, so a
        window expiring between turns heals the engine at the next one."""
        if factor < 1.0:
            raise ValueError("slowdown factor must be >= 1.0")
        self._slowdown[engine] = factor

    def on_engine_failure(self, engine: int) -> None:
        """An engine died. The caller has already parked its views
        (``set_engine_live(engine, False)``); here the failure is counted
        and stamped on the engine-count timeline as a ``fail`` event so
        capacity loss is visible next to grow/shrink decisions."""
        self.engine_failures += 1
        self.record_scale_event("fail", engine)

    def charge_recovery_prefill(self, computed_tokens: int,
                                at: float) -> Tuple[int, float]:
        """Charge a replay re-prefill to the least-backlogged *live*
        prefill instance, starting no earlier than ``at`` (the failure-
        detection instant). Returns ``(instance, completion_time)``;
        concurrent recoveries serialize per instance exactly like arrivals
        do."""
        cands = self.live_prefill_ids or list(range(self.n_prefill))
        i = min(cands, key=lambda j: (self._instance_free_at[j], j))
        start = max(at, self._instance_free_at[i])
        end = start + computed_tokens * self.config.prefill_token_cost_s
        self._instance_free_at[i] = end
        return i, end

    def on_recovery(self, trace: RequestTrace, fail_t: float,
                    tokens_replayed: int, ready_at: float) -> None:
        """A failed engine's in-flight request was rebuilt by replay
        re-prefill and is ready for re-admission at ``ready_at``. The
        latency is charged to the trace (``recovery_seconds``) without
        touching the original prefill/TTFT fields — TTFT already happened;
        recovery is a separate, separately-reported hit."""
        dt = ready_at - fail_t
        trace.recoveries += 1
        trace.tokens_replayed += tokens_replayed
        trace.recovery_seconds += dt
        self.recoveries += 1
        self.tokens_replayed += tokens_replayed
        self.recovery_ttfts.append(dt)

    def on_readmit(self, trace: RequestTrace, engine: int,
                   ready_at: float) -> None:
        """Re-admission of a recovered request. Unlike :meth:`on_admit`
        this must NOT restamp ``decode_admit`` (the original admission is
        what TTFT/queue statistics mean); it only moves the request to its
        new engine and keeps that engine's clock monotone past the
        recovered KV's ready time."""
        trace.decode_engine = engine
        self._decode_now[engine] = max(self._decode_now[engine], ready_at)

    def record_scale_event(self, action: str, engine: int,
                           role: str = "decode") -> None:
        """Stamp a grow/shrink/shift decision on the virtual timeline
        (called after the pool applied it, so the live counts are the new
        ones). ``role`` tags which pool the event's ``engine`` id indexes;
        joint shifts (``shift_p2d`` / ``shift_d2p``) move both counts, so
        both timelines get a point."""
        n_live = sum(self._live)
        n_prefill_live = sum(self._prefill_live)
        t = self.decode_now
        self.scale_events.append({"t": t, "action": action, "engine": engine,
                                  "role": role, "engines_live": n_live,
                                  "prefill_live": n_prefill_live})
        self.engine_count_timeline.append((t, n_live))
        self.prefill_count_timeline.append((t, n_prefill_live))

    def feedback_mtp_acceptance(self) -> Optional[float]:
        """Fold the draft-acceptance rate *measured* by the finished trace
        back into the decode cost model between serve() waves (ROADMAP:
        acceptance-rate feedback into ``DecodeCostModel.mtp_accept``).

        ``decode_tokens`` is credited per iteration as 1 + accepted, so the
        wave's mean acceptance is ``tokens/iters - 1``. The admission gate
        is rebuilt on the calibrated cost: a high-acceptance wave buys a
        larger admitted batch next wave (each iteration now provably emits
        more tokens per unit budget), a low one shrinks it. Returns the
        measured rate, or None when there is nothing to learn or the
        measured rate would make a queue-mode budget unsatisfiable."""
        if not self.config.use_mtp:
            return None
        iters = sum(t.decode_iters for t in self.tracker.finished)
        if iters <= 0:
            return None
        toks = sum(t.decode_tokens for t in self.tracker.finished)
        accept = min(1.0, max(0.0, toks / iters - 1.0))
        new_cost = dataclasses.replace(self.cost, mtp_accept=accept)
        try:
            gate = AdmissionGate(new_cost, self.gate.budget_s,
                                 self.config.admission,
                                 class_budgets=self._class_budgets(),
                                 class_modes=self._class_modes(),
                                 hit_aware=self.config.hit_aware_admission)
        except ValueError:
            return None
        self.cost, self.gate = new_cost, gate
        return accept

    def on_finish(self, trace: RequestTrace, tokens_out: int) -> None:
        trace.tokens_out = tokens_out

    # -- reporting ---------------------------------------------------------
    def trace_records(self) -> List[Dict[str, Any]]:
        """Structured per-request trace, rid-sorted — the benchmark feed."""
        return [self.traces[rid].to_dict() for rid in sorted(self.traces)]

    def summary(self) -> Dict[str, float]:
        s = self.tracker.summary()
        s["decode_steps"] = self.decode_steps
        s["decode_virtual_s"] = self.decode_busy
        s["decode_tokens"] = self.decode_token_count
        if self.decode_steps:
            s["tokens_per_decode_step"] = (self.decode_token_count
                                           / self.decode_steps)
        # Dead-slot observability: fraction of slot-iterations the device
        # spent on resident-but-masked slots (continuous batching exists
        # to drive this toward zero).
        occupied = self.live_slot_iters + self.masked_slot_iters
        s["live_slot_iters"] = self.live_slot_iters
        s["masked_slot_iters"] = self.masked_slot_iters
        s["dead_slot_rate"] = (self.masked_slot_iters / occupied
                               if occupied else 0.0)
        s["mid_scan_refills"] = self.mid_scan_refills
        if self.gate.max_batch is not None:
            s["admitted_batch_cap"] = self.gate.max_batch
        if self.n_decode > 1:
            makespan = max(max(self._decode_now), 1e-12)
            s["decode_engines"] = self.n_decode
            s["engines_live"] = sum(self._live)
            s["migrations"] = self.migrations
            s["engine_decode_steps"] = list(self._eng_steps)
            s["engine_decode_tokens"] = list(self._eng_tokens)
            s["engine_masked_iters"] = list(self._eng_masked)
            s["engine_busy_s"] = [round(b, 9) for b in self._eng_busy]
            s["engine_util"] = [round(b / makespan, 4)
                                for b in self._eng_busy]
        # Fault-tolerance metrics are unconditional: their zeros are the
        # assertion that a run was fault-free, not an absence of data.
        s["engine_failures"] = self.engine_failures
        s["recoveries"] = self.recoveries
        s["tokens_replayed"] = self.tokens_replayed
        s["retries"] = self.transfer_retries
        s["transfer_timeouts"] = self.transfer_timeouts
        s["transfer_corruptions"] = self.transfer_corruptions
        # SLO-class overload control metrics: unconditional zeros, like the
        # fault metrics — "no preemptions" is an assertion, not missing data.
        s["preemptions"] = self.preemptions
        s["preempt_tokens_replayed"] = self.preempt_tokens_replayed
        if self.preempt_latencies:
            s["preempt_p50_s"] = SLOTracker._pct(self.preempt_latencies, 50)
            s["preempt_p99_s"] = SLOTracker._pct(self.preempt_latencies, 99)
        if self.config.brownout:
            s["brownout_level"] = self.brownout_level
            s["brownout_transitions"] = len(self.brownout_events)
            s["brownout_peak_level"] = max(
                (e["to"] for e in self.brownout_events), default=0)
            s["brownout_timeline"] = [
                [round(e["t"], 9), e["from"], e["to"]]
                for e in self.brownout_events]
        if self.recovery_ttfts:
            s["recovery_ttft_p50_s"] = SLOTracker._pct(self.recovery_ttfts, 50)
            s["recovery_ttft_p99_s"] = SLOTracker._pct(self.recovery_ttfts, 99)
        if self.config.stream_handoff or self.stream_requests:
            s["stream_requests"] = self.stream_requests
            s["stream_chunks"] = self.stream_chunks
            s["stream_overlap_s"] = self.stream_overlap_s
            s["stream_bytes"] = self.stream_bytes
            s["stream_max_chunk_bytes"] = self.stream_max_chunk_bytes
        if self.n_prefill > 1 or self.config.joint_autoscale:
            s["prefill_instances"] = self.n_prefill
            s["prefill_live"] = sum(self._prefill_live)
        if self.config.autoscale or self.config.joint_autoscale \
                or self.scale_events:
            # An autoscale wave with zero events is a legitimate all-hold
            # run — still report the (flat) timeline rather than looking
            # like autoscale was off.
            s["scale_events"] = len(self.scale_events)
            s["scale_grows"] = sum(e["action"] == "grow"
                                   for e in self.scale_events)
            s["scale_shrinks"] = sum(e["action"] == "shrink"
                                     for e in self.scale_events)
            s["engine_count_timeline"] = [[round(t, 9), n] for t, n
                                          in self.engine_count_timeline]
        if self.config.joint_autoscale or any(
                e["action"].startswith("shift_") for e in self.scale_events):
            s["shifts_d2p"] = sum(e["action"] == "shift_d2p"
                                  for e in self.scale_events)
            s["shifts_p2d"] = sum(e["action"] == "shift_p2d"
                                  for e in self.scale_events)
            s["prefill_count_timeline"] = [[round(t, 9), n] for t, n
                                           in self.prefill_count_timeline]
        return s
