"""Deterministic fault injection for the serving plane.

Production MaaS treats failure recovery as a first-class scheduler concern
(DeepServe; paper §4.1's independently scaled pools only pay off if the
plane survives component loss). This module supplies the *deterministic*
half of that story: faults are **scheduled, not sampled at run time**. A
:class:`FaultPlan` is a list of :class:`FaultEvent`\\ s pinned either to
the virtual clock (engine crashes, slow-engine stragglers) or to
RDMA-plane operation ordinals (transfer timeouts / payload corruption),
so a fixed plan + request stream reproduces the identical failure
sequence — and therefore the identical recovery trace — every run. The
seeded :meth:`FaultPlan.random` generator derives a plan from a single
integer, which is what ``serve.py --fault-plan random --fault-seed N``
and the fault soak use.

Event kinds
-----------
``engine_crash``     — decode engine ``engine`` dies when *its own*
                       virtual clock reaches ``at`` (detected at the next
                       chunk boundary; in-flight requests are recovered by
                       replay re-prefill, see ``ServingSystem``).
``transfer_timeout`` — the next ``count`` RDMA ops of kind ``op``
                       (``transfer`` | ``migrate`` | ``any``) at or after
                       attempt ordinal ``after`` stall for the transfer
                       engine's timeout window and must be retried.
``transfer_corrupt`` — same addressing, but the payload arrives with a
                       mismatched fingerprint (full wire cost paid, the
                       delivery is discarded and retried).
``slow_engine``      — engine ``engine`` (or every engine, ``engine=-1``)
                       runs ``factor``× slower while its clock is inside
                       ``[at, at + duration)``.
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import Any, Dict, List, Optional, Sequence

FAULT_KINDS = ("engine_crash", "transfer_timeout", "transfer_corrupt",
               "slow_engine")
TRANSFER_OPS = ("transfer", "migrate", "any")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault. Field relevance depends on ``kind`` (see the
    module docstring); irrelevant fields keep their defaults."""

    kind: str
    engine: int = -1                 # crash / straggler target (-1 = all,
    #                                  stragglers only; crashes need an id)
    at: float = 0.0                  # virtual seconds on the engine clock
    op: str = "any"                  # transfer faults: which RDMA op
    after: int = 0                   # transfer faults: skip the first N
    #                                  matching attempts
    count: int = 1                   # transfer faults: attempts affected
    factor: float = 1.0              # slow_engine: step-time multiplier
    duration: float = float("inf")   # slow_engine: window length
    # Transfer faults under pipelined chunked streaming: one request's
    # handoff is now MANY transfer ops, so a plan written against op
    # ordinals alone silently retargets a different chunk when chunking
    # changes. rid/chunk >= 0 scope the event to one request and/or one
    # chunk; the `after` ordinal then counts only that (rid, op, chunk)'s
    # own attempts. -1 (the default) keeps the legacy op-scope addressing,
    # so pre-streaming plans stay valid for unchunked ops.
    rid: int = -1                    # transfer faults: target request
    chunk: int = -1                  # transfer faults: target stream chunk

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"available: {FAULT_KINDS}")
        if self.op not in TRANSFER_OPS:
            raise ValueError(f"unknown transfer op {self.op!r}; "
                             f"available: {TRANSFER_OPS}")
        if self.kind == "engine_crash" and self.engine < 0:
            raise ValueError("engine_crash needs an explicit engine id")
        if self.count < 1 or self.after < 0:
            raise ValueError("need count >= 1 and after >= 0")
        if self.rid < -1 or self.chunk < -1:
            raise ValueError("rid/chunk must be >= 0, or -1 for unscoped")
        if self.factor < 1.0:
            raise ValueError("slow_engine factor must be >= 1.0 (a straggler"
                             " never speeds an engine up)")
        if self.at < 0.0 or self.duration <= 0.0:
            raise ValueError("need at >= 0 and duration > 0")

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        if d["duration"] == float("inf"):
            d["duration"] = None        # JSON-safe
        return d


@dataclasses.dataclass
class FaultPlan:
    """An ordered, finite fault schedule (order breaks transfer-fault ties:
    the first matching event claims an attempt)."""

    events: List[FaultEvent] = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        self.events = [e if isinstance(e, FaultEvent) else FaultEvent(**e)
                       for e in self.events]

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Parse a JSON plan: either a bare event list or
        ``{"events": [...]}``. ``duration: null`` means unbounded."""
        data = json.loads(text)
        if isinstance(data, dict):
            data = data.get("events", [])
        events = []
        for raw in data:
            raw = dict(raw)
            if raw.get("duration") is None:
                raw.pop("duration", None)
            events.append(FaultEvent(**raw))
        return cls(events)

    @classmethod
    def load(cls, spec: str, *, seed: int = 0, n_engines: int = 2,
             horizon_s: float = 0.5) -> "FaultPlan":
        """CLI entry: ``@path`` reads a JSON file, the literal ``random``
        derives a seeded plan, anything else is inline JSON."""
        if spec == "random":
            return cls.random(seed, n_engines=n_engines, horizon_s=horizon_s)
        if spec.startswith("@"):
            with open(spec[1:], "r", encoding="utf-8") as fh:
                return cls.parse(fh.read())
        return cls.parse(spec)

    @classmethod
    def random(cls, seed: int, *, n_engines: int, horizon_s: float,
               n_crashes: int = 1, n_transfer_faults: int = 1,
               n_stragglers: int = 1) -> "FaultPlan":
        """Seeded plan generator: everything below derives from ``seed``
        through one ``random.Random`` stream, so the same seed always
        yields the same plan (the acceptance criterion's ≥1 mid-decode
        crash + ≥1 transfer timeout is guaranteed by construction)."""
        rng = random.Random(seed)
        events: List[FaultEvent] = []
        for _ in range(n_crashes):
            events.append(FaultEvent(
                "engine_crash", engine=rng.randrange(max(1, n_engines)),
                at=rng.uniform(0.1, 0.9) * horizon_s))
        for i in range(n_transfer_faults):
            kind = "transfer_timeout" if i == 0 else rng.choice(
                ("transfer_timeout", "transfer_corrupt"))
            events.append(FaultEvent(
                kind, op=rng.choice(("transfer", "migrate", "any")),
                after=rng.randrange(4), count=rng.randrange(1, 3)))
        for _ in range(n_stragglers):
            start = rng.uniform(0.0, 0.5) * horizon_s
            events.append(FaultEvent(
                "slow_engine", engine=rng.randrange(max(1, n_engines)),
                at=start, factor=1.0 + rng.uniform(0.5, 3.0),
                duration=rng.uniform(0.1, 0.5) * horizon_s))
        return cls(events)

    def __add__(self, other: "FaultPlan") -> "FaultPlan":
        """Compose two plans into one schedule (self's events first —
        order is the tie-break for transfer-fault claims, so composition
        is deterministic and associative but not commutative). Lets the
        workload soak cross a crash plan with a straggler/transfer plan
        without regenerating either."""
        if not isinstance(other, FaultPlan):
            return NotImplemented
        return FaultPlan(list(self.events) + list(other.events))

    def to_json(self) -> str:
        return json.dumps({"events": [e.to_dict() for e in self.events]})


class FaultInjector:
    """Consumes a :class:`FaultPlan` against the serving loop.

    Stateful but deterministic: every query either reads pure plan state
    (``slowdown``) or consumes scheduled events in plan order
    (``due_crashes``, ``transfer_fault``). ``seed`` is provenance only —
    it labels the injector when the plan came from :meth:`FaultPlan.random`
    so traces/benches can report which seeded schedule ran.
    """

    def __init__(self, plan: FaultPlan, seed: int = 0):
        self.plan = plan
        self.seed = seed
        self._crash_events = [e for e in plan.events
                              if e.kind == "engine_crash"]
        self._crash_fired = [False] * len(self._crash_events)
        self._slow_events = [e for e in plan.events if e.kind == "slow_engine"]
        self._transfer_events = [
            e for e in plan.events
            if e.kind in ("transfer_timeout", "transfer_corrupt")]
        self._consumed = [0] * len(self._transfer_events)
        # Per-event matching-attempt counters: event i has seen _seen[i]
        # attempts inside its own scope (op alone for legacy events;
        # op + rid/chunk for scoped ones), so `after` always means "skip
        # the first N attempts THIS event could have claimed". For
        # unscoped events this is arithmetically identical to the old
        # global per-op / per-any ordinals — pre-streaming plans keep
        # firing on the very same attempts.
        self._seen = [0] * len(self._transfer_events)
        # Observability counters (mirrored into bench fault sections).
        self.crashes_fired = 0
        self.timeouts_injected = 0
        self.corruptions_injected = 0

    # -- engine crashes ----------------------------------------------------
    def due_crashes(self, clocks: Sequence[float]) -> List[int]:
        """Engines whose scheduled crash time has been reached by *their
        own* virtual clock. Each crash event fires exactly once; firing is
        recorded even for an engine id outside ``clocks`` (a plan written
        for a bigger pool must not re-arm forever)."""
        due: List[int] = []
        for i, ev in enumerate(self._crash_events):
            if self._crash_fired[i]:
                continue
            if ev.engine >= len(clocks):
                self._crash_fired[i] = True
                continue
            if clocks[ev.engine] >= ev.at:
                self._crash_fired[i] = True
                self.crashes_fired += 1
                due.append(ev.engine)
        return sorted(set(due))

    # -- stragglers --------------------------------------------------------
    def slowdown(self, engine: int, now: float) -> float:
        """The step-time multiplier ``engine`` suffers at virtual time
        ``now`` (1.0 = healthy; overlapping windows take the worst)."""
        factor = 1.0
        for ev in self._slow_events:
            if ev.engine not in (-1, engine):
                continue
            if ev.at <= now < ev.at + ev.duration:
                factor = max(factor, ev.factor)
        return factor

    # -- transfer faults ---------------------------------------------------
    def transfer_fault(self, op: str, rid: Optional[int] = None,
                       chunk: Optional[int] = None) -> Optional[str]:
        """Per-attempt hook for ``KVTransferEngine``: returns ``"timeout"``
        / ``"corrupt"`` when a scheduled fault claims this attempt, else
        None. Addressing for legacy (unscoped) events is by attempt
        *ordinal* within the event's op scope (``op="any"`` scopes over
        all RDMA attempts) — bit-compatible with pre-streaming plans. An
        event carrying ``rid``/``chunk`` >= 0 instead claims only attempts
        for that request/chunk, with ``after`` counted against that
        ``(rid, op, chunk)``'s own attempts — chunked streaming multiplies
        transfer ops per request, and scoped addressing is what keeps a
        plan aimed at one chunk from silently retargeting another. In both
        schemes retries of a faulted op count as fresh attempts, so a
        ``count=k`` event fails the op ``k`` consecutive times (how
        backoff and retry exhaustion get exercised)."""
        a_rid = -1 if rid is None else rid
        a_chunk = -1 if chunk is None else chunk
        # Count the attempt against EVERY event whose scope it falls in
        # (even events that will not claim it): an event's ordinal stream
        # must be independent of which other event fires first, or plan
        # composition would stop being deterministic.
        ordinals: Dict[int, int] = {}
        for i, ev in enumerate(self._transfer_events):
            if ev.op not in (op, "any"):
                continue
            if ev.rid >= 0 and ev.rid != a_rid:
                continue
            if ev.chunk >= 0 and ev.chunk != a_chunk:
                continue
            ordinals[i] = self._seen[i]
            self._seen[i] += 1
        for i, ordinal in ordinals.items():
            ev = self._transfer_events[i]
            if ordinal >= ev.after and self._consumed[i] < ev.count:
                self._consumed[i] += 1
                if ev.kind == "transfer_timeout":
                    self.timeouts_injected += 1
                    return "timeout"
                self.corruptions_injected += 1
                return "corrupt"
        return None

    # -- reporting ---------------------------------------------------------
    def summary(self) -> Dict[str, int]:
        return {"seed": self.seed,
                "planned_events": len(self.plan.events),
                "crashes_fired": self.crashes_fired,
                "timeouts_injected": self.timeouts_injected,
                "corruptions_injected": self.corruptions_injected}
