"""PDC peer-to-peer serving engines (paper §4.1).

Three independently scalable pools, communicating only via explicit KV
interfaces:

* :class:`PrefillEngine`  — prompt processing + EMS context-cache reuse/store
  (reused prefixes skip computation; suffixes run with position offsets).
* :class:`DecodeEngine`   — continuous-batched autoregressive decode over
  fixed slots whose allocation/eviction and per-request ``cache_len``
  accounting live in :class:`~repro.serving.scheduler.DecodeSlotManager`;
  optional MTP speculative decoding and two-stream microbatch interleaving
  (:class:`~repro.serving.scheduler.MicrobatchInterleaver`).
* :class:`ServingSystem`  — the peer-to-peer glue. Every scheduling
  *decision* (prefill routing policy, SLO admission control, trace/clock
  bookkeeping) is delegated to :class:`~repro.serving.scheduler.Scheduler`;
  this class only moves tensors: run prefill, hand KV off over the
  RDMA-plane transfer engine, insert into decode slots, step decode.

Everything runs functionally on CPU with smoke configs; on TPU the same
step functions are pjit-ed over the production mesh (launch/serve.py).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import mtp as mtp_mod
from repro.mempool.context_cache import ContextCache
from repro.mempool.ems import EMSService
from repro.models import model as model_mod
from repro.serving import cache_ops
from repro.serving.faults import FaultInjector
from repro.serving.pool import (DecodePool, DrainError, JointAutoscaler,
                                PoolAutoscaler, PrefillPool,
                                make_decode_router)
from repro.serving.scheduler import (
    DecodeSlotManager,
    MicrobatchInterleaver,
    Scheduler,
    SchedulerConfig,
    SlotError,
)
from repro.serving.transfer import KVTransferEngine, TransferError


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    arrival: float = 0.0
    # SLO tier: "interactive" (stringent TPOT budget, protected under
    # overload) or "batch" (relaxed budget; first to degrade).
    slo_class: str = "interactive"


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: List[int]
    reused_tokens: int = 0
    computed_tokens: int = 0
    prefill_instance: int = -1
    transfer_seconds: float = 0.0
    decode_iters: int = 0
    shed: bool = False
    slo_class: str = "interactive"


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


class PrefillEngine:
    #: tokens per jitted prefill_continue call on the EMS-reuse suffix path
    #: (the tail chunk is padded to this length, so exactly one program is
    #: compiled regardless of suffix length).
    SUFFIX_CHUNK = 32

    def __init__(self, params, cfg: ModelConfig, capacity: int,
                 context_cache: Optional[ContextCache] = None,
                 instance_id: int = 0, moe_fn=None,
                 suffix_chunk: Optional[int] = None,
                 prefill_chunk: Optional[int] = None):
        self.params, self.cfg, self.capacity = params, cfg, capacity
        self.cc = context_cache
        self.instance_id = instance_id
        self.load = 0  # in-flight prompt tokens (scheduler signal)
        # EMS device-tier tag: blocks this instance computes land (dirty)
        # in its own HBM tier and write back to the shared pool async.
        self._ems_tag = f"prefill{instance_id}"
        self.suffix_chunk = suffix_chunk or self.SUFFIX_CHUNK
        # Fresh prompts, when set, run through chunked prefill_continue
        # calls of this width (offset 0 on a fresh cache == prefill): one
        # compiled program per width instead of one per prompt length.
        # Fresh-path and EMS-suffix dispatches are counted separately so
        # the compile-cache hit rate reflects one chunk configuration.
        self.prefill_chunk = prefill_chunk
        self.continue_calls = 0            # fresh-path dispatches
        self.continue_widths: set = set()  # fresh-path compiled widths
        self.suffix_calls = 0              # EMS-suffix dispatches
        self.suffix_widths: set = set()
        self._chunkable = model_mod.supports_prefill_continue(cfg, capacity)
        self._prefill = jax.jit(
            lambda p, b: model_mod.prefill(p, cfg, b, capacity, moe_fn,
                                           cache_dtype=jnp.float32))
        # Per-token fallback for archs prefill_continue cannot serve
        # (ring-buffer caches). Cache buffers are donated: the suffix loop
        # updates them in place instead of copying per step.
        self._step = jax.jit(
            lambda p, t, c, l: model_mod.decode_step(p, cfg, t, c, l, moe_fn),
            donate_argnums=(2,))
        self._continue = jax.jit(
            lambda p, t, c, off: model_mod.prefill_continue(p, cfg, t, c,
                                                            off, moe_fn),
            donate_argnums=(2,))

    def _fresh_cache(self):
        return model_mod.make_caches(self.cfg, 1, self.capacity, jnp.float32)

    @property
    def continue_cache_hit_rate(self) -> float:
        """Fraction of fresh-path chunked-prefill dispatches that reuse an
        already compiled program (1 - distinct widths / calls)."""
        if not self.continue_calls:
            return float("nan")
        return 1.0 - len(self.continue_widths) / self.continue_calls

    def _continue_chunks(self, tokens, caches, pos: int, chunk: int,
                         fresh: bool):
        """Feed ``tokens`` at positions ``pos..`` through jitted
        prefill_continue calls of bounded width ``chunk`` (tail padded, so
        one program serves every length). Returns (last_logits_row, caches,
        end_pos); padded positions land beyond the final cache_len, so
        decode overwrites them before they are ever attendable."""
        if pos + len(tokens) > self.capacity:
            raise ValueError(
                f"prompt run of {len(tokens)} tokens at offset {pos} "
                f"exceeds the prefill cache capacity {self.capacity}")
        st, last = 0, None
        while st < len(tokens):
            # Call width: the chunk, clamped to the cache headroom so the
            # padded write never overruns the static capacity buffer.
            width = min(chunk, self.capacity - pos)
            part = tokens[st:st + width]
            toks = jnp.asarray([list(part) + [0] * (width - len(part))],
                               jnp.int32)
            if fresh:
                self.continue_calls += 1
                self.continue_widths.add(width)
            else:
                self.suffix_calls += 1
                self.suffix_widths.add(width)
            logits, caches = self._continue(self.params, toks, caches,
                                            jnp.int32(pos))
            pos += len(part)
            st += len(part)
            last = logits[0, len(part) - 1]
        return last, caches, pos

    def run(self, req: Request) -> Tuple[int, Any, RequestResult]:
        """Process one prompt. Returns (first_token, caches(B=1), result)."""
        cfg = self.cfg
        prompt = list(req.prompt)
        res = RequestResult(req.rid, [], prefill_instance=self.instance_id)
        self.load += len(prompt)
        try:
            reuse_len = 0
            caches = None
            if self.cc is not None and cfg.attention_kind != "none" \
                    and not cfg.is_hybrid:
                reuse_len, keys = self.cc.match_prefix(prompt)
                reuse_len = min(reuse_len, len(prompt) - 1)
                reuse_len -= reuse_len % self.cc.block
                keys = keys[: reuse_len // self.cc.block]
                if reuse_len > 0:
                    # Resolve through the cache service (EMS: engine-HBM
                    # tier first, then pooled tier with an RDMA promote). A
                    # block evicted between match and fetch shortens the
                    # returned prefix — shrink the reuse and recompute the
                    # rest instead of crashing on the race.
                    flats = self.cc.fetch(keys, engine=self._ems_tag)
                    if len(flats) < len(keys):
                        reuse_len = len(flats) * self.cc.block
                    if reuse_len > 0:
                        caches = self._fresh_cache()
                        tmpl = cache_ops.seq_slice(cfg, caches, 0,
                                                   self.cc.block)
                        for bi, flat in enumerate(flats):
                            payload = cache_ops.unpack_payload(flat, tmpl)
                            caches = cache_ops.seq_insert(
                                cfg, caches, payload, bi * self.cc.block)
            if reuse_len > 0:
                # Suffix-only computation: teacher-forced continuation from
                # the reused prefix (positions offset by reuse_len). The
                # whole suffix runs in chunked prefill_continue calls — one
                # jitted dispatch per SUFFIX_CHUNK tokens instead of one per
                # token (ring-buffer caches fall back to the token loop).
                if not self._chunkable:
                    logits = None
                    cl = jnp.int32(reuse_len)
                    for tok in prompt[reuse_len:]:
                        t = jnp.full((1, 1), tok, jnp.int32)
                        logits, caches = self._step(self.params, t, caches, cl)
                        cl = cl + 1
                    last = logits[0]
                else:
                    last, caches, _ = self._continue_chunks(
                        prompt[reuse_len:], caches, reuse_len,
                        self.suffix_chunk, fresh=False)
                first = int(jnp.argmax(last))
                res.computed_tokens = len(prompt) - reuse_len
            elif self.prefill_chunk and self._chunkable:
                # Fresh prompt, bounded compile shapes: the whole prompt
                # runs through chunked prefill_continue calls against a
                # fresh cache (offset 0) — one compiled program per chunk
                # width instead of one per prompt length, so long/varied
                # prompts stop exploding the jit cache.
                caches = self._fresh_cache()
                last, caches, _ = self._continue_chunks(
                    prompt, caches, 0, self.prefill_chunk, fresh=True)
                first = int(jnp.argmax(last))
                res.computed_tokens = len(prompt)
            else:
                batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
                logits, caches = self._prefill(self.params, batch)
                first = int(jnp.argmax(logits[0, len(prompt) - 1]))
                res.computed_tokens = len(prompt)
            res.reused_tokens = reuse_len

            # Store newly computed full blocks back to EMS (async IRL).
            # One jitted slice+pack builds every block payload at once.
            if self.cc is not None and cfg.attention_kind != "none" \
                    and not cfg.is_hybrid:
                n_blocks = len(prompt) // self.cc.block
                payloads = cache_ops.pack_blocks(cfg, caches, n_blocks,
                                                 self.cc.block)
                if payloads:
                    self.cc.store(prompt[: n_blocks * self.cc.block],
                                  payloads, engine=self._ems_tag)
            return first, caches, res
        finally:
            self.load -= len(prompt)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Slot:
    """Engine-side per-request payload riding in the slot manager."""
    remaining: int
    result: RequestResult


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig, max_batch: int, capacity: int,
                 moe_fn=None, use_mtp: bool = False, mtp_params=None, seed=0,
                 interleave: bool = False, n_micro: int = 2,
                 decode_chunk: int = 1, mtp_fused: bool = False):
        self.params, self.cfg = params, cfg
        self.b, self.capacity = max_batch, capacity
        self.use_mtp = use_mtp
        self.mtp_params = mtp_params
        self.decode_chunk = max(1, int(decode_chunk))
        self.mtp_fused = bool(mtp_fused) and use_mtp
        if self.mtp_fused and not mtp_mod.can_fuse_verify(cfg, capacity):
            warnings.warn("fused MTP verification needs a causal/MLA "
                          "non-ring cache; falling back to the two-forward "
                          "verify", stacklevel=2)
            self.mtp_fused = False
        self.caches = model_mod.make_caches(cfg, max_batch, capacity, jnp.float32)
        self.cache_len = jnp.zeros((max_batch,), jnp.int32)
        # Shape/dtype fixed point up front: donated cache buffers then alias
        # input->output from the first jitted step on every arch family.
        self.caches = model_mod.decode_ready_caches(params, cfg, self.caches,
                                                    self.cache_len, moe_fn)
        self.cur_tok = jnp.zeros((max_batch,), jnp.int32)
        self.draft_tok = jnp.zeros((max_batch,), jnp.int32)
        self.slot_mgr = DecodeSlotManager(max_batch, capacity)
        self.key = jax.random.PRNGKey(seed)
        self.iters = 0
        interleaver = MicrobatchInterleaver(n_micro if interleave else 1)
        # Hybrid caches nest SSM state with batch on axis 2, which the
        # microbatch split heuristic (batch = axis 1 for rank>=3) mis-slices.
        self.interleaved = (interleaver.applicable(max_batch)
                            and not use_mtp and not cfg.is_hybrid)
        if interleave and not self.interleaved:
            if use_mtp:
                reason = "MTP speculative decoding steps are not interleavable"
            elif cfg.is_hybrid:
                reason = ("hybrid-architecture caches are not microbatch-"
                          "splittable (SSM state batch axis)")
            elif n_micro < 2:
                reason = f"n_micro={n_micro} means no pairing"
            else:
                reason = (f"max_batch={max_batch} is not divisible by "
                          f"n_micro={n_micro}")
            warnings.warn("decode microbatch interleaving requested but "
                          f"disabled: {reason}", stacklevel=2)

        def _step(p, t, c, l):
            base = lambda tt, cc, ll: model_mod.decode_step(  # noqa: E731
                p, cfg, tt, cc, ll, moe_fn)
            fn = interleaver.wrap(base, max_batch) if self.interleaved else base
            return fn(t, c, l)

        # Cache buffers are donated so each jitted step reuses them in
        # place instead of allocating + copying a fresh cache per token.
        self._step = jax.jit(_step, donate_argnums=(2,))

        # Continuous batching jits the scan at a small ladder of widths
        # (powers of two up to decode_chunk, plus decode_chunk itself) so
        # the effective chunk can shrink to where a refill or a finish
        # lands without recompiling per width request. Loops jit lazily:
        # a wave that never shrinks compiles exactly one program, same as
        # before.
        self._chunk_widths = sorted(
            {w for w in (1 << p for p in range(self.decode_chunk.bit_length()))
             if w <= self.decode_chunk} | {self.decode_chunk})
        self._loops: dict = {}        # width -> jitted decode_loop
        self._loops_mtp: dict = {}    # width -> jitted decode_loop_mtp
        # Dead-slot observability: slot-iterations the device spent on
        # live vs resident-but-masked slots across this engine's lifetime.
        self.live_slot_iters = 0
        self.dead_slot_iters = 0

        def _make_loop(width: int):
            def _loop(p, t, c, l, left):
                base = lambda tt, cc, ll: model_mod.decode_step(  # noqa: E731
                    p, cfg, tt, cc, ll, moe_fn)
                fn = interleaver.wrap(base, max_batch) \
                    if self.interleaved else base
                return model_mod.decode_loop(p, cfg, t, c, l, width,
                                             steps_left=left, step_fn=fn)
            return jax.jit(_loop, donate_argnums=(2,))

        self._make_loop = _make_loop
        if use_mtp:
            self._propose = jax.jit(
                lambda p, mp, t: mtp_mod.propose_draft(p, mp, cfg, t))
            self._mtp_step = jax.jit(
                lambda p, mp, x, d, c, l, k: mtp_mod.mtp_step(
                    p, mp, cfg, x, d, c, l, k, moe_fn,
                    fused_verify=self.mtp_fused),
                donate_argnums=(4,))

            # Scanned MTP fast path: `width` speculative iterations (up
            # to 2*width tokens) per host sync, cache donated.
            def _make_loop_mtp(width: int):
                return jax.jit(
                    lambda p, mp, x, d, c, l, left, k:
                    model_mod.decode_loop_mtp(
                        p, mp, cfg, x, d, c, l, width,
                        steps_left=left, key=k, greedy=True,
                        fused_verify=self.mtp_fused, moe_fn=moe_fn),
                    donate_argnums=(4,))

            self._make_loop_mtp = _make_loop_mtp

    def _get_loop(self, width: int):
        if width not in self._loops:
            self._loops[width] = self._make_loop(width)
        return self._loops[width]

    def _get_loop_mtp(self, width: int):
        if width not in self._loops_mtp:
            self._loops_mtp[width] = self._make_loop_mtp(width)
        return self._loops_mtp[width]

    def _effective_chunk(self, refill_pending: bool) -> int:
        """Continuous batching: the scan width for the next dispatch.

        Shrink from ``decode_chunk`` to where the next host sync can do
        useful work: ``min(remaining)`` across active slots (a slot
        finishing mid-scan would burn masked iterations past that point —
        under MTP a slot needs at least ceil(remaining/2) iterations, so
        that is the bound), and width 1 when an admission is pending and
        a slot is free, so the refill lands at the earliest sync. The
        result snaps DOWN to the pre-jitted width ladder — never up, so
        no masked tail is ever dispatched on purpose."""
        k = self.decode_chunk
        lefts = [info.payload.remaining
                 for _, info in self.slot_mgr.active_slots()]
        if lefts:
            m = min(lefts)
            need = max(1, (m + 1) // 2) if self.use_mtp else max(1, m)
            k = min(k, need)
        if refill_pending and self.slot_mgr.free > 0:
            k = 1
        for w in reversed(self._chunk_widths):
            if w <= k:
                return w
        return 1

    def free_slot(self) -> Optional[int]:
        return self.slot_mgr.free_slot()

    def add(self, slot: int, req_cache, first_token: int, prompt_len: int,
            result: RequestResult, max_new: int) -> None:
        self.slot_mgr.allocate(result.rid, prompt_len,
                               payload=_Slot(max_new - 1, result), slot=slot)
        self.caches = cache_ops.insert_request(self.cfg, self.caches,
                                               req_cache, slot)
        self.cache_len = self.cache_len.at[slot].set(prompt_len)
        self.cur_tok = self.cur_tok.at[slot].set(first_token)
        result.tokens.append(first_token)
        if self.use_mtp:
            d = self._propose(self.params, self.mtp_params,
                              self.cur_tok[slot: slot + 1])
            self.draft_tok = self.draft_tok.at[slot].set(d[0])

    @property
    def active(self) -> int:
        return self.slot_mgr.active

    def export_slot(self, slot: int) -> Tuple[np.ndarray, int, int, int]:
        """Drain one active slot's device state for cross-engine migration:
        (packed cache bytes, cache_len, cur_tok, draft_tok). The cache rows
        are serialized byte-exactly via :func:`cache_ops.pack_request` —
        the payload a peer engine re-inserts bitwise-identically."""
        info = self.slot_mgr.get(slot)
        if info is None:
            raise SlotError(f"export of empty slot {slot}")
        req_slice = cache_ops.slice_request(self.cfg, self.caches, slot)
        return (cache_ops.pack_request(self.cfg, req_slice),
                int(self.cache_len[slot]), int(self.cur_tok[slot]),
                int(self.draft_tok[slot]))

    def import_slot(self, slot: int, flat: np.ndarray, cache_len: int,
                    cur_tok: int, draft_tok: int, rid: int,
                    payload: Any) -> None:
        """Land a migrated request on ``slot``: allocate the slot with the
        engine-side payload that traveled with it, then unpack the drained
        cache bytes against this engine's own layout (shape/dtype template
        from the destination row) and insert them."""
        self.slot_mgr.allocate(rid, cache_len, payload=payload, slot=slot)
        template = cache_ops.slice_request(self.cfg, self.caches, slot)
        req_cache = cache_ops.unpack_request(self.cfg, flat, template)
        self.caches = cache_ops.insert_request(self.cfg, self.caches,
                                               req_cache, slot)
        self.cache_len = self.cache_len.at[slot].set(cache_len)
        self.cur_tok = self.cur_tok.at[slot].set(cur_tok)
        self.draft_tok = self.draft_tok.at[slot].set(draft_tok)

    def step(self) -> List[RequestResult]:
        """One host-sync decode turn. Returns requests finished this turn."""
        return self.step_chunk()[0]

    def step_chunk(self, continuous: bool = False,
                   refill_pending: bool = False
                   ) -> Tuple[List[RequestResult],
                              List[Tuple[List[int], List[int],
                                         dict, List[int]]]]:
        """One host-sync decode turn: ``decode_chunk`` device iterations per
        jitted call on the fast path (one otherwise). ``continuous``
        enables adaptive chunk sizing (:meth:`_effective_chunk`):
        ``refill_pending`` then signals a gate-held admission that could
        land in a free slot, pulling the next host sync forward.

        Returns ``(finished, iter_log)``; ``iter_log`` holds one
        ``(live_rids, finished_rids, tokens_by_rid, masked_rids)`` entry
        per device iteration actually dispatched, so the scheduler can
        attribute virtual-clock time per-iteration to the slots that did
        work — and credit the tokens each iteration committed (MTP:
        1+accepted) — while ``masked_rids`` (resident at dispatch but
        ``lv[i, j]`` false) feed the dead-slot counters without being
        charged as batch occupancy.
        """
        if self.decode_chunk > 1:
            width = (self._effective_chunk(refill_pending) if continuous
                     else self.decode_chunk)
            return (self._step_chunked_mtp(width) if self.use_mtp
                    else self._step_chunked(width))

        self.iters += 1
        active_rids = [info.rid for _, info in self.slot_mgr.active_slots()]
        self.key, sub = jax.random.split(self.key)
        if self.use_mtp:
            emitted, accepted, x_next, d_next, self.caches, self.cache_len = \
                self._mtp_step(self.params, self.mtp_params, self.cur_tok,
                               self.draft_tok, self.caches, self.cache_len, sub)
            self.cur_tok, self.draft_tok = x_next, d_next
            em = np.asarray(emitted)
            acc = np.asarray(accepted)
        else:
            logits, self.caches = self._step(self.params, self.cur_tok[:, None],
                                             self.caches, self.cache_len)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self.cache_len = self.cache_len + 1
            self.cur_tok = nxt
            em = np.asarray(nxt)[:, None]
            acc = np.zeros(self.b, bool)

        finished = []
        tokens_by_rid: dict = {}
        for i, info in list(self.slot_mgr.active_slots()):
            slot: _Slot = info.payload
            slot.result.decode_iters += 1
            # Mirror the device-side cache growth (MTP appends the accepted
            # draft token too) with capacity enforcement.
            self.slot_mgr.advance(i, 2 if (self.use_mtp and acc[i]) else 1)
            new_toks = [int(em[i, 0])]
            if self.use_mtp and acc[i] and slot.remaining > 1:
                new_toks.append(int(em[i, 1]))
            committed = 0
            for t in new_toks:
                if slot.remaining > 0:
                    slot.result.tokens.append(t)
                    slot.remaining -= 1
                    committed += 1
            tokens_by_rid[info.rid] = committed
            if slot.remaining <= 0:
                finished.append(slot.result)
                self.slot_mgr.release(i)
        # Per-step decode never masks a resident slot (capacity overflow
        # raises in advance() instead) — the dead-slot set is empty.
        self.live_slot_iters += len(active_rids)
        return finished, [(active_rids, [r.rid for r in finished],
                           tokens_by_rid, [])]

    def _step_chunked(self, width: int) -> Tuple[
            List[RequestResult],
            List[Tuple[List[int], List[int], dict, List[int]]]]:
        """Device-resident fast path: ``width`` scanned iterations, one
        host sync. Slot accounting is reconciled in DecodeSlotManager.advance
        as the chunk drains, iteration by iteration. The live/masked split
        per iteration comes from the device's ``lv`` mask: a slot that was
        resident when the scan was dispatched but masked at iteration j
        (finished earlier in the chunk, or capacity-frozen) burned a dead
        device iteration — logged in ``masked_rids``, never charged as
        live batch occupancy."""
        left = np.zeros((self.b,), np.int32)
        resident = {}                   # slot index -> rid at dispatch time
        for i, info in self.slot_mgr.active_slots():
            left[i] = min(info.payload.remaining, width)
            resident[i] = info.rid
        emitted, live, self.cur_tok, self.caches, self.cache_len = \
            self._get_loop(width)(self.params, self.cur_tok, self.caches,
                                  self.cache_len, jnp.asarray(left))
        em = np.asarray(emitted)
        lv = np.asarray(live)

        finished: List[RequestResult] = []
        iter_log: List[Tuple[List[int], List[int], dict, List[int]]] = []
        for j in range(width):
            self.iters += 1
            live_rids: List[int] = []
            masked_rids: List[int] = []
            fin_this: List[RequestResult] = []
            tokens_by_rid: dict = {}
            for i, rid in resident.items():
                if not lv[i, j]:
                    masked_rids.append(rid)
                    continue
                info = self.slot_mgr.get(i)   # live => not yet released
                slot: _Slot = info.payload
                slot.result.decode_iters += 1
                self.slot_mgr.advance(i, 1)
                slot.result.tokens.append(int(em[i, j]))
                slot.remaining -= 1
                live_rids.append(rid)
                tokens_by_rid[rid] = 1
                if slot.remaining <= 0:
                    fin_this.append(slot.result)
                    self.slot_mgr.release(i)
            self.live_slot_iters += len(live_rids)
            self.dead_slot_iters += len(masked_rids)
            iter_log.append((live_rids, [r.rid for r in fin_this],
                             tokens_by_rid, masked_rids))
            finished.extend(fin_this)
        self._raise_if_capacity_frozen(lv)
        return finished, iter_log

    def _step_chunked_mtp(self, width: int) -> Tuple[
            List[RequestResult],
            List[Tuple[List[int], List[int], dict, List[int]]]]:
        """Scanned MTP fast path: ``width`` speculative iterations — up to
        ``2*width`` tokens — per host sync. Per-iteration accept/reject
        ran on-device; here the emitted runs are committed slot by slot,
        mirroring the per-step MTP accounting (advance 2 on accept, credit
        the accepted draft token only while the request still wants
        tokens). Live/masked attribution follows the device ``lv`` mask
        exactly as in :meth:`_step_chunked`."""
        left = np.zeros((self.b,), np.int32)
        resident = {}                   # slot index -> rid at dispatch time
        for i, info in self.slot_mgr.active_slots():
            left[i] = info.payload.remaining
            resident[i] = info.rid
        self.key, sub = jax.random.split(self.key)
        (emitted, accepted, live, self.cur_tok, self.draft_tok, self.caches,
         self.cache_len) = self._get_loop_mtp(width)(
            self.params, self.mtp_params, self.cur_tok, self.draft_tok,
            self.caches, self.cache_len, jnp.asarray(left), sub)
        em = np.asarray(emitted)        # (B, width, 2)
        acc = np.asarray(accepted)      # (B, width)
        lv = np.asarray(live)           # (B, width)

        finished: List[RequestResult] = []
        iter_log: List[Tuple[List[int], List[int], dict, List[int]]] = []
        for j in range(width):
            self.iters += 1
            live_rids: List[int] = []
            masked_rids: List[int] = []
            fin_this: List[RequestResult] = []
            tokens_by_rid: dict = {}
            for i, rid in resident.items():
                if not lv[i, j]:
                    masked_rids.append(rid)
                    continue
                info = self.slot_mgr.get(i)   # live => not yet released
                slot: _Slot = info.payload
                slot.result.decode_iters += 1
                self.slot_mgr.advance(i, 2 if acc[i, j] else 1)
                new_toks = [int(em[i, j, 0])]
                if acc[i, j] and slot.remaining > 1:
                    new_toks.append(int(em[i, j, 1]))
                committed = 0
                for t in new_toks:
                    if slot.remaining > 0:
                        slot.result.tokens.append(t)
                        slot.remaining -= 1
                        committed += 1
                live_rids.append(rid)
                tokens_by_rid[rid] = committed
                if slot.remaining <= 0:
                    fin_this.append(slot.result)
                    self.slot_mgr.release(i)
            self.live_slot_iters += len(live_rids)
            self.dead_slot_iters += len(masked_rids)
            iter_log.append((live_rids, [r.rid for r in fin_this],
                             tokens_by_rid, masked_rids))
            finished.extend(fin_this)
        self._raise_if_capacity_frozen(lv)
        return finished, iter_log

    def _raise_if_capacity_frozen(self, lv: np.ndarray) -> None:
        """Enforce the capacity invariant the masked device loop would
        otherwise hide: a slot that still wants tokens but was never live
        this chunk is capacity-frozen — fail fast like per-step decode
        does via DecodeSlotManager.advance, instead of livelocking."""
        for i, info in list(self.slot_mgr.active_slots()):
            if info.payload.remaining > 0 and not lv[i].any():
                raise SlotError(
                    f"rid={info.rid} cache_len {info.cache_len} has hit the "
                    f"decode capacity {self.slot_mgr.capacity} with "
                    f"{info.payload.remaining} tokens still requested")


# ---------------------------------------------------------------------------
# Peer-to-peer serving system (PDC glue)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _PendingAdmission:
    first: int
    caches: Any
    prompt_len: int
    result: RequestResult
    max_new: int
    block_keys: Tuple[str, ...] = ()
    # Engine-failure recovery: a recovered request re-enters the admission
    # queue with its replay KV ready at an explicit instant (the trace's
    # ready_at property keeps describing the ORIGINAL prefill handoff) and
    # is re-admitted via on_readmit so decode_admit/TTFT stay untouched.
    ready_at: Optional[float] = None
    recovered: bool = False


class ServingSystem:
    """Peer-to-peer PDC pipeline wired through the pluggable scheduler.

    ``policy`` selects the prefill router by name (``least_loaded``,
    ``round_robin``, ``queue_depth``); ``tpot_budget_ms`` + ``admission``
    configure SLO admission control; ``interleave`` pairs two decode
    microbatches per step. ``decode_engines`` > 1 builds a
    :class:`~repro.serving.pool.DecodePool` of identical engines behind a
    ``decode_router`` policy (``least_loaded_slots``, ``round_robin``,
    ``cache_affinity``) with cross-engine KV migration. ``autoscale=True``
    (with ``min_engines``/``max_engines`` clamps) lets a deterministic
    :class:`~repro.serving.pool.PoolAutoscaler` grow the pool mid-wave
    (fresh engine spawn, or revival of a parked one) and shrink it through
    migration-backed retirement; ``decode_engines`` is then the *initial*
    pool size. Pass a full :class:`SchedulerConfig` as ``scheduler_config``
    to override cost-model constants; explicitly passed scheduling kwargs
    still win over the provided config.

    Peer-to-peer PDC additions: ``prefill_engines`` sizes a
    :class:`~repro.serving.pool.PrefillPool` (same spawn/park/retire/fail
    lifecycle as the decode pool, routed over the live roster only);
    ``stream_handoff=True`` replaces the synchronous whole-request KV
    handoff with pipelined chunked streaming (``stream_chunk`` tokens per
    RDMA op, transfer overlapped behind the remaining prefill compute,
    token-identical to the synchronous path); ``joint_autoscale=True`` runs
    a :class:`~repro.serving.pool.JointAutoscaler` that shifts engines
    between the prefill and decode roles under one SLO budget
    (``ttft_budget_ms`` + ``tpot_budget_ms``) inside the
    ``min_prefill``/``max_prefill`` and ``min_engines``/``max_engines``
    clamps.
    """

    def __init__(self, params, cfg: ModelConfig, *, n_prefill: int = 2,
                 prefill_engines: Optional[int] = None,
                 decode_batch: int = 4, capacity: int = 128,
                 decode_engines: int = 1,
                 decode_router: Optional[str] = None,
                 decode_rebalance_every: Optional[int] = None,
                 autoscale: Optional[bool] = None,
                 min_engines: Optional[int] = None,
                 max_engines: Optional[int] = None,
                 joint_autoscale: Optional[bool] = None,
                 min_prefill: Optional[int] = None,
                 max_prefill: Optional[int] = None,
                 ttft_budget_ms: Optional[float] = None,
                 stream_handoff: Optional[bool] = None,
                 stream_chunk: Optional[int] = None,
                 context_cache: Optional[ContextCache] = None,
                 use_mtp: bool = False, mtp_params=None,
                 mtp_fused: bool = False, moe_fn=None,
                 policy: Optional[str] = None,
                 tpot_budget_ms: Optional[float] = None,
                 admission: Optional[str] = None,
                 interleave: Optional[bool] = None,
                 decode_chunk: Optional[int] = None,
                 continuous_batching: Optional[bool] = None,
                 prefill_chunk: Optional[int] = None,
                 degrade_shed_queue_s: Optional[float] = None,
                 batch_tpot_budget_ms: Optional[float] = None,
                 batch_admission: Optional[str] = None,
                 preempt_batch: Optional[bool] = None,
                 brownout: Optional[bool] = None,
                 brownout_patience: Optional[int] = None,
                 brownout_cooldown: Optional[int] = None,
                 hit_aware_admission: Optional[bool] = None,
                 scheduler_config: Optional[SchedulerConfig] = None,
                 fault_injector: Optional[FaultInjector] = None):
        self.cfg = cfg
        self.cc = context_cache
        overrides = {k: v for k, v in (
            ("policy", policy), ("tpot_budget_ms", tpot_budget_ms),
            ("admission", admission), ("interleave_microbatches", interleave),
            ("decode_chunk", decode_chunk),
            ("continuous_batching", continuous_batching),
            ("decode_policy", decode_router),
            ("decode_rebalance_every", decode_rebalance_every),
            ("autoscale", autoscale),
            ("min_engines", min_engines), ("max_engines", max_engines),
            ("joint_autoscale", joint_autoscale),
            ("min_prefill", min_prefill), ("max_prefill", max_prefill),
            ("ttft_budget_ms", ttft_budget_ms),
            ("stream_handoff", stream_handoff),
            ("stream_chunk", stream_chunk),
            ("degrade_shed_queue_s", degrade_shed_queue_s),
            ("batch_tpot_budget_ms", batch_tpot_budget_ms),
            ("batch_admission", batch_admission),
            ("preempt_batch", preempt_batch),
            ("brownout", brownout),
            ("brownout_patience", brownout_patience),
            ("brownout_cooldown", brownout_cooldown),
            ("hit_aware_admission", hit_aware_admission),
        ) if v is not None}
        # use_mtp is engine state, not policy: the scheduler's MTP cost
        # accounting must always match what the decode engine actually runs
        # (a provided scheduler_config cannot flip it — reconfigure_scheduler
        # enforces the same invariant later).
        overrides["use_mtp"] = bool(use_mtp)
        sched_cfg = dataclasses.replace(
            scheduler_config or SchedulerConfig(), **overrides)
        if sched_cfg.autoscale and not (
                sched_cfg.min_engines <= decode_engines
                <= sched_cfg.max_engines):
            raise ValueError(
                f"decode_engines={decode_engines} must start inside the "
                f"autoscale clamp [{sched_cfg.min_engines}, "
                f"{sched_cfg.max_engines}]")
        n_prefill = prefill_engines if prefill_engines is not None \
            else n_prefill
        if sched_cfg.joint_autoscale:
            if not (1 <= sched_cfg.min_prefill <= n_prefill
                    <= sched_cfg.max_prefill):
                raise ValueError(
                    f"prefill_engines={n_prefill} must start inside the "
                    f"joint-autoscale clamp [{sched_cfg.min_prefill}, "
                    f"{sched_cfg.max_prefill}] (min_prefill >= 1)")
            if not (sched_cfg.min_engines <= decode_engines
                    <= sched_cfg.max_engines):
                raise ValueError(
                    f"decode_engines={decode_engines} must start inside the "
                    f"joint-autoscale decode clamp [{sched_cfg.min_engines}, "
                    f"{sched_cfg.max_engines}]")
        if sched_cfg.stream_chunk is not None and sched_cfg.stream_chunk < 1:
            raise ValueError("stream_chunk must be >= 1")
        self.capacity = capacity

        def prefill_factory(i: int) -> PrefillEngine:
            # The joint controller's prefill grow path: an engine identical
            # to the roster's, numbered by its instance id.
            return PrefillEngine(params, cfg, capacity, context_cache,
                                 i, moe_fn, prefill_chunk=prefill_chunk)

        self.prefill_pool = PrefillPool(
            [prefill_factory(i) for i in range(n_prefill)],
            engine_factory=prefill_factory)
        # Shared list: pool growth is immediately visible to the serve loop.
        self.prefills = self.prefill_pool.engines

        def engine_factory(seed: int) -> DecodeEngine:
            # The autoscaler's grow path: a fresh engine identical to the
            # pool's (same jit config), seeded by its engine id.
            return DecodeEngine(params, cfg, decode_batch, capacity,
                                moe_fn, use_mtp, mtp_params, seed=seed,
                                interleave=sched_cfg.interleave_microbatches,
                                n_micro=sched_cfg.n_micro,
                                decode_chunk=sched_cfg.decode_chunk,
                                mtp_fused=mtp_fused)

        engines = [engine_factory(e) for e in range(decode_engines)]
        # Affinity routing scores residency against the shared EMS index
        # when the cache is an EMSService; a plain ContextCache keeps the
        # legacy advisory per-engine residency.
        self._ems = context_cache if isinstance(context_cache, EMSService) \
            else None
        self.pool = DecodePool(
            engines, make_decode_router(sched_cfg.decode_policy,
                                        decode_engines, ems=self._ems),
            engine_factory=engine_factory)
        self.decode = engines[0]       # single-engine compatibility alias
        self.faults = fault_injector
        self.transfer = KVTransferEngine(
            fault_hook=None if self.faults is None
            else self.faults.transfer_fault)
        self.scheduler = Scheduler(self.prefill_pool.n, self.pool.slot_mgrs,
                                   sched_cfg)
        # In-flight registry: rid -> original Request, kept from KV handoff
        # until decode finish/shed. Engine-failure recovery needs the
        # prompt and token budget to rebuild a crashed slot by replay
        # re-prefill; nothing else retains them once prefill returns.
        self._inflight: dict = {}

    def reconfigure_scheduler(self, scheduler_config: SchedulerConfig) -> None:
        """Swap policy/SLO configuration between serve() waves without
        rebuilding (re-jitting) the engines. Control-plane only: decode
        microbatch interleaving is baked into the jitted step at
        construction, so a config that flips it is rejected."""
        cur = self.scheduler.config
        new = scheduler_config
        if (new.interleave_microbatches != cur.interleave_microbatches
                or (new.interleave_microbatches
                    and new.n_micro != cur.n_micro)):
            raise ValueError(
                "interleave_microbatches/n_micro are baked into the jitted "
                "decode step at ServingSystem construction; build a new "
                "system to change them")
        if new.decode_chunk != cur.decode_chunk:
            raise ValueError(
                "decode_chunk is baked into the jitted decode loop at "
                "ServingSystem construction; build a new system to change it")
        # continuous_batching is deliberately NOT baked: adaptive widths
        # jit lazily per width, so flipping it between waves only warms
        # additional scan programs on demand.
        if new.use_mtp != self.decode.use_mtp:
            raise ValueError(
                "use_mtp is baked into the decode engine at ServingSystem "
                "construction; build a new system to change it")
        if new.decode_policy != cur.decode_policy:
            # Routing is pure control plane: swap the pool router in place
            # (a fresh policy instance — affinity/cursor state resets).
            self.pool.router = make_decode_router(new.decode_policy,
                                                  self.pool.n,
                                                  ems=self._ems)
        self.scheduler = Scheduler(self.prefill_pool.n, self.pool.slot_mgrs,
                                   scheduler_config)
        # Engine liveness is pool state: carry parked engines (both roles)
        # into the fresh scheduler's views.
        for e, live in enumerate(self.pool.live_mask):
            if not live:
                self.scheduler.set_engine_live(e, False)
        for i, live in enumerate(self.prefill_pool.live_mask):
            if not live:
                self.scheduler.set_prefill_live(i, False)

    def migrate_request(self, rid: int, dst_engine: int) -> float:
        """Force a cross-engine KV migration of an in-flight request (the
        drain is charged to the RDMA-plane transfer engine and recorded on
        the scheduler trace). Returns the virtual drain seconds."""
        trace = self.scheduler.traces.get(rid)
        src_e, _, seconds = self.pool.migrate(rid, dst_engine, self.transfer)
        if trace is not None:
            self.scheduler.on_migrate(trace, src_e, dst_engine, seconds)
        return seconds

    # -- fault tolerance ---------------------------------------------------
    def _apply_faults(self) -> List["_PendingAdmission"]:
        """One injector evaluation: re-assert straggler factors from each
        engine's clock, then fire any due engine crashes (a crash is
        detected at the chunk boundary after its scheduled instant — the
        tokens the engine emitted up to detection were already streamed,
        which is exactly why recovery is teacher-forced replay). Returns
        the recovered admissions, to be requeued at the FRONT of the
        waiting queue (they predate everything still queued)."""
        if self.faults is None:
            return []
        sched = self.scheduler
        for e in range(self.pool.n):
            sched.set_engine_slowdown(
                e, self.faults.slowdown(e, sched.engine_clock(e)))
        clocks = [sched.engine_clock(e) for e in range(self.pool.n)]
        recovered: List[_PendingAdmission] = []
        for e in self.faults.due_crashes(clocks):
            if not self.pool.live_mask[e]:
                continue               # already parked/dead: crash is moot
            recovered.extend(self._fail_engine(e))
        return recovered

    def _fail_engine(self, engine: int) -> List["_PendingAdmission"]:
        """Kill ``engine`` and recover its in-flight requests by replay
        re-prefill. Slot accounting is conserved through the failure
        (``fail_engine`` releases every slot), the scheduler's live mask
        and timeline record the capacity loss, and each lost request comes
        back as a recovered pending admission."""
        sched = self.scheduler
        fail_t = sched.engine_clock(engine)
        lost = self.pool.fail_engine(engine)
        sched.set_engine_live(engine, False)
        sched.on_engine_failure(engine)
        return [self._replay_recover(rid, payload, fail_t)
                for rid, payload, _cache_len in lost]

    def _replay_rebuild(self, rid: int, slot_payload: "_Slot",
                        at: float) -> Tuple["_PendingAdmission", int]:
        """Rebuild an interrupted request's KV: re-prefill its prompt plus
        a teacher-forced replay of every already-emitted token but the last
        (EMS-cached prefix blocks are reused, so mostly only the emitted
        suffix is recomputed), and verify greedy determinism — the replay
        prefill's next-token argmax must reproduce the last emitted token.
        The rebuilt output is therefore token-identical to the
        uninterrupted run by construction, not by luck. Shared by engine-
        failure recovery and batch-tier preemption; returns the pending
        re-admission and the replayed-token count."""
        sched = self.scheduler
        req: Request = self._inflight[rid]
        result = slot_payload.result
        remaining = slot_payload.remaining
        emitted = list(result.tokens)
        if not emitted or remaining <= 0:
            raise SlotError(
                f"rid={rid} interrupted with no emitted token or no budget "
                f"({len(emitted)} emitted, {remaining} remaining) — a live "
                "slot always holds >= 1 token and wants >= 1 more")
        replay = list(req.prompt) + emitted[:-1]
        # Replay runs on a live prefill instance — with a pooled roster the
        # original instance 0 may be parked by the joint controller.
        live = self.prefill_pool.live_ids
        first, caches, rres = self.prefills[live[0] if live else 0].run(
            Request(rid, replay, 1, arrival=at))
        if first != emitted[-1]:
            raise RuntimeError(
                f"replay re-prefill diverged for rid={rid}: argmax after "
                f"teacher-forcing {len(replay)} tokens gave {first}, the "
                f"interrupted engine had emitted {emitted[-1]} — greedy "
                "decode must be deterministic for replay to be token-exact")
        _, prefill_done = sched.charge_recovery_prefill(
            rres.computed_tokens, at)
        # Re-handoff over the RDMA plane. Fault-plan events may still claim
        # these attempts; an exhausted handoff costs more virtual time and
        # is simply re-sent (the plan is finite, so this terminates).
        tdt = 0.0
        while True:
            try:
                tdt += self.transfer.transfer(caches, rid=rid)
                break
            except TransferError as exc:
                tdt += exc.seconds
        ready = prefill_done + tdt
        del result.tokens[-1:]   # pool.add re-appends the verified token
        keys = tuple(self.cc.block_keys(replay)) \
            if self.cc is not None and self.pool.router.uses_affinity else ()
        return _PendingAdmission(first, caches, len(replay), result,
                                 remaining + 1, keys,
                                 ready_at=ready, recovered=True), \
            len(emitted) - 1

    def _replay_recover(self, rid: int, slot_payload: "_Slot",
                        fail_t: float) -> "_PendingAdmission":
        """Engine-failure recovery: rebuild the crashed slot by replay
        re-prefill and charge the latency as a recovery on the trace."""
        item, replayed = self._replay_rebuild(rid, slot_payload, fail_t)
        self.scheduler.on_recovery(self.scheduler.traces[rid], fail_t,
                                   tokens_replayed=replayed,
                                   ready_at=item.ready_at)
        return item

    def _preempt_request(self, rid: int) -> "_PendingAdmission":
        """Batch-tier preemption: evict ``rid``'s decode slot (the engine
        stays live; slot accounting is conserved), park its prompt +
        emitted tokens, and rebuild the KV by the same teacher-forced
        replay as failure recovery — so the resumed request finishes
        token-identical to the unpreempted run. The eviction-to-ready
        latency is charged to the victim's trace as ``preempt_seconds``."""
        sched = self.scheduler
        engine, payload, _cache_len = self.pool.evict(rid)
        t = sched.engine_clock(engine)
        item, replayed = self._replay_rebuild(rid, payload, t)
        sched.on_preempt(sched.traces[rid], t, tokens_replayed=replayed,
                         ready_at=item.ready_at)
        return item

    def _make_autoscaler(self) -> Optional[PoolAutoscaler]:
        """One PoolAutoscaler per serve() wave, built from the scheduler's
        *current* config and cost model (MTP feedback may have recalibrated
        the cost between waves — the controller must project TPOT with the
        same model the admission gate enforces)."""
        cfg = self.scheduler.config
        if not cfg.autoscale:
            return None
        return PoolAutoscaler(
            self.scheduler.cost, self.pool.engines[0].slot_mgr.n_slots,
            cfg.min_engines, cfg.max_engines,
            tpot_budget_s=self.scheduler.gate.budget_s,
            grow_patience=cfg.autoscale_grow_patience,
            shrink_patience=cfg.autoscale_shrink_patience,
            cooldown=cfg.autoscale_cooldown)

    def _autoscale_tick(self, scaler: Optional[PoolAutoscaler],
                        queue_depth: int) -> List["_PendingAdmission"]:
        """One controller evaluation between decode turns: apply a grow
        (spawn or revive an engine, register/warm its scheduler views) or a
        shrink (atomic migration-backed retirement, every move stamped on
        the trace), and record the scale event on the virtual timeline.
        The live roster may be empty after engine failures — the grow path
        (respawn toward ``min_engines``) must still run then. Returns any
        recovered admissions a drain-failure fallback produced (normally
        empty)."""
        if scaler is None:
            return []
        sched, pool = self.scheduler, self.pool
        # Shrink victim: fewest active slots among the LIVE roster; ties
        # retire the latest-spawned engine so engine 0 stays the stable
        # anchor. Post-failure the roster can be empty: no victim, and the
        # controller sees n_live=0 (dead engines are not capacity).
        victim = min(pool.live_ids,
                     key=lambda i: (pool.engines[i].active, -i)) \
            if pool.live_ids else None
        shrinkable = victim is not None and pool.n_live > 1 \
            and pool.can_drain(victim)
        decision = scaler.decide(pool.n_live, pool.active, queue_depth,
                                 shrinkable=shrinkable)
        if decision == "grow":
            engine, revived = pool.spawn_engine()
            if revived:
                sched.set_engine_live(engine, True)
            else:
                sched.register_engine(pool.engines[engine].slot_mgr)
            sched.record_scale_event("grow", engine)
        elif decision == "shrink":
            try:
                moved = pool.retire_engine(victim, self.transfer)
            except DrainError as exc:
                # The RDMA plane exhausted its retries mid-drain. The
                # completed moves stand; the stuck request's KV is intact
                # on the victim but must never be propagated unverified —
                # fall back to failing the victim over to replay
                # re-prefill, which completes the shrink with recovered
                # (token-identical) requests instead of garbage KV.
                for rid, dst, seconds in exc.moved:
                    sched.on_migrate(sched.traces[rid], victim, dst, seconds)
                return self._fail_engine(victim)
            for rid, dst, seconds in moved:
                sched.on_migrate(sched.traces[rid], victim, dst, seconds)
            sched.set_engine_live(victim, False)
            sched.record_scale_event("shrink", victim)
        return []

    def _make_joint(self) -> Optional[JointAutoscaler]:
        """One joint P/D controller per serve() wave (same rebuild rationale
        as :meth:`_make_autoscaler`): it shifts engine capacity between the
        prefill and decode roles under one SLO budget instead of growing
        the cluster."""
        cfg = self.scheduler.config
        if not cfg.joint_autoscale:
            return None
        return JointAutoscaler(
            self.scheduler.cost, self.pool.engines[0].slot_mgr.n_slots,
            min_prefill=cfg.min_prefill, max_prefill=cfg.max_prefill,
            min_decode=cfg.min_engines, max_decode=cfg.max_engines,
            tpot_budget_s=self.scheduler.gate.budget_s,
            ttft_budget_s=None if cfg.ttft_budget_ms is None
            else cfg.ttft_budget_ms * 1e-3,
            patience=cfg.joint_patience, cooldown=cfg.joint_cooldown)

    def _joint_tick(self, joint: Optional[JointAutoscaler],
                    queue_depth: int) -> List["_PendingAdmission"]:
        """One joint-controller evaluation between decode turns.

        ``shift_d2p`` retires the least-active decode engine (atomic
        migration-backed drain, falling back to replay-recovery engine
        failure exactly like the shrink path) and spawns/revives a prefill
        instance; ``shift_p2d`` parks the least-loaded prefill instance and
        spawns/revives a decode engine. Both directions are stamped on the
        scale-event timeline with their role so benches can plot the
        capacity see-saw."""
        if joint is None:
            return []
        sched, pool = self.scheduler, self.pool
        backlog = sched.prefill_backlog_s(sched.decode_now)
        victim = min(pool.live_ids,
                     key=lambda i: (pool.engines[i].active, -i)) \
            if pool.live_ids else None
        shrinkable = victim is not None and pool.n_live > 1 \
            and pool.can_drain(victim)
        decision = joint.decide(
            self.prefill_pool.n_live, pool.n_live, pool.active, queue_depth,
            backlog, decode_shrinkable=shrinkable)
        if decision == "shift_d2p":
            recovered: List[_PendingAdmission] = []
            try:
                moved = pool.retire_engine(victim, self.transfer)
            except DrainError as exc:
                for rid, dst, seconds in exc.moved:
                    sched.on_migrate(sched.traces[rid], victim, dst, seconds)
                recovered = self._fail_engine(victim)
            else:
                for rid, dst, seconds in moved:
                    sched.on_migrate(sched.traces[rid], victim, dst, seconds)
                sched.set_engine_live(victim, False)
            inst, revived = self.prefill_pool.spawn_engine()
            if revived:
                sched.set_prefill_live(inst, True)
            else:
                sched.register_prefill_instance()
            sched.record_scale_event("shift_d2p", victim, role="joint")
            return recovered
        if decision == "shift_p2d":
            # Prefill victim: least in-flight prompt tokens; ties park the
            # latest-spawned instance so instance 0 stays the anchor.
            pvictim = min(self.prefill_pool.live_ids,
                          key=lambda i: (self.prefills[i].load, -i))
            self.prefill_pool.retire_engine(pvictim)
            if self._ems is not None:
                # Retirement must not lose cached prefixes: demote the
                # instance's dirty HBM blocks into the shared pool tier.
                self._ems.drop_engine(self.prefills[pvictim]._ems_tag)
            sched.set_prefill_live(pvictim, False)
            engine, revived = pool.spawn_engine()
            if revived:
                sched.set_engine_live(engine, True)
            else:
                sched.register_engine(pool.engines[engine].slot_mgr)
            sched.record_scale_event("shift_p2d", engine, role="joint")
        return []

    # -- pipelined KV handoff ----------------------------------------------
    def _streamable(self) -> bool:
        """Chunked streaming needs sliceable sequence-axis caches — the
        same family EMS block reuse supports (ring-buffer SSM/hybrid
        state has no per-position KV to ship incrementally)."""
        return (self.scheduler.config.stream_handoff
                and self.cfg.attention_kind != "none"
                and not self.cfg.is_hybrid)

    def _stream_handoff(self, req: Request, trace, res: RequestResult,
                        caches: Any) -> Any:
        """Pipelined chunked KV handoff: ship each chunk's KV while the
        next chunk is still computing.

        The wire carries exactly the prompt's KV rows (``pack_blocks`` full
        chunks + a packed tail), chunk ``i`` becoming sendable when its last
        token's prefill completes — interpolated on the virtual clock from
        the trace's actual prefill window, so EMS-reused prefix chunks are
        ready immediately and the final chunk lands exactly at
        ``prefill_end``. Each chunk's transfer overlaps the remaining
        compute; the trace is charged only the pipeline tail past
        ``prefill_end`` (so ``ready_at = prefill_end + transfer_seconds``
        keeps meaning "KV fully landed"), with the hidden seconds recorded
        as ``overlap_seconds``. Returns the decode-side cache rebuilt from
        the streamed payloads — the bytes decode consumes are the bytes
        that crossed the wire, which is what makes streamed-vs-synchronous
        bit-identity a real end-to-end property rather than an accounting
        claim."""
        sched = self.scheduler
        cfg = self.cfg
        chunk = sched.config.stream_chunk or 8
        plen = len(req.prompt)
        n_full = plen // chunk
        segments: List[Tuple[int, int, np.ndarray]] = []
        payloads = cache_ops.pack_blocks(cfg, caches, n_full, chunk)
        for i, flat in enumerate(payloads):
            segments.append((i * chunk, chunk, np.asarray(flat)))
        tail = plen - n_full * chunk
        if tail:
            flat = cache_ops.pack_payload(
                cache_ops.seq_slice(cfg, caches, n_full * chunk, tail))
            segments.append((n_full * chunk, tail, np.asarray(flat)))
        # Compute-availability per chunk, interpolated from the prefill
        # window (charged per *computed* token; reused tokens are free).
        span = trace.prefill_end - trace.prefill_start
        per_tok = span / max(1, res.computed_tokens)
        prev_end = -float("inf")
        wire_total = 0.0
        total_bytes = 0
        max_chunk_bytes = 0
        for ci, (start, length, flat) in enumerate(segments):
            done = trace.prefill_start + \
                max(0, start + length - res.reused_tokens) * per_tok
            dt = self.transfer.transfer(flat, rid=req.rid, chunk=ci)
            nbytes = flat.size * flat.dtype.itemsize
            wire_total += dt
            total_bytes += nbytes
            max_chunk_bytes = max(max_chunk_bytes, nbytes)
            prev_end = max(done, prev_end) + dt
        seconds = prev_end - trace.prefill_end
        overlap = wire_total - seconds
        res.transfer_seconds = seconds
        sched.on_stream_transfer(trace, seconds, len(segments), overlap,
                                 total_bytes, max_chunk_bytes)
        # Rebuild the decode-side cache from what actually crossed the
        # wire. Positions past the prompt start zeroed (the synchronous
        # path may carry padded-write garbage there); both are beyond
        # cache_len, never attendable, and decode overwrites them.
        rebuilt = model_mod.make_caches(cfg, 1, self.capacity, jnp.float32)
        for start, length, flat in segments:
            tmpl = cache_ops.seq_slice(cfg, rebuilt, start, length)
            payload = cache_ops.unpack_payload(flat, tmpl)
            rebuilt = cache_ops.seq_insert(cfg, rebuilt, payload, start)
        return rebuilt

    def serve(self, requests: List[Request],
              open_loop: bool = False) -> List[RequestResult]:
        """Serve a request wave. ``open_loop`` drives arrival-time
        scheduling on the virtual clock: a request becomes visible to
        prefill only once the clock reaches its ``arrival``, and its KV is
        admissible only once the clock reaches its ``ready_at`` — so a
        Poisson burst actually queues against the admission gate instead
        of being batched up front (closed loop, the default, feeds
        everything immediately)."""
        sched = self.scheduler
        sched.begin_epoch()            # rids may repeat across serve() waves
        scaler = self._make_autoscaler()
        joint = self._make_joint()
        streaming = self._streamable()
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        results: List[RequestResult] = []
        waiting: List[_PendingAdmission] = []
        eps = 1e-12
        self._inflight.clear()
        # Per-epoch RDMA retry accounting (engine counters are lifetime).
        xfer0 = (self.transfer.retries, self.transfer.timeouts,
                 self.transfer.corruptions)

        def sync_transfer_counters() -> None:
            sched.transfer_retries = self.transfer.retries - xfer0[0]
            sched.transfer_timeouts = self.transfer.timeouts - xfer0[1]
            sched.transfer_corruptions = self.transfer.corruptions - xfer0[2]

        def item_ready(item: _PendingAdmission) -> float:
            """When this admission's KV is available: the recovery instant
            for recovered requests, the original handoff otherwise."""
            if item.ready_at is not None:
                return item.ready_at
            return sched.traces[item.result.rid].ready_at

        def shed_item(item: _PendingAdmission) -> None:
            """Unified shed semantics: like the up-front capacity reject,
            a gate shed returns no tokens — the prefill output is dropped,
            not delivered — and contributes nothing to throughput."""
            trace = sched.traces[item.result.rid]
            item.result.shed = True
            item.result.tokens.clear()
            sched.on_shed(trace)
            sched.on_finish(trace, 0)
            results.append(item.result)
            self._inflight.pop(item.result.rid, None)

        def item_class(item: _PendingAdmission) -> str:
            return sched.traces[item.result.rid].slo_class

        def youngest_batch_victim() -> Optional[int]:
            """Preemption victim: the most recently admitted batch-tier
            slot across the live pool (max decode_admit; rid breaks ties
            deterministically). Interactive slots are never victims."""
            best = None
            for e in self.pool.live_ids:
                for _slot, info in \
                        self.pool.engines[e].slot_mgr.active_slots():
                    tr = sched.traces.get(info.rid)
                    if tr is None or tr.slo_class != "batch":
                        continue
                    key = (tr.decode_admit, tr.rid)
                    if best is None or key > best[0]:
                        best = (key, tr.rid)
            return None if best is None else best[1]

        def try_preempt(item: _PendingAdmission, trace,
                        parked: List[_PendingAdmission]) -> Tuple[str, int]:
            """Evict youngest batch-tier slots until ``item`` (interactive,
            gate-blocked) becomes admissible or no victims remain. Each
            victim is parked as a recovered-style pending re-admission at
            the BACK of the queue (deprioritized — that is the point of
            preemption). Bounded by the pool's batch-tier slot count."""
            while True:
                victim = youngest_batch_victim()
                if victim is None:
                    return "wait", 0
                parked.append(self._preempt_request(victim))
                engine = self.pool.select_engine(item.block_keys)
                decision = sched.admission_decision(trace, engine,
                                                    recovered=item.recovered)
                if decision != "wait":
                    return decision, engine

        def admit_class(items: List[_PendingAdmission], mid_turn: bool,
                        parked: List[_PendingAdmission]
                        ) -> Tuple[List[_PendingAdmission], bool]:
            """One SLO class's FIFO admission pass: admit gate-ready items
            in order; the gate may queue or shed. Returns ``(kept,
            ready_blocked)`` — ``ready_blocked`` means a gate-ready item
            is still waiting (under strict priority a blocked interactive
            pass bars the batch pass, and it is the brownout ladder's
            pressure signal)."""
            kept: List[_PendingAdmission] = []
            for idx, item in enumerate(items):
                trace = sched.traces[item.result.rid]
                ready = item_ready(item)
                if open_loop and ready > sched.decode_now + eps:
                    # KV not yet ready on the open-loop clock: hold, and
                    # within-class FIFO holds the rest of the class.
                    kept.extend(items[idx:])
                    return kept, False
                engine = self.pool.select_engine(item.block_keys)
                decision = sched.admission_decision(trace, engine,
                                                    recovered=item.recovered)
                if decision == "shed" and item.recovered:
                    # Recovered/preempted requests already streamed tokens;
                    # shedding them would break replay token identity. They
                    # queue through shed modes and brownout levels alike.
                    decision = "wait"
                if (decision == "wait" and sched.preemption_enabled
                        and trace.slo_class != "batch"):
                    decision, engine = try_preempt(item, trace, parked)
                if decision == "admit":
                    slot = self.pool.engines[engine].free_slot()
                    if slot is None:
                        # Stale admission: the gate said "admit" but no slot
                        # is actually free (gate/slot state diverged). Never
                        # pass slot=None into DecodeSlotManager.allocate —
                        # requeue and retry after the next decode turn.
                        kept.extend(items[idx:])
                        return kept, True
                    self.pool.add(engine, slot, item.caches, item.first,
                                  item.prompt_len, item.result, item.max_new,
                                  item.block_keys)
                    if item.recovered:
                        sched.on_readmit(trace, engine, ready)
                    else:
                        sched.on_admit(trace, slot, engine)
                    if mid_turn:
                        sched.note_mid_scan_refill()
                elif decision == "shed":
                    shed_item(item)
                else:  # wait: keep within-class FIFO, stop this class
                    kept.extend(items[idx:])
                    return kept, True
            return kept, False

        def admit_waiting(mid_turn: bool = False) -> None:
            """Admit gate-ready requests with strict SLO-class priority:
            the interactive tier first (FIFO within the class), then the
            batch tier only if no gate-ready interactive request is still
            blocked — batch never delays a gate-ready interactive request.
            Runs once per wave boundary, and — under continuous batching —
            again after each engine's chunk drains (``mid_turn``), so a
            freed slot takes the next admission before the next engine
            steps instead of waiting out the whole turn."""
            nonlocal waiting
            if not self.pool.live_ids:
                # Total capacity loss. With an autoscaler the respawn path
                # will restore the floor — hold the queue. Without one no
                # engine is ever coming back: shed everything rather than
                # deadlock (graceful degradation's last resort).
                if scaler is None:
                    for item in waiting:
                        shed_item(item)
                    waiting = []
                return
            degrade = sched.config.degrade_shed_queue_s
            now = sched.decode_now
            # Class-ordered queue-age shedding: graceful degradation
            # (degrade_shed_queue_s) plus the brownout ladder's level-3
            # batch-age shed. At equal queue age the batch-tier backlog is
            # cut before any interactive request — interactive over-age
            # sheds only in a round with no over-age batch left. Recovered/
            # preempted items are exempt (replay identity).
            if degrade is not None or sched.brownout_level >= 3:
                over_batch: List[_PendingAdmission] = []
                over_inter: List[_PendingAdmission] = []
                for item in waiting:
                    if item.recovered:
                        continue
                    age = now - item_ready(item)
                    batch_tier = item_class(item) == "batch"
                    if degrade is not None and age > degrade + eps:
                        (over_batch if batch_tier else over_inter).append(item)
                    elif (batch_tier and sched.brownout_level >= 3
                          and age > sched.config.brownout_queue_age_s + eps):
                        over_batch.append(item)
                for item in over_batch or over_inter:
                    shed_item(item)
                waiting = [it for it in waiting if not it.result.shed]
            # Strict-priority class passes. Preempted victims are parked
            # during the interactive pass and re-enter at the back of the
            # queue; the merged keep-list preserves arrival order so each
            # class's FIFO survives the partition.
            parked: List[_PendingAdmission] = []
            inter = [it for it in waiting if item_class(it) != "batch"]
            batch = [it for it in waiting if item_class(it) == "batch"]
            inter_kept, ready_blocked = admit_class(inter, mid_turn, parked)
            if ready_blocked:
                batch_kept = batch   # batch never jumps a blocked interactive
            else:
                batch_kept, _ = admit_class(batch, mid_turn, parked)
            keep = {id(it) for it in inter_kept}
            keep.update(id(it) for it in batch_kept)
            waiting = [it for it in waiting if id(it) in keep] + parked

        def refill_imminent(engine: int) -> bool:
            """Could an admission land on ``engine`` around its next chunk?
            If so the adaptive scan shrinks so the host sync arrives where
            the refill can happen. Closed loop, any gate-held request
            qualifies; open loop, only work that becomes ready within
            roughly one full-width chunk of this engine's clock — a
            far-future arrival must not degrade the scan to per-step."""
            if not open_loop:
                return bool(waiting)
            horizon = (sched.config.decode_chunk
                       * sched.cost.step_time(self.pool.engines[engine].active))
            t = sched.engine_clock(engine) + horizon + eps
            if any(item_ready(w) <= t for w in waiting):
                return True
            return bool(pending) and pending[0].arrival <= t
        # Worst-case decode cache growth: max_new - 1 iterations, +1 slack
        # for an MTP accept on the final emitted token.
        slack = 1 if self.decode.use_mtp else 0
        affinity = self.cc is not None and self.pool.router.uses_affinity
        rebalance_every = sched.config.decode_rebalance_every
        decode_turns = 0
        while pending or waiting or self.pool.active:
            # Fault injection first: straggler factors re-asserted from the
            # engine clocks, due crashes fired. Recovered requests requeue
            # at the FRONT of the admission queue — they were admitted
            # before anything still waiting.
            recovered = self._apply_faults()
            if recovered:
                waiting[0:0] = recovered
            # prefill (async wrt decode; modeled sequentially on 1 CPU)
            while pending and (not open_loop or
                               pending[0].arrival <= sched.decode_now + eps):
                req = pending.pop(0)
                trace = sched.on_arrival(req.rid, req.arrival,
                                         len(req.prompt),
                                         slo_class=req.slo_class)
                if sched.config.hit_aware_admission and self.cc is not None:
                    # Hit-aware admission: probe the shared cache index at
                    # enqueue so the gate charges only the uncached suffix.
                    # Non-mutating on EMS; the prefill reuse clamp below
                    # re-derives the authoritative count.
                    trace.cached_tokens = self.cc.probe_prefix(req.prompt)
                # max_new <= 1 never decodes, so only the prompt must fit
                # (in the prefill cache, which shares `capacity`).
                need = len(req.prompt) if req.max_new_tokens <= 1 \
                    else len(req.prompt) + req.max_new_tokens - 1 + slack
                if need > self.decode.capacity:
                    # Reject up front: admitting would overflow the static KV
                    # slot mid-decode and abort the whole batch.
                    res = RequestResult(req.rid, [], shed=True,
                                        slo_class=req.slo_class)
                    sched.on_shed(trace)
                    sched.on_finish(trace, 0)
                    results.append(res)
                    continue
                eng = self.prefills[sched.route_prefill(
                    trace, [e.load for e in self.prefills],
                    candidates=self.prefill_pool.live_ids)]
                first, caches, res = eng.run(req)
                res.slo_class = req.slo_class
                sched.on_prefill_done(trace, eng.instance_id,
                                      res.computed_tokens, res.reused_tokens)
                if req.max_new_tokens <= 1:
                    # Prefill already produced the only requested token:
                    # no decode slot (a dead step could overflow a prompt-
                    # filled KV slot) and no KV handoff to charge.
                    if req.max_new_tokens == 1:
                        res.tokens.append(first)
                    sched.on_prefill_only_finish(trace)
                    sched.on_finish(trace, len(res.tokens))
                    results.append(res)
                    continue
                if streaming:
                    caches = self._stream_handoff(req, trace, res, caches)
                else:
                    res.transfer_seconds = self.transfer.transfer(
                        caches, rid=req.rid)
                    sched.on_transfer(trace, res.transfer_seconds)
                keys = tuple(self.cc.block_keys(req.prompt)) if affinity \
                    else ()
                self._inflight[req.rid] = req
                waiting.append(_PendingAdmission(first, caches,
                                                 len(req.prompt), res,
                                                 req.max_new_tokens, keys))
            admit_waiting()
            # Brownout ladder tick: one pressure observation per loop turn.
            # Pressure = a gate-ready interactive request is still blocked
            # after admission ran; calm turns (including idle ones) let the
            # ladder descend, so a drained burst always steps back down.
            if sched.config.brownout:
                now = sched.decode_now + eps
                sched.note_overload(any(
                    item_class(it) != "batch" and item_ready(it) <= now
                    for it in waiting))
            # decode turn: decode_chunk device iterations per host sync on
            # the fast path; every engine with active slots steps, and each
            # engine's virtual clock is charged per iteration so trace/SLO
            # semantics match per-step single-engine decode. Continuous
            # batching steps engines individually (adaptive scan width) and
            # re-runs admission after each engine's chunk drains, so freed
            # slots refill mid-turn — before the next engine steps — while
            # per-engine clock charging and the autoscaler's demand signal
            # (evaluated once per turn, below) stay exactly as in the
            # wave-shaped loop.
            if self.pool.active:
                decode_turns += 1
                continuous = sched.config.continuous_batching
                stepped = []
                for engine in list(self.pool.live_ids):
                    if not self.pool.engines[engine].active:
                        continue
                    finished, iter_log = self.pool.step_engine(
                        engine, continuous=continuous,
                        refill_pending=continuous and refill_imminent(engine))
                    stepped.append(engine)
                    for entry in iter_log:
                        sched.on_decode_step(*entry, engine=engine)
                    for r in finished:
                        sched.on_finish(sched.traces[r.rid], len(r.tokens))
                        self._inflight.pop(r.rid, None)
                    results.extend(finished)
                    if continuous and waiting:
                        admit_waiting(mid_turn=True)
                sched.sync_idle_clocks(stepped)
                if rebalance_every and decode_turns % rebalance_every == 0:
                    try:
                        moved = self.pool.rebalance(self.transfer)
                    except TransferError:
                        # Exhausted retries on an *optional* move: the
                        # victim is intact on its source engine (migrate
                        # releases the source only after delivery), so
                        # skip this rebalance rather than escalate.
                        moved = None
                    if moved is not None:
                        rid, src_e, dst_e, seconds = moved
                        sched.on_migrate(sched.traces[rid], src_e, dst_e,
                                         seconds)
                # Autoscale between decode turns: demand = resident slots
                # + the admissions the gate is holding right now. Open
                # loop, a waiting request whose KV is still in flight
                # (ready_at in the future) is NOT queue pressure yet — no
                # engine could serve it, so spawning for it would buy an
                # idle engine and churn the pool.
                if scaler is not None or joint is not None:
                    if open_loop:
                        now = sched.decode_now + eps
                        queued = sum(1 for item in waiting
                                     if item_ready(item) <= now)
                    else:
                        queued = len(waiting)
                    recovered = self._autoscale_tick(scaler, queued)
                    recovered.extend(self._joint_tick(joint, queued))
                    if recovered:
                        waiting[0:0] = recovered
            elif (scaler is not None or joint is not None) and waiting \
                    and not self.pool.live_ids:
                # Every engine is dead and nothing can step: run the
                # controllers anyway so the respawn-toward-min_engines /
                # shift-prefill-to-decode paths restore capacity (the tick
                # above only runs between decode turns, which need a live
                # engine to exist).
                self._autoscale_tick(scaler, len(waiting))
                self._joint_tick(joint, len(waiting))
            elif open_loop and (pending or waiting):
                # Decode pool idle with future work: fast-forward the
                # virtual clock to the next event that can actually
                # unblock progress. Admission is FIFO, so that is the
                # *head* waiting request's KV-ready time — not the min
                # over all waiting requests: a later-arriving request can
                # finish prefill earlier (shorter prompt, idler instance),
                # and advancing only to its ready_at would leave the head
                # still gated and the loop spinning on the same instant.
                events = []
                if waiting:
                    events.append(item_ready(waiting[0]))
                if pending:
                    events.append(pending[0].arrival)
                sched.advance_clock(min(events))
        sync_transfer_counters()
        if self.decode.use_mtp:
            # Acceptance-rate feedback: fold the wave's measured draft
            # acceptance into the cost model so the next wave's admission
            # gate sizes its batch to observed, not assumed, speculation.
            sched.feedback_mtp_acceptance()
        return results
