"""PDC peer-to-peer serving engines (paper §4.1).

Three independently scalable pools, communicating only via explicit KV
interfaces:

* :class:`PrefillEngine`  — prompt processing + EMS context-cache reuse/store
  (reused prefixes skip computation; suffixes run with position offsets).
* :class:`DecodeEngine`   — continuous-batched autoregressive decode over
  fixed slots with **per-request cache lengths** (vector cache_len), optional
  MTP speculative decoding and microbatch interleaving.
* :class:`ServingSystem`  — the peer-to-peer glue: a *stateless* scheduler
  routes prefills to the least-loaded instance (no cache-locality constraint
  — the paper's central contrast with KVCache-centric designs), hands KV off
  over the RDMA-plane transfer engine, and inserts requests into any free
  decode slot.

Everything runs functionally on CPU with smoke configs; on TPU the same
step functions are pjit-ed over the production mesh (launch/serve.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import mtp as mtp_mod
from repro.mempool.context_cache import ContextCache
from repro.models import model as model_mod
from repro.serving import cache_ops
from repro.serving.transfer import KVTransferEngine


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    arrival: float = 0.0


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: List[int]
    reused_tokens: int = 0
    computed_tokens: int = 0
    prefill_instance: int = -1
    transfer_seconds: float = 0.0
    decode_iters: int = 0


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


class PrefillEngine:
    def __init__(self, params, cfg: ModelConfig, capacity: int,
                 context_cache: Optional[ContextCache] = None,
                 instance_id: int = 0, moe_fn=None):
        self.params, self.cfg, self.capacity = params, cfg, capacity
        self.cc = context_cache
        self.instance_id = instance_id
        self.load = 0  # in-flight prompt tokens (scheduler signal)
        self._prefill = jax.jit(
            lambda p, b: model_mod.prefill(p, cfg, b, capacity, moe_fn,
                                           cache_dtype=jnp.float32))
        self._step = jax.jit(
            lambda p, t, c, l: model_mod.decode_step(p, cfg, t, c, l, moe_fn))

    def _fresh_cache(self):
        return model_mod.make_caches(self.cfg, 1, self.capacity, jnp.float32)

    def run(self, req: Request) -> Tuple[int, Any, RequestResult]:
        """Process one prompt. Returns (first_token, caches(B=1), result)."""
        cfg = self.cfg
        prompt = list(req.prompt)
        res = RequestResult(req.rid, [], prefill_instance=self.instance_id)
        self.load += len(prompt)
        try:
            reuse_len = 0
            caches = None
            if self.cc is not None and cfg.attention_kind != "none" \
                    and not cfg.is_hybrid:
                reuse_len, keys = self.cc.match_prefix(prompt)
                reuse_len = min(reuse_len, len(prompt) - 1)
                reuse_len -= reuse_len % self.cc.block
                keys = keys[: reuse_len // self.cc.block]
                if reuse_len > 0:
                    caches = self._fresh_cache()
                    tmpl = cache_ops.seq_slice(cfg, caches, 0, self.cc.block)
                    for bi, key in enumerate(keys):
                        flat = self.cc.pool.get(key)
                        payload = cache_ops.unpack_payload(flat, tmpl)
                        caches = cache_ops.seq_insert(cfg, caches, payload,
                                                      bi * self.cc.block)
            if reuse_len > 0:
                # Suffix-only computation: teacher-forced continuation from
                # the reused prefix (positions offset by reuse_len).
                logits = None
                cl = jnp.int32(reuse_len)
                for tok in prompt[reuse_len:]:
                    t = jnp.full((1, 1), tok, jnp.int32)
                    logits, caches = self._step(self.params, t, caches, cl)
                    cl = cl + 1
                first = int(jnp.argmax(logits[0]))
                res.computed_tokens = len(prompt) - reuse_len
            else:
                batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
                logits, caches = self._prefill(self.params, batch)
                first = int(jnp.argmax(logits[0, len(prompt) - 1]))
                res.computed_tokens = len(prompt)
            res.reused_tokens = reuse_len

            # Store newly computed full blocks back to EMS (async IRL).
            if self.cc is not None and cfg.attention_kind != "none" \
                    and not cfg.is_hybrid:
                n_blocks = len(prompt) // self.cc.block
                payloads = []
                for bi in range(n_blocks):
                    sl = cache_ops.seq_slice(cfg, caches, bi * self.cc.block,
                                             self.cc.block)
                    payloads.append(cache_ops.pack_payload(sl))
                if payloads:
                    self.cc.store(prompt[: n_blocks * self.cc.block], payloads)
            return first, caches, res
        finally:
            self.load -= len(prompt)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Slot:
    rid: int
    remaining: int
    result: RequestResult


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig, max_batch: int, capacity: int,
                 moe_fn=None, use_mtp: bool = False, mtp_params=None, seed=0):
        self.params, self.cfg = params, cfg
        self.b, self.capacity = max_batch, capacity
        self.use_mtp = use_mtp
        self.mtp_params = mtp_params
        self.caches = model_mod.make_caches(cfg, max_batch, capacity, jnp.float32)
        self.cache_len = jnp.zeros((max_batch,), jnp.int32)
        self.cur_tok = jnp.zeros((max_batch,), jnp.int32)
        self.draft_tok = jnp.zeros((max_batch,), jnp.int32)
        self.slots: List[Optional[_Slot]] = [None] * max_batch
        self.key = jax.random.PRNGKey(seed)
        self.iters = 0
        self._step = jax.jit(
            lambda p, t, c, l: model_mod.decode_step(p, cfg, t, c, l, moe_fn))
        if use_mtp:
            self._mtp_step = jax.jit(
                lambda p, mp, x, d, c, l, k: mtp_mod.mtp_step(
                    p, mp, cfg, x, d, c, l, k, moe_fn))

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def add(self, slot: int, req_cache, first_token: int, prompt_len: int,
            result: RequestResult, max_new: int) -> None:
        self.caches = cache_ops.insert_request(self.cfg, self.caches,
                                               req_cache, slot)
        self.cache_len = self.cache_len.at[slot].set(prompt_len)
        self.cur_tok = self.cur_tok.at[slot].set(first_token)
        result.tokens.append(first_token)
        self.slots[slot] = _Slot(result.rid, max_new - 1, result)
        if self.use_mtp:
            d = mtp_mod.propose_draft(self.params, self.mtp_params, self.cfg,
                                      self.cur_tok[slot: slot + 1])
            self.draft_tok = self.draft_tok.at[slot].set(int(d[0]))

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def step(self) -> List[RequestResult]:
        """One batched decode iteration. Returns requests finished this step."""
        self.iters += 1
        self.key, sub = jax.random.split(self.key)
        if self.use_mtp:
            emitted, accepted, x_next, d_next, self.caches, self.cache_len = \
                self._mtp_step(self.params, self.mtp_params, self.cur_tok,
                               self.draft_tok, self.caches, self.cache_len, sub)
            self.cur_tok, self.draft_tok = x_next, d_next
            em = np.asarray(emitted)
            acc = np.asarray(accepted)
        else:
            logits, self.caches = self._step(self.params, self.cur_tok[:, None],
                                             self.caches, self.cache_len)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self.cache_len = self.cache_len + 1
            self.cur_tok = nxt
            em = np.asarray(nxt)[:, None]
            acc = np.zeros(self.b, bool)

        finished = []
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            slot.result.decode_iters += 1
            new_toks = [int(em[i, 0])]
            if self.use_mtp and acc[i] and slot.remaining > 1:
                new_toks.append(int(em[i, 1]))
            for t in new_toks:
                if slot.remaining > 0:
                    slot.result.tokens.append(t)
                    slot.remaining -= 1
            if slot.remaining <= 0:
                finished.append(slot.result)
                self.slots[i] = None
        return finished


# ---------------------------------------------------------------------------
# Peer-to-peer serving system (PDC glue)
# ---------------------------------------------------------------------------


class ServingSystem:
    def __init__(self, params, cfg: ModelConfig, *, n_prefill: int = 2,
                 decode_batch: int = 4, capacity: int = 128,
                 context_cache: Optional[ContextCache] = None,
                 use_mtp: bool = False, mtp_params=None, moe_fn=None):
        self.cfg = cfg
        self.cc = context_cache
        self.prefills = [PrefillEngine(params, cfg, capacity, context_cache,
                                       i, moe_fn) for i in range(n_prefill)]
        self.decode = DecodeEngine(params, cfg, decode_batch, capacity,
                                   moe_fn, use_mtp, mtp_params)
        self.transfer = KVTransferEngine()

    def _route(self) -> PrefillEngine:
        """Stateless scheduling: least-loaded instance, NO locality term —
        any NPU can reach any cached block uniformly over UB (paper §4.1)."""
        return min(self.prefills, key=lambda e: e.load)

    def serve(self, requests: List[Request]) -> List[RequestResult]:
        pending = list(requests)
        results: List[RequestResult] = []
        waiting: List[Tuple[int, Any, int, RequestResult, int]] = []
        while pending or waiting or self.decode.active:
            # prefill (async wrt decode; modeled sequentially on 1 CPU)
            while pending:
                req = pending.pop(0)
                eng = self._route()
                first, caches, res = eng.run(req)
                res.transfer_seconds = self.transfer.transfer(caches)
                waiting.append((first, caches, len(req.prompt), res,
                                req.max_new_tokens))
            # admit into free decode slots
            admitted = []
            for item in waiting:
                slot = self.decode.free_slot()
                if slot is None:
                    break
                first, caches, plen, res, mnt = item
                req_cache = caches  # prefill ran with batch=1
                self.decode.add(slot, req_cache, first, plen, res, mnt)
                admitted.append(item)
            for item in admitted:
                waiting.remove(item)
            # decode step
            if self.decode.active:
                results.extend(self.decode.step())
        return results
