"""Multi-instance decode pools with EMS-aware routing and cross-engine KV
migration (paper §4.1; xDeepServe / DeepServe pool-level scheduling).

The paper's peer-to-peer architecture scales the decode pool independently
of prefill and caching, and the UB plane makes *any* decode instance
reachable from the shared KV store. This module adds the pool layer on top
of :class:`~repro.serving.engine.DecodeEngine`:

* :class:`DecodePoolRouter` — pluggable decode-engine routing policy (by
  name: ``least_loaded_slots``, ``round_robin``, ``cache_affinity``).
  Unlike :class:`~repro.serving.scheduler.PrefillRouter` (locality-free by
  design), decode routing MAY use data placement: ``cache_affinity``
  prefers the engine that already holds a request's reusable EMS prefix
  blocks (block keys from ``mempool/context_cache.py``), so the warm KV
  never crosses engines. ``select`` must be *pure* — the pool commits a
  decision via :meth:`DecodePoolRouter.on_admit` only when the request is
  actually placed, so a gated/waiting request never mutates router state
  (decisions stay deterministic across admission retries).
* :class:`DecodePool` — owns N engines (identical model/capacity), steps
  every engine with active slots per serving turn, and performs
  **cross-engine KV migration**: a slot's cache rows are drained through
  :func:`~repro.serving.cache_ops.pack_request` into one contiguous byte
  buffer, charged to the RDMA-plane transfer engine, and re-inserted
  bit-exactly into a peer engine — the mechanism behind hot-pool
  rebalancing and engine retirement.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.scheduler import SlotError


# ---------------------------------------------------------------------------
# Decode-pool routing policies
# ---------------------------------------------------------------------------


class DecodePoolRouter:
    """Chooses a decode engine for an admitted request.

    ``select`` sees per-engine active/free slot counts plus the request's
    EMS block keys, and must be pure and deterministic; state transitions
    happen only in ``on_admit`` (called when the placement commits).
    """

    name = "base"
    #: whether the ServingSystem should compute EMS block keys per request
    uses_affinity = False

    def __init__(self, n_engines: int):
        if n_engines < 1:
            raise ValueError("need at least one decode engine")
        self.n = n_engines

    def select(self, active: Sequence[int], free: Sequence[int],
               block_keys: Sequence[str] = ()) -> int:
        raise NotImplementedError

    def on_admit(self, engine: int,
                 block_keys: Sequence[str] = ()) -> None:  # pragma: no cover
        """Notification that a routed request was actually placed."""


class LeastLoadedSlotsRouter(DecodePoolRouter):
    """Engine with the fewest active slots, preferring engines that have a
    free slot at all (ties → lowest id)."""

    name = "least_loaded_slots"

    def select(self, active: Sequence[int], free: Sequence[int],
               block_keys: Sequence[str] = ()) -> int:
        return min(range(self.n), key=lambda i: (free[i] <= 0, active[i], i))


class PoolRoundRobinRouter(DecodePoolRouter):
    """Strict cyclic assignment in admission order. The cursor advances on
    *commit* (``on_admit``), so a request the gate holds retries the same
    engine — deterministic for a fixed request stream."""

    name = "round_robin"

    def __init__(self, n_engines: int):
        super().__init__(n_engines)
        self._next = 0

    def select(self, active: Sequence[int], free: Sequence[int],
               block_keys: Sequence[str] = ()) -> int:
        return self._next

    def on_admit(self, engine: int,
                 block_keys: Sequence[str] = ()) -> None:
        self._next = (engine + 1) % self.n


class CacheAffinityRouter(DecodePoolRouter):
    """EMS-aware placement: prefer the engine already holding the request's
    reusable prefix blocks (most matched block keys wins), falling back to
    least-loaded-slots. Engines with no free slot are deprioritized so
    affinity never stalls the pool while a peer sits idle; the residency
    map persists across serve() waves (cache affinity is cross-wave by
    nature)."""

    name = "cache_affinity"
    uses_affinity = True

    def __init__(self, n_engines: int):
        super().__init__(n_engines)
        self._resident: Dict[str, int] = {}   # block key -> last engine

    def score(self, block_keys: Sequence[str]) -> List[int]:
        scores = [0] * self.n
        for k in block_keys:
            e = self._resident.get(k)
            if e is not None:
                scores[e] += 1
        return scores

    def select(self, active: Sequence[int], free: Sequence[int],
               block_keys: Sequence[str] = ()) -> int:
        scores = self.score(block_keys)
        return min(range(self.n),
                   key=lambda i: (free[i] <= 0, -scores[i], active[i], i))

    def on_admit(self, engine: int,
                 block_keys: Sequence[str] = ()) -> None:
        for k in block_keys:
            self._resident[k] = engine


DECODE_ROUTERS = {r.name: r for r in
                  (LeastLoadedSlotsRouter, PoolRoundRobinRouter,
                   CacheAffinityRouter)}


def make_decode_router(policy: str, n_engines: int) -> DecodePoolRouter:
    try:
        return DECODE_ROUTERS[policy](n_engines)
    except KeyError:
        raise ValueError(
            f"unknown decode routing policy {policy!r}; "
            f"available: {sorted(DECODE_ROUTERS)}") from None


# ---------------------------------------------------------------------------
# The pool
# ---------------------------------------------------------------------------


class DecodePool:
    """N decode engines behind one routing/migration facade.

    Engines must be homogeneous (same model config and KV capacity) so a
    migrated cache payload lands on an identical layout. Compute stays in
    the engines; the pool only routes, steps, and moves KV.
    """

    def __init__(self, engines: Sequence, router: DecodePoolRouter):
        engines = list(engines)
        if not engines:
            raise ValueError("need at least one decode engine")
        if router.n != len(engines):
            raise ValueError(
                f"router sized for {router.n} engines, pool has "
                f"{len(engines)}")
        if len({e.capacity for e in engines}) != 1 or \
                len({e.cfg.name for e in engines}) != 1:
            raise ValueError(
                "pool engines must share model config and KV capacity "
                "(migration payloads assume an identical cache layout)")
        self.engines = engines
        self.router = router
        self.migrations = 0
        self.migrated_bytes = 0

    # -- aggregate views ---------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.engines)

    @property
    def active(self) -> int:
        return sum(e.active for e in self.engines)

    @property
    def capacity(self) -> int:
        return self.engines[0].capacity

    @property
    def use_mtp(self) -> bool:
        return self.engines[0].use_mtp

    @property
    def slot_mgrs(self) -> List:
        return [e.slot_mgr for e in self.engines]

    def locate(self, rid: int) -> Optional[Tuple[int, int]]:
        """(engine, slot) currently decoding ``rid``, or None."""
        for e, eng in enumerate(self.engines):
            for slot, info in eng.slot_mgr.active_slots():
                if info.rid == rid:
                    return e, slot
        return None

    # -- routing + placement ----------------------------------------------
    def select_engine(self, block_keys: Sequence[str] = ()) -> int:
        return self.router.select([e.active for e in self.engines],
                                  [e.slot_mgr.free for e in self.engines],
                                  block_keys)

    def add(self, engine: int, slot: int, req_cache, first_token: int,
            prompt_len: int, result, max_new: int,
            block_keys: Sequence[str] = ()) -> None:
        """Place a prefilled request on ``engine`` and commit the routing
        decision (router state mutates only here)."""
        self.engines[engine].add(slot, req_cache, first_token, prompt_len,
                                 result, max_new)
        self.router.on_admit(engine, block_keys)

    # -- stepping ----------------------------------------------------------
    def step_all(self) -> List[Tuple[int, list, list]]:
        """One decode turn across the pool: every engine with active slots
        runs one host-sync chunk. Returns ``(engine, finished, iter_log)``
        per stepped engine, in engine order, so the scheduler can charge
        each engine's virtual clock independently."""
        out = []
        for e, eng in enumerate(self.engines):
            if eng.active:
                finished, iter_log = eng.step_chunk()
                out.append((e, finished, iter_log))
        return out

    # -- cross-engine KV migration ----------------------------------------
    def migrate(self, rid: int, dst_engine: int,
                transfer=None) -> Tuple[int, int, float]:
        """Drain ``rid``'s slot from its current engine into ``dst_engine``
        bit-exactly. Returns (src_engine, dst_slot, transfer_seconds).

        The slot's cache rows, ``cache_len``, current/draft tokens, and
        engine-side payload all move; the drain is charged to the
        RDMA-plane ``transfer`` engine when one is given (the paper's
        scale-out plane — migration never contends with decode compute).
        """
        loc = self.locate(rid)
        if loc is None:
            raise SlotError(f"rid={rid} is not resident in any pool engine")
        src_e, src_slot = loc
        if src_e == dst_engine:
            raise ValueError(
                f"rid={rid} already decodes on engine {dst_engine}")
        if not 0 <= dst_engine < self.n:
            raise ValueError(f"no engine {dst_engine} in a pool of {self.n}")
        src, dst = self.engines[src_e], self.engines[dst_engine]
        dst_slot = dst.slot_mgr.free_slot()
        if dst_slot is None:
            raise SlotError(
                f"engine {dst_engine} has no free slot for migration")
        flat, cache_len, cur_tok, draft_tok = src.export_slot(src_slot)
        seconds = 0.0 if transfer is None else transfer.migrate(flat)
        info = src.slot_mgr.release(src_slot)
        dst.import_slot(dst_slot, flat, cache_len, cur_tok, draft_tok,
                        info.rid, info.payload)
        self.migrations += 1
        self.migrated_bytes += int(flat.nbytes)
        return src_e, dst_slot, seconds

    def rebalance(self, transfer=None
                  ) -> Optional[Tuple[int, int, int, float]]:
        """Migrate one request from the hottest engine to the coldest when
        the active-slot imbalance is >= 2 and the coldest has room — the
        pool-level rebalancing that keeps per-engine batches (and therefore
        per-engine TPOT) even. Deterministic: lowest engine ids win ties,
        the hottest engine's lowest-numbered active slot moves. Returns
        (rid, src_engine, dst_engine, seconds) or None."""
        act = [e.active for e in self.engines]
        hot = min(range(self.n), key=lambda i: (-act[i], i))
        cold = min(range(self.n), key=lambda i: (act[i], i))
        if act[hot] - act[cold] < 2 \
                or self.engines[cold].slot_mgr.free_slot() is None:
            return None
        _, info = next(self.engines[hot].slot_mgr.active_slots())
        rid = info.rid
        src_e, _, seconds = self.migrate(rid, cold, transfer)
        return rid, src_e, cold, seconds

    def drain_engine(self, engine: int, transfer=None
                     ) -> List[Tuple[int, int, float]]:
        """Retire an engine: migrate every active slot to peers with free
        capacity (least-loaded first). Returns one (rid, dst, seconds) per
        moved request; raises :class:`SlotError` when the peers cannot
        absorb the load."""
        moved = []
        for _, info in list(self.engines[engine].slot_mgr.active_slots()):
            peers = [i for i in range(self.n) if i != engine
                     and self.engines[i].slot_mgr.free_slot() is not None]
            if not peers:
                raise SlotError(
                    f"cannot drain engine {engine}: no peer has a free slot")
            dst = min(peers, key=lambda i: (self.engines[i].active, i))
            _, _, seconds = self.migrate(info.rid, dst, transfer)
            moved.append((info.rid, dst, seconds))
        return moved

    # -- reporting ---------------------------------------------------------
    def engine_stats(self) -> List[Dict[str, int]]:
        return [{"engine": e, "active": eng.active, "iters": eng.iters,
                 "slots_acquired": eng.slot_mgr.acquired,
                 "slots_released": eng.slot_mgr.released}
                for e, eng in enumerate(self.engines)]
