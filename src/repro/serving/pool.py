"""Multi-instance decode pools with EMS-aware routing and cross-engine KV
migration (paper §4.1; xDeepServe / DeepServe pool-level scheduling).

The paper's peer-to-peer architecture scales the decode pool independently
of prefill and caching, and the UB plane makes *any* decode instance
reachable from the shared KV store. This module adds the pool layer on top
of :class:`~repro.serving.engine.DecodeEngine`:

* :class:`DecodePoolRouter` — pluggable decode-engine routing policy (by
  name: ``least_loaded_slots``, ``round_robin``, ``cache_affinity``).
  Unlike :class:`~repro.serving.scheduler.PrefillRouter` (locality-free by
  design), decode routing MAY use data placement: ``cache_affinity``
  prefers the engine that already holds a request's reusable EMS prefix
  blocks (block keys from ``mempool/context_cache.py``), so the warm KV
  never crosses engines. ``select`` must be *pure* — the pool commits a
  decision via :meth:`DecodePoolRouter.on_admit` only when the request is
  actually placed, so a gated/waiting request never mutates router state
  (decisions stay deterministic across admission retries).
* :class:`DecodePool` — owns N engines (identical model/capacity), steps
  every engine with active slots per serving turn, and performs
  **cross-engine KV migration**: a slot's cache rows are drained through
  :func:`~repro.serving.cache_ops.pack_request` into one contiguous byte
  buffer, charged to the RDMA-plane transfer engine, and re-inserted
  bit-exactly into a peer engine — the mechanism behind hot-pool
  rebalancing and engine retirement.
* :class:`PoolAutoscaler` — deterministic grow/hold/shrink controller for
  the decode pool (the paper's independent decode-pool scaling): between
  decode turns it compares demand (active slots + admission-queue depth)
  against the per-engine batch the TPOT budget admits
  (:meth:`DecodeCostModel.max_batch_for`) and, with hysteresis, asks the
  pool to spawn a fresh engine or retire one via migration-backed
  :meth:`DecodePool.retire_engine`.

The pool distinguishes **live** and **parked** engines: retirement drains
an engine's slots to live peers and parks it (the jitted programs stay
warm), and a later grow revives the lowest parked engine before paying
for a new one — so scale oscillation never re-compiles.

Peer-to-peer PDC completes the picture with the prefill side:

* :class:`PrefillPool` — the same spawn/park/retire/fail lifecycle over
  :class:`~repro.serving.engine.PrefillEngine` instances. Prefill holds no
  resident per-request state between requests, so retirement parks an
  instance immediately (no drain) and failure only loses the instance,
  never a request. Instance ids are stable; the scheduler's
  ``PrefillRouter.resize`` / ``set_prefill_live`` views key on them.
* :class:`JointAutoscaler` — a capacity-conserving controller that shifts
  engines between the prefill and decode roles under one SLO budget
  (DeepServe's serverless joint P/D scaling): TTFT pressure (virtual
  prefill backlog past the TTFT budget) converts a drained decode engine
  into a prefill instance; TPOT pressure (decode demand past the SLO
  batch cap) converts an idle prefill instance into a decode engine.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.serving.scheduler import DecodeCostModel, SlotError
from repro.serving.transfer import TransferError


class DrainError(SlotError):
    """An engine drain moved some requests and then hit an exhausted
    RDMA-plane transfer. ``moved`` holds the migrations that completed
    (those requests live on their destinations); ``failed_rid`` is the
    request whose payload never left the source engine — its slot is
    intact there, so the caller can fall back to replay re-prefill
    instead of propagating possibly-garbage KV."""

    def __init__(self, msg: str, moved: List[Tuple[int, int, float]],
                 failed_rid: int):
        super().__init__(msg)
        self.moved = moved
        self.failed_rid = failed_rid


# ---------------------------------------------------------------------------
# Decode-pool routing policies
# ---------------------------------------------------------------------------


class DecodePoolRouter:
    """Chooses a decode engine for an admitted request.

    ``select`` sees per-engine active/free slot counts plus the request's
    EMS block keys, and must be pure and deterministic; state transitions
    happen only in ``on_admit`` (called when the placement commits).
    ``candidates`` restricts the choice to the pool's *live* engines
    (autoscaling parks retired engines in place, so engine ids are stable
    but not all of them are eligible); omitted means every engine.
    """

    name = "base"
    #: whether the ServingSystem should compute EMS block keys per request
    uses_affinity = False

    def __init__(self, n_engines: int):
        if n_engines < 1:
            raise ValueError("need at least one decode engine")
        self.n = n_engines

    def resize(self, n_engines: int) -> None:
        """The pool spawned engines: ids ``[old_n, n_engines)`` now exist."""
        if n_engines < self.n:
            raise ValueError(
                "pool engine ids never disappear (retired engines are "
                f"parked, not removed): cannot resize {self.n} -> {n_engines}")
        self.n = n_engines

    def _candidates(self,
                    candidates: Optional[Sequence[int]]) -> List[int]:
        cands = list(range(self.n)) if candidates is None else list(candidates)
        if not cands:
            raise ValueError("no live decode engine to route to")
        return cands

    def select(self, active: Sequence[int], free: Sequence[int],
               block_keys: Sequence[str] = (),
               candidates: Optional[Sequence[int]] = None) -> int:
        raise NotImplementedError

    def on_admit(self, engine: int,
                 block_keys: Sequence[str] = ()) -> None:  # pragma: no cover
        """Notification that a routed request was actually placed."""

    def on_retire(self, engine: int) -> None:  # pragma: no cover - hook
        """Notification that ``engine`` left the live set (drained and
        parked, or failed): any placement state pointing at it is stale."""

    def on_migrate(self, engine: int,
                   block_keys: Sequence[str] = ()) -> None:  # pragma: no cover
        """Notification that an in-flight request's KV landed on
        ``engine`` via cross-engine migration. Distinct from ``on_admit``
        on purpose: a migration is not an admission (the round-robin
        cursor must not advance for one), but affinity state must follow
        the bytes."""

    def residency(self, engine: int, block_keys: Sequence[str]) -> int:
        """How many of ``block_keys`` this router believes are resident on
        ``engine`` (0 for locality-free policies) — the rebalancer's signal
        for picking migration victims that will not thrash affinity."""
        return 0


class LeastLoadedSlotsRouter(DecodePoolRouter):
    """Engine with the fewest active slots, preferring engines that have a
    free slot at all (ties → lowest id)."""

    name = "least_loaded_slots"

    def select(self, active: Sequence[int], free: Sequence[int],
               block_keys: Sequence[str] = (),
               candidates: Optional[Sequence[int]] = None) -> int:
        return min(self._candidates(candidates),
                   key=lambda i: (free[i] <= 0, active[i], i))


class PoolRoundRobinRouter(DecodePoolRouter):
    """Strict cyclic assignment in admission order. The cursor advances on
    *commit* (``on_admit``), so a request the gate holds retries the same
    engine — deterministic for a fixed request stream. With parked engines
    the cycle runs over the live ids (first live id at or after the
    cursor)."""

    name = "round_robin"

    def __init__(self, n_engines: int):
        super().__init__(n_engines)
        self._next = 0

    def select(self, active: Sequence[int], free: Sequence[int],
               block_keys: Sequence[str] = (),
               candidates: Optional[Sequence[int]] = None) -> int:
        cands = self._candidates(candidates)
        for i in cands:
            if i >= self._next:
                return i
        return cands[0]                      # wrap past the highest live id

    def on_admit(self, engine: int,
                 block_keys: Sequence[str] = ()) -> None:
        self._next = (engine + 1) % self.n


class CacheAffinityRouter(DecodePoolRouter):
    """EMS-aware placement: prefer the engine already holding the request's
    reusable prefix blocks, falling back to least-loaded-slots. Engines
    with no free slot are deprioritized so affinity never stalls the pool
    while a peer sits idle.

    With an :class:`~repro.mempool.ems.EMSService` bound (``ems=``), the
    residency signal is **derived from the shared EMS index** — the
    hit-depth of the request's leading block keys in each engine's device
    tier (``engine_residency``), with placements/migrations recorded as
    EMS pins and retire/fail dropping the whole tier. Routing and cache
    reality therefore cannot drift: the router reads the same structure
    the cache serves from. Without an EMS the legacy advisory
    key→last-engine map is kept for back-compat (it persists across
    serve() waves; cache affinity is cross-wave by nature)."""

    name = "cache_affinity"
    uses_affinity = True

    def __init__(self, n_engines: int, ems=None):
        super().__init__(n_engines)
        self.ems = ems
        self._resident: Dict[str, int] = {}   # block key -> last engine

    @staticmethod
    def _tag(engine: int) -> str:
        """EMS device-tier tag of a pool decode engine."""
        return f"decode{engine}"

    def score(self, block_keys: Sequence[str]) -> List[int]:
        if self.ems is not None:
            return [self.ems.engine_residency(self._tag(e), block_keys)
                    for e in range(self.n)]
        scores = [0] * self.n
        for k in block_keys:
            e = self._resident.get(k)
            if e is not None:
                scores[e] += 1
        return scores

    def select(self, active: Sequence[int], free: Sequence[int],
               block_keys: Sequence[str] = (),
               candidates: Optional[Sequence[int]] = None) -> int:
        scores = self.score(block_keys)
        return min(self._candidates(candidates),
                   key=lambda i: (free[i] <= 0, -scores[i], active[i], i))

    def on_admit(self, engine: int,
                 block_keys: Sequence[str] = ()) -> None:
        if self.ems is not None:
            self.ems.pin(self._tag(engine), block_keys)
            return
        for k in block_keys:
            self._resident[k] = engine

    def on_retire(self, engine: int) -> None:
        # A parked or failed engine's cache rows are dead: routing future
        # requests toward it by stale residency would fight the live mask.
        # With an EMS the device tier is dropped (dirty blocks demote
        # first), so the pooled tier keeps every cached prefix.
        if self.ems is not None:
            self.ems.drop_engine(self._tag(engine))
            return
        self._resident = {k: e for k, e in self._resident.items()
                          if e != engine}

    def on_migrate(self, engine: int,
                   block_keys: Sequence[str] = ()) -> None:
        if self.ems is not None:
            self.ems.pin(self._tag(engine), block_keys)
            return
        for k in block_keys:
            self._resident[k] = engine

    def residency(self, engine: int, block_keys: Sequence[str]) -> int:
        if self.ems is not None:
            return self.ems.engine_residency(self._tag(engine), block_keys)
        return sum(1 for k in block_keys
                   if self._resident.get(k) == engine)


DECODE_ROUTERS = {r.name: r for r in
                  (LeastLoadedSlotsRouter, PoolRoundRobinRouter,
                   CacheAffinityRouter)}


def make_decode_router(policy: str, n_engines: int,
                       ems=None) -> DecodePoolRouter:
    """Build a decode-pool router by name. ``ems`` (an
    :class:`~repro.mempool.ems.EMSService`, or None) binds affinity-aware
    policies to the shared cache index; locality-free policies ignore it."""
    try:
        cls = DECODE_ROUTERS[policy]
    except KeyError:
        raise ValueError(
            f"unknown decode routing policy {policy!r}; "
            f"available: {sorted(DECODE_ROUTERS)}") from None
    if cls.uses_affinity:
        return cls(n_engines, ems=ems)
    return cls(n_engines)


# ---------------------------------------------------------------------------
# The pool
# ---------------------------------------------------------------------------


class DecodePool:
    """N decode engines behind one routing/migration facade.

    Engines must be homogeneous (same model config and KV capacity) so a
    migrated cache payload lands on an identical layout. Compute stays in
    the engines; the pool only routes, steps, and moves KV.

    ``engine_factory`` (seed -> DecodeEngine) enables the autoscaling grow
    path: :meth:`spawn_engine` revives the lowest parked engine when one
    exists (retirement parks engines in place, so engine ids — and every
    per-engine scheduler view keyed on them — stay stable) and otherwise
    constructs a fresh engine mid-wave.
    """

    def __init__(self, engines: Sequence, router: DecodePoolRouter,
                 engine_factory: Optional[Callable] = None):
        engines = list(engines)
        if not engines:
            raise ValueError("need at least one decode engine")
        if router.n != len(engines):
            raise ValueError(
                f"router sized for {router.n} engines, pool has "
                f"{len(engines)}")
        self._assert_homogeneous(engines)
        self.engines = engines
        self.router = router
        self.engine_factory = engine_factory
        self._live = [True] * len(engines)
        # Dead ≠ parked: a parked engine drained its slots and keeps warm
        # device state (revival is free); a dead engine crashed, its KV is
        # lost, and revival means a process restart over the same id.
        self._dead = [False] * len(engines)
        self._request_keys: Dict[int, Tuple[str, ...]] = {}
        self.migrations = 0
        self.migrated_bytes = 0
        self.failures = 0
        self.preemptions = 0

    @staticmethod
    def _assert_homogeneous(engines: Sequence) -> None:
        if len({(e.capacity, e.cfg.name) for e in engines}) != 1:
            raise ValueError(
                "pool engines must share model config and KV capacity "
                "(migration payloads assume an identical cache layout)")

    # -- aggregate views ---------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.engines)

    @property
    def n_live(self) -> int:
        return sum(self._live)

    @property
    def live_ids(self) -> List[int]:
        return [i for i, live in enumerate(self._live) if live]

    @property
    def live_mask(self) -> List[bool]:
        return list(self._live)

    @property
    def n_dead(self) -> int:
        return sum(self._dead)

    @property
    def dead_ids(self) -> List[int]:
        return [i for i, dead in enumerate(self._dead) if dead]

    @property
    def active(self) -> int:
        """Active slots across *live* engines — serveable demand. Parked
        and failed engines hold no work by construction (drain moves it,
        ``fail_engine`` releases it), so excluding them is belt-and-braces
        for the autoscaler's demand math: a non-live engine must never
        count as capacity or as load."""
        return sum(e.active for e, live in zip(self.engines, self._live)
                   if live)

    @property
    def capacity(self) -> int:
        return self.engines[0].capacity

    @property
    def use_mtp(self) -> bool:
        return self.engines[0].use_mtp

    @property
    def slot_mgrs(self) -> List:
        return [e.slot_mgr for e in self.engines]

    def locate(self, rid: int) -> Optional[Tuple[int, int]]:
        """(engine, slot) currently decoding ``rid``, or None."""
        for e, eng in enumerate(self.engines):
            for slot, info in eng.slot_mgr.active_slots():
                if info.rid == rid:
                    return e, slot
        return None

    # -- routing + placement ----------------------------------------------
    def select_engine(self, block_keys: Sequence[str] = ()) -> int:
        return self.router.select([e.active for e in self.engines],
                                  [e.slot_mgr.free for e in self.engines],
                                  block_keys, candidates=self.live_ids)

    def add(self, engine: int, slot: int, req_cache, first_token: int,
            prompt_len: int, result, max_new: int,
            block_keys: Sequence[str] = ()) -> None:
        """Place a prefilled request on ``engine`` and commit the routing
        decision (router state mutates only here)."""
        if not self._live[engine]:
            raise SlotError(f"engine {engine} is parked (retired)")
        self.engines[engine].add(slot, req_cache, first_token, prompt_len,
                                 result, max_new)
        if block_keys:
            self._request_keys[result.rid] = tuple(block_keys)
        self.router.on_admit(engine, block_keys)

    # -- stepping ----------------------------------------------------------
    def step_engine(self, engine: int, continuous: bool = False,
                    refill_pending: bool = False) -> Tuple[list, list]:
        """One host-sync chunk on a single engine (the continuous-batching
        serve loop steps engines individually so freed slots can be
        refilled *between* engine chunks within one decode turn).
        ``continuous``/``refill_pending`` thread through to
        :meth:`~repro.serving.engine.DecodeEngine.step_chunk`'s adaptive
        chunk sizing. Returns ``(finished, iter_log)``."""
        eng = self.engines[engine]
        finished, iter_log = eng.step_chunk(continuous=continuous,
                                            refill_pending=refill_pending)
        for r in finished:
            self._request_keys.pop(r.rid, None)
        return finished, iter_log

    def step_all(self) -> List[Tuple[int, list, list]]:
        """One decode turn across the pool: every live engine with active
        slots runs one host-sync chunk. Returns ``(engine, finished,
        iter_log)`` per stepped engine, in engine order, so the scheduler
        can charge each engine's virtual clock independently."""
        out = []
        for e, eng in enumerate(self.engines):
            if self._live[e] and eng.active:
                finished, iter_log = self.step_engine(e)
                out.append((e, finished, iter_log))
        return out

    # -- engine lifecycle (autoscaling + failure) --------------------------
    def fail_engine(self, engine: int) -> List[Tuple[int, Any, int]]:
        """Crash ``engine``: mark it dead (distinct from parked — its
        device-side KV is lost; revival is a process restart, not a warm
        unpark), release every active slot with conserved accounting
        (``acquired == released + active`` holds across the failure), and
        clear the router's residency for it so post-failure routing never
        scores a dead engine. Returns the in-flight ``(rid, payload,
        cache_len)`` records so the serving layer can recover each request
        by replay re-prefill."""
        if self._dead[engine]:
            raise ValueError(f"engine {engine} is already dead")
        eng = self.engines[engine]
        lost: List[Tuple[int, Any, int]] = []
        for slot, info in list(eng.slot_mgr.active_slots()):
            eng.slot_mgr.release(slot)
            self._request_keys.pop(info.rid, None)
            lost.append((info.rid, info.payload, info.cache_len))
        self._live[engine] = False
        self._dead[engine] = True
        self.failures += 1
        self.router.on_retire(engine)
        return lost

    def evict(self, rid: int) -> Tuple[int, Any, int]:
        """Preempt one in-flight request: release its slot with conserved
        accounting and return ``(engine, payload, cache_len)`` so the
        serving layer can park it (prompt + emitted tokens) for replay
        re-admission. The engine stays live — unlike :meth:`fail_engine`
        its router residency is kept, so a cache-affine re-admission can
        still prefer the engine whose EMS blocks are warm. The freed
        slot's device-side KV is abandoned in place: a later ``add`` on
        the slot overwrites it, exactly like post-failure slot reuse."""
        loc = self.locate(rid)
        if loc is None:
            raise SlotError(f"rid {rid} is not decoding on any engine")
        engine, slot = loc
        info = self.engines[engine].slot_mgr.release(slot)
        self._request_keys.pop(rid, None)
        self.preemptions += 1
        return engine, info.payload, info.cache_len

    def spawn_engine(self) -> Tuple[int, bool]:
        """Grow the pool by one live engine. Returns ``(engine, revived)``:
        the lowest parked engine is revived when one exists (its jitted
        programs are already warm; its drained slots are empty), then the
        lowest dead engine is restarted over its stable id (its slots were
        released at failure, so the stale device state is unreachable),
        otherwise ``engine_factory`` builds a fresh engine whose id extends
        the pool (never reindexing peers)."""
        for e, live in enumerate(self._live):
            if not live and not self._dead[e]:
                self._live[e] = True
                return e, True
        for e, dead in enumerate(self._dead):
            if dead:
                self._dead[e] = False
                self._live[e] = True
                return e, True
        if self.engine_factory is None:
            raise RuntimeError(
                "pool has no engine_factory; cannot spawn a new engine")
        eng = self.engine_factory(self.n)
        self._assert_homogeneous([self.engines[0], eng])
        self.engines.append(eng)
        self._live.append(True)
        self._dead.append(False)
        self.router.resize(self.n)
        return self.n - 1, False

    def retire_engine(self, engine: int, transfer=None
                      ) -> List[Tuple[int, int, float]]:
        """Shrink the pool: atomically drain ``engine`` to its live peers
        and park it (the engine object — and its id — survive for a later
        revival). Returns the drain's ``(rid, dst, seconds)`` moves."""
        if not self._live[engine]:
            raise ValueError(f"engine {engine} is already parked")
        if self.n_live <= 1:
            raise ValueError("cannot retire the last live engine")
        moved = self.drain_engine(engine, transfer)
        self._live[engine] = False
        self.router.on_retire(engine)
        return moved

    # -- cross-engine KV migration ----------------------------------------
    def migrate(self, rid: int, dst_engine: int,
                transfer=None) -> Tuple[int, int, float]:
        """Drain ``rid``'s slot from its current engine into ``dst_engine``
        bit-exactly. Returns (src_engine, dst_slot, transfer_seconds).

        The slot's cache rows, ``cache_len``, current/draft tokens, and
        engine-side payload all move; the drain is charged to the
        RDMA-plane ``transfer`` engine when one is given (the paper's
        scale-out plane — migration never contends with decode compute).
        """
        loc = self.locate(rid)
        if loc is None:
            raise SlotError(f"rid={rid} is not resident in any pool engine")
        src_e, src_slot = loc
        if src_e == dst_engine:
            raise ValueError(
                f"rid={rid} already decodes on engine {dst_engine}")
        if not 0 <= dst_engine < self.n:
            raise ValueError(f"no engine {dst_engine} in a pool of {self.n}")
        if not self._live[dst_engine]:
            raise SlotError(
                f"engine {dst_engine} is parked (retired); cannot migrate "
                f"rid={rid} onto it")
        src, dst = self.engines[src_e], self.engines[dst_engine]
        dst_slot = dst.slot_mgr.free_slot()
        if dst_slot is None:
            raise SlotError(
                f"engine {dst_engine} has no free slot for migration")
        flat, cache_len, cur_tok, draft_tok = src.export_slot(src_slot)
        # The RDMA charge (and its retry loop) runs BEFORE the source slot
        # is released: an exhausted transfer raises here and the request
        # stays intact on the source engine — a failed migration never
        # half-moves a request or propagates an unverified payload.
        seconds = 0.0 if transfer is None else transfer.migrate(flat)
        info = src.slot_mgr.release(src_slot)
        dst.import_slot(dst_slot, flat, cache_len, cur_tok, draft_tok,
                        info.rid, info.payload)
        self.router.on_migrate(dst_engine, self._request_keys.get(rid, ()))
        self.migrations += 1
        self.migrated_bytes += int(flat.nbytes)
        return src_e, dst_slot, seconds

    def rebalance(self, transfer=None
                  ) -> Optional[Tuple[int, int, int, float]]:
        """Migrate one request from the hottest live engine to the coldest
        when the active-slot imbalance is >= 2 and the coldest has room —
        the pool-level rebalancing that keeps per-engine batches (and
        therefore per-engine TPOT) even. Deterministic: lowest engine ids
        win ties. The victim is the hottest engine's lowest-numbered active
        slot **without block residency on that engine** (per the router's
        affinity map): migrating a request off the engine that holds its
        cached prefix blocks would make the ``cache_affinity`` router fight
        the move on the very next shared-prefix admission. Returns
        (rid, src_engine, dst_engine, seconds) or None."""
        live = self.live_ids
        if len(live) < 2:
            return None
        act = [self.engines[i].active for i in range(self.n)]
        hot = min(live, key=lambda i: (-act[i], i))
        cold = min(live, key=lambda i: (act[i], i))
        if act[hot] - act[cold] < 2 \
                or self.engines[cold].slot_mgr.free_slot() is None:
            return None
        slots = list(self.engines[hot].slot_mgr.active_slots())
        _, info = min(slots, key=lambda si: (self.router.residency(
            hot, self._request_keys.get(si[1].rid, ())) > 0, si[0]))
        rid = info.rid
        src_e, _, seconds = self.migrate(rid, cold, transfer)
        return rid, src_e, cold, seconds

    def peer_free_slots(self, engine: int) -> int:
        """Aggregate free slots across ``engine``'s live peers — the
        capacity a drain must fit into to be all-or-nothing."""
        return sum(self.engines[i].slot_mgr.free for i in self.live_ids
                   if i != engine)

    def can_drain(self, engine: int) -> bool:
        return self.engines[engine].active <= self.peer_free_slots(engine)

    def drain_engine(self, engine: int, transfer=None
                     ) -> List[Tuple[int, int, float]]:
        """Retire an engine's load: migrate every active slot to live peers
        with free capacity (least-loaded first). All-or-nothing: aggregate
        peer free capacity is pre-checked, so the drain either moves every
        request or raises :class:`SlotError` having moved none (a raise
        after a partial drain would leave an engine half-retired with no
        way to tell which requests moved)."""
        victims = list(self.engines[engine].slot_mgr.active_slots())
        headroom = self.peer_free_slots(engine)
        if len(victims) > headroom:
            raise SlotError(
                f"cannot drain engine {engine}: {len(victims)} active "
                f"requests but live peers have only {headroom} free slots "
                "(drain is all-or-nothing; nothing was migrated)")
        moved = []
        for _, info in victims:
            peers = [i for i in self.live_ids if i != engine
                     and self.engines[i].slot_mgr.free_slot() is not None]
            dst = min(peers, key=lambda i: (self.engines[i].active, i))
            try:
                _, _, seconds = self.migrate(info.rid, dst, transfer)
            except TransferError as exc:
                # The capacity pre-check held but the RDMA plane gave out
                # mid-drain. Completed moves stand; the failed request is
                # still whole on the source — surface both so the caller
                # can recover it by replay instead of unwinding the drain.
                raise DrainError(
                    f"drain of engine {engine} failed migrating "
                    f"rid={info.rid} after {len(moved)} completed moves: "
                    f"{exc}", moved, info.rid) from exc
            moved.append((info.rid, dst, seconds))
        return moved

    # -- reporting ---------------------------------------------------------
    def engine_stats(self) -> List[Dict[str, int]]:
        return [{"engine": e, "live": self._live[e], "dead": self._dead[e],
                 "active": eng.active,
                 "iters": eng.iters,
                 "live_slot_iters": eng.live_slot_iters,
                 "dead_slot_iters": eng.dead_slot_iters,
                 "slots_acquired": eng.slot_mgr.acquired,
                 "slots_released": eng.slot_mgr.released}
                for e, eng in enumerate(self.engines)]


# ---------------------------------------------------------------------------
# Prefill pool (peer-to-peer PDC: the prefill side scales independently)
# ---------------------------------------------------------------------------


class PrefillPool:
    """N prefill instances behind the decode pool's lifecycle semantics.

    Unlike decode engines, prefill instances are stateless between
    requests (``PrefillEngine.run`` is synchronous and holds no resident
    slots), so the lifecycle is lighter: retirement parks an instance
    immediately — no drain, nothing to migrate — and failure loses only
    the instance, never an in-flight request. What *is* shared with
    :class:`DecodePool` is the stable-id contract: instance ids never
    disappear or reindex, parked instances revive for free (their jitted
    programs stay warm), dead instances restart over their own id, and a
    fresh spawn extends the roster through ``engine_factory``
    (``instance_id -> PrefillEngine``). The scheduler mirrors the roster
    via ``register_prefill_instance`` / ``set_prefill_live``.
    """

    def __init__(self, engines: Sequence,
                 engine_factory: Optional[Callable] = None):
        engines = list(engines)
        if not engines:
            raise ValueError("need at least one prefill instance")
        self._assert_homogeneous(engines)
        self.engines = engines
        self.engine_factory = engine_factory
        self._live = [True] * len(engines)
        self._dead = [False] * len(engines)
        self.spawns = 0
        self.retires = 0
        self.failures = 0

    @staticmethod
    def _assert_homogeneous(engines: Sequence) -> None:
        if len({(e.capacity, e.cfg.name) for e in engines}) != 1:
            raise ValueError(
                "prefill instances must share model config and cache "
                "capacity (handoff payloads assume an identical layout)")

    # -- aggregate views ---------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.engines)

    @property
    def n_live(self) -> int:
        return sum(self._live)

    @property
    def live_ids(self) -> List[int]:
        return [i for i, live in enumerate(self._live) if live]

    @property
    def live_mask(self) -> List[bool]:
        return list(self._live)

    @property
    def n_dead(self) -> int:
        return sum(self._dead)

    @property
    def dead_ids(self) -> List[int]:
        return [i for i, dead in enumerate(self._dead) if dead]

    @property
    def loads(self) -> List[int]:
        """Per-instance in-flight prompt tokens (full roster, stable ids;
        parked instances report 0 by construction)."""
        return [e.load for e in self.engines]

    # -- lifecycle ---------------------------------------------------------
    def spawn_engine(self) -> Tuple[int, bool]:
        """Grow the pool by one live instance. Returns ``(instance,
        revived)`` with the same preference order as the decode pool:
        revive the lowest parked instance (warm programs), restart the
        lowest dead one over its stable id, else build a fresh instance
        whose id extends the roster."""
        for i, live in enumerate(self._live):
            if not live and not self._dead[i]:
                self._live[i] = True
                self.spawns += 1
                return i, True
        for i, dead in enumerate(self._dead):
            if dead:
                self._dead[i] = False
                self._live[i] = True
                self.spawns += 1
                return i, True
        if self.engine_factory is None:
            raise RuntimeError(
                "prefill pool has no engine_factory; cannot spawn a new "
                "instance")
        eng = self.engine_factory(self.n)
        self._assert_homogeneous([self.engines[0], eng])
        self.engines.append(eng)
        self._live.append(True)
        self._dead.append(False)
        self.spawns += 1
        return self.n - 1, False

    def retire_engine(self, instance: int) -> None:
        """Shrink the pool: park ``instance`` (its id — and warm jitted
        programs — survive for a later revival). Prefill holds no resident
        requests, so there is nothing to drain; already-routed work was
        charged to the instance's virtual clock and completes there."""
        if not self._live[instance]:
            raise ValueError(f"prefill instance {instance} is already parked")
        if self.n_live <= 1:
            raise ValueError("cannot retire the last live prefill instance")
        self._live[instance] = False
        self.retires += 1

    def fail_engine(self, instance: int) -> None:
        """Crash ``instance``: dead, not parked (revival is a restart).
        No request is lost — prefill runs to completion synchronously —
        but the roster shrinks until a spawn restarts the id."""
        if self._dead[instance]:
            raise ValueError(f"prefill instance {instance} is already dead")
        self._live[instance] = False
        self._dead[instance] = True
        self.failures += 1

    # -- reporting ---------------------------------------------------------
    def engine_stats(self) -> List[Dict[str, Any]]:
        return [{"instance": i, "live": self._live[i], "dead": self._dead[i],
                 "load": eng.load,
                 "fresh_dispatches": eng.continue_calls,
                 "suffix_dispatches": eng.suffix_calls}
                for i, eng in enumerate(self.engines)]


# ---------------------------------------------------------------------------
# SLO-driven utilization controller
# ---------------------------------------------------------------------------


class PoolAutoscaler:
    """Deterministic grow/hold/shrink controller for the decode pool.

    Evaluated between decode turns on pure control-plane signals — no
    wall clock, no randomness — so a fixed request stream always produces
    the same scale-event sequence:

    * **demand** = pool-wide active slots + admission-queue depth (the
      requests that would decode right now if capacity allowed);
    * **per-engine cap** = the largest batch one engine may carry: its
      slot count, intersected with the batch whose projected per-token
      TPOT meets the budget (:meth:`DecodeCostModel.max_batch_for` — the
      same projection the admission gate enforces).

    Grow when demand exceeds what the live engines can carry at the SLO
    cap (spreading the demand over N engines would push projected TPOT
    past the budget, so the gate is queuing); shrink when N-1 engines
    could absorb the whole demand at the cap and nothing is queued. Both
    need the condition to hold for ``grow_patience`` / ``shrink_patience``
    consecutive turns, and every scale event starts a ``cooldown`` during
    which the controller holds (and its streaks reset) — the hysteresis
    that keeps a demand level sitting exactly on a threshold from flapping
    the pool. Never emits grow and shrink for the same turn by
    construction (one decision per ``decide``; the conditions are
    mutually exclusive for any cap >= 1).
    """

    def __init__(self, cost: DecodeCostModel, n_slots: int,
                 min_engines: int, max_engines: int,
                 tpot_budget_s: Optional[float] = None,
                 grow_patience: int = 1, shrink_patience: int = 3,
                 cooldown: int = 2):
        if n_slots < 1:
            raise ValueError("n_slots must be positive")
        if not 1 <= min_engines <= max_engines:
            raise ValueError(
                f"need 1 <= min_engines <= max_engines, got "
                f"[{min_engines}, {max_engines}]")
        if grow_patience < 1 or shrink_patience < 1 or cooldown < 0:
            raise ValueError("patience must be >= 1 and cooldown >= 0")
        self.engine_cap = n_slots
        if tpot_budget_s is not None:
            self.engine_cap = min(n_slots,
                                  max(1, cost.max_batch_for(tpot_budget_s)))
        self.min_engines = min_engines
        self.max_engines = max_engines
        self.grow_patience = grow_patience
        self.shrink_patience = shrink_patience
        self.cooldown = cooldown
        self.reset()

    def reset(self) -> None:
        """Fresh hysteresis state (one serve() wave = one controller run)."""
        self._grow_streak = 0
        self._shrink_streak = 0
        self._cooldown_left = 0

    def decide(self, n_live: int, active: int, queue_depth: int,
               shrinkable: bool = True) -> str:
        """'grow' | 'hold' | 'shrink' for this decode turn.

        ``shrinkable`` is the pool's atomic-drain pre-check for the would-be
        victim (``DecodePool.can_drain``): a shrink the peers cannot absorb
        is reported as hold (the shrink streak resets; no cooldown is
        spent on it).

        ``n_live`` must be the pool's *live* roster for this turn —
        failed/parked engines excluded — not the constructed engine count:
        a dead engine counts as neither capacity nor demand. When capacity
        loss drops the roster below ``min_engines`` the controller respawns
        immediately, bypassing patience and cooldown: hysteresis exists to
        damp demand noise, not to slow down failure recovery.
        """
        if n_live < self.min_engines:
            self._grow_streak = self._shrink_streak = 0
            self._cooldown_left = 0
            return "grow"
        if self._cooldown_left > 0:
            self._cooldown_left -= 1
            self._grow_streak = self._shrink_streak = 0
            return "hold"
        demand = active + queue_depth
        if demand > n_live * self.engine_cap and n_live < self.max_engines:
            self._shrink_streak = 0
            self._grow_streak += 1
            if self._grow_streak >= self.grow_patience:
                self._grow_streak = 0
                self._cooldown_left = self.cooldown
                return "grow"
            return "hold"
        self._grow_streak = 0
        if (queue_depth == 0 and n_live > self.min_engines
                and demand <= (n_live - 1) * self.engine_cap and shrinkable):
            self._shrink_streak += 1
            if self._shrink_streak >= self.shrink_patience:
                self._shrink_streak = 0
                self._cooldown_left = self.cooldown
                return "shrink"
            return "hold"
        self._shrink_streak = 0
        return "hold"


class JointAutoscaler:
    """Capacity-conserving joint P/D controller: shift engines between the
    prefill and decode roles under one SLO budget.

    Where :class:`PoolAutoscaler` changes the decode pool's *size*, this
    controller changes the *split* of a fixed engine budget between roles
    (the generalization the paper's peer-to-peer architecture implies and
    DeepServe's serverless controller implements). Evaluated between
    decode turns on pure control-plane signals, so a fixed request stream
    always produces the same shift sequence:

    * **TPOT pressure** — decode demand (active slots + admission-queue
      depth) exceeds what the live decode engines carry at the SLO batch
      cap (the same :meth:`DecodeCostModel.max_batch_for` projection the
      admission gate enforces);
    * **TTFT pressure** — the worst live prefill instance's virtual
      backlog (queued prefill seconds, :meth:`Scheduler.prefill_backlog_s`)
      exceeds the TTFT budget.

    ``shift_d2p`` fires when prefill is TTFT-pressured AND the decode pool
    can spare an engine (demand fits on N-1 engines at the cap, the victim
    is drainable, and the clamps allow it): one decode engine drains and
    parks, one prefill instance spawns. ``shift_p2d`` is the mirror image
    for TPOT pressure against an idle prefill pool. Per-direction patience
    plus a shared cooldown give the same flap-damping hysteresis as the
    size controller; the two directions are mutually exclusive within a
    turn by construction (each requires the other role to be unpressured).
    """

    def __init__(self, cost: DecodeCostModel, n_slots: int, *,
                 min_prefill: int, max_prefill: int,
                 min_decode: int, max_decode: int,
                 tpot_budget_s: Optional[float] = None,
                 ttft_budget_s: Optional[float] = None,
                 patience: int = 1, cooldown: int = 2):
        if n_slots < 1:
            raise ValueError("n_slots must be positive")
        for lo, hi, what in ((min_prefill, max_prefill, "prefill"),
                             (min_decode, max_decode, "decode")):
            if not 1 <= lo <= hi:
                raise ValueError(
                    f"need 1 <= min_{what} <= max_{what}, got [{lo}, {hi}]")
        if patience < 1 or cooldown < 0:
            raise ValueError("patience must be >= 1 and cooldown >= 0")
        self.engine_cap = n_slots
        if tpot_budget_s is not None:
            self.engine_cap = min(n_slots,
                                  max(1, cost.max_batch_for(tpot_budget_s)))
        self.min_prefill = min_prefill
        self.max_prefill = max_prefill
        self.min_decode = min_decode
        self.max_decode = max_decode
        self.ttft_budget_s = ttft_budget_s
        self.patience = patience
        self.cooldown = cooldown
        self.reset()

    def reset(self) -> None:
        """Fresh hysteresis state (one serve() wave = one controller run)."""
        self._d2p_streak = 0
        self._p2d_streak = 0
        self._cooldown_left = 0

    def decide(self, n_live_prefill: int, n_live_decode: int, active: int,
               queue_depth: int, prefill_backlog_s: float,
               decode_shrinkable: bool = True) -> str:
        """'shift_d2p' | 'shift_p2d' | 'hold' for this decode turn.

        ``decode_shrinkable`` is the atomic-drain pre-check for the
        would-be decode victim (``DecodePool.can_drain``); a d2p shift the
        peers cannot absorb reports hold and resets the streak, exactly
        like the size controller's shrink path.
        """
        if self._cooldown_left > 0:
            self._cooldown_left -= 1
            self._d2p_streak = self._p2d_streak = 0
            return "hold"
        demand = active + queue_depth
        ttft_pressured = (self.ttft_budget_s is not None
                          and prefill_backlog_s > self.ttft_budget_s)
        tpot_pressured = demand > n_live_decode * self.engine_cap
        # An idle prefill pool has burned through its backlog (well under
        # budget); only then may it donate an instance to decode.
        prefill_idle = prefill_backlog_s <= (self.ttft_budget_s or 0.0) / 2
        if (ttft_pressured and not tpot_pressured and decode_shrinkable
                and n_live_decode > self.min_decode
                and queue_depth == 0
                and demand <= (n_live_decode - 1) * self.engine_cap
                and n_live_prefill < self.max_prefill):
            self._p2d_streak = 0
            self._d2p_streak += 1
            if self._d2p_streak >= self.patience:
                self._d2p_streak = 0
                self._cooldown_left = self.cooldown
                return "shift_d2p"
            return "hold"
        self._d2p_streak = 0
        if (tpot_pressured and not ttft_pressured and prefill_idle
                and n_live_prefill > self.min_prefill
                and n_live_decode < self.max_decode):
            self._p2d_streak += 1
            if self._p2d_streak >= self.patience:
                self._p2d_streak = 0
                self._cooldown_left = self.cooldown
                return "shift_p2d"
            return "hold"
        self._p2d_streak = 0
        return "hold"
