"""Open-loop workload generation for the PDC serving system.

The paper evaluates serving under *open-loop* load: requests arrive on
their own clock and the scheduler must absorb bursts, not a closed loop
that feeds the next request only when the previous one finishes. This
module generates arrival-timed request streams for
``ServingSystem.serve(..., open_loop=True)``, which replays them on the
scheduler's virtual timeline so the TPOT admission gate (queue/shed) is
exercised under genuine queueing pressure.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.serving.engine import Request


def poisson_requests(n_requests: int, rate_rps: float, prompt_len: int,
                     max_new: int, vocab_size: int, *, seed: int,
                     shared_prefix: int = 0,
                     start: float = 0.0) -> List[Request]:
    """Homogeneous Poisson arrival stream: exponential inter-arrival gaps
    at ``rate_rps`` requests per (virtual) second.

    ``shared_prefix`` tokens are common across all prompts so the stream
    also exercises EMS context-cache reuse under load;
    ``shared_prefix == prompt_len`` makes every prompt identical — the
    fully-cached multi-turn re-entry stream the EMS benches replay.
    ``seed`` is a
    *required* keyword: every arrival gap and prompt token comes from one
    PRNG seeded with it, so the stream — and therefore the scheduler's
    virtual timeline and every SLO statistic derived from it — is exactly
    reproducible across runs (benches replay identical traces).
    """
    if n_requests < 1:
        raise ValueError("n_requests must be positive")
    if rate_rps <= 0:
        raise ValueError("rate_rps must be positive")
    if not 0 <= shared_prefix <= prompt_len:
        raise ValueError("shared_prefix must be in [0, prompt_len]")
    rng = np.random.RandomState(seed)
    arrivals = start + np.cumsum(rng.exponential(1.0 / rate_rps, n_requests))
    prefix = list(rng.randint(0, vocab_size, shared_prefix))
    return [
        Request(i,
                prefix + list(rng.randint(0, vocab_size,
                                          prompt_len - shared_prefix)),
                max_new, arrival=float(arrivals[i]))
        for i in range(n_requests)
    ]
