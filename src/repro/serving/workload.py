"""Open-loop workload generation for the PDC serving system.

The paper evaluates serving under *open-loop* load: requests arrive on
their own clock and the scheduler must absorb bursts, not a closed loop
that feeds the next request only when the previous one finishes. This
module generates arrival-timed request streams for
``ServingSystem.serve(..., open_loop=True)``, which replays them on the
scheduler's virtual timeline so the TPOT admission gate (queue/shed) is
exercised under genuine queueing pressure.

Production suite: beyond the homogeneous :func:`poisson_requests` stream,
:func:`production_requests` draws heavy-tailed (lognormal, clipped)
prompt/output length mixtures under Poisson, bursty, or diurnal arrival
shapes with a per-class interactive/batch mix, and
:func:`multi_turn_sessions` generates multi-turn conversations whose
later turns re-enter with the grown prefix of everything said so far
(the EMS context-cache reuse pattern). Every generator is driven by a
single ``np.random.RandomState(seed)``, so identical arguments produce
bit-identical streams — the soak's determinism digest depends on it.
``start``/``rid_base`` let callers generate a long stream in independent
chunks (per-chunk seeds) without rid collisions or time overlap.
"""
from __future__ import annotations

import math
from typing import List

import numpy as np

from repro.serving.engine import Request

#: arrival-shape registry for production_requests
ARRIVAL_SHAPES = ("poisson", "burst", "diurnal")


def poisson_requests(n_requests: int, rate_rps: float, prompt_len: int,
                     max_new: int, vocab_size: int, *, seed: int,
                     shared_prefix: int = 0,
                     start: float = 0.0,
                     slo_class: str = "interactive",
                     rid_base: int = 0) -> List[Request]:
    """Homogeneous Poisson arrival stream: exponential inter-arrival gaps
    at ``rate_rps`` requests per (virtual) second.

    ``shared_prefix`` tokens are common across all prompts so the stream
    also exercises EMS context-cache reuse under load;
    ``shared_prefix == prompt_len`` makes every prompt identical — the
    fully-cached multi-turn re-entry stream the EMS benches replay.
    ``seed`` is a
    *required* keyword: every arrival gap and prompt token comes from one
    PRNG seeded with it, so the stream — and therefore the scheduler's
    virtual timeline and every SLO statistic derived from it — is exactly
    reproducible across runs (benches replay identical traces).
    ``slo_class`` stamps every request with an SLO tier; ``rid_base``
    offsets the request ids so independently generated streams can be
    merged without collisions.
    """
    if n_requests < 1:
        raise ValueError("n_requests must be positive")
    if rate_rps <= 0:
        raise ValueError("rate_rps must be positive")
    if prompt_len < 1:
        raise ValueError("prompt_len must be positive")
    if max_new < 1:
        raise ValueError("max_new must be positive")
    if not 0 <= shared_prefix <= prompt_len:
        raise ValueError("shared_prefix must be in [0, prompt_len]")
    rng = np.random.RandomState(seed)
    arrivals = start + np.cumsum(rng.exponential(1.0 / rate_rps, n_requests))
    prefix = list(rng.randint(0, vocab_size, shared_prefix))
    return [
        Request(rid_base + i,
                prefix + list(rng.randint(0, vocab_size,
                                          prompt_len - shared_prefix)),
                max_new, arrival=float(arrivals[i]), slo_class=slo_class)
        for i in range(n_requests)
    ]


def _lognormal_lengths(rng: np.random.RandomState, n: int, median: int,
                       sigma: float, max_len: int) -> np.ndarray:
    """Heavy-tailed integer lengths: lognormal with the given median and
    log-sigma, clipped to ``[1, max_len]`` (the tail mass lands on the
    clip, which is exactly how real serving truncates context)."""
    draws = rng.lognormal(mean=math.log(max(1, median)), sigma=sigma, size=n)
    return np.clip(np.rint(draws), 1, max_len).astype(int)


def _arrival_times(rng: np.random.RandomState, n: int, rate_rps: float,
                   shape: str, start: float, *, burst_every_s: float,
                   burst_len_s: float, burst_factor: float,
                   diurnal_period_s: float,
                   diurnal_amplitude: float) -> List[float]:
    """Arrival instants under one of the registered shapes.

    ``poisson`` is the homogeneous stream; ``burst`` multiplies the rate
    by ``burst_factor`` inside periodic windows (``burst_len_s`` out of
    every ``burst_every_s``); ``diurnal`` modulates the rate sinusoidally
    over ``diurnal_period_s`` (a compressed day). Non-homogeneous shapes
    draw each gap at the *local* rate — deterministic given the seed and
    exact enough for scheduler stress, which cares about the bursts, not
    the point-process fine print.
    """
    if shape not in ARRIVAL_SHAPES:
        raise ValueError(
            f"arrival shape must be one of {ARRIVAL_SHAPES}, got {shape!r}")
    t = start
    out: List[float] = []
    for _ in range(n):
        if shape == "poisson":
            local = rate_rps
        elif shape == "burst":
            in_burst = (t % burst_every_s) < burst_len_s
            local = rate_rps * (burst_factor if in_burst else 1.0)
        else:  # diurnal
            phase = 2.0 * math.pi * (t % diurnal_period_s) / diurnal_period_s
            local = rate_rps * (1.0 + diurnal_amplitude * math.sin(phase))
            local = max(local, 0.05 * rate_rps)
        t += float(rng.exponential(1.0 / local))
        out.append(t)
    return out


def production_requests(n_requests: int, *, seed: int, vocab_size: int,
                        rate_rps: float, arrival_shape: str = "poisson",
                        prompt_len_median: int = 32,
                        prompt_len_sigma: float = 0.6,
                        prompt_len_max: int = 256,
                        max_new_median: int = 8,
                        max_new_sigma: float = 0.7,
                        max_new_max: int = 64,
                        interactive_frac: float = 0.7,
                        burst_every_s: float = 1.0,
                        burst_len_s: float = 0.2,
                        burst_factor: float = 8.0,
                        diurnal_period_s: float = 10.0,
                        diurnal_amplitude: float = 0.8,
                        shared_prefix: int = 0,
                        start: float = 0.0,
                        rid_base: int = 0) -> List[Request]:
    """Production-shaped request stream: heavy-tailed lognormal prompt and
    output lengths, a per-request interactive/batch class mix
    (``interactive_frac`` is the Bernoulli probability of the interactive
    tier), and a configurable arrival shape (``poisson`` | ``burst`` |
    ``diurnal``). Seed-deterministic end to end; ``start``/``rid_base``
    support chunked generation of arbitrarily long streams.
    """
    if n_requests < 1:
        raise ValueError("n_requests must be positive")
    if rate_rps <= 0:
        raise ValueError("rate_rps must be positive")
    if not 0.0 <= interactive_frac <= 1.0:
        raise ValueError("interactive_frac must be in [0, 1]")
    if prompt_len_median < 1 or max_new_median < 1:
        raise ValueError("length medians must be positive")
    if not 0 <= shared_prefix <= prompt_len_max:
        raise ValueError("shared_prefix must be in [0, prompt_len_max]")
    rng = np.random.RandomState(seed)
    arrivals = _arrival_times(
        rng, n_requests, rate_rps, arrival_shape, start,
        burst_every_s=burst_every_s, burst_len_s=burst_len_s,
        burst_factor=burst_factor, diurnal_period_s=diurnal_period_s,
        diurnal_amplitude=diurnal_amplitude)
    prompt_lens = _lognormal_lengths(rng, n_requests, prompt_len_median,
                                     prompt_len_sigma, prompt_len_max)
    max_news = _lognormal_lengths(rng, n_requests, max_new_median,
                                  max_new_sigma, max_new_max)
    classes = np.where(rng.uniform(size=n_requests) < interactive_frac,
                       "interactive", "batch")
    prefix = list(rng.randint(0, vocab_size, shared_prefix))
    reqs = []
    for i in range(n_requests):
        plen = max(int(prompt_lens[i]), shared_prefix + 1) \
            if shared_prefix else int(prompt_lens[i])
        body = list(rng.randint(0, vocab_size, plen - shared_prefix))
        reqs.append(Request(rid_base + i, prefix + body, int(max_news[i]),
                            arrival=float(arrivals[i]),
                            slo_class=str(classes[i])))
    return reqs


def multi_turn_sessions(n_sessions: int, *, seed: int, vocab_size: int,
                        session_rate_rps: float, turns: int = 3,
                        turn_tokens_median: int = 12,
                        turn_tokens_sigma: float = 0.5,
                        turn_tokens_max: int = 64,
                        max_new_median: int = 6,
                        max_new_sigma: float = 0.5,
                        max_new_max: int = 32,
                        think_time_s: float = 0.02,
                        slo_class: str = "interactive",
                        start: float = 0.0,
                        rid_base: int = 0) -> List[Request]:
    """Multi-turn conversation sessions: each session starts on a Poisson
    clock at ``session_rate_rps``; turn ``t+1`` re-enters with the *grown
    prefix* of turn ``t``'s full context (its prompt plus a reply-sized
    continuation) followed by a fresh user utterance — the EMS
    context-cache reuse pattern, where only the new suffix needs prefill
    compute. Turn gaps are exponential around ``think_time_s`` plus the
    previous turn's reply budget on the virtual clock. Seed-deterministic;
    rids are dense from ``rid_base`` in (session, turn) order.
    """
    if n_sessions < 1:
        raise ValueError("n_sessions must be positive")
    if turns < 1:
        raise ValueError("turns must be positive")
    if session_rate_rps <= 0:
        raise ValueError("session_rate_rps must be positive")
    if think_time_s < 0:
        raise ValueError("think_time_s must be non-negative")
    rng = np.random.RandomState(seed)
    session_starts = start + np.cumsum(
        rng.exponential(1.0 / session_rate_rps, n_sessions))
    reqs: List[Request] = []
    rid = rid_base
    for s in range(n_sessions):
        t = float(session_starts[s])
        context: List[int] = []
        for _turn in range(turns):
            utter = int(_lognormal_lengths(rng, 1, turn_tokens_median,
                                           turn_tokens_sigma,
                                           turn_tokens_max)[0])
            max_new = int(_lognormal_lengths(rng, 1, max_new_median,
                                             max_new_sigma, max_new_max)[0])
            prompt = context + list(rng.randint(0, vocab_size, utter))
            reqs.append(Request(rid, prompt, max_new, arrival=t,
                                slo_class=slo_class))
            rid += 1
            # The next turn's context is this turn's full prompt plus a
            # reply-sized continuation (the assistant's turn): generation
            # happens at serve time, so the *shape* of the grown prefix is
            # what the workload models — prefix reuse hits on the prompt
            # part either way.
            context = prompt + list(rng.randint(0, vocab_size, max_new))
            t += max_new * 1e-3 + float(rng.exponential(max(think_time_s,
                                                            1e-6)))
    reqs.sort(key=lambda r: (r.arrival, r.rid))
    return reqs
