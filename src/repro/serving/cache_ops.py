"""Structure-aware batch-axis ops over model cache pytrees.

Caches built by models.model.make_caches have family-specific layouts
(layer-stacked KV, MLA latent, SSM state, hybrid group caches); these helpers
slice/insert per-request rows for continuous batching and serialize per-token
blocks for the EMS context cache.
"""
from __future__ import annotations

import functools
import zlib
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.attention import KVCache
from repro.models.mamba2 import SSMState
from repro.models.model import build_plan, make_caches
from repro.models.model import cache_batch_axes as _model_cache_batch_axes


def cache_batch_axes(cfg: ModelConfig, caches: Dict[str, Any]) -> Dict[str, Any]:
    """Pytree of batch-axis indices matching the cache structure
    (None = unbatched leaf, e.g. length scalars). The structure is derived
    from cfg alone; ``caches`` is accepted for call-site symmetry."""
    del caches
    return _model_cache_batch_axes(cfg)


def _map2(fn, tree, axes):
    return jax.tree.map(fn, tree, axes)


def slice_request(cfg: ModelConfig, caches, row: int):
    """Extract one request's cache (batch dim kept = 1)."""
    axes = cache_batch_axes(cfg, caches)
    return _map2(
        lambda leaf, ax: leaf if ax is None else
        jax.lax.dynamic_slice_in_dim(leaf, row, 1, axis=ax),
        caches, axes)


def insert_request(cfg: ModelConfig, caches, req_cache, row: int):
    """Write one request's cache (batch=1) into batch slot ``row``."""
    axes = cache_batch_axes(cfg, caches)
    return jax.tree.map(
        lambda dst, src, ax: dst if ax is None else
        jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), row, axis=ax),
        caches, req_cache, axes)


def seq_slice(cfg: ModelConfig, caches, start: int, length: int):
    """Slice ``length`` tokens of sequence state (KV/MLA buffers only) —
    the payload unit of context caching. SSM states are not sliceable by
    token (noted inapplicability, DESIGN.md §3)."""
    out = {}
    for seg in build_plan(cfg):
        c = caches[seg.name]
        if seg.kind in ("dense", "moe"):
            if cfg.attention_kind == "mla":
                out[seg.name] = jax.lax.dynamic_slice_in_dim(
                    c["mla"], start, length, axis=2)
            else:
                out[seg.name] = (
                    jax.lax.dynamic_slice_in_dim(c.k, start, length, axis=2),
                    jax.lax.dynamic_slice_in_dim(c.v, start, length, axis=2))
    return out


def seq_insert(cfg: ModelConfig, caches, payload: Dict[str, Any], start: int):
    """Insert a seq_slice payload back at token offset ``start``."""
    new = dict(caches)
    for seg in build_plan(cfg):
        if seg.name not in payload:
            continue
        c = caches[seg.name]
        pl = payload[seg.name]
        if cfg.attention_kind == "mla":
            new[seg.name] = {**c, "mla": jax.lax.dynamic_update_slice_in_dim(
                c["mla"], pl.astype(c["mla"].dtype), start, axis=2)}
        else:
            k, v = pl
            new[seg.name] = KVCache(
                jax.lax.dynamic_update_slice_in_dim(c.k, k.astype(c.k.dtype), start, axis=2),
                jax.lax.dynamic_update_slice_in_dim(c.v, v.astype(c.v.dtype), start, axis=2),
                c.length)
    return new


@functools.partial(jax.jit, static_argnums=(0, 2, 3))
def _pack_blocks(cfg: ModelConfig, caches, n_blocks: int, block: int) -> jax.Array:
    """Jitted batched EMS pack: all block payloads in one slice+pack."""
    payload = seq_slice(cfg, caches, 0, n_blocks * block)
    rows = []
    for leaf in jax.tree.leaves(payload):
        # leaf: (L, B, n_blocks*block, ...) — bring the block index to the
        # front so row ``bi`` ravels exactly like
        # ``pack_payload(seq_slice(cfg, caches, bi*block, block))``.
        l, b = leaf.shape[0], leaf.shape[1]
        x = leaf.reshape((l, b, n_blocks, block) + leaf.shape[3:])
        x = jnp.moveaxis(x, 2, 0).astype(jnp.float32).reshape(n_blocks, -1)
        rows.append(x)
    return jnp.concatenate(rows, axis=1)


def pack_blocks(cfg: ModelConfig, caches, n_blocks: int,
                block: int) -> List[np.ndarray]:
    """Build every EMS block payload for tokens [0, n_blocks*block) in ONE
    jitted slice+pack instead of a Python ``seq_slice``/``pack_payload``
    round-trip per block. Row ``bi`` is byte-identical to
    ``pack_payload(seq_slice(cfg, caches, bi*block, block))``."""
    if n_blocks <= 0:
        return []
    flat = np.asarray(_pack_blocks(cfg, caches, n_blocks, block))
    return [flat[bi] for bi in range(n_blocks)]


def payload_token_nbytes(cfg: ModelConfig, caches) -> int:
    """Stored bytes per cached token: the size of a one-token
    :func:`seq_slice` payload as :func:`pack_payload` serializes it
    (float32 storage). EMS capacity sizing and bench byte accounting both
    derive per-block footprints from this instead of re-deriving model
    cache layouts by hand."""
    payload = seq_slice(cfg, caches, 0, 1)
    return sum(int(x.size) for x in jax.tree.leaves(payload)) * 4


def fingerprint(payload: Any) -> int:
    """Order-stable CRC32 over every array leaf's raw bytes — the
    integrity check :class:`~repro.serving.transfer.KVTransferEngine`
    verifies on delivery before a migrated/transferred payload is allowed
    to land in a destination cache. Non-array leaves (lengths folded into
    scalars etc.) are skipped exactly as :func:`cache_nbytes` skips them."""
    crc = 0
    for leaf in jax.tree.leaves(payload):
        if hasattr(leaf, "dtype"):
            crc = zlib.crc32(
                np.ascontiguousarray(np.asarray(leaf)).tobytes(), crc)
    return crc


def pack_request(cfg: ModelConfig, req_slice) -> np.ndarray:
    """Serialize one request's cache slice (a :func:`slice_request` result)
    into a contiguous byte buffer — the drain unit of cross-engine KV
    migration. Only batched leaves are packed (unbatched bookkeeping leaves
    such as ``length`` scalars stay engine-local, exactly as
    :func:`insert_request` leaves them untouched). Bytes are *viewed*, not
    cast, so the round trip through :func:`unpack_request` is bit-exact for
    every dtype."""
    axes = cache_batch_axes(cfg, req_slice)
    parts: List[np.ndarray] = []
    jax.tree.map(
        lambda leaf, ax: None if ax is None else parts.append(
            np.ascontiguousarray(np.asarray(leaf)).reshape(-1).view(np.uint8)),
        req_slice, axes)
    return np.concatenate(parts) if parts else np.zeros(0, np.uint8)


def unpack_request(cfg: ModelConfig, flat: np.ndarray, template):
    """Inverse of :func:`pack_request`. ``template`` is a shape/dtype
    reference slice from the *destination* engine (``slice_request`` of the
    target row); its unbatched leaves pass through unchanged."""
    axes = cache_batch_axes(cfg, template)
    offset = [0]

    def _take(leaf, ax):
        if ax is None:
            return leaf
        n = leaf.size * leaf.dtype.itemsize
        arr = np.frombuffer(flat[offset[0]:offset[0] + n].tobytes(),
                            dtype=leaf.dtype).reshape(leaf.shape)
        offset[0] += n
        return jnp.asarray(arr)

    out = jax.tree.map(_take, template, axes)
    if offset[0] != flat.size:
        raise ValueError(
            f"migration payload of {flat.size} bytes does not match the "
            f"destination cache layout ({offset[0]} bytes expected)")
    return out


def pack_payload(payload: Dict[str, Any]) -> np.ndarray:
    """Flatten a seq_slice payload to one contiguous byte buffer (the unit
    stored in the EMS pool)."""
    leaves = [np.asarray(x, np.float32).ravel() for x in jax.tree.leaves(payload)]
    return np.concatenate(leaves) if leaves else np.zeros(0, np.float32)


def payload_like(cfg: ModelConfig, batch: int, length: int, template) -> Dict[str, Any]:
    return seq_slice(cfg, template, 0, length)


def unpack_payload(flat: np.ndarray, template: Dict[str, Any]) -> Dict[str, Any]:
    leaves, treedef = jax.tree.flatten(template)
    out, off = [], 0
    for leaf in leaves:
        n = leaf.size
        out.append(jnp.asarray(flat[off:off + n], jnp.float32).reshape(leaf.shape))
        off += n
    return jax.tree.unflatten(treedef, out)
