"""Low-interference prefill→decode KV transfer (paper §4.3.3).

Three mechanisms, reproduced:

* **RDMA-plane isolation** — KV handoff is charged to a dedicated plane
  (400 Gbps/NPU, the paper's scale-out plane; on our TPU mapping this is the
  ``pod`` axis / DCI path) so it never contends with UB-plane decode traffic.
* **Deterministic group connection mapping** — the paper's exact formulas
  balancing which prefill TP rank each decode (tp, dp) rank pulls from.
* **Asynchronous scheduling** — the ServingSystem dispatches prefill and the
  transfer from a background logical thread; decode never blocks (modeled by
  charging transfer time to the request's TTFT, not to decode steps).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import numpy as np

from repro.mempool.pool import PlaneModel, SimClock

RDMA_PLANE = PlaneModel("rdma", 50e9, 5e-6)   # 400 Gbps unidirectional / NPU


def prefill_source_rank(prefill_tp: int, decode_tp: int, decode_dp: int,
                        decode_tp_rank: int, decode_dp_rank: int) -> int:
    """Paper §4.3.3 deterministic group connection mapping."""
    ratio = prefill_tp // decode_tp
    group_size = max(1, decode_dp // max(ratio, 1))
    group_id = decode_dp_rank // group_size
    return group_id * decode_tp + decode_tp_rank


def connection_map(prefill_tp: int, decode_tp: int, decode_dp: int
                   ) -> Dict[tuple, int]:
    """Full (tp_rank, dp_rank) -> prefill source rank mapping."""
    return {(t, d): prefill_source_rank(prefill_tp, decode_tp, decode_dp, t, d)
            for t in range(decode_tp) for d in range(decode_dp)}


def transfer_balance(mapping: Dict[tuple, int], prefill_tp: int) -> float:
    """min/max pulls per source rank (1.0 = perfectly balanced)."""
    counts = np.zeros(prefill_tp, np.int64)
    for src in mapping.values():
        counts[src % prefill_tp] += 1
    nz = counts[counts > 0]
    return float(nz.min() / nz.max()) if len(nz) else 1.0


def cache_nbytes(cache: Any) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree.leaves(cache) if hasattr(x, "dtype"))


class KVTransferEngine:
    """Charges each prefill→decode handoff to the RDMA plane."""

    def __init__(self, clock: SimClock | None = None,
                 plane: PlaneModel = RDMA_PLANE):
        self.clock = clock or SimClock()
        self.plane = plane
        self.transfers = 0
        self.bytes_moved = 0
        self.migrations = 0
        self.bytes_migrated = 0

    def transfer(self, cache: Any) -> float:
        nbytes = cache_nbytes(cache)
        dt = self.clock.charge(self.plane, nbytes)
        self.transfers += 1
        self.bytes_moved += nbytes
        return dt

    def migrate(self, payload: Any) -> float:
        """Cross-engine decode KV migration rides the same isolated plane
        as the prefill→decode handoff (it must never contend with decode
        compute traffic), accounted separately so pool rebalancing cost is
        visible in benchmarks."""
        nbytes = cache_nbytes(payload)
        dt = self.clock.charge(self.plane, nbytes)
        self.migrations += 1
        self.bytes_migrated += nbytes
        return dt
