"""Low-interference prefill→decode KV transfer (paper §4.3.3).

Three mechanisms, reproduced:

* **RDMA-plane isolation** — KV handoff is charged to a dedicated plane
  (400 Gbps/NPU, the paper's scale-out plane; on our TPU mapping this is the
  ``pod`` axis / DCI path) so it never contends with UB-plane decode traffic.
* **Deterministic group connection mapping** — the paper's exact formulas
  balancing which prefill TP rank each decode (tp, dp) rank pulls from.
* **Asynchronous scheduling** — the ServingSystem dispatches prefill and the
  transfer from a background logical thread; decode never blocks (modeled by
  charging transfer time to the request's TTFT, not to decode steps).

Fault tolerance (ISSUE 7): every ``transfer``/``migrate`` carries a payload
fingerprint and, when a fault hook is installed, runs a timeout + capped
exponential-backoff retry loop on the virtual clock. An exhausted op raises
:class:`TransferTimeout` / :class:`TransferCorruption` (both
:class:`TransferError`) carrying the seconds already burned, so callers can
charge the trace and fall back to replay re-prefill instead of propagating
garbage KV. Without a fault hook the data path is bit- and cost-identical
to the fault-free engine.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.mempool.pool import PlaneModel, SimClock
from repro.serving.cache_ops import fingerprint

RDMA_PLANE = PlaneModel("rdma", 50e9, 5e-6)   # 400 Gbps unidirectional / NPU


class TransferError(RuntimeError):
    """An RDMA-plane op failed after exhausting its retries. ``seconds``
    is the virtual time already charged to the clock (timeout windows,
    backoff sleeps, wasted wire time), ``attempts`` the attempts made."""

    def __init__(self, msg: str, *, seconds: float = 0.0, nbytes: int = 0,
                 attempts: int = 0):
        super().__init__(msg)
        self.seconds = seconds
        self.nbytes = nbytes
        self.attempts = attempts


class TransferTimeout(TransferError):
    """Every attempt stalled past the timeout window."""


class TransferCorruption(TransferError):
    """Every attempt delivered a payload whose fingerprint mismatched."""


def prefill_source_rank(prefill_tp: int, decode_tp: int, decode_dp: int,
                        decode_tp_rank: int, decode_dp_rank: int) -> int:
    """Paper §4.3.3 deterministic group connection mapping."""
    ratio = prefill_tp // decode_tp
    group_size = max(1, decode_dp // max(ratio, 1))
    group_id = decode_dp_rank // group_size
    return group_id * decode_tp + decode_tp_rank


def connection_map(prefill_tp: int, decode_tp: int, decode_dp: int
                   ) -> Dict[tuple, int]:
    """Full (tp_rank, dp_rank) -> prefill source rank mapping."""
    return {(t, d): prefill_source_rank(prefill_tp, decode_tp, decode_dp, t, d)
            for t in range(decode_tp) for d in range(decode_dp)}


def live_connection_map(live_ranks: Sequence[int], decode_tp: int,
                        decode_dp: int) -> Dict[tuple, int]:
    """Connection mapping over the *live* prefill roster.

    With pooled spawn/park/retire the prefill ranks are no longer the
    contiguous ``0..tp-1`` the paper's formula assumes: the roster is an
    arbitrary set of instance ids. We apply the deterministic mapping over
    ``len(live_ranks)`` virtual slots, then translate each slot to the
    actual live rank in sorted id order — so the map only ever points at
    live instances and stays deterministic for a given roster.
    """
    order = sorted(set(live_ranks))
    if not order:
        raise ValueError("live_connection_map needs at least one live rank")
    n = len(order)
    base = connection_map(n, decode_tp, decode_dp)
    return {key: order[src % n] for key, src in base.items()}


def transfer_balance(mapping: Dict[tuple, int], prefill_tp: int,
                     live_ranks: Optional[Sequence[int]] = None) -> float:
    """min/max pulls per source rank (1.0 = perfectly balanced).

    Legacy call (``live_ranks=None``) assumes the static contiguous
    ``0..prefill_tp-1`` roster. With pooled spawn/retire that assumption
    lies: pass the live roster and the balance is recomputed over exactly
    those ranks — a mapping still pointing at a retired rank raises
    instead of silently folding its pulls onto a live one.
    """
    if live_ranks is not None:
        order = sorted(set(live_ranks))
        if not order:
            raise ValueError("transfer_balance needs at least one live rank")
        index = {rank: i for i, rank in enumerate(order)}
        counts = np.zeros(len(order), np.int64)
        for src in mapping.values():
            if src not in index:
                raise ValueError(
                    f"stale connection map: source rank {src} is not in the "
                    f"live prefill roster {order}")
            counts[index[src]] += 1
    else:
        counts = np.zeros(prefill_tp, np.int64)
        for src in mapping.values():
            counts[src % prefill_tp] += 1
    nz = counts[counts > 0]
    return float(nz.min() / nz.max()) if len(nz) else 1.0


def cache_nbytes(cache: Any) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree.leaves(cache) if hasattr(x, "dtype"))


class KVTransferEngine:
    """Charges each prefill→decode handoff to the RDMA plane.

    ``fault_hook(op) -> None | "timeout" | "corrupt"`` (typically
    :meth:`~repro.serving.faults.FaultInjector.transfer_fault`) is consulted
    once per delivery *attempt*; a faulted attempt charges its cost
    (timeout window, or full wire time for a corrupted delivery), then the
    op backs off ``backoff_base_s · 2^k`` capped at ``backoff_cap_s`` and
    retries, up to ``max_retries`` retries before raising. With no hook
    the fast path is exactly the fault-free engine — one charge, no
    fingerprint work — so fault-free runs stay bit- and cost-identical.
    """

    def __init__(self, clock: SimClock | None = None,
                 plane: PlaneModel = RDMA_PLANE, *,
                 timeout_s: float = 2e-3, max_retries: int = 3,
                 backoff_base_s: float = 2.5e-4, backoff_cap_s: float = 2e-3,
                 fault_hook: Optional[Callable[[str], Optional[str]]] = None):
        if timeout_s <= 0 or max_retries < 0:
            raise ValueError("need timeout_s > 0 and max_retries >= 0")
        if backoff_base_s <= 0 or backoff_cap_s < backoff_base_s:
            raise ValueError("need 0 < backoff_base_s <= backoff_cap_s")
        self.clock = clock or SimClock()
        self.plane = plane
        self.timeout_s = timeout_s
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.fault_hook = fault_hook
        # Hook arity is probed once per hook object: new-style hooks
        # (FaultInjector.transfer_fault) take (op, rid, chunk) so chunked
        # streaming can address faults per (rid, op, chunk); legacy
        # ``lambda op: ...`` hooks keep working unchanged.
        self._hook_probed: Any = None
        self._hook_scoped = False
        self.transfers = 0
        self.bytes_moved = 0
        self.migrations = 0
        self.bytes_migrated = 0
        self.promotes = 0
        self.bytes_promoted = 0
        self.demotes = 0
        self.bytes_demoted = 0
        self.retries = 0
        self.timeouts = 0
        self.corruptions = 0
        self.fingerprint_checks = 0

    def _idle(self, seconds: float) -> float:
        """Charge non-wire virtual time (timeout windows, backoff sleeps)
        to the clock."""
        self.clock.elapsed += seconds
        return seconds

    def _consult_hook(self, op: str, rid: Optional[int],
                      chunk: Optional[int]) -> Optional[str]:
        """Call the fault hook with per-(rid, chunk) scope when it accepts
        it, falling back to the legacy single-argument form otherwise."""
        hook = self.fault_hook
        if hook is not self._hook_probed:
            self._hook_probed = hook
            try:
                params = inspect.signature(hook).parameters
                self._hook_scoped = ("rid" in params and "chunk" in params) \
                    or any(p.kind == inspect.Parameter.VAR_KEYWORD
                           for p in params.values())
            except (TypeError, ValueError):
                self._hook_scoped = False
        if self._hook_scoped:
            return hook(op, rid=rid, chunk=chunk)
        return hook(op)

    def _deliver(self, payload: Any, op: str, rid: Optional[int] = None,
                 chunk: Optional[int] = None) -> Tuple[float, int]:
        """One op through the retry loop. Returns (seconds, nbytes) on a
        fingerprint-verified delivery; raises :class:`TransferError` after
        ``max_retries`` failed retries with the burned seconds attached."""
        nbytes = cache_nbytes(payload)
        if self.fault_hook is None:
            return self.clock.charge(self.plane, nbytes), nbytes
        sent_fp = fingerprint(payload)
        dt, failures = 0.0, 0
        while True:
            fault = self._consult_hook(op, rid, chunk)
            if fault == "timeout":
                # The plane stalls for the full window before the sender
                # gives up on this attempt; no bytes land.
                dt += self._idle(self.timeout_s)
                self.timeouts += 1
                err, what = TransferTimeout, "timed out"
            elif fault == "corrupt":
                # Full wire cost paid, but the delivered fingerprint
                # mismatches — the delivery is discarded, never applied.
                dt += self.clock.charge(self.plane, nbytes)
                self.fingerprint_checks += 1
                self.corruptions += 1
                err, what = TransferCorruption, "arrived corrupted"
            else:
                dt += self.clock.charge(self.plane, nbytes)
                self.fingerprint_checks += 1
                if fingerprint(payload) != sent_fp:
                    # Genuine (non-injected) corruption of the in-memory
                    # payload between send and delivery.
                    raise TransferCorruption(
                        f"{op} payload of {nbytes} B mutated in flight",
                        seconds=dt, nbytes=nbytes, attempts=failures + 1)
                return dt, nbytes
            failures += 1
            if failures > self.max_retries:
                raise err(
                    f"{op} of {nbytes} B {what} on all {failures} attempts "
                    f"({self.max_retries} retries exhausted)",
                    seconds=dt, nbytes=nbytes, attempts=failures)
            self.retries += 1
            dt += self._idle(min(self.backoff_base_s * (1 << (failures - 1)),
                                 self.backoff_cap_s))

    def transfer(self, cache: Any, *, rid: Optional[int] = None,
                 chunk: Optional[int] = None) -> float:
        dt, nbytes = self._deliver(cache, "transfer", rid, chunk)
        self.transfers += 1
        self.bytes_moved += nbytes
        return dt

    def migrate(self, payload: Any, *, rid: Optional[int] = None,
                chunk: Optional[int] = None) -> float:
        """Cross-engine decode KV migration rides the same isolated plane
        as the prefill→decode handoff (it must never contend with decode
        compute traffic), accounted separately so pool rebalancing cost is
        visible in benchmarks."""
        dt, nbytes = self._deliver(payload, "migrate", rid, chunk)
        self.migrations += 1
        self.bytes_migrated += nbytes
        return dt

    def promote(self, payload: Any) -> float:
        """EMS tier promotion (pooled host tier → device HBM): same
        isolated plane, separate books so cache-tier traffic is visible
        next to handoff/migration traffic."""
        dt, nbytes = self._deliver(payload, "promote")
        self.promotes += 1
        self.bytes_promoted += nbytes
        return dt

    def demote(self, payload: Any) -> float:
        """EMS write-back demotion (device HBM → pooled host tier)."""
        dt, nbytes = self._deliver(payload, "demote")
        self.demotes += 1
        self.bytes_demoted += nbytes
        return dt
