from repro.serving.engine import (  # noqa: F401
    DecodeEngine,
    PrefillEngine,
    Request,
    RequestResult,
    ServingSystem,
)
from repro.serving.scheduler import (  # noqa: F401
    ROUTERS,
    AdmissionGate,
    BrownoutLadder,
    DecodeCostModel,
    DecodeSlotManager,
    LeastLoadedRouter,
    MicrobatchInterleaver,
    PrefillRouter,
    QueueDepthRouter,
    RequestTrace,
    RoundRobinRouter,
    Scheduler,
    SchedulerConfig,
    SlotError,
    SLOTracker,
    decode_cost_from_roofline,
    make_router,
)
from repro.serving.pool import (  # noqa: F401
    DECODE_ROUTERS,
    CacheAffinityRouter,
    DecodePool,
    DecodePoolRouter,
    DrainError,
    JointAutoscaler,
    LeastLoadedSlotsRouter,
    PoolAutoscaler,
    PoolRoundRobinRouter,
    PrefillPool,
    make_decode_router,
)
from repro.serving.workload import (  # noqa: F401
    ARRIVAL_SHAPES,
    multi_turn_sessions,
    poisson_requests,
    production_requests,
)
from repro.serving.transfer import (  # noqa: F401
    KVTransferEngine,
    TransferCorruption,
    TransferError,
    TransferTimeout,
    connection_map,
    live_connection_map,
    prefill_source_rank,
    transfer_balance,
)
from repro.serving.faults import (  # noqa: F401
    FaultEvent,
    FaultInjector,
    FaultPlan,
)
