from repro.serving.engine import (  # noqa: F401
    DecodeEngine,
    PrefillEngine,
    Request,
    RequestResult,
    ServingSystem,
)
from repro.serving.transfer import (  # noqa: F401
    KVTransferEngine,
    connection_map,
    prefill_source_rank,
    transfer_balance,
)
