"""Deterministic synthetic data pipeline with sequence packing.

No external datasets are available offline, so the corpus is a seeded
Zipf-distributed token stream with injected n-gram structure (so loss
measurably decreases during training). Documents of variable length are
packed into fixed-length training sequences (the same packing the paper's
prefill-side SP stage assumes), with next-token labels.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    ngram_order: int = 3


class SyntheticCorpus:
    """Seeded document stream: Zipf unigrams + a sticky n-gram transition
    table, giving a learnable (non-uniform) conditional distribution."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.RandomState(cfg.seed)
        v = cfg.vocab_size
        # sparse "grammar": each context token prefers a few successors
        self.n_succ = 4
        self.succ = self.rng.randint(0, v, size=(v, self.n_succ))
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks ** 1.2)
        self.unigram /= self.unigram.sum()

    def _doc(self) -> np.ndarray:
        n = max(8, int(self.rng.exponential(self.cfg.mean_doc_len)))
        out = np.empty(n, np.int32)
        out[0] = self.rng.choice(self.cfg.vocab_size, p=self.unigram)
        for i in range(1, n):
            if self.rng.rand() < 0.7:   # follow grammar
                out[i] = self.succ[out[i - 1], self.rng.randint(self.n_succ)]
            else:
                out[i] = self.rng.choice(self.cfg.vocab_size, p=self.unigram)
        return out

    def packed_batches(self) -> Iterator[Dict[str, np.ndarray]]:
        """Infinite iterator of {tokens, labels} packed to (B, S)."""
        cfg = self.cfg
        buf = np.empty(0, np.int32)
        need = cfg.global_batch * (cfg.seq_len + 1)
        while True:
            while len(buf) < need:
                buf = np.concatenate([buf, self._doc()])
            chunk = buf[:need].reshape(cfg.global_batch, cfg.seq_len + 1)
            buf = buf[need:]
            yield {"tokens": chunk[:, :-1].copy(),
                   "labels": chunk[:, 1:].copy()}


def make_batch_iter(vocab_size: int, seq_len: int, global_batch: int,
                    seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    return SyntheticCorpus(
        DataConfig(vocab_size, seq_len, global_batch, seed)).packed_batches()
