from repro.data.pipeline import DataConfig, SyntheticCorpus, make_batch_iter  # noqa: F401
