"""Shared building blocks: RMSNorm, RoPE, SwiGLU, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, gain: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * gain.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    dt = x.dtype
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def dense_init(key: jax.Array, shape, dtype, scale: float | None = None) -> jax.Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / (fan_in ** 0.5)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
