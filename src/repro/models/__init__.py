from repro.models.model import (  # noqa: F401
    build_plan,
    cache_batch_axes,
    decode_loop,
    decode_loop_mtp,
    decode_step,
    forward,
    init_params,
    lm_loss,
    make_caches,
    prefill,
    prefill_continue,
)
