from repro.models.model import (  # noqa: F401
    build_plan,
    decode_step,
    forward,
    init_params,
    lm_loss,
    make_caches,
    prefill,
)
