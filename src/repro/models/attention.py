"""GQA attention: chunked (memory-bounded) prefill + single-token decode.

Variants (per ModelConfig): causal, bidirectional (encoder), sliding-window
(serving path for long-context decode of full-attention archs), qk-norm
(Qwen3), QKV bias (Qwen2.5).

The prefill path scans over query chunks so the score tensor never exceeds
(B, chunk, H, S) — the pure-JAX analogue of flash attention's tiling, and the
reference the Pallas kernels are validated against. The decode path attends
one new token against the (possibly sequence-sharded) KV cache; under pjit
the softmax reductions over the sharded S axis lower to all-reduces, which is
our TPU-native stand-in for the paper's DP-attention with UB-pooled KV.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_norm

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Per-segment stacked KV cache. k/v: (L, B, S, KV, hd).

    Whether the cache is a sliding-window ring buffer is a *static* property
    derived from (cfg, seq_len) via :func:`is_ring` — it is deliberately not a
    field so the cache stays a clean jit-able pytree.
    """
    k: jax.Array
    v: jax.Array
    length: jax.Array   # scalar int32: number of tokens written (global)

    @property
    def capacity(self) -> int:
        return self.k.shape[2]


def is_ring(cfg: ModelConfig, seq_len: int) -> bool:
    return bool(cfg.sliding_window and seq_len > cfg.sliding_window)


def init_attention_params(key, cfg: ModelConfig, n_layers: int, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "ln": jnp.ones((n_layers, d), dtype),
        "wq": dense_init(ks[0], (n_layers, d, h * hd), dtype),
        "wk": dense_init(ks[1], (n_layers, d, kv * hd), dtype),
        "wv": dense_init(ks[2], (n_layers, d, kv * hd), dtype),
        "wo": dense_init(ks[3], (n_layers, h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n_layers, h * hd), dtype)
        p["bk"] = jnp.zeros((n_layers, kv * hd), dtype)
        p["bv"] = jnp.zeros((n_layers, kv * hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((n_layers, hd), dtype)
        p["k_norm"] = jnp.ones((n_layers, hd), dtype)
    return p


def _project_qkv(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,KV,hd), with qk-norm + RoPE."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.attention_kind != "bidirectional" or True:
        # RoPE is applied for all archs in the zoo (hubert uses it in lieu of
        # its conv positional encoding — frontend carve-out, see DESIGN.md).
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask) -> jax.Array:
    """q: (B,Sq,H,hd); k/v: (B,Skv,KV,hd); mask: (B|1, Sq, Skv) bool."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    qg = q.reshape(b, sq, kvh, groups, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / (hd ** 0.5)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h * hd).astype(q.dtype)


def _pick_chunk(s: int, target: int = 512) -> int:
    if s <= target:
        return s
    c = target
    while s % c:
        c //= 2
    return max(c, 1)


def block_skip_enabled() -> bool:
    """Causal block-skipping (beyond-paper §Perf optimization): the flash-
    style prefill loop visits only kv blocks ≤ the query block (and within
    the sliding window), halving executed attention FLOPs vs the masked
    full-S baseline. Opt-in via REPRO_BLOCK_SKIP=1."""
    import os
    return os.environ.get("REPRO_BLOCK_SKIP", "0") == "1"


def _flash_causal(q, k, v, cfg: ModelConfig, chunk: int):
    """Block-skipped causal attention with an online-softmax kv-block loop.

    q: (B,S,H,hd); k/v: (B,S,KV,hd). Query chunk ci attends kv blocks
    [lo(ci), ci] only — lo respects the sliding window when configured.
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    nc = s // chunk
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def one_chunk(ci):
        qc = jax.lax.dynamic_slice_in_dim(q, ci * chunk, chunk, axis=1)
        qg = qc.reshape(b, chunk, kvh, groups, hd).astype(jnp.float32)
        q_pos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)

        def kv_block(j, carry):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(kf, j * chunk, chunk, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vf, j * chunk, chunk, axis=1)
            scores = jnp.einsum("bskgh,btkh->bkgst", qg, kb) / (hd ** 0.5)
            kv_pos = j * chunk + jnp.arange(chunk, dtype=jnp.int32)
            mask = kv_pos[None, :] <= q_pos[:, None]
            if cfg.sliding_window:
                mask &= kv_pos[None, :] > q_pos[:, None] - cfg.sliding_window
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(scores, axis=-1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(scores - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bkgst,btkh->bkgsh", p, vb)
            return m_new, l_new, acc_new

        m0 = jnp.full((b, kvh, groups, chunk, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, groups, chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, kvh, groups, chunk, hd), jnp.float32)
        if cfg.sliding_window:
            # first kv block containing any in-window position for this chunk
            lo = jnp.maximum(0, (ci * chunk - (cfg.sliding_window - 1)) // chunk)
        else:
            lo = jnp.int32(0)
        m, l, acc = jax.lax.fori_loop(lo, ci + 1, kv_block, (m0, l0, a0))
        out = acc / jnp.maximum(l, 1e-30)
        # (b,kv,g,chunk,hd) -> (b,chunk,h*hd)
        return jnp.moveaxis(out, 3, 1).reshape(b, chunk, h * hd).astype(q.dtype)

    from repro.models.scan_util import chunk_map
    if nc == 1:
        return one_chunk(jnp.int32(0))
    outs = chunk_map(one_chunk, nc)
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h * hd)


def attention_prefill(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence attention, chunked over queries. Returns (out, (k, v))."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q, k, v = _project_qkv(p, x, cfg, positions)
    chunk = _pick_chunk(s)
    n_chunks = s // chunk

    if cfg.attention_kind != "bidirectional" and block_skip_enabled():
        out = _flash_causal(q, k, v, cfg, chunk)
        out = jnp.einsum("bse,ed->bsd", out, p["wo"])
        return out, (k, v)

    kv_pos = jnp.arange(s, dtype=jnp.int32)

    def one_chunk(ci):
        q_pos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        qc = jax.lax.dynamic_slice_in_dim(q, ci * chunk, chunk, axis=1)
        if cfg.attention_kind == "bidirectional":
            mask = jnp.ones((1, chunk, s), bool)
        else:
            mask = (kv_pos[None, :] <= q_pos[:, None])[None]
            if cfg.sliding_window and s > cfg.sliding_window:
                mask &= (kv_pos[None, :] > q_pos[:, None] - cfg.sliding_window)[None]
        return _sdpa(qc, k, v, mask)

    if n_chunks == 1:
        out = one_chunk(jnp.int32(0))
    else:
        from repro.models.scan_util import chunk_map
        outs = chunk_map(one_chunk, n_chunks)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, -1)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"])
    return out, (k, v)


def _positions_of(cache_len: jax.Array, b: int) -> jax.Array:
    """cache_len: scalar or (B,) -> positions (B, 1)."""
    if cache_len.ndim == 0:
        return jnp.broadcast_to(cache_len[None], (b, 1)).astype(jnp.int32)
    return cache_len[:, None].astype(jnp.int32)


def update_cache(cache: jax.Array, new: jax.Array, slot: jax.Array) -> jax.Array:
    """Write (B,1,...) entry at per-request or scalar slot into (B,S,...)."""
    if slot.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new.astype(cache.dtype), slot, axis=1)
    b = cache.shape[0]
    return cache.at[jnp.arange(b), slot].set(new[:, 0].astype(cache.dtype))


def decode_valid_mask(cache_len: jax.Array, cap: int, ring: bool) -> jax.Array:
    """(B|1, 1, S) boolean mask of attendable cache slots (incl. new token).

    MTP-aware: cache_len may be per-request (B,) — the paper's "varying
    effective sequence lengths within the same batch" (§4.2.2 issue 3).
    """
    kv_idx = jnp.arange(cap, dtype=jnp.int32)
    cl = cache_len[None] if cache_len.ndim == 0 else cache_len  # (B|1,)
    if ring:
        valid = kv_idx[None, :] <= jnp.minimum(cl[:, None], cap - 1)
    else:
        valid = kv_idx[None, :] <= cl[:, None]
    return valid[:, None, :]                                    # (B|1,1,S)


def attention_decode(
    p: dict,
    x: jax.Array,                 # (B, 1, D) — one new token per request
    cache_k: jax.Array,           # (B, S, KV, hd)
    cache_v: jax.Array,
    cache_len: jax.Array,         # int32: scalar or per-request (B,)
    cfg: ModelConfig,
    ring: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step. Returns (out (B,1,D), new_cache_k, new_cache_v)."""
    b = x.shape[0]
    cap = cache_k.shape[1]
    positions = _positions_of(cache_len, b)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    slot = (cache_len % cap).astype(jnp.int32) if ring else cache_len
    cache_k = update_cache(cache_k, k_new, slot)
    cache_v = update_cache(cache_v, v_new, slot)
    mask = decode_valid_mask(cache_len, cap, ring)
    out = _sdpa(q, cache_k, cache_v, mask)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"])
    return out, cache_k, cache_v


def attention_extend(
    p: dict,
    x: jax.Array,                 # (B, S, D) — teacher-forced new tokens
    cache_k: jax.Array,           # (B, cap, KV, hd), first `offset` valid
    cache_v: jax.Array,
    offset: jax.Array,            # int32: scalar or per-request (B,) (traced)
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Multi-token cached attention: process S known tokens at positions
    ``offset .. offset+S-1`` in one shot — the batched generalization of
    :func:`attention_decode` (S=1) used by the chunked suffix-prefill fast
    path. A per-request ``offset`` (B,) supports divergent sequence lengths
    within one batch — the MTP fused base+draft verification forward (paper
    §4.2.2 issue 3). No ring-buffer support (neither the EMS reuse path nor
    MTP verification ever sees rings).

    Returns (out (B,S,D), new_cache_k, new_cache_v)."""
    b, s, _ = x.shape
    cap = cache_k.shape[1]
    offset = jnp.asarray(offset, jnp.int32)
    if offset.ndim == 0:
        q_pos = offset + jnp.arange(s, dtype=jnp.int32)     # (S,)
        positions = jnp.broadcast_to(q_pos[None], (b, s))
        q, k_new, v_new = _project_qkv(p, x, cfg, positions)
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_new.astype(cache_k.dtype), offset, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_new.astype(cache_v.dtype), offset, axis=1)
        kv_idx = jnp.arange(cap, dtype=jnp.int32)
        mask = (kv_idx[None, :] <= q_pos[:, None])[None]    # (1, S, cap)
    else:
        positions = offset[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
        q, k_new, v_new = _project_qkv(p, x, cfg, positions)
        rows = jnp.arange(b, dtype=jnp.int32)[:, None]
        # Out-of-bounds scatter rows are dropped (masked callers rely on it).
        cache_k = cache_k.at[rows, positions].set(k_new.astype(cache_k.dtype))
        cache_v = cache_v.at[rows, positions].set(v_new.astype(cache_v.dtype))
        kv_idx = jnp.arange(cap, dtype=jnp.int32)
        mask = kv_idx[None, None, :] <= positions[:, :, None]   # (B, S, cap)
    out = _sdpa(q, cache_k, cache_v, mask)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"])
    return out, cache_k, cache_v


def make_cache(cfg: ModelConfig, n_layers: int, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> KVCache:
    cap = cfg.sliding_window if is_ring(cfg, seq_len) else seq_len
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (n_layers, batch, cap, kv, hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))
