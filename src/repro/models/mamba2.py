"""Mamba2 block — SSD (state-space duality) chunked scan. [arXiv:2405.21060]

Prefill uses the exact chunked SSD algorithm: quadratic attention-like intra-
chunk term + sequential inter-chunk state recurrence (one lax.scan carrying
the (B, H, P, N) state). Decode is the O(1) recurrence. The attention-free
path is what makes the ``long_500k`` shape native for mamba2/zamba2 (see
DESIGN.md §3); ``ssd_reference`` (naive token-level recurrence) is the test
oracle, and kernels/ssd_scan provides the Pallas intra-chunk kernel.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm


class SSMState(NamedTuple):
    h: jax.Array       # (L, B, H, P, N) recurrent state
    conv: jax.Array    # (L, B, conv-1, conv_channels) rolling conv inputs
    length: jax.Array  # scalar int32


def init_mamba_params(key, cfg: ModelConfig, n_layers: int, dtype) -> dict:
    d = cfg.d_model
    din = d * cfg.ssm_expand
    n, h = cfg.ssm_state, cfg.ssm_heads
    conv_ch = din + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.ones((n_layers, d), dtype),
        # in_proj -> [z (din), x (din), B (n), C (n), dt (h)]
        "in_proj": dense_init(ks[0], (n_layers, d, 2 * din + 2 * n + h), dtype),
        "conv_w": dense_init(ks[1], (n_layers, cfg.ssm_conv, conv_ch), dtype, scale=0.5),
        "conv_b": jnp.zeros((n_layers, conv_ch), dtype),
        "dt_bias": jnp.zeros((n_layers, h), jnp.float32),
        "A_log": jnp.zeros((n_layers, h), jnp.float32),   # A = -exp(A_log) = -1
        "D": jnp.ones((n_layers, h), jnp.float32),
        "norm_gain": jnp.ones((n_layers, din), dtype),
        "out_proj": dense_init(ks[2], (n_layers, din, d), dtype),
    }


def _split_proj(p: dict, x: jax.Array, cfg: ModelConfig):
    din = cfg.d_model * cfg.ssm_expand
    n, h = cfg.ssm_state, cfg.ssm_heads
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din: 2 * din + 2 * n]
    dt_raw = zxbcdt[..., 2 * din + 2 * n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    return z, xbc, dt  # dt: (b,s,h) f32


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. xbc: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):  # K is tiny (4); unrolled taps
        out = out + pad[:, i: i + xbc.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def ssd_reference(x, dt, a_log, bmat, cmat):
    """Naive per-token recurrence (oracle). x: (B,S,H,P); B/C: (B,S,N)."""
    a = -jnp.exp(a_log)                                     # (H,)

    def step(h, inp):
        xt, dtt, bt, ct = inp                               # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * a)[..., None, None]           # (B,H,1,1)
        h = h * decay + (dtt[..., None] * xt)[..., None] * bt[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    b, s, h, pdim = x.shape
    n = bmat.shape[-1]
    h0 = jnp.zeros((b, h, pdim, n), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(bmat, 1, 0).astype(jnp.float32),
          jnp.moveaxis(cmat, 1, 0).astype(jnp.float32))
    hT, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hT                       # (B,S,H,P), (B,H,P,N)


def ssd_chunked(x, dt, a_log, bmat, cmat, chunk: int, h0=None):
    """Exact chunked SSD. Shapes as ssd_reference. Returns (y, h_final)."""
    b, s, h, pdim = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q
    a = -jnp.exp(a_log)
    dta = dt * a                                             # (b,s,h) f32, <=0

    xc = x.reshape(b, nc, q, h, pdim).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h)
    dtac = dta.reshape(b, nc, q, h)
    bc = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, q, n).astype(jnp.float32)
    cum = jnp.cumsum(dtac, axis=2)                           # (b,nc,q,h)

    if h0 is None:
        h0 = jnp.zeros((b, h, pdim, n), jnp.float32)

    idx = jnp.arange(q)
    causal = idx[:, None] >= idx[None, :]                    # (q,k)

    def body(hstate, inp):
        x_c, dt_c, cum_c, b_c, c_c = inp                     # leading dim b
        decay_out = jnp.exp(cum_c)                           # (b,q,h)
        y_inter = jnp.einsum("bqn,bhpn->bqhp", c_c, hstate) * decay_out[..., None]
        lmat = jnp.exp(cum_c[:, :, None, :] - cum_c[:, None, :, :])  # (b,q,k,h)
        cb = jnp.einsum("bqn,bkn->bqk", c_c, b_c)
        w = cb[..., None] * lmat * dt_c[:, None, :, :]
        w = jnp.where(causal[None, :, :, None], w, 0.0)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", w, x_c)
        decay_to_end = jnp.exp(cum_c[:, -1:, :] - cum_c)     # (b,q,h)
        contrib = jnp.einsum("bqh,bqhp,bqn->bhpn", decay_to_end * dt_c, x_c, b_c)
        hstate = hstate * jnp.exp(cum_c[:, -1, :])[:, :, None, None] + contrib
        return hstate, y_inter + y_intra

    seq = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0), jnp.moveaxis(cum, 1, 0),
           jnp.moveaxis(bc, 1, 0), jnp.moveaxis(cc, 1, 0))
    h_final, ys = jax.lax.scan(body, h0, seq)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, pdim)
    return y, h_final


def mamba_prefill(p: dict, x: jax.Array, cfg: ModelConfig
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B,S,D). Returns (out (B,S,D), h_state, conv_state)."""
    bsz, s, d = x.shape
    din = d * cfg.ssm_expand
    n, h = cfg.ssm_state, cfg.ssm_heads
    z, xbc_raw, dt = _split_proj(p, x, cfg)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xin = xbc[..., :din].reshape(bsz, s, h, cfg.ssm_head_dim)
    bmat = xbc[..., din: din + n]
    cmat = xbc[..., din + n:]
    y, h_final = ssd_chunked(xin, dt, p["A_log"], bmat, cmat, cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None] * xin.astype(jnp.float32)
    y = y.reshape(bsz, s, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(z.dtype),
                 p["norm_gain"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    # conv state: last (K-1) raw xbc inputs
    k = cfg.ssm_conv
    conv_state = xbc_raw[:, -(k - 1):, :] if s >= k - 1 else jnp.pad(
        xbc_raw, ((0, 0), (k - 1 - s, 0), (0, 0)))
    return out, h_final, conv_state


def mamba_decode(p: dict, x: jax.Array, h_state: jax.Array, conv_state: jax.Array,
                 cfg: ModelConfig) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One token. x: (B,1,D); h_state: (B,H,P,N); conv_state: (B,K-1,C)."""
    bsz, _, d = x.shape
    din = d * cfg.ssm_expand
    n, h = cfg.ssm_state, cfg.ssm_heads
    z, xbc_raw, dt = _split_proj(p, x, cfg)                  # seq dim = 1
    window = jnp.concatenate([conv_state, xbc_raw], axis=1)  # (B,K,C)
    new_conv_state = window[:, 1:, :]
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(conv_out)
    xin = xbc[..., :din].reshape(bsz, h, cfg.ssm_head_dim)
    bmat, cmat = xbc[..., din: din + n], xbc[..., din + n:]
    a = -jnp.exp(p["A_log"])
    dtt = dt[:, 0]                                           # (B,H)
    decay = jnp.exp(dtt * a)[..., None, None]
    h_state = h_state * decay + (dtt[..., None] * xin)[..., None] * bmat[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h_state, cmat)
    y = y + p["D"][None, :, None] * xin
    y = y.reshape(bsz, 1, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(z.dtype),
                 p["norm_gain"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, h_state, new_conv_state


def make_ssm_state(cfg: ModelConfig, n_layers: int, batch: int) -> SSMState:
    din = cfg.d_model * cfg.ssm_expand
    conv_ch = din + 2 * cfg.ssm_state
    return SSMState(
        h=jnp.zeros((n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32),
        conv=jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, conv_ch), jnp.bfloat16),
        length=jnp.zeros((), jnp.int32),
    )
