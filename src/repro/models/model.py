"""Unified transformer assembly for every assigned architecture family.

The model is organized as *segments* of structurally-identical layers; each
segment's parameters are stacked on a leading layer axis and executed with
``jax.lax.scan`` (keeps 512-device dry-run compiles tractable and HLO small).

Families → segment plans:
  dense / vlm / audio : [dense × L]
  moe                 : [dense × first_k_dense] + [moe × (L - k)]
  ssm                 : [mamba × L]
  hybrid (zamba2)     : [mamba groups of ``attn_every`` + one *shared* attention
                         block applied after each group] + [mamba tail]

Six entry points: ``forward`` (full-sequence, training), ``prefill``
(full-sequence + cache materialization), ``decode_step`` (one token),
``decode_loop`` (N scanned decode steps with on-device greedy sampling —
the serving fast path), ``decode_loop_mtp`` (N scanned MTP speculative
iterations with on-device accept/reject — up to 2N tokens per host sync),
and ``prefill_continue`` (teacher-forced continuation against an existing
cache: the EMS-reuse suffix path, the bounded-shape fresh-prefill chunk
step, and — with per-request offsets — the MTP fused verification
forward).
MoE execution is pluggable via ``moe_fn`` — default is the single-device
capacity implementation; ``core/lep.py`` supplies the shard_map LEP version.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


from repro.models.scan_util import scan_unroll  # noqa: E402


def _scan(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=scan_unroll())

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.attention import KVCache
from repro.models.layers import dense_init, rms_norm, swiglu
from repro.models.mamba2 import SSMState

MoeFn = Callable[[dict, jax.Array, ModelConfig], Tuple[jax.Array, Dict[str, jax.Array]]]


# ---------------------------------------------------------------------------
# Segment plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    name: str
    kind: str        # dense | moe | mamba_groups | mamba_tail
    n_layers: int    # layers in this segment (groups*per_group for mamba_groups)
    per_group: int = 0


def build_plan(cfg: ModelConfig) -> List[Segment]:
    if cfg.is_hybrid:
        groups = cfg.num_layers // cfg.attn_every
        tail = cfg.num_layers % cfg.attn_every
        plan = [Segment("mamba_groups", "mamba_groups",
                        groups * cfg.attn_every, cfg.attn_every)]
        if tail:
            plan.append(Segment("mamba_tail", "mamba_tail", tail))
        return plan
    if cfg.is_ssm:
        return [Segment("mamba", "mamba_tail", cfg.num_layers)]
    if cfg.is_moe:
        plan = []
        if cfg.first_k_dense:
            plan.append(Segment("dense_lead", "dense", cfg.first_k_dense))
        plan.append(Segment("moe", "moe", cfg.num_layers - cfg.first_k_dense))
        return plan
    return [Segment("dense", "dense", cfg.num_layers)]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_attn(key, cfg: ModelConfig, n_layers: int, dtype):
    if cfg.attention_kind == "mla":
        return mla_mod.init_mla_params(key, cfg, n_layers, dtype)
    return attn_mod.init_attention_params(key, cfg, n_layers, dtype)


def _init_mlp(key, cfg: ModelConfig, n_layers: int, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "ln": jnp.ones((n_layers, d), dtype),
        "w_gate": dense_init(ks[0], (n_layers, d, f), dtype),
        "w_up": dense_init(ks[1], (n_layers, d, f), dtype),
        "w_down": dense_init(ks[2], (n_layers, f, d), dtype),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = _dtype(cfg)
    plan = build_plan(cfg)
    keys = jax.random.split(key, len(plan) + 4)
    params: dict = {
        "embed": dense_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype, scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "segments": {},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_size), dtype)
    for i, seg in enumerate(plan):
        k = keys[2 + i]
        if seg.kind == "dense":
            ka, km = jax.random.split(k)
            params["segments"][seg.name] = {
                "attn": _init_attn(ka, cfg, seg.n_layers, dtype),
                "mlp": _init_mlp(km, cfg, seg.n_layers, dtype),
            }
        elif seg.kind == "moe":
            ka, km = jax.random.split(k)
            params["segments"][seg.name] = {
                "attn": _init_attn(ka, cfg, seg.n_layers, dtype),
                "moe": moe_mod.init_moe_params(km, cfg, seg.n_layers, dtype),
            }
        else:  # mamba_groups / mamba_tail
            params["segments"][seg.name] = {
                "mamba": mamba_mod.init_mamba_params(k, cfg, seg.n_layers, dtype),
            }
    if cfg.is_hybrid:
        ka, km = jax.random.split(keys[-1])
        params["shared_attn"] = {
            "attn": _init_attn(ka, cfg, 1, dtype),
            "mlp": _init_mlp(km, cfg, 1, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_inputs(params: dict, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    if cfg.frontend == "audio_frames":
        return batch["frames"].astype(_dtype(cfg))
    x = params["embed"][batch["tokens"]]
    if cfg.frontend == "vision_patches" and "prefix_emb" in batch:
        x = jnp.concatenate([batch["prefix_emb"].astype(x.dtype), x], axis=1)
    return x


def unembed(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("...d,dv->...v", x, head)


# ---------------------------------------------------------------------------
# Per-layer blocks (single-layer params)
# ---------------------------------------------------------------------------


def _attn_block_prefill(pl_attn, x, cfg, positions):
    h = rms_norm(x, pl_attn["ln"], cfg.norm_eps)
    if cfg.attention_kind == "mla":
        mode = os.environ.get("REPRO_MLA_HYBRID", "")
        if mode in ("a2a", "rs"):
            # Paper §4.3.1 staged hybrid parallelism (SP→TP→SP) — enabled
            # for prefill when a mesh context is active (launch/variants).
            from repro.core.parallel import get_current_mesh
            mesh = get_current_mesh()
            if mesh is not None:
                from repro.core.hybrid_parallel import mla_prefill_hybrid
                out, latent = mla_prefill_hybrid(pl_attn, h, cfg, mesh,
                                                 oproj_mode=mode)
                return x + out, latent
        out, latent = mla_mod.mla_prefill(pl_attn, h, cfg, positions)
        return x + out, latent
    out, (k, v) = attn_mod.attention_prefill(pl_attn, h, cfg, positions)
    return x + out, (k, v)


def _attn_block_decode(pl_attn, x, cfg, cache_k, cache_v, cache_len, ring):
    h = rms_norm(x, pl_attn["ln"], cfg.norm_eps)
    if cfg.attention_kind == "mla":
        out, new_cache = mla_mod.mla_decode(pl_attn, h, cache_k, cache_len, cfg)
        return x + out, new_cache, None
    out, ck, cv = attn_mod.attention_decode(pl_attn, h, cache_k, cache_v,
                                            cache_len, cfg, ring)
    return x + out, ck, cv


def _mlp_block(pl_mlp, x, cfg):
    h = rms_norm(x, pl_mlp["ln"], cfg.norm_eps)
    return x + swiglu(h, pl_mlp["w_gate"], pl_mlp["w_up"], pl_mlp["w_down"])


def _moe_block(pl_moe, x, cfg, moe_fn: MoeFn):
    b, s, d = x.shape
    h = rms_norm(x, pl_moe["ln"], cfg.norm_eps)
    out, aux = moe_fn(pl_moe, h.reshape(b * s, d), cfg)
    return x + out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Full-sequence execution (training / prefill)
# ---------------------------------------------------------------------------


def _seg_full(seg: Segment, seg_params: dict, shared_attn, x, cfg: ModelConfig,
              moe_fn: MoeFn, positions, want_cache: bool):
    """Run a segment over the full sequence via lax.scan over layers."""
    aux0 = jnp.zeros((), jnp.float32)

    if seg.kind in ("dense", "moe"):
        def body(carry, pl):
            h, aux = carry
            h, cache = _attn_block_prefill(pl["attn"], h, cfg, positions)
            if seg.kind == "moe":
                h, a = _moe_block(pl["moe"], h, cfg, moe_fn)
                aux = aux + a["aux_loss"]
            else:
                h = _mlp_block(pl["mlp"], h, cfg)
            ys = cache if want_cache else None
            return (h, aux), ys

        (x, aux), caches = _scan(body, (x, aux0), seg_params)
        return x, aux, caches

    if seg.kind == "mamba_tail":
        def body(carry, pl):
            h, aux = carry
            hin = rms_norm(h, pl["mamba"]["ln"], cfg.norm_eps)
            out, hstate, conv = mamba_mod.mamba_prefill(pl["mamba"], hin, cfg)
            ys = (hstate, conv) if want_cache else None
            return (h + out, aux), ys

        (x, aux), caches = _scan(body, (x, aux0), seg_params)
        return x, aux, caches

    # mamba_groups: scan over groups; each group = per_group mamba layers
    # (inner scan) followed by the *shared* attention block (closure params).
    g = seg.n_layers // seg.per_group
    grouped = jax.tree.map(
        lambda a: a.reshape((g, seg.per_group) + a.shape[1:]), seg_params)

    def group_body(carry, pl_group):
        h, aux = carry

        def inner(hc, pl):
            hin = rms_norm(hc, pl["mamba"]["ln"], cfg.norm_eps)
            out, hstate, conv = mamba_mod.mamba_prefill(pl["mamba"], hin, cfg)
            return hc + out, (hstate, conv) if want_cache else None

        h, mcaches = _scan(inner, h, pl_group)
        pl_sa = jax.tree.map(lambda a: a[0], shared_attn)
        h, kv = _attn_block_prefill(pl_sa["attn"], h, cfg, positions)
        h = _mlp_block(pl_sa["mlp"], h, cfg)
        ys = (mcaches, kv) if want_cache else None
        return (h, aux), ys

    (x, aux), caches = _scan(group_body, (x, aux0), grouped)
    return x, aux, caches


def forward(params: dict, cfg: ModelConfig, batch: Dict[str, jax.Array],
            moe_fn: Optional[MoeFn] = None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence forward (no cache). Returns (logits, aux)."""
    moe_fn = moe_fn or moe_mod.moe_capacity
    x = embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    aux_total = jnp.zeros((), jnp.float32)
    for seg in build_plan(cfg):
        x, aux, _ = _seg_full(seg, params["segments"][seg.name],
                              params.get("shared_attn"), x, cfg, moe_fn,
                              positions, want_cache=False)
        aux_total = aux_total + aux
    logits = unembed(params, cfg, x)
    return logits, {"aux_loss": aux_total}


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def make_caches(cfg: ModelConfig, batch: int, capacity: int,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    caches: Dict[str, Any] = {}
    for seg in build_plan(cfg):
        if seg.kind in ("dense", "moe"):
            if cfg.attention_kind == "mla":
                caches[seg.name] = {
                    "mla": mla_mod.make_mla_cache(cfg, seg.n_layers, batch, capacity, dtype),
                    "length": jnp.zeros((), jnp.int32),
                }
            else:
                cap = cfg.sliding_window if attn_mod.is_ring(cfg, capacity) else capacity
                kvshape = (seg.n_layers, batch, cap, cfg.num_kv_heads, cfg.head_dim)
                caches[seg.name] = KVCache(jnp.zeros(kvshape, dtype),
                                           jnp.zeros(kvshape, dtype),
                                           jnp.zeros((), jnp.int32))
        elif seg.kind == "mamba_tail":
            caches[seg.name] = mamba_mod.make_ssm_state(cfg, seg.n_layers, batch)
        else:  # mamba_groups
            g = seg.n_layers // seg.per_group
            din = cfg.d_model * cfg.ssm_expand
            conv_ch = din + 2 * cfg.ssm_state
            caches[seg.name] = {
                "ssm": {
                    "h": jnp.zeros((g, seg.per_group, batch, cfg.ssm_heads,
                                    cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
                    "conv": jnp.zeros((g, seg.per_group, batch,
                                       cfg.ssm_conv - 1, conv_ch), jnp.bfloat16),
                    "length": jnp.zeros((), jnp.int32),
                },
                "length": jnp.zeros((), jnp.int32),
            }
            cap = cfg.sliding_window if attn_mod.is_ring(cfg, capacity) else capacity
            kvshape = (g, batch, cap, cfg.num_kv_heads, cfg.head_dim)
            caches[seg.name]["shared_kv"] = KVCache(
                jnp.zeros(kvshape, dtype), jnp.zeros(kvshape, dtype),
                jnp.zeros((), jnp.int32))
    return caches


# ---------------------------------------------------------------------------
# Decode step (one new token per request)
# ---------------------------------------------------------------------------


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                caches: Dict[str, Any], cache_len: jax.Array,
                moe_fn: Optional[MoeFn] = None
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """tokens: (B, 1) int32. Returns (logits (B, V), updated caches)."""
    moe_fn = moe_fn or moe_mod.moe_capacity
    x = params["embed"][tokens].astype(_dtype(cfg))           # (B,1,D)
    new_caches: Dict[str, Any] = {}
    for seg in build_plan(cfg):
        seg_params = params["segments"][seg.name]
        cache = caches[seg.name]
        if seg.kind in ("dense", "moe"):
            if cfg.attention_kind == "mla":
                def body(h, xs):
                    pl, c = xs
                    hin = rms_norm(h, pl["attn"]["ln"], cfg.norm_eps)
                    out, nc = mla_mod.mla_decode(pl["attn"], hin, c, cache_len, cfg)
                    h2 = h + out
                    if seg.kind == "moe":
                        h2, _ = _moe_block(pl["moe"], h2, cfg, moe_fn)
                    else:
                        h2 = _mlp_block(pl["mlp"], h2, cfg)
                    return h2, nc

                x, new_mla = _scan(body, x, (seg_params, cache["mla"]))
                new_caches[seg.name] = {"mla": new_mla, "length": cache_len + 1}
            else:
                ring = (cfg.sliding_window is not None
                        and cache.k.shape[2] == cfg.sliding_window)

                def body(h, xs):
                    pl, ck, cv = xs
                    h2, nk, nv = _attn_block_decode(pl["attn"], h, cfg, ck, cv,
                                                    cache_len, ring)
                    if seg.kind == "moe":
                        h2, _ = _moe_block(pl["moe"], h2, cfg, moe_fn)
                    else:
                        h2 = _mlp_block(pl["mlp"], h2, cfg)
                    return h2, (nk, nv)

                x, (nk, nv) = _scan(body, x, (seg_params, cache.k, cache.v))
                new_caches[seg.name] = KVCache(nk, nv, cache_len + 1)
        elif seg.kind == "mamba_tail":
            def body(h, xs):
                pl, hs, cs = xs
                hin = rms_norm(h, pl["mamba"]["ln"], cfg.norm_eps)
                out, nhs, ncs = mamba_mod.mamba_decode(pl["mamba"], hin, hs, cs, cfg)
                return h + out, (nhs, ncs)

            x, (nh, nc) = _scan(body, x, (seg_params, cache.h, cache.conv))
            new_caches[seg.name] = SSMState(nh, nc, cache_len + 1)
        else:  # mamba_groups
            g = seg.n_layers // seg.per_group
            grouped = jax.tree.map(
                lambda a: a.reshape((g, seg.per_group) + a.shape[1:]), seg_params)
            ring = bool(cfg.sliding_window) and \
                cache["shared_kv"].k.shape[2] == cfg.sliding_window

            def group_body(h, xs):
                pl_group, hs, cs, ck, cv = xs

                def inner(hc, ys):
                    pl, hs1, cs1 = ys
                    hin = rms_norm(hc, pl["mamba"]["ln"], cfg.norm_eps)
                    out, nhs, ncs = mamba_mod.mamba_decode(pl["mamba"], hin, hs1, cs1, cfg)
                    return hc + out, (nhs, ncs)

                h, (nhs, ncs) = _scan(inner, h, (pl_group, hs, cs))
                pl_sa = jax.tree.map(lambda a: a[0], params["shared_attn"])
                h, nk, nv = _attn_block_decode(pl_sa["attn"], h, cfg, ck, cv,
                                               cache_len, ring)
                h = _mlp_block(pl_sa["mlp"], h, cfg)
                return h, (nhs, ncs, nk, nv)

            ssm = cache["ssm"]
            x, (nhs, ncs, nk, nv) = _scan(
                group_body, x,
                (grouped, ssm["h"], ssm["conv"],
                 cache["shared_kv"].k, cache["shared_kv"].v))
            new_caches[seg.name] = {
                "ssm": {"h": nhs, "conv": ncs, "length": ssm["length"] + 1},
                "length": cache_len + 1,
                "shared_kv": KVCache(nk, nv, cache_len + 1),
            }
    logits = unembed(params, cfg, x[:, 0:1, :])[:, 0, :]
    return logits, new_caches


# ---------------------------------------------------------------------------
# Cache pytree structure helpers (shared with serving/cache_ops.py)
# ---------------------------------------------------------------------------


def cache_batch_axes(cfg: ModelConfig) -> Dict[str, Any]:
    """Pytree of batch-axis indices matching the make_caches structure
    (None = unbatched leaf, e.g. length scalars)."""
    axes: Dict[str, Any] = {}
    for seg in build_plan(cfg):
        if seg.kind in ("dense", "moe"):
            if cfg.attention_kind == "mla":
                axes[seg.name] = {"mla": 1, "length": None}
            else:
                axes[seg.name] = KVCache(1, 1, None)
        elif seg.kind == "mamba_tail":
            axes[seg.name] = SSMState(1, 1, None)
        else:
            axes[seg.name] = {
                "ssm": {"h": 2, "conv": 2, "length": None},
                "length": None,
                "shared_kv": KVCache(1, 1, None),
            }
    return axes


def _with_lengths(cfg: ModelConfig, caches: Dict[str, Any],
                  length: jax.Array) -> Dict[str, Any]:
    """Return caches with every bookkeeping ``length`` leaf set to ``length``
    (decode_loop carries per-slot lengths, so the leaves must keep a stable
    (B,) shape across scan iterations)."""
    out = dict(caches)
    for seg in build_plan(cfg):
        c = out[seg.name]
        if seg.kind in ("dense", "moe"):
            if cfg.attention_kind == "mla":
                out[seg.name] = {**c, "length": length}
            else:
                out[seg.name] = KVCache(c.k, c.v, length)
        elif seg.kind == "mamba_tail":
            out[seg.name] = SSMState(c.h, c.conv, length)
        else:
            out[seg.name] = {
                **c,
                "ssm": {**c["ssm"], "length": length},
                "length": length,
                "shared_kv": KVCache(c["shared_kv"].k, c["shared_kv"].v,
                                     length),
            }
    return out


def _cache_capacity(cfg: ModelConfig, caches: Dict[str, Any]) -> Optional[int]:
    """Static token capacity of the tightest non-ring sequence buffer
    (None when nothing bounds decode length, e.g. pure-SSM or all-ring)."""
    caps = []
    for seg in build_plan(cfg):
        c = caches[seg.name]
        if seg.kind in ("dense", "moe"):
            if cfg.attention_kind == "mla":
                caps.append(c["mla"].shape[2])
            else:
                cap = c.k.shape[2]
                if not (cfg.sliding_window and cap == cfg.sliding_window):
                    caps.append(cap)
        elif seg.kind == "mamba_groups":
            cap = c["shared_kv"].k.shape[2]
            if not (cfg.sliding_window and cap == cfg.sliding_window):
                caps.append(cap)
    return min(caps) if caps else None


def decode_ready_caches(params: dict, cfg: ModelConfig,
                        caches: Dict[str, Any], cache_len: jax.Array,
                        moe_fn: Optional[MoeFn] = None,
                        step_fn: Optional[Callable] = None) -> Dict[str, Any]:
    """Normalize a fresh cache pytree to decode's shape/dtype fixed point:
    per-slot ``length`` leaves and post-step state dtypes (e.g. the hybrid
    conv window, bf16 after prefill -> f32 after one step; the upcast is
    exact). Keeps ``lax.scan`` carries stable and lets donated cache
    buffers alias input->output from the very first jitted step."""
    b = cache_len.shape[0]
    if step_fn is None:
        def step_fn(t, c, l):
            return decode_step(params, cfg, t, c, l, moe_fn)
    caches = _with_lengths(cfg, caches, cache_len)
    tok = jnp.zeros((b, 1), jnp.int32)
    for _ in range(2):
        try:
            out = jax.eval_shape(step_fn, tok, caches, cache_len)[1]
        except Exception:       # exotic step_fn: skip dtype stabilization
            break
        if all(c.dtype == o.dtype for c, o in
               zip(jax.tree.leaves(caches), jax.tree.leaves(out))):
            break
        caches = jax.tree.map(
            lambda c, o: c if c.dtype == o.dtype else c.astype(o.dtype),
            caches, out)
    return caches


# ---------------------------------------------------------------------------
# Scanned multi-step decode (device-resident fast path)
# ---------------------------------------------------------------------------


def _masked_select(mask: jax.Array, new: jax.Array, old: jax.Array,
                   ax, b: int) -> jax.Array:
    """Per-slot freeze: keep ``old`` where ``mask`` is False along the batch
    axis ``ax`` (None = unbatched bookkeeping leaf, always take ``new``)."""
    if ax is None:
        return new
    shape = [1] * new.ndim
    shape[ax] = b
    return jnp.where(mask.reshape(shape), new, old)


def decode_loop(params: dict, cfg: ModelConfig, tokens: jax.Array,
                caches: Dict[str, Any], cache_len: jax.Array, n_steps: int,
                *, steps_left: Optional[jax.Array] = None,
                moe_fn: Optional[MoeFn] = None,
                step_fn: Optional[Callable] = None
                ) -> Tuple[jax.Array, jax.Array, jax.Array,
                           Dict[str, Any], jax.Array]:
    """``n_steps`` greedy decode iterations in one ``lax.scan`` — N tokens
    per host sync instead of one.

    Sampling (argmax) happens on-device, and per-slot done/capacity masking
    keeps finished or capacity-full slots frozen: their token, cache content,
    and ``cache_len`` hold bit-exactly while live slots advance, so a chunked
    engine emits token-identical output to ``n_steps`` sequential
    :func:`decode_step` calls.

    tokens: (B,) int32 current token per slot; cache_len: (B,) int32 (scalars
    are broadcast). steps_left: (B,) int32 tokens each slot still wants
    (defaults to ``n_steps`` everywhere; may exceed ``n_steps`` — the
    continuous-batching engine jits this function at several scan widths
    and dispatches the widest pre-jitted width that fits
    ``min(steps_left)``, so a slot's remaining budget routinely spans
    multiple dispatches). ``step_fn`` overrides the inner
    ``(tokens (B,1), caches, cache_len) -> (logits, caches)`` step — the
    hook the microbatch interleaver wraps.

    Returns ``(emitted (B, n_steps), live (B, n_steps), tokens (B,), caches,
    cache_len)``; ``emitted[:, j]`` is meaningful only where ``live[:, j]``.
    Chunk-split invariance: because frozen slots hold bit-exactly and live
    slots see the identical per-step computation, any partition of N total
    iterations into scan dispatches emits identical tokens.
    """
    if tokens.ndim != 1:
        raise ValueError(f"decode_loop wants tokens of shape (B,), "
                         f"got {tokens.shape}")
    if n_steps < 1:
        raise ValueError(f"decode_loop needs n_steps >= 1, got {n_steps}")
    b = tokens.shape[0]
    cache_len = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    if steps_left is None:
        steps_left = jnp.full((b,), n_steps, jnp.int32)
    else:
        # A stale/negative budget must read as "done", not wrap around.
        steps_left = jnp.maximum(jnp.asarray(steps_left, jnp.int32), 0)
    if step_fn is None:
        mf = moe_fn

        def step_fn(t, c, l):  # noqa: E731 — default inner step
            return decode_step(params, cfg, t, c, l, mf)

    cap = _cache_capacity(cfg, caches)
    axes = cache_batch_axes(cfg)
    caches = decode_ready_caches(params, cfg, caches, cache_len,
                                 step_fn=step_fn)

    def _select(mask, new, old, ax):
        return _masked_select(mask, new, old, ax, b)

    def body(carry, _):
        tok, cl, left, cs = carry
        live = left > 0
        if cap is not None:
            live &= cl < cap
        logits, ncs = step_fn(tok[:, None], cs, cl)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tok = jnp.where(live, nxt, tok)
        cl = cl + live.astype(jnp.int32)
        left = left - live.astype(jnp.int32)
        ncs = jax.tree.map(
            lambda n, o, ax: _select(live, n, o, ax), ncs, cs, axes)
        ncs = _with_lengths(cfg, ncs, cl)
        return (tok, cl, left, ncs), (nxt, live)

    (tokens, cache_len, _, caches), (em, lv) = jax.lax.scan(
        body, (tokens, cache_len, steps_left, caches), None, length=n_steps)
    return em.T, lv.T, tokens, caches, cache_len


# ---------------------------------------------------------------------------
# Scanned MTP speculative decode (device-resident fast path, paper §4.2.4)
# ---------------------------------------------------------------------------


def decode_loop_mtp(params: dict, mtp: dict, cfg: ModelConfig,
                    tokens: jax.Array, drafts: jax.Array,
                    caches: Dict[str, Any], cache_len: jax.Array,
                    n_iters: int, *,
                    steps_left: Optional[jax.Array] = None,
                    key: Optional[jax.Array] = None,
                    greedy: bool = True, fused_verify: bool = False,
                    moe_fn: Optional[MoeFn] = None
                    ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                               jax.Array, Dict[str, Any], jax.Array]:
    """``n_iters`` MTP iterations in one ``lax.scan`` — up to ``2*n_iters``
    tokens per host sync with speculation, sampling, accept/reject, and
    cache bookkeeping all on-device (the §4.2.4 decode headline composed
    with the PR 2 chunked-decode fast path).

    Each iteration runs one :func:`repro.core.mtp.mtp_step`: base + draft
    verification forwards (or ONE fused two-token teacher-forced forward
    when ``fused_verify`` — see :func:`repro.core.mtp.can_fuse_verify`),
    in-graph sampling, per-slot accept/reject, and the next draft proposal.
    Accepted iterations advance ``cache_len`` by 2, rejected by 1 (the
    stale speculative KV slot is overwritten by the next live iteration's
    base write), so effective sequence lengths diverge within the batch.

    Per-slot masking composes with the chunked-decode rules: a slot is live
    while it still wants tokens (``steps_left > 0``) and both KV writes fit
    (``cache_len + 2 <= capacity``); frozen slots hold their token, draft,
    cache content, and ``cache_len`` bit-exactly.

    tokens/drafts: (B,) int32 — last committed token and its proposed
    successor (:func:`repro.core.mtp.propose_draft`). steps_left: (B,)
    tokens each slot still wants (defaults to ``2*n_iters``; may exceed
    what ``n_iters`` can drain — the continuous-batching engine dispatches
    several pre-jitted widths against the same remaining budgets, and
    greedy accept/reject is PRNG-independent so any width split commits
    identical tokens). Returns
    ``(emitted (B, n_iters, 2), accepted (B, n_iters), live (B, n_iters),
    tokens, drafts, caches, cache_len)``; row ``emitted[:, j]`` is
    meaningful only where ``live[:, j]``, and ``emitted[:, j, 1]`` only
    where additionally ``accepted[:, j]``.
    """
    from repro.core import mtp as mtp_mod  # deferred: core.mtp imports us

    if tokens.ndim != 1:
        raise ValueError(f"decode_loop_mtp wants tokens of shape (B,), "
                         f"got {tokens.shape}")
    if n_iters < 1:
        raise ValueError(f"decode_loop_mtp needs n_iters >= 1, got {n_iters}")
    b = tokens.shape[0]
    cache_len = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    if steps_left is None:
        steps_left = jnp.full((b,), 2 * n_iters, jnp.int32)
    else:
        steps_left = jnp.maximum(jnp.asarray(steps_left, jnp.int32), 0)
    if key is None:
        key = jax.random.PRNGKey(0)

    cap = _cache_capacity(cfg, caches)
    axes = cache_batch_axes(cfg)
    caches = decode_ready_caches(params, cfg, caches, cache_len, moe_fn)

    def body(carry, _):
        tok, drf, cl, left, k, cs = carry
        live = left > 0
        if cap is not None:
            live &= cl + 2 <= cap       # base + speculative writes must fit
        k, sub = jax.random.split(k)
        em, acc, x_next, d_next, ncs, new_len = mtp_mod.mtp_step(
            params, mtp, cfg, tok, drf, cs, cl, sub, moe_fn, greedy,
            fused_verify)
        acc &= live
        tok = jnp.where(live, x_next, tok)
        drf = jnp.where(live, d_next, drf)
        cl = jnp.where(live, new_len, cl)
        left = left - jnp.where(live, 1 + acc.astype(jnp.int32), 0)
        ncs = jax.tree.map(
            lambda n, o, ax: _masked_select(live, n, o, ax, b), ncs, cs, axes)
        ncs = _with_lengths(cfg, ncs, cl)
        return (tok, drf, cl, left, k, ncs), (em, acc, live)

    (tokens, drafts, cache_len, _, _, caches), (em, acc, lv) = jax.lax.scan(
        body, (tokens, drafts, cache_len, steps_left, key, caches), None,
        length=n_iters)
    return (jnp.moveaxis(em, 0, 1), acc.T, lv.T, tokens, drafts, caches,
            cache_len)


# ---------------------------------------------------------------------------
# Chunked suffix prefill (teacher-forced continuation, EMS-reuse fast path)
# ---------------------------------------------------------------------------


def supports_prefill_continue(cfg: ModelConfig, capacity: int) -> bool:
    """Static eligibility for :func:`prefill_continue` (and everything
    built on it: chunked suffix/fresh prefill, the MTP fused verification):
    a token-addressable, non-ring cache."""
    return (cfg.attention_kind in ("causal", "mla")
            and not cfg.is_ssm and not cfg.is_hybrid
            and not attn_mod.is_ring(cfg, capacity))


def prefill_continue(params: dict, cfg: ModelConfig, tokens: jax.Array,
                     caches: Dict[str, Any], offset: jax.Array,
                     moe_fn: Optional[MoeFn] = None
                     ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Teacher-forced continuation: run ``tokens`` (B, S) at positions
    ``offset .. offset+S-1`` against caches whose first ``offset`` positions
    are valid — the whole suffix in ONE call instead of S ``decode_step``
    round-trips. Also serves as the long-prompt chunk step (advance
    ``offset`` between calls; with ``offset=0`` on a fresh cache this IS a
    bounded-shape prefill chunk) and, with a per-request ``offset`` (B,),
    as the MTP fused base+draft verification forward (divergent in-batch
    lengths). Returns (logits (B, S, V), new caches).

    Attention/MLA archs only: SSM state is not token-addressable. Callers
    must not pass *wrapped* ring caches (serving gates this path on
    ``attention.is_ring(cfg, capacity)`` — a ring buffer's wraparound write
    pattern is indistinguishable from a plain cache by shape alone, and a
    plain cache whose capacity merely equals ``sliding_window`` is fine)."""
    moe_fn = moe_fn or moe_mod.moe_capacity
    if cfg.is_ssm or cfg.is_hybrid or cfg.attention_kind not in ("causal",
                                                                 "mla"):
        raise NotImplementedError(
            "prefill_continue requires a causal-attention or MLA arch")
    x = params["embed"][tokens].astype(_dtype(cfg))
    b, s, _ = x.shape
    offset = jnp.asarray(offset, jnp.int32)
    new_caches: Dict[str, Any] = {}
    for seg in build_plan(cfg):
        seg_params = params["segments"][seg.name]
        cache = caches[seg.name]
        if cfg.attention_kind == "mla":
            def body(h, xs, seg=seg):
                pl, c = xs
                hin = rms_norm(h, pl["attn"]["ln"], cfg.norm_eps)
                out, nc = mla_mod.mla_extend(pl["attn"], hin, c, offset, cfg)
                h = h + out
                if seg.kind == "moe":
                    h, _ = _moe_block(pl["moe"], h, cfg, moe_fn)
                else:
                    h = _mlp_block(pl["mlp"], h, cfg)
                return h, nc

            x, new_mla = _scan(body, x, (seg_params, cache["mla"]))
            new_caches[seg.name] = {"mla": new_mla, "length": offset + s}
        else:
            def body(h, xs, seg=seg):
                pl, ck, cv = xs
                hin = rms_norm(h, pl["attn"]["ln"], cfg.norm_eps)
                out, nk, nv = attn_mod.attention_extend(pl["attn"], hin, ck,
                                                        cv, offset, cfg)
                h = h + out
                if seg.kind == "moe":
                    h, _ = _moe_block(pl["moe"], h, cfg, moe_fn)
                else:
                    h = _mlp_block(pl["mlp"], h, cfg)
                return h, (nk, nv)

            x, (nk, nv) = _scan(body, x, (seg_params, cache.k, cache.v))
            new_caches[seg.name] = KVCache(nk, nv, offset + s)
    logits = unembed(params, cfg, x)
    return logits, new_caches


# ---------------------------------------------------------------------------
# Prefill (full sequence + cache materialization)
# ---------------------------------------------------------------------------


def _write_kv(tmpl: jax.Array, k: jax.Array, s: int, cache_dtype) -> jax.Array:
    """Write freshly-computed K or V (L,B,S,KV,hd) into a capacity buffer.

    Ring buffers (sliding-window serving at long context) place token p at
    slot p % cap, matching attention_decode's write pattern.
    """
    cap = tmpl.shape[2]
    if s <= cap:
        return jax.lax.dynamic_update_slice_in_dim(
            tmpl, k.astype(cache_dtype), 0, axis=2)
    last = k[:, :, -cap:].astype(cache_dtype)
    return jnp.roll(last, shift=s % cap, axis=2)


def prefill(params: dict, cfg: ModelConfig, batch: Dict[str, jax.Array],
            capacity: int, moe_fn: Optional[MoeFn] = None,
            cache_dtype=jnp.bfloat16) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run the prompt, return (logits (B,S,V), caches padded to capacity)."""
    moe_fn = moe_fn or moe_mod.moe_capacity
    x = embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    caches = make_caches(cfg, b, capacity, cache_dtype)
    new_caches: Dict[str, Any] = {}
    for seg in build_plan(cfg):
        x, _aux, segc = _seg_full(seg, params["segments"][seg.name],
                                  params.get("shared_attn"), x, cfg, moe_fn,
                                  positions, want_cache=True)
        tmpl = caches[seg.name]
        if seg.kind in ("dense", "moe"):
            if cfg.attention_kind == "mla":
                buf = jax.lax.dynamic_update_slice_in_dim(
                    tmpl["mla"], segc.astype(cache_dtype), 0, axis=2)
                new_caches[seg.name] = {"mla": buf,
                                        "length": jnp.int32(s)}
            else:
                k, v = segc
                new_caches[seg.name] = KVCache(
                    _write_kv(tmpl.k, k, s, cache_dtype),
                    _write_kv(tmpl.v, v, s, cache_dtype), jnp.int32(s))
        elif seg.kind == "mamba_tail":
            hstate, conv = segc
            new_caches[seg.name] = SSMState(hstate, conv.astype(tmpl.conv.dtype),
                                            jnp.int32(s))
        else:
            (mh, mconv), (k, v) = segc
            nk = _write_kv(tmpl["shared_kv"].k, k, s, cache_dtype)
            nv = _write_kv(tmpl["shared_kv"].v, v, s, cache_dtype)
            new_caches[seg.name] = {
                "ssm": {"h": mh, "conv": mconv.astype(jnp.bfloat16),
                        "length": jnp.int32(s)},
                "length": jnp.int32(s),
                "shared_kv": KVCache(nk, nv, jnp.int32(s)),
            }
    logits = unembed(params, cfg, x)
    return logits, new_caches


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(params: dict, cfg: ModelConfig, batch: Dict[str, jax.Array],
            moe_fn: Optional[MoeFn] = None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(params, cfg, batch, moe_fn)
    labels = batch["labels"]
    if cfg.frontend == "vision_patches" and "prefix_emb" in batch:
        logits = logits[:, batch["prefix_emb"].shape[1]:, :]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(lse - gold)
    loss = nll + cfg.router_aux_loss_coef * aux["aux_loss"]
    return loss, {"nll": nll, **aux}
