"""Multi-head Latent Attention (DeepSeek-style), per paper §4.2.2 / §4.3.1.

Two execution forms, equivalence-tested against each other:

* ``mla_prefill`` — the *unabsorbed* form the paper uses for prefill (§4.3.1):
  latents are expanded to full per-head K/V and the layer behaves as standard
  MHA ("without certain weight matrix absorption to enhance raw computational
  efficiency"). Chunked over queries like models/attention.py.
* ``mla_decode`` — the *absorbed* form for decode: queries are pulled into
  latent space through W_UK so attention runs directly against the compressed
  (kv_lora_rank + rope) cache — the 93.3% KV-cache reduction the paper cites.
  The Pallas kernel in kernels/mla_attention implements this inner loop.

The latent KV cache is (B, S, kv_lora_rank + qk_rope_head_dim); under pjit it
is sequence-sharded over the ``model`` axis (our TPU analogue of the paper's
UB-pooled DP320 cache — see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import NEG_INF, _pick_chunk
from repro.models.layers import apply_rope, dense_init, rms_norm


def init_mla_params(key, cfg: ModelConfig, n_layers: int, dtype) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "ln": jnp.ones((n_layers, d), dtype),
        "wq_a": dense_init(ks[0], (n_layers, d, qr), dtype),
        "q_ln": jnp.ones((n_layers, qr), dtype),
        "wq_b": dense_init(ks[1], (n_layers, qr, h * (nope + rope)), dtype),
        "wkv_a": dense_init(ks[2], (n_layers, d, kvr + rope), dtype),
        "kv_ln": jnp.ones((n_layers, kvr), dtype),
        "wk_b": dense_init(ks[3], (n_layers, kvr, h * nope), dtype),
        "wv_b": dense_init(ks[4], (n_layers, kvr, h * vd), dtype),
        "wo": dense_init(ks[5], (n_layers, h * vd, d), dtype),
    }


def _mla_qkv_latent(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    """Shared 'MLAProlog': projections + norms + RoPE (paper fuses these)."""
    b, s, _ = x.shape
    h = cfg.num_heads
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    q = rms_norm(q, p["q_ln"], cfg.norm_eps)
    q = jnp.einsum("bsr,re->bse", q, p["wq_b"]).reshape(b, s, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv, k_rope = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    c_kv = rms_norm(c_kv, p["kv_ln"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def _mla_flash_causal(q_nope, q_rope, k_nope, k_rope, vfull, scale: float,
                      chunk: int) -> jax.Array:
    """Block-skipped causal MLA attention (flash kv-block loop; the query
    chunk visits only kv blocks ≤ its own). Returns (B,S,H,vd) f32."""
    b, s, h, nope = q_nope.shape
    vd = vfull.shape[-1]
    nc = s // chunk
    knf = k_nope.astype(jnp.float32)
    krf = k_rope.astype(jnp.float32)
    vf = vfull.astype(jnp.float32)

    def one_chunk(ci):
        qn = jax.lax.dynamic_slice_in_dim(q_nope, ci * chunk, chunk, 1
                                          ).astype(jnp.float32)
        qr = jax.lax.dynamic_slice_in_dim(q_rope, ci * chunk, chunk, 1
                                          ).astype(jnp.float32)
        q_pos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)

        def kv_block(j, carry):
            m, l, acc = carry
            knb = jax.lax.dynamic_slice_in_dim(knf, j * chunk, chunk, 1)
            krb = jax.lax.dynamic_slice_in_dim(krf, j * chunk, chunk, 1)
            vb = jax.lax.dynamic_slice_in_dim(vf, j * chunk, chunk, 1)
            scores = (jnp.einsum("bshe,bthe->bhst", qn, knb)
                      + jnp.einsum("bshe,bte->bhst", qr, krb)) * scale
            kv_pos = j * chunk + jnp.arange(chunk, dtype=jnp.int32)
            mask = kv_pos[None, :] <= q_pos[:, None]
            scores = jnp.where(mask[None, None], scores, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(scores, -1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            pr = jnp.exp(scores - m_new)
            l_new = l * alpha + jnp.sum(pr, -1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bhst,bthe->bhse", pr, vb)
            return m_new, l_new, acc_new

        m0 = jnp.full((b, h, chunk, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, h, chunk, vd), jnp.float32)
        m, l, acc = jax.lax.fori_loop(0, ci + 1, kv_block, (m0, l0, a0))
        out = acc / jnp.maximum(l, 1e-30)                    # (b,h,chunk,vd)
        return jnp.moveaxis(out, 1, 2)                       # (b,chunk,h,vd)

    from repro.models.scan_util import chunk_map
    if nc == 1:
        return one_chunk(jnp.int32(0))
    outs = chunk_map(one_chunk, nc)
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, vd)


def mla_prefill(p: dict, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array | None = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Unabsorbed MHA-form prefill. Returns (out, latent_cache (B,S,kvr+rope))."""
    from repro.models.attention import block_skip_enabled

    b, s, _ = x.shape
    h = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q_nope, q_rope, c_kv, k_rope = _mla_qkv_latent(p, x, cfg, positions)

    k_nope = jnp.einsum("bsr,re->bse", c_kv, p["wk_b"]).reshape(b, s, h, nope)
    vfull = jnp.einsum("bsr,re->bse", c_kv, p["wv_b"]).reshape(b, s, h, vd)
    scale = 1.0 / ((nope + rope) ** 0.5)

    chunk = _pick_chunk(s)
    n_chunks = s // chunk

    if block_skip_enabled():
        out = _mla_flash_causal(q_nope, q_rope, k_nope, k_rope, vfull,
                                scale, chunk)
        out = out.reshape(b, s, h * vd).astype(x.dtype)
        out = jnp.einsum("bse,ed->bsd", out, p["wo"])
        latent = jnp.concatenate([c_kv, k_rope], axis=-1)
        return out, latent

    kv_pos = jnp.arange(s, dtype=jnp.int32)

    def one_chunk(ci):
        q_pos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        qn = jax.lax.dynamic_slice_in_dim(q_nope, ci * chunk, chunk, axis=1)
        qr = jax.lax.dynamic_slice_in_dim(q_rope, ci * chunk, chunk, axis=1)
        scores = (
            jnp.einsum("bshe,bthe->bhst", qn.astype(jnp.float32), k_nope.astype(jnp.float32))
            + jnp.einsum("bshe,bte->bhst", qr.astype(jnp.float32), k_rope.astype(jnp.float32))
        ) * scale
        mask = kv_pos[None, :] <= q_pos[:, None]
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhst,bthe->bshe", probs, vfull.astype(jnp.float32))

    if n_chunks == 1:
        out = one_chunk(jnp.int32(0))
    else:
        from repro.models.scan_util import chunk_map
        outs = chunk_map(one_chunk, n_chunks)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, vd)
    out = out.reshape(b, s, h * vd).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"])
    latent = jnp.concatenate([c_kv, k_rope], axis=-1)
    return out, latent


def mla_decode(p: dict, x: jax.Array, cache: jax.Array, cache_len: jax.Array,
               cfg: ModelConfig, use_kernel: bool = False
               ) -> Tuple[jax.Array, jax.Array]:
    """Absorbed decode step.

    x: (B, 1, D); cache: (B, S, kvr+rope). Returns (out (B,1,D), new cache).
    """
    from repro.models.attention import _positions_of, decode_valid_mask, update_cache

    b = x.shape[0]
    h = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    cap = cache.shape[1]
    positions = _positions_of(cache_len, b)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv_latent(p, x, cfg, positions)

    new_entry = jnp.concatenate([c_kv, k_rope], axis=-1)        # (B,1,kvr+rope)
    cache = update_cache(cache, new_entry, cache_len)

    # Absorb W_UK into the query: q_lat (B,1,H,kvr)
    wk = p["wk_b"].reshape(kvr, h, nope)
    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope.astype(jnp.float32),
                       wk.astype(jnp.float32))
    scale = 1.0 / ((nope + rope) ** 0.5)
    vmask = decode_valid_mask(cache_len, cap, ring=False)        # (B|1,1,S)

    if use_kernel and cache_len.ndim == 0:
        from repro.kernels.mla_attention.ops import mla_decode_attention
        valid = jnp.arange(cap, dtype=jnp.int32) <= cache_len
        o_lat = mla_decode_attention(
            q_lat[:, 0], q_rope[:, 0], cache.astype(jnp.float32), valid, scale, kvr)
        o_lat = o_lat[:, None]
    else:
        ck = cache[..., :kvr].astype(jnp.float32)                # (B,S,kvr)
        kr = cache[..., kvr:].astype(jnp.float32)                # (B,S,rope)
        scores = (
            jnp.einsum("bshr,btr->bhst", q_lat, ck)
            + jnp.einsum("bshe,bte->bhst", q_rope.astype(jnp.float32), kr)
        ) * scale
        scores = jnp.where(vmask[:, :, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", probs, ck)          # (B,1,H,kvr)

    wv = p["wv_b"].reshape(kvr, h, vd)
    out = jnp.einsum("bshr,rhe->bshe", o_lat, wv.astype(jnp.float32))
    out = out.reshape(b, 1, h * vd).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"])
    return out, cache


def mla_extend(p: dict, x: jax.Array, cache: jax.Array, offset: jax.Array,
               cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Absorbed-form teacher-forced continuation — the S-token
    generalization of :func:`mla_decode` used by chunked suffix prefill.

    x: (B, S, D) at positions ``offset .. offset+S-1``; cache:
    (B, cap, kvr+rope) with the first ``offset`` rows valid. ``offset`` may
    be per-request (B,) — divergent in-batch lengths for the MTP fused
    verification forward. Returns (out (B,S,D), new cache)."""
    b, s, _ = x.shape
    h = cfg.num_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    cap = cache.shape[1]
    offset = jnp.asarray(offset, jnp.int32)
    if offset.ndim == 0:
        q_pos = offset + jnp.arange(s, dtype=jnp.int32)      # (S,)
        positions = jnp.broadcast_to(q_pos[None], (b, s))
    else:
        positions = offset[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv_latent(p, x, cfg, positions)

    new_entry = jnp.concatenate([c_kv, k_rope], axis=-1)     # (B,S,kvr+rope)
    if offset.ndim == 0:
        cache = jax.lax.dynamic_update_slice_in_dim(
            cache, new_entry.astype(cache.dtype), offset, axis=1)
    else:
        rows = jnp.arange(b, dtype=jnp.int32)[:, None]
        # Out-of-bounds scatter rows are dropped (masked callers rely on it).
        cache = cache.at[rows, positions].set(new_entry.astype(cache.dtype))

    wk = p["wk_b"].reshape(kvr, h, nope)
    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope.astype(jnp.float32),
                       wk.astype(jnp.float32))
    scale = 1.0 / ((nope + rope) ** 0.5)
    ck = cache[..., :kvr].astype(jnp.float32)                # (B,cap,kvr)
    kr = cache[..., kvr:].astype(jnp.float32)                # (B,cap,rope)
    scores = (
        jnp.einsum("bshr,btr->bhst", q_lat, ck)
        + jnp.einsum("bshe,bte->bhst", q_rope.astype(jnp.float32), kr)
    ) * scale
    kv_idx = jnp.arange(cap, dtype=jnp.int32)
    mask = kv_idx[None, None, :] <= positions[:, :, None]    # (B, S, cap)
    scores = jnp.where(mask[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bshr", probs, ck)          # (B,S,H,kvr)
    wv = p["wv_b"].reshape(kvr, h, vd)
    out = jnp.einsum("bshr,rhe->bshe", o_lat, wv.astype(jnp.float32))
    out = out.reshape(b, s, h * vd).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"])
    return out, cache


def make_mla_cache(cfg: ModelConfig, n_layers: int, batch: int, seq_len: int,
                   dtype=jnp.bfloat16) -> jax.Array:
    width = cfg.kv_lora_rank + cfg.qk_rope_head_dim
    return jnp.zeros((n_layers, batch, seq_len, width), dtype)
