"""Scan/map helpers shared by the model stack.

REPRO_SCAN_UNROLL=full (set by the dry-run) unrolls layer scans and chunk
maps so XLA cost_analysis attributes FLOPs to every iteration; the default
(1) keeps rolled loops for fast compiles everywhere else."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp


def scan_unroll():
    v = os.environ.get("REPRO_SCAN_UNROLL", "1")
    return True if v == "full" else int(v)


def chunk_map(fn, n_chunks: int):
    """Map fn over chunk indices 0..n-1, stacking results on axis 0.

    Always rolled: unrolling 64 attention chunks × 61 layers makes XLA CPU
    compiles intractable. The dry-run instead adds an analytic correction
    for the (1 - 1/n_chunks) of attention FLOPs the rolled loop hides from
    cost_analysis (launch/dryrun.py _chunk_flops_correction)."""
    return jax.lax.map(fn, jnp.arange(n_chunks, dtype=jnp.int32))
