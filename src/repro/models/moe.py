"""MoE layer: top-k router, shared experts, and two reference executions.

* ``moe_reference`` — dense all-experts compute (exact, O(T·E) FLOPs); the
  oracle for everything else.
* ``moe_capacity`` — static capacity-bounded gather→expert→scatter, the
  single-device semantics of the paper's FusedDispatch/FusedCombine static
  pre-allocated buffers (paper Eq. 1–2). ``core/lep.py`` wraps this with
  shard_map + all_to_all (+ early INT8 quantization) for large-scale EP.

Router follows DeepSeek/OLMoE practice: softmax → top-k → renormalize, with a
Switch-style load-balance auxiliary loss (the serving-side analogue of the
paper's EPLB is in core/lep.py via redundant expert replicas).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, swiglu


def init_moe_params(key, cfg: ModelConfig, n_layers: int, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 7)
    p = {
        "ln": jnp.ones((n_layers, d), dtype),
        "router": dense_init(ks[0], (n_layers, d, e), jnp.float32),
        "w_gate": dense_init(ks[1], (n_layers, e, d, f), dtype),
        "w_up": dense_init(ks[2], (n_layers, e, d, f), dtype),
        "w_down": dense_init(ks[3], (n_layers, e, f, d), dtype),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        p["shared_gate"] = dense_init(ks[4], (n_layers, d, fs), dtype)
        p["shared_up"] = dense_init(ks[5], (n_layers, d, fs), dtype)
        p["shared_down"] = dense_init(ks[6], (n_layers, fs, d), dtype)
    return p


def route(router_w: jax.Array, x: jax.Array, cfg: ModelConfig
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (T, D) -> (top-k ids (T,K), renormalized probs (T,K), aux loss)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    e = cfg.num_experts
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, e, dtype=jnp.float32), axis=1), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * mean_p)
    return top_i, top_p, aux


def _shared_out(p: dict, x: jax.Array) -> jax.Array:
    if "shared_gate" not in p:
        return jnp.zeros_like(x)
    return swiglu(x, p["shared_gate"], p["shared_up"], p["shared_down"])


def moe_reference(p: dict, x: jax.Array, cfg: ModelConfig
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Dense all-experts oracle. x: (T, D)."""
    top_i, top_p, aux = route(p["router"], x, cfg)
    g = jnp.einsum("td,edf->tef", x, p["w_gate"])
    u = jnp.einsum("td,edf->tef", x, p["w_up"])
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, p["w_down"])
    w = jnp.sum(
        jax.nn.one_hot(top_i, cfg.num_experts, dtype=jnp.float32)
        * top_p[..., None], axis=1)                                # (T, E)
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), w).astype(x.dtype)
    return out + _shared_out(p, x), {"aux_loss": aux}


def capacity_for(cfg: ModelConfig, n_tokens: int, ep_degree: int = 1) -> int:
    """Static buffer depth per expert — the paper's max_tokens (Eq. 2)."""
    per = n_tokens * cfg.num_experts_per_tok / max(cfg.num_experts, 1)
    cap = int(per * cfg.capacity_factor) + 1
    return max(8, ((cap + 7) // 8) * 8)  # 8-aligned for TPU sublanes


def dispatch_indices(top_i: jax.Array, num_experts: int, capacity: int):
    """Compute scatter locations for capacity-bounded dispatch.

    top_i: (T, K). Returns (expert_slot (T,K), valid (T,K)) where expert_slot
    is the position within the expert's capacity buffer.
    """
    t, k = top_i.shape
    flat_e = top_i.reshape(-1)                                     # (T*K,)
    # Stable ordering: tokens keep arrival order within an expert, matching
    # the paper's deterministic pre-allocated buffer offsets.
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)  # (TK, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1                      # running count
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    valid = slot < capacity
    return slot.reshape(t, k), valid.reshape(t, k)


def moe_capacity(p: dict, x: jax.Array, cfg: ModelConfig,
                 capacity: int | None = None
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Capacity-bounded gather→expert→scatter (single-device FusedDispatch)."""
    t, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = capacity or capacity_for(cfg, t)
    top_i, top_p, aux = route(p["router"], x, cfg)
    slot, valid = dispatch_indices(top_i, e, cap)

    # Scatter tokens into the (E, C, D) buffer ("FusedDispatch").
    buf = jnp.zeros((e, cap, d), x.dtype)
    flat_e, flat_s = top_i.reshape(-1), slot.reshape(-1)
    flat_v = valid.reshape(-1)
    tok_ids = jnp.repeat(jnp.arange(t), k)
    safe_s = jnp.where(flat_v, flat_s, cap - 1)  # clamp; invalid contributions zeroed
    contrib = jnp.where(flat_v[:, None], x[tok_ids], 0)
    buf = buf.at[flat_e, safe_s].add(contrib)

    # Expert FFN over the static buffer.
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"])

    # Gather back + weighted combine ("FusedCombine").
    gathered = y[flat_e, safe_s]                                  # (T*K, D)
    gathered = jnp.where(flat_v[:, None], gathered, 0)
    weighted = gathered.astype(jnp.float32) * top_p.reshape(-1)[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[tok_ids].add(weighted).astype(x.dtype)

    dropped = jnp.sum(~flat_v)
    return out + _shared_out(p, x), {"aux_loss": aux, "dropped": dropped}
