"""Public jit'd wrapper for fused dispatch quantization."""
from __future__ import annotations

import functools

import jax

from repro.kernels import INTERPRET
from repro.kernels.dispatch_quant.dispatch_quant import dispatch_quantize_pallas


@functools.partial(jax.jit, static_argnames=("bt",))
def dispatch_quantize(x, bt: int = 256):
    """x: (T, D) float -> (q int8 (T,D), per-token scale f32 (T,1))."""
    return dispatch_quantize_pallas(x, bt=bt, interpret=INTERPRET)
