"""Pure-jnp oracle for fused per-token INT8 quantization (early quantization)."""
import jax.numpy as jnp


def dispatch_quantize_ref(x):
    """x: (T, D) float -> (q int8 (T,D), scale f32 (T,1)); scale = absmax/127."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale
