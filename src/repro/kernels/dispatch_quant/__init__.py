from repro.kernels.dispatch_quant.ops import dispatch_quantize  # noqa: F401
