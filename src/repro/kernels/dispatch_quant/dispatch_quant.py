"""Pallas TPU kernel: fused per-token INT8 quantize+pack for FusedDispatch.

Paper §4.2.1 Opt-2 "Early Quantization": token hidden states are quantized to
INT8 (+ per-token fp32 scale) *before* the dispatch all-to-all, cutting the
collective payload ~2× vs BF16 (7.5 KB vs 14 KB per 7168-dim token). On
Ascend this runs on AIV cores inside the send pipeline; the TPU analogue is
this VPU row-wise kernel fused into the dispatch producer so the all_to_all
moves int8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import TPUCompilerParams


def _kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # (BT, D)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)  # (BT, 1)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def dispatch_quantize_pallas(x, bt: int = 256, interpret: bool = False):
    """x: (T, D) -> (int8 (T,D), f32 scale (T,1))."""
    t, d = x.shape
    bt = min(bt, t)
    while t % bt:
        bt //= 2
    return pl.pallas_call(
        _kernel,
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), jnp.int8),
            jax.ShapeDtypeStruct((t, 1), jnp.float32),
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
