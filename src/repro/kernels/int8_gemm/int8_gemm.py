"""Pallas TPU kernel: INT8 GEMM with per-token × per-channel rescale.

Paper §4.5 "Efficient INT8 Matrix Multiplication Kernels": activations are
quantized per token (dynamic), weights per output channel (static); the MXU
runs int8×int8→int32 and a single fp32 rescale produces BF16 output. Tiling
is (BM, BN, BK) with an int32 VMEM accumulator carried over the sequential K
grid dimension — K-innermost so the accumulator tile stays resident (the
data-reuse property Table 10 attributes to the Ascend L1-resident tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import TPUCompilerParams


def _kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(kb == pl.num_programs(2) - 1)
    def _finalize():
        scaled = acc_ref[...].astype(jnp.float32) * xs_ref[...] * ws_ref[...]
        o_ref[...] = scaled.astype(o_ref.dtype)


def int8_matmul_pallas(x_q, w_q, x_scale, w_scale, out_dtype=jnp.bfloat16,
                       bm: int = 128, bn: int = 128, bk: int = 128,
                       interpret: bool = False):
    m, k = x_q.shape
    _, n = w_q.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    while m % bm:
        bm //= 2
    while n % bn:
        bn //= 2
    while k % bk:
        bk //= 2
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale)
