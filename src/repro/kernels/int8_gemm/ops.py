"""Public jit'd wrapper for the INT8 GEMM kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import INTERPRET
from repro.kernels.int8_gemm.int8_gemm import int8_matmul_pallas


@functools.partial(jax.jit, static_argnames=("out_dtype", "bm", "bn", "bk"))
def int8_matmul(x_q, w_q, x_scale, w_scale, out_dtype=jnp.bfloat16,
                bm: int = 128, bn: int = 128, bk: int = 128):
    return int8_matmul_pallas(x_q, w_q, x_scale, w_scale, out_dtype,
                              bm=bm, bn=bn, bk=bk, interpret=INTERPRET)
