from repro.kernels.int8_gemm.ops import int8_matmul  # noqa: F401
