"""Pure-jnp oracle for the INT8 GEMM with mixed-granularity rescale."""
import jax.numpy as jnp


def int8_matmul_ref(x_q, w_q, x_scale, w_scale, out_dtype=jnp.bfloat16):
    """x_q: (M,K) int8; w_q: (K,N) int8; x_scale: (M,1) f32 (per token);
    w_scale: (1,N) f32 (per channel). Returns (M,N) out_dtype."""
    acc = jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)
