"""Pallas TPU kernels for the paper's compute hot-spots.

Four kernels, each a package with ``<name>.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd public wrapper) and ``ref.py`` (pure-jnp oracle):

* ``mla_attention``  — absorbed-MLA decode attention over the compressed
  latent KV cache (paper §4.2.2, FlashMLA analogue; Tables 8/9).
* ``int8_gemm``      — INT8×INT8→INT32 GEMM with per-token × per-channel
  rescale (paper §4.5; Table 10).
* ``ssd_scan``       — Mamba2 SSD chunked scan (assigned mamba2/zamba2 archs).
* ``dispatch_quant`` — fused per-token INT8 quantize+pack, the producer side
  of FusedDispatch's early quantization (paper §4.2.1).

On this CPU-only container kernels run under ``interpret=True``; on real TPU
the same pallas_call lowers to Mosaic. All kernels are validated against
their ``ref.py`` oracles across shape/dtype sweeps in tests/.
"""

import jax

INTERPRET = jax.default_backend() == "cpu"
