"""Pallas TPU kernel: Mamba2 SSD chunked scan (state-space duality).

Grid: (batch, heads, chunks) with the chunk axis sequential ("arbitrary") so
the (P, N) recurrent state lives in a VMEM scratch across chunks. Per chunk
the kernel computes the quadratic intra-chunk term (an attention-like
(Q,Q) matmul on the MXU), the inter-chunk term from the carried state, and
the state update — the exact SSD decomposition of arXiv:2405.21060 §6.

Heads are a parallel grid dimension: each head's chunk tile is
(Q, P) × (Q, N) — with Q=chunk=128, P=64, N=128 everything is 128-lane
aligned, the MXU-friendly tiling this container validates via interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import TPUCompilerParams


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,       # inputs
            y_ref, hout_ref,                          # outputs
            h_ref,                                    # scratch (P, N)
            *, chunk: int):
    ci = pl.program_id(2)
    ncs = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    a = a_ref[0]                                       # scalar A_log for head
    bmat = b_ref[0].astype(jnp.float32)                # (Q, N)
    cmat = c_ref[0].astype(jnp.float32)                # (Q, N)

    dta = dt * (-jnp.exp(a))                           # (Q,) <= 0
    cum = jnp.cumsum(dta)                              # (Q,)

    # inter-chunk: y_inter[t] = exp(cum[t]) * C_t · h
    y_inter = jax.lax.dot_general(
        cmat, h_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * jnp.exp(cum)[:, None]  # (Q, P)

    # intra-chunk: W[t,s] = (C_t·B_s) * exp(cum[t]-cum[s]) * dt[s], s <= t
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)     # (Q, Q)
    lmat = jnp.exp(cum[:, None] - cum[None, :])
    idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w = jnp.where(idx >= jdx, cb * lmat * dt[None, :], 0.0)
    y_intra = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = y_inter + y_intra

    # state update: h = exp(cum[-1]) * h + sum_s exp(cum[-1]-cum[s]) dt_s x_s B_s^T
    decay_to_end = jnp.exp(cum[-1] - cum) * dt                        # (Q,)
    contrib = jax.lax.dot_general(
        x * decay_to_end[:, None], bmat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                           # (P, N)
    h_ref[...] = h_ref[...] * jnp.exp(cum[-1]) + contrib

    @pl.when(ci == ncs - 1)
    def _emit_state():
        hout_ref[0, 0] = h_ref[...]


def ssd_scan_pallas(x, dt, a_log, bmat, cmat, chunk: int = 128,
                    interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); a_log: (H,); B/C: (B,S,N).

    Returns (y (B,S,H,P) f32, h_final (B,H,P,N) f32).
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    while s % q:
        q //= 2
    ncs = s // q
    kernel = functools.partial(_kernel, chunk=q)
    y, hout = pl.pallas_call(
        kernel,
        grid=(b, h, ncs),
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, q, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x.astype(jnp.float32), dt.astype(jnp.float32), a_log.astype(jnp.float32),
      bmat.astype(jnp.float32), cmat.astype(jnp.float32))
    return y, hout
