"""Public jit'd wrapper for the SSD chunked-scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels import INTERPRET
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, a_log, bmat, cmat, chunk: int = 128):
    """SSD scan. x: (B,S,H,P); dt: (B,S,H); a_log: (H,); B/C: (B,S,N).
    Returns (y (B,S,H,P) f32, h_final (B,H,P,N) f32)."""
    return ssd_scan_pallas(x, dt, a_log, bmat, cmat, chunk=chunk,
                           interpret=INTERPRET)
