"""Pure-jnp oracle for the SSD chunked scan: the naive token recurrence."""
from repro.models.mamba2 import ssd_reference


def ssd_scan_ref(x, dt, a_log, bmat, cmat):
    """x: (B,S,H,P); dt: (B,S,H) f32; a_log: (H,); B/C: (B,S,N).

    Returns (y (B,S,H,P) f32, h_final (B,H,P,N) f32).
    """
    return ssd_reference(x, dt, a_log, bmat, cmat)
