"""Pure-jnp oracle for absorbed-MLA decode attention."""
import jax.numpy as jnp
import jax

NEG_INF = -1e30


def mla_decode_attention_ref(q_lat, q_rope, cache, valid, scale, kvr: int):
    """q_lat: (B,H,R); q_rope: (B,H,Dr); cache: (B,S,R+Dr) f32; valid: (S,) bool.

    Returns o_lat (B,H,R) f32 — attention output still in latent space.
    """
    ck = cache[..., :kvr]
    kr = cache[..., kvr:]
    scores = (jnp.einsum("bhr,btr->bht", q_lat, ck)
              + jnp.einsum("bhe,bte->bht", q_rope, kr)) * scale
    scores = jnp.where(valid[None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bht,btr->bhr", probs, ck)
