"""Public jit'd wrapper for the absorbed-MLA decode attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels import INTERPRET
from repro.kernels.mla_attention.mla_attention import mla_decode_attention_pallas


@functools.partial(jax.jit, static_argnames=("scale", "kvr", "block_s"))
def mla_decode_attention(q_lat, q_rope, cache, valid, scale: float, kvr: int,
                         block_s: int = 128):
    """q_lat (B,H,R), q_rope (B,H,Dr), cache (B,S,R+Dr) f32, valid (S,) bool
    -> o_lat (B,H,R) f32."""
    return mla_decode_attention_pallas(q_lat, q_rope, cache, valid,
                                       float(scale), int(kvr),
                                       block_s=block_s, interpret=INTERPRET)
