from repro.kernels.mla_attention.ops import mla_decode_attention  # noqa: F401
