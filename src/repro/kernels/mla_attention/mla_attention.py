"""Pallas TPU kernel: absorbed-MLA decode attention (FlashMLA analogue).

One new query token per request attends against the compressed latent KV
cache (kv_lora_rank + rope dims). Flash-decoding style: the sequence axis is
tiled into VMEM-resident blocks with a running (max, sum, acc) softmax, so
the (B, S, R+Dr) cache streams HBM→VMEM once in 128-aligned tiles — the TPU
analogue of the paper's NZ-formatted KV cache (§4.2.2, DESIGN.md §5.3).

Grid: (batch, seq_blocks); seq dimension is "arbitrary" (sequential) so the
running-softmax scratch carries across blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import TPUCompilerParams

NEG_INF = -1e30


def _kernel(q_lat_ref, q_rope_ref, cache_ref, valid_ref,  # inputs
            out_ref,                                      # output
            m_ref, l_ref, acc_ref,                        # scratch
            *, scale: float, kvr: int, block_s: int):
    sb = pl.program_id(1)
    nsb = pl.num_programs(1)

    @pl.when(sb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lat = q_lat_ref[0]                     # (H, R)
    q_rope = q_rope_ref[0]                   # (H, Dr)
    cache = cache_ref[0]                     # (BS, R+Dr) f32
    ck = cache[:, :kvr]                      # (BS, R)
    kr = cache[:, kvr:]                      # (BS, Dr)
    valid = valid_ref[0]                     # (BS,) int32 (1 = attendable)

    scores = (
        jax.lax.dot_general(q_lat, ck, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + jax.lax.dot_general(q_rope, kr, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    ) * scale                                # (H, BS)
    scores = jnp.where(valid[None, :] > 0, scores, NEG_INF)

    m_prev = m_ref[...]                      # (H, 1)
    m_cur = jnp.max(scores, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)              # (H, BS)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, ck, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(sb == nsb - 1)
    def _finalize():
        out_ref[0] = acc_ref[...] / l_ref[...]


def mla_decode_attention_pallas(q_lat, q_rope, cache, valid, scale: float,
                                kvr: int, block_s: int = 128,
                                interpret: bool = False):
    """q_lat: (B,H,R) f32; q_rope: (B,H,Dr) f32; cache: (B,S,R+Dr) f32;
    valid: (S,) bool. Returns (B,H,R) f32."""
    b, h, r = q_lat.shape
    s = cache.shape[1]
    bs = min(block_s, s)
    while s % bs:
        bs //= 2
    n_sb = s // bs
    valid_i = valid.astype(jnp.int32)[None, :]   # (1, S) — lane-aligned

    kernel = functools.partial(_kernel, scale=scale, kvr=kvr, block_s=bs)
    return pl.pallas_call(
        kernel,
        grid=(b, n_sb),
        in_specs=[
            pl.BlockSpec((1, h, r), lambda bi, si: (bi, 0, 0)),
            pl.BlockSpec((1, h, q_rope.shape[-1]), lambda bi, si: (bi, 0, 0)),
            pl.BlockSpec((1, bs, cache.shape[-1]), lambda bi, si: (bi, si, 0)),
            pl.BlockSpec((1, bs), lambda bi, si: (0, si)),
        ],
        out_specs=pl.BlockSpec((1, h, r), lambda bi, si: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, r), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),   # running max
            pltpu.VMEM((h, 1), jnp.float32),   # running sum
            pltpu.VMEM((h, r), jnp.float32),   # accumulator
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q_lat, q_rope, cache, valid_i)
