"""AdamW + cosine schedule with linear warmup (pure JAX, no optax offline)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = cfg.lr * (step + 1) / cfg.warmup_steps
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps), 0, 1)
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                    * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos).astype(jnp.float32)


def init_opt_state(params: Any) -> OptState:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return OptState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))


def _global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptConfig, params: Any, grads: Any, state: OptState
                 ) -> Tuple[Any, OptState, dict]:
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    step = state.step + 1
    lr = lr_at(cfg, state.step)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        # decoupled weight decay on matrices only
        if p.ndim >= 2:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step, mu, nu), {"grad_norm": gnorm, "lr": lr}
