from repro.train.loop import make_train_step, train  # noqa: F401
from repro.train.optimizer import OptConfig, OptState, adamw_update, init_opt_state, lr_at  # noqa: F401
