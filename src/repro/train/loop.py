"""Training loop: jitted train_step with optional remat + microbatching."""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.microbatch import microbatched_loss
from repro.models import model as model_mod
from repro.train.optimizer import OptConfig, OptState, adamw_update, init_opt_state


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, moe_fn=None,
                    remat: bool = False, n_micro: int = 1) -> Callable:
    loss_fn = lambda p, b: model_mod.lm_loss(p, cfg, b, moe_fn)
    if remat:
        loss_fn = jax.checkpoint(loss_fn)
    loss_fn = microbatched_loss(loss_fn, n_micro)

    def train_step(params, opt_state: OptState, batch: Dict[str, jax.Array]):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def train(params, cfg: ModelConfig, batches: Iterator[Dict], steps: int,
          opt_cfg: Optional[OptConfig] = None, moe_fn=None,
          log_every: int = 10, jit: bool = True, n_micro: int = 1):
    """Simple driver used by examples/ and tests. Returns (params, history)."""
    opt_cfg = opt_cfg or OptConfig(total_steps=steps, warmup_steps=max(1, steps // 10))
    step_fn = make_train_step(cfg, opt_cfg, moe_fn, n_micro=n_micro)
    if jit:
        step_fn = jax.jit(step_fn)
    opt_state = init_opt_state(params)
    history = []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            rec = {k: float(v) for k, v in m.items()}
            rec["step"] = i
            history.append(rec)
            print(f"step {i:5d} loss={rec['loss']:.4f} nll={rec.get('nll', 0):.4f} "
                  f"lr={rec['lr']:.2e} gnorm={rec['grad_norm']:.2f}", flush=True)
    return params, history
