"""Mesh context + sharding helpers shared by train/serve/dry-run paths."""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def set_current_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_current_mesh() -> Optional[Mesh]:
    return _MESH


@contextmanager
def mesh_context(mesh: Mesh):
    prev = get_current_mesh()
    set_current_mesh(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        set_current_mesh(prev)


def batch_axes(mesh: Optional[Mesh] = None) -> Tuple[str, ...]:
    """Axes the global batch is sharded over (pod joins data when present)."""
    mesh = mesh or _MESH
    if mesh is None:
        return ()
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def all_axes(mesh: Optional[Mesh] = None) -> Tuple[str, ...]:
    mesh = mesh or _MESH
    return tuple(mesh.axis_names) if mesh is not None else ()


def axis_size(mesh: Mesh, axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def constrain(x, *spec):
    """with_sharding_constraint iff a mesh context is active."""
    mesh = _MESH
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
