"""Multiple-Token Prediction (paper §4.2.4) with CPU-free in-graph sampling.

DeepSeek-style MTP: a lightweight draft module predicts one speculative token
per decode step; the next step validates it against the main model. The paper
identifies two pipeline-break sources — CPU-side metadata init and CPU-side
sampling — and removes both. Our JAX analogue is strictly stronger: the whole
iteration (draft, validation, acceptance, sampling, cache update) is a single
jitted graph. Metadata (sequence lengths) is precomputed as traced values
("aggregated metadata initialization") and sampling runs on-device as sort/
cumsum/filter ops fused into the step ("CPU-free in-NPU sampling").

Two modes:
* ``mtp_step``     — batched aligned MTP: every request processes base +
  speculative token per iteration; acceptance is per-request, emission is
  (1 + accepted) tokens. Cache stays aligned by re-validating from the base
  slot each iteration (rejected speculative entries are overwritten), exactly
  the paper's "varying effective sequence lengths within the same batch".
* benchmarks model the paper's 70% single-token acceptance when comparing
  against SGLang "Simulated MTP" (paper Table 4).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_mod
from repro.models.layers import dense_init, rms_norm


def init_mtp_params(key, cfg: ModelConfig) -> dict:
    """Draft head: combine last hidden + next-token embedding -> logits.
    (DeepSeek MTP module distilled to one projection block.)"""
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    return {
        "ln": jnp.ones((d,), jnp.dtype(cfg.dtype)),
        "mix": dense_init(k1, (2 * d, d), jnp.dtype(cfg.dtype)),
        "proj": dense_init(k2, (d, d), jnp.dtype(cfg.dtype)),
    }


# ---------------------------------------------------------------------------
# On-device sampling (paper: "CPU-Free In-NPU Sampling")
# ---------------------------------------------------------------------------


def sample_top_p(key, logits: jax.Array, temperature: float = 0.6,
                 top_p: float = 0.95) -> jax.Array:
    """Nucleus sampling entirely in-graph: sort -> cumsum -> filter -> gumbel.
    logits: (B, V) -> (B,) int32. Temperature/top-p default to the paper's
    DeepSeek-R1 eval settings (§5.3)."""
    logits = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
    sorted_probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(sorted_probs, axis=-1)
    # keep the smallest prefix with cumulative mass >= top_p
    cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
    cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
    filtered = jnp.where(logits >= cutoff, logits, -1e30)
    g = -jnp.log(-jnp.log(jax.random.uniform(key, filtered.shape) + 1e-20) + 1e-20)
    return jnp.argmax(filtered + g, axis=-1).astype(jnp.int32)


def sample_greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# MTP decode iteration
# ---------------------------------------------------------------------------


def draft_logits(params: dict, mtp: dict, cfg: ModelConfig,
                 hidden: jax.Array, next_tok: jax.Array) -> jax.Array:
    """hidden: (B, D) final hidden of base token; next_tok: (B,) sampled."""
    emb = params["embed"][next_tok].astype(hidden.dtype)
    h = jnp.concatenate([rms_norm(hidden, mtp["ln"], cfg.norm_eps), emb], axis=-1)
    h = jax.nn.silu(jnp.einsum("bd,de->be", h, mtp["mix"]))
    h = jnp.einsum("bd,de->be", h, mtp["proj"])
    return model_mod.unembed(params, cfg, h)


def propose_draft(params: dict, mtp: dict, cfg: ModelConfig,
                  token: jax.Array) -> jax.Array:
    """Draft the successor of ``token`` (B,) -> (B,)."""
    hidden = params["embed"][token].astype(jnp.dtype(cfg.dtype))
    return sample_greedy(draft_logits(params, mtp, cfg, hidden, token))


def mtp_step(params: dict, mtp: dict, cfg: ModelConfig,
             x_prev: jax.Array, d_prev: jax.Array,
             caches: Dict[str, Any], cache_len: jax.Array,
             key: jax.Array, moe_fn=None, greedy: bool = True
             ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                        Dict[str, Any], jax.Array]:
    """One MTP iteration (k=1 speculative decode).

    Carry: ``x_prev`` (B,) — last committed token (its KV not yet cached) at
    per-request positions ``cache_len`` (B,), and ``d_prev`` (B,) — the draft
    of x_prev's successor proposed last iteration.

    The iteration runs BOTH tokens through the main model in one graph:

      f1 = decode(x_prev, len)   -> logits₁ ; slot len     = x_prev KV (always right)
      f2 = decode(d_prev, len+1) -> logits₂ ; slot len+1   = d_prev KV (speculative)
      y1 = sample(logits₁)                — the true token at len+1 (emitted)
      accepted = (y1 == d_prev)           — speculation validated
      y2 = sample(logits₂)                — token at len+2, valid iff accepted

    Accepted requests emit 2 tokens and advance 2; rejected requests emit 1,
    advance 1, and their stale slot len+1 is overwritten next iteration by
    the per-request scatter write (attention.update_cache). This is exactly
    the paper's §4.2.2-(3) regime: effective sequence lengths diverge within
    one batch, handled by per-request (B,) cache_len masks. In the
    memory-bound decode regime the two forwards share one weight stream, so
    wall-clock/iter ≈ one forward while emitting 1+α tokens (paper: α≈0.7).

    No CPU in the loop: metadata (cache_len±1) is traced ("aggregated
    metadata initialization") and sampling is in-graph ("CPU-free in-NPU
    sampling"). Returns (emitted (B,2), accepted (B,), x_next, d_next,
    caches, new_len).
    """
    if cache_len.ndim == 0:
        cache_len = jnp.broadcast_to(cache_len, x_prev.shape[:1])
    k1, k2 = jax.random.split(key)
    logits1, caches = model_mod.decode_step(params, cfg, x_prev[:, None],
                                            caches, cache_len, moe_fn)
    logits2, caches = model_mod.decode_step(params, cfg, d_prev[:, None],
                                            caches, cache_len + 1, moe_fn)
    y1 = sample_greedy(logits1) if greedy else sample_top_p(k1, logits1)
    accepted = y1 == d_prev
    y2 = sample_greedy(logits2) if greedy else sample_top_p(k2, logits2)
    emitted = jnp.stack([y1, y2], axis=1)
    x_next = jnp.where(accepted, y2, y1)
    d_next = propose_draft(params, mtp, cfg, x_next)
    new_len = cache_len + 1 + accepted.astype(jnp.int32)
    return emitted, accepted, x_next, d_next, caches, new_len
