"""Multiple-Token Prediction (paper §4.2.4) with CPU-free in-graph sampling.

DeepSeek-style MTP: a lightweight draft module predicts one speculative token
per decode step; the next step validates it against the main model. The paper
identifies two pipeline-break sources — CPU-side metadata init and CPU-side
sampling — and removes both. Our JAX analogue is strictly stronger: the whole
iteration (draft, validation, acceptance, sampling, cache update) is a single
jitted graph. Metadata (sequence lengths) is precomputed as traced values
("aggregated metadata initialization") and sampling runs on-device as sort/
cumsum/filter ops fused into the step ("CPU-free in-NPU sampling").

Three modes:
* ``mtp_step``     — batched aligned MTP: every request processes base +
  speculative token per iteration; acceptance is per-request, emission is
  (1 + accepted) tokens. Cache stays aligned by re-validating from the base
  slot each iteration (rejected speculative entries are overwritten), exactly
  the paper's "varying effective sequence lengths within the same batch".
* ``fused_verify=True`` — the base and speculative tokens run through the
  main model in ONE two-token teacher-forced forward (``attention_extend`` /
  ``mla_extend`` with per-request offsets) instead of two sequential decode
  steps: one pass over the weights per iteration, the memory-bound regime
  where the paper's +44% iteration latency (Fig. 22b) comes from.
* ``model.decode_loop_mtp`` — N MTP iterations in one ``lax.scan`` (the
  device-resident serving fast path; see models/model.py).
* benchmarks model the paper's 70% single-token acceptance when comparing
  against SGLang "Simulated MTP" (paper Table 4); ``fit_draft_head``
  distills a smoke-scale draft head so live benches measure real acceptance.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_mod
from repro.models.layers import dense_init, rms_norm


def init_mtp_params(key, cfg: ModelConfig) -> dict:
    """Draft head: combine last hidden + next-token embedding -> logits.
    (DeepSeek MTP module distilled to one projection block.)"""
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    return {
        "ln": jnp.ones((d,), jnp.dtype(cfg.dtype)),
        "mix": dense_init(k1, (2 * d, d), jnp.dtype(cfg.dtype)),
        "proj": dense_init(k2, (d, d), jnp.dtype(cfg.dtype)),
    }


# ---------------------------------------------------------------------------
# On-device sampling (paper: "CPU-Free In-NPU Sampling")
# ---------------------------------------------------------------------------


def sample_top_p(key, logits: jax.Array, temperature: float = 0.6,
                 top_p: float = 0.95) -> jax.Array:
    """Nucleus sampling entirely in-graph: sort -> cumsum -> filter -> gumbel.
    logits: (B, V) -> (B,) int32. Temperature/top-p default to the paper's
    DeepSeek-R1 eval settings (§5.3).

    The filter always keeps at least one token per row: the cutoff index is
    clamped to V-1 so ``top_p >= 1.0`` (every prefix mass can stay below
    top_p) selects the whole vocabulary instead of indexing out of bounds,
    and the ``>= cutoff`` comparison keeps the top token even when its mass
    alone exceeds ``top_p``."""
    logits = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    v = logits.shape[-1]
    sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
    sorted_probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(sorted_probs, axis=-1)
    # keep the smallest prefix with cumulative mass >= top_p (>= 1 token)
    cutoff_idx = jnp.minimum(jnp.sum(cum < top_p, axis=-1, keepdims=True),
                             v - 1)
    cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
    filtered = jnp.where(logits >= cutoff, logits, -1e30)
    g = -jnp.log(-jnp.log(jax.random.uniform(key, filtered.shape) + 1e-20) + 1e-20)
    return jnp.argmax(filtered + g, axis=-1).astype(jnp.int32)


def sample_greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# MTP decode iteration
# ---------------------------------------------------------------------------


def draft_logits(params: dict, mtp: dict, cfg: ModelConfig,
                 hidden: jax.Array, next_tok: jax.Array) -> jax.Array:
    """hidden: (B, D) final hidden of base token; next_tok: (B,) sampled."""
    emb = params["embed"][next_tok].astype(hidden.dtype)
    h = jnp.concatenate([rms_norm(hidden, mtp["ln"], cfg.norm_eps), emb], axis=-1)
    h = jax.nn.silu(jnp.einsum("bd,de->be", h, mtp["mix"]))
    h = jnp.einsum("bd,de->be", h, mtp["proj"])
    return model_mod.unembed(params, cfg, h)


def propose_draft(params: dict, mtp: dict, cfg: ModelConfig,
                  token: jax.Array) -> jax.Array:
    """Draft the successor of ``token`` (B,) -> (B,)."""
    hidden = params["embed"][token].astype(jnp.dtype(cfg.dtype))
    return sample_greedy(draft_logits(params, mtp, cfg, hidden, token))


def can_fuse_verify(cfg: ModelConfig, capacity: int) -> bool:
    """Is the one-forward base+draft verification available? Requires a
    token-addressable, non-ring cache (the extend kernels' contract —
    exactly :func:`repro.models.model.supports_prefill_continue`)."""
    return model_mod.supports_prefill_continue(cfg, capacity)


def verify_pair(params: dict, cfg: ModelConfig, x_prev: jax.Array,
                d_prev: jax.Array, caches: Dict[str, Any],
                cache_len: jax.Array, moe_fn=None
                ) -> Tuple[jax.Array, jax.Array, Dict[str, Any]]:
    """Fused verification: run (x_prev, d_prev) at per-request positions
    (cache_len, cache_len+1) through the main model in ONE teacher-forced
    forward — one pass over the weights instead of two sequential decode
    steps. Returns (logits1 (B,V), logits2 (B,V), new caches); logits1
    scores the successor of x_prev, logits2 the successor of d_prev."""
    pair = jnp.stack([x_prev, d_prev], axis=1)              # (B, 2)
    logits, caches = model_mod.prefill_continue(params, cfg, pair, caches,
                                                cache_len, moe_fn)
    return logits[:, 0, :], logits[:, 1, :], caches


def mtp_step(params: dict, mtp: dict, cfg: ModelConfig,
             x_prev: jax.Array, d_prev: jax.Array,
             caches: Dict[str, Any], cache_len: jax.Array,
             key: jax.Array, moe_fn=None, greedy: bool = True,
             fused_verify: bool = False
             ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                        Dict[str, Any], jax.Array]:
    """One MTP iteration (k=1 speculative decode).

    Carry: ``x_prev`` (B,) — last committed token (its KV not yet cached) at
    per-request positions ``cache_len`` (B,), and ``d_prev`` (B,) — the draft
    of x_prev's successor proposed last iteration.

    The iteration runs BOTH tokens through the main model in one graph:

      f1 = decode(x_prev, len)   -> logits₁ ; slot len     = x_prev KV (always right)
      f2 = decode(d_prev, len+1) -> logits₂ ; slot len+1   = d_prev KV (speculative)
      y1 = sample(logits₁)                — the true token at len+1 (emitted)
      accepted = (y1 == d_prev)           — speculation validated
      y2 = sample(logits₂)                — token at len+2, valid iff accepted

    Accepted requests emit 2 tokens and advance 2; rejected requests emit 1,
    advance 1, and their stale slot len+1 is overwritten next iteration by
    the per-request scatter write (attention.update_cache). This is exactly
    the paper's §4.2.2-(3) regime: effective sequence lengths diverge within
    one batch, handled by per-request (B,) cache_len masks. In the
    memory-bound decode regime the two forwards share one weight stream, so
    wall-clock/iter ≈ one forward while emitting 1+α tokens (paper: α≈0.7).

    No CPU in the loop: metadata (cache_len±1) is traced ("aggregated
    metadata initialization") and sampling is in-graph ("CPU-free in-NPU
    sampling"). With ``fused_verify`` both forwards collapse into one
    two-token teacher-forced pass (:func:`verify_pair`) — same token
    semantics, one weight stream per iteration (requires
    :func:`can_fuse_verify`; float reduction order differs from the
    two-step form, so it is not bitwise-identical to it). Returns
    (emitted (B,2), accepted (B,), x_next, d_next, caches, new_len).
    """
    if cache_len.ndim == 0:
        cache_len = jnp.broadcast_to(cache_len, x_prev.shape[:1])
    k1, k2 = jax.random.split(key)
    if fused_verify:
        logits1, logits2, caches = verify_pair(params, cfg, x_prev, d_prev,
                                               caches, cache_len, moe_fn)
    else:
        logits1, caches = model_mod.decode_step(params, cfg, x_prev[:, None],
                                                caches, cache_len, moe_fn)
        logits2, caches = model_mod.decode_step(params, cfg, d_prev[:, None],
                                                caches, cache_len + 1, moe_fn)
    y1 = sample_greedy(logits1) if greedy else sample_top_p(k1, logits1)
    accepted = y1 == d_prev
    y2 = sample_greedy(logits2) if greedy else sample_top_p(k2, logits2)
    emitted = jnp.stack([y1, y2], axis=1)
    x_next = jnp.where(accepted, y2, y1)
    d_next = propose_draft(params, mtp, cfg, x_next)
    new_len = cache_len + 1 + accepted.astype(jnp.int32)
    return emitted, accepted, x_next, d_next, caches, new_len


# ---------------------------------------------------------------------------
# Draft-head distillation (smoke-scale stand-in for the trained MTP module)
# ---------------------------------------------------------------------------


def fit_draft_head(params: dict, cfg: ModelConfig, mtp: dict, key: jax.Array,
                   *, prompts: Optional[jax.Array] = None, n_seq: int = 16,
                   prompt_len: int = 12, gen_len: int = 32, steps: int = 300,
                   lr: float = 3e-3, moe_fn=None) -> dict:
    """Distill the draft head against the base model's own greedy
    continuations of ``prompts`` (random prompts when omitted).

    Real deployments ship an MTP module trained jointly with the base model
    (paper α≈0.7); our smoke models are random, so an untrained head accepts
    at chance level and every MTP measurement degenerates. This fits the
    head's (token -> successor) map on self-generated traces with plain
    in-repo Adam, so measured acceptance reflects the mechanism rather than
    draft quality. A random base model's successor map is context-specific
    — there is nothing for a one-token head to generalize to — so pass the
    *serving* prompt distribution for meaningful live-bench acceptance
    (the trained-MTP analogue of matching train and serve distributions).

    Returns the updated draft-head params (base ``params`` stay frozen).
    """
    if prompts is None:
        k_prompt, _ = jax.random.split(key)
        prompts = jax.random.randint(k_prompt, (n_seq, prompt_len), 0,
                                     cfg.vocab_size, dtype=jnp.int32)
    prompts = jnp.asarray(prompts, jnp.int32)
    n_seq, prompt_len = prompts.shape
    capacity = prompt_len + gen_len + 2
    logits, caches = model_mod.prefill(params, cfg, {"tokens": prompts},
                                       capacity, moe_fn,
                                       cache_dtype=jnp.float32)
    tok0 = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    cl0 = jnp.full((n_seq,), prompt_len, jnp.int32)
    em, _, _, _, _ = model_mod.decode_loop(params, cfg, tok0, caches, cl0,
                                           gen_len, moe_fn=moe_fn)
    seq = jnp.concatenate([tok0[:, None], em], axis=1)       # (n_seq, G+1)
    cur = seq[:, :-1].reshape(-1)
    nxt = seq[:, 1:].reshape(-1)

    def loss_fn(mp):
        hidden = params["embed"][cur].astype(jnp.dtype(cfg.dtype))
        lg = draft_logits(params, mp, cfg, hidden, cur).astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, nxt[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - gold)

    @jax.jit
    def adam_step(mp, mu, nu, t):
        g = jax.grad(loss_fn)(mp)
        mu = jax.tree.map(lambda m, gg: 0.9 * m + 0.1 * gg, mu, g)
        nu = jax.tree.map(lambda v, gg: 0.999 * v + 0.001 * gg * gg, nu, g)
        mp = jax.tree.map(
            lambda p, m, v: (p - lr * (m / (1 - 0.9 ** t))
                             / (jnp.sqrt(v / (1 - 0.999 ** t)) + 1e-8)
                             ).astype(p.dtype),
            mp, mu, nu)
        return mp, mu, nu

    mu = jax.tree.map(jnp.zeros_like, mtp)
    nu = jax.tree.map(jnp.zeros_like, mtp)
    for t in range(1, steps + 1):
        mtp, mu, nu = adam_step(mtp, mu, nu, jnp.float32(t))
    return mtp
