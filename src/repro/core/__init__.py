"""The paper's named contributions as composable modules.

* lep.py             — Large-scale Expert Parallelism + FusedDispatch/Combine
* microbatch.py      — two-stream microbatch pipelining (decode + prefill)
* mtp.py             — multiple-token prediction with in-graph sampling
* hybrid_parallel.py — staged SP→TP→SP MLA prefill
* parallel.py        — mesh context / sharding helpers
"""
from repro.core.lep import make_lep_moe_fn, pick_lep_plan  # noqa: F401
from repro.core.microbatch import microbatched, microbatched_loss  # noqa: F401
from repro.core.mtp import (  # noqa: F401
    can_fuse_verify,
    fit_draft_head,
    init_mtp_params,
    mtp_step,
    propose_draft,
    sample_top_p,
)
from repro.core.hybrid_parallel import mla_prefill_hybrid  # noqa: F401
from repro.core.parallel import constrain, mesh_context, set_current_mesh  # noqa: F401
