"""Staged hybrid parallelism (SP→TP→SP) for MLA prefill — paper §4.3.1.

Pure data parallelism for prefill MLA suffers sequence-length skew and
insufficient concurrency (paper Fig. 16a). The staged scheme instead:

* **Stage 1 (SP)** — packed tokens are sharded *by sequence* over the model
  axis; per-token work (input RMSNorm + the down-projections wq_a / wkv_a,
  i.e. MLAProlog's front half) is perfectly load-balanced regardless of
  request lengths.
* **All-Gather** — performed *after* dimensionality reduction (the latents
  are q_lora_rank=1536 and kv_lora_rank+rope=576 wide vs d_model=7168), so
  the collective moves ~3.5× less than gathering hidden states. This is the
  paper's own justification for the placement.
* **Stage 2 (TP)** — attention heads are sharded over the model axis; each
  rank expands the latents for its H/m heads (unabsorbed MHA form, as the
  paper uses for prefill) and runs full-sequence chunked attention.
* **Stage 3 (SP)** — two variants:
    - ``oproj_mode="a2a"`` (paper-faithful Fig. 17): All-to-All reshards
      head-sharded outputs back to sequence shards, then o_proj runs locally.
    - ``oproj_mode="rs"`` (beyond-paper): o_proj is computed in TP form on
      head shards and reduce-scattered over the sequence — moves D=7168
      floats/token instead of H·v_d=16384, a ~2.3× collective saving.
      Recorded separately in EXPERIMENTS.md §Perf.

Returns sequence-sharded outputs and the latent KV cache (already in the
layout the decode path consumes).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.configs.base import ModelConfig
from repro.models.attention import NEG_INF, _pick_chunk
from repro.models.layers import apply_rope, rms_norm


def mla_prefill_hybrid(p: dict, x: jax.Array, cfg: ModelConfig, mesh: Mesh,
                       axis: str = "model", oproj_mode: str = "a2a"
                       ) -> Tuple[jax.Array, jax.Array]:
    """p: single-layer MLA params; x: (B, S, D) with S sharded over ``axis``.

    Returns (out (B,S,D) seq-sharded, latent cache (B,S,kvr+rope) seq-sharded).
    """
    assert oproj_mode in ("a2a", "rs")
    h = cfg.num_heads
    m = mesh.shape[axis]
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    scale = 1.0 / ((nope + rope) ** 0.5)

    def body(x_loc, wq_a, q_ln, wq_b, wkv_a, kv_ln, wk_b, wv_b, wo):
        # x_loc is the already-normed layer input (caller applies the layer
        # RMSNorm, matching the mla_prefill interface); being per-token, that
        # norm is itself sequence-parallel under the same sharding.
        b, s_loc, d = x_loc.shape
        rank = jax.lax.axis_index(axis)
        pos_loc = rank * s_loc + jnp.arange(s_loc, dtype=jnp.int32)

        # ---- Stage 1 (SP): latent down-projections on sequence shards ----
        xin = x_loc
        q_lat = rms_norm(jnp.einsum("bsd,dr->bsr", xin, wq_a), q_ln, cfg.norm_eps)
        kv = jnp.einsum("bsd,dr->bsr", xin, wkv_a)
        c_kv = rms_norm(kv[..., :kvr], kv_ln, cfg.norm_eps)
        k_rope = apply_rope(kv[..., kvr:][:, :, None, :],
                            jnp.broadcast_to(pos_loc, (b, s_loc)),
                            cfg.rope_theta)[:, :, 0, :]
        latent_loc = jnp.concatenate([c_kv, k_rope], axis=-1)

        # ---- All-Gather (post-reduction latents, paper-placed) ----
        q_lat_full = jax.lax.all_gather(q_lat, axis, axis=1, tiled=True)
        latent_full = jax.lax.all_gather(latent_loc, axis, axis=1, tiled=True)
        s = s_loc * m
        pos_full = jnp.arange(s, dtype=jnp.int32)

        # ---- Stage 2 (TP over heads): expand latents, chunked attention ----
        h_loc = h // m
        q = jnp.einsum("bsr,re->bse", q_lat_full, wq_b)
        q = q.reshape(b, s, h_loc, nope + rope)
        q_nope, q_rope = q[..., :nope], q[..., nope:]
        q_rope = apply_rope(q_rope, jnp.broadcast_to(pos_full, (b, s)),
                            cfg.rope_theta)
        c_full, kr_full = latent_full[..., :kvr], latent_full[..., kvr:]
        k_nope = jnp.einsum("bsr,re->bse", c_full, wk_b).reshape(b, s, h_loc, nope)
        v = jnp.einsum("bsr,re->bse", c_full, wv_b).reshape(b, s, h_loc, vd)

        chunk = _pick_chunk(s)
        nc = s // chunk

        def one_chunk(ci):
            qp = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
            qn = jax.lax.dynamic_slice_in_dim(q_nope, ci * chunk, chunk, axis=1)
            qrp = jax.lax.dynamic_slice_in_dim(q_rope, ci * chunk, chunk, axis=1)
            scores = (jnp.einsum("bshe,bthe->bhst", qn.astype(jnp.float32),
                                 k_nope.astype(jnp.float32))
                      + jnp.einsum("bshe,bte->bhst", qrp.astype(jnp.float32),
                                   kr_full.astype(jnp.float32))) * scale
            mask = pos_full[None, :] <= qp[:, None]
            scores = jnp.where(mask[None, None], scores, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1)
            return jnp.einsum("bhst,bthe->bshe", probs, v.astype(jnp.float32))

        if nc == 1:
            out_h = one_chunk(jnp.int32(0))
        else:
            from repro.models.scan_util import chunk_map
            outs = chunk_map(one_chunk, nc)
            out_h = jnp.moveaxis(outs, 0, 1).reshape(b, s, h_loc, vd)
        out_h = out_h.astype(x_loc.dtype)                    # (B, S, H_loc, vd)

        # ---- Stage 3 (back to SP) ----
        if oproj_mode == "a2a":
            # Paper Fig. 17: All-to-All head-shards -> sequence-shards,
            # then o_proj locally over all heads. wo arrives replicated.
            out_seq = jax.lax.all_to_all(out_h, axis, split_axis=1,
                                         concat_axis=2, tiled=True)
            out = jnp.einsum("bse,ed->bsd",
                             out_seq.reshape(b, s_loc, h * vd), wo)
        else:
            # Beyond-paper: TP o_proj on head shards + reduce-scatter over
            # the sequence (moves D instead of H*vd floats per token).
            partial = jnp.einsum("bshe,hed->bsd", out_h,
                                 wo.reshape(h_loc, vd, d))
            out = jax.lax.psum_scatter(partial, axis, scatter_dimension=1,
                                       tiled=True)
        return out, latent_loc

    wo_spec = P() if oproj_mode == "a2a" else P("model", None)
    out, latent = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axis, None),            # x: sequence-sharded
                  P(), P(), P(None, axis),        # wq_a, q_ln, wq_b(heads)
                  P(), P(), P(None, axis),        # wkv_a, kv_ln, wk_b(heads)
                  P(None, axis), wo_spec),        # wv_b(heads), wo
        out_specs=(P(None, axis, None), P(None, axis, None)),
        check_vma=False,
    )(x, p["wq_a"], p["q_ln"], p["wq_b"], p["wkv_a"], p["kv_ln"],
      p["wk_b"], p["wv_b"], p["wo"])
    return out, latent
