"""Large-scale Expert Parallelism (LEP) — paper §4.2, the core contribution.

Maps the paper's FusedDispatch / FusedCombine onto TPU-native constructs:

* **Static pre-allocated buffers** (paper Eq. 1–2): the capacity-bounded
  (slots, C, D) dispatch buffer is a static shape — XLA requires this anyway,
  making the paper's "static execution" the natural design point.
* **Early INT8 quantization** (Opt. ②): the dispatch payload is quantized to
  int8 + per-slot fp32 scale *before* the all_to_all, cutting collective
  bytes ~2× vs BF16. Combine returns unquantized BF16 (paper Fig. 12).
* **AIV-direct writes** (Opt. ①) have no public-XLA analogue; the latency
  insight is realized by fusing quantize+pack into the dispatch producer
  (kernels/dispatch_quant) and exposing independent microbatch streams for
  collective/compute overlap (core/microbatch.py). See DESIGN.md §5.2.
* **EPLB redundancy** (paper: 32 redundant router experts): optional
  ``redundancy=r`` replicates each expert r× so slots fill the mesh exactly
  (e.g. olmoe's 64 experts × 4 = 256 slots = one slot per die on a 256-chip
  pod — the paper's "one expert per NPU die" EP320 configuration).

Sharding modes
--------------
Tokens are always sharded over *all* mesh axes (the paper's DP-attention +
EP-MoE over the same dies). ``ep_axes`` selects the EP domain:

* ``("data","model")`` — full-mesh EP (paper-faithful LEP; requires
  E·r % n_devices == 0). DeepSeek-R1's 256 experts on a 256-die pod give
  exactly one expert per die.
* ``("model",)`` — EP over the model axis, experts replicated over data
  (small MoEs like olmoe in training), or FFN-sharded over data with ZeRO-3
  style weight all-gather (``ffn_shard_axis="data"``, required for the
  1T-param kimi-k2 to fit HBM; see DESIGN.md §4).
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models.layers import swiglu


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def lep_capacity(t_loc: int, k: int, slots: int, factor: float,
                 align: int = 8) -> int:
    """Static buffer depth per (slot, source-rank) — paper Eq. 2.
    ``align`` pads to TPU sublanes; decode paths may use align=1 (the
    8-floor causes up to 8× over-dispatch when t_loc·k/slots ≈ 1)."""
    cap = _cdiv(int(t_loc * k * factor), slots) + 1
    return max(align, ((cap + align - 1) // align) * align)


def _quantize_rows(x: jax.Array, use_kernel: bool) -> Tuple[jax.Array, jax.Array]:
    """Per-row int8 quantization (early quantization, paper Opt. ②)."""
    if use_kernel:
        from repro.kernels.dispatch_quant.ops import dispatch_quantize
        shp = x.shape
        q, s = dispatch_quantize(x.reshape(-1, shp[-1]))
        return q.reshape(shp), s.reshape(shp[:-1] + (1,))
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def make_lep_moe_fn(
    mesh: Mesh,
    ep_axes: Tuple[str, ...] = ("model",),
    *,
    quantize: bool = True,
    redundancy: int = 1,
    ffn_shard_axis: Optional[str] = None,
    ffn_gather: str = "weights",     # "weights" (ZeRO-3) | "tokens"
    quantize_gather: bool = False,   # int8 payload for the token all-gather
    capacity_factor: Optional[float] = None,
    capacity_align: int = 8,
    use_quant_kernel: bool = False,
    naive: bool = False,
    pack_scales: bool = True,
):
    """Build a MoeFn executing routed experts with shard_map LEP.

    ``naive=True`` reproduces the paper's Fig. 10a baseline: BF16 payloads
    (no early quantization) plus an explicit routing-metadata all_to_all —
    the configuration FusedDispatch/FusedCombine improve upon.

    ``pack_scales`` (default on) rides the per-row fp32 dequant scale inside
    the int8 dispatch payload (bitcast to 4 trailing int8 lanes), so the
    quantized dispatch hop issues exactly ONE all_to_all — the paper's
    FusedDispatch "one collective per hop" property. ``pack_scales=False``
    keeps the two-collective (payload + scales) baseline for comparison.
    """
    mesh_axes = tuple(mesh.axis_names)
    n_dev = math.prod(mesh.shape[a] for a in mesh_axes)
    ep_total = math.prod(mesh.shape[a] for a in ep_axes)
    if naive:
        quantize = False

    def moe_fn(p: dict, x: jax.Array, cfg: ModelConfig
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        t, d = x.shape
        e, k = cfg.num_experts, cfg.num_experts_per_tok
        r = redundancy
        slots = e * r
        assert slots % ep_total == 0, (
            f"experts*redundancy ({slots}) must divide over EP domain "
            f"({ep_total}); adjust ep_axes or redundancy")
        slots_loc = slots // ep_total
        factor = capacity_factor or cfg.capacity_factor

        # Pad tokens to the device count so every rank gets equal rows.
        t_pad = _cdiv(t, n_dev) * n_dev
        x_pad = jnp.pad(x, ((0, t_pad - t), (0, 0)))
        valid = (jnp.arange(t_pad, dtype=jnp.int32) < t)
        t_loc = t_pad // n_dev
        cap = lep_capacity(t_loc, k, slots, factor, capacity_align)

        # Expert weights: slot-replicated layout when redundancy > 1.
        wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
        if r > 1:
            rep = lambda w: jnp.repeat(w, r, axis=0)
            wg, wu, wd = rep(wg), rep(wu), rep(wd)

        tok_spec = P(mesh_axes)           # flat token dim over every axis
        w_spec = P(ep_axes, None, ffn_shard_axis)
        wd_spec = P(ep_axes, ffn_shard_axis, None)

        def body(x_loc, valid_loc, router_w, wg_l, wu_l, wd_l):
            tl = x_loc.shape[0]
            top_i, top_p, aux = moe_mod.route(router_w, x_loc, cfg)
            # Padded rows: spread over experts, zero combine weight.
            row = jnp.arange(tl, dtype=jnp.int32)
            spread = (row[:, None] * k + jnp.arange(k)[None, :]) % e
            top_i = jnp.where(valid_loc[:, None], top_i, spread)
            top_p = jnp.where(valid_loc[:, None], top_p, 0.0)

            # Redundancy: replica chosen by token index (EPLB load spread).
            slot_ids = top_i * r + (row[:, None] % r) if r > 1 else top_i

            meta_term = 0.0
            if naive:
                # Fig. 10a baseline: explicit metadata all_to_all first.
                counts = jnp.sum(
                    jax.nn.one_hot(slot_ids, slots, dtype=jnp.int32), axis=(0, 1))
                counts = counts.reshape(ep_total, slots_loc)
                counts_recv = jax.lax.all_to_all(counts, ep_axes, 0, 0)
                # keep the collective live (mirrors the real data dependency
                # of Fig. 10a's metadata exchange on the dispatch step)
                meta_term = jnp.sum(counts_recv).astype(jnp.float32) * 0.0

            # --- FusedDispatch: pack into the static (slots, C, D) buffer ---
            slot_pos, in_cap = moe_mod.dispatch_indices(slot_ids, slots, cap)
            flat_slot = slot_ids.reshape(-1)
            flat_pos = jnp.where(in_cap.reshape(-1), slot_pos.reshape(-1), cap - 1)
            tok_of = jnp.repeat(jnp.arange(tl), k)
            contrib = jnp.where(in_cap.reshape(-1)[:, None], x_loc[tok_of], 0)
            buf = jnp.zeros((slots, cap, d), x_loc.dtype)
            buf = buf.at[flat_slot, flat_pos].add(contrib)

            if quantize:   # early quantization BEFORE the collective
                q, scale = _quantize_rows(buf, use_quant_kernel)
                if pack_scales:
                    # Single-collective dispatch: bitcast each row's fp32
                    # scale to 4 int8 lanes riding at the payload tail, so
                    # the hop is ONE all_to_all instead of payload + scales.
                    sb = jax.lax.bitcast_convert_type(scale, jnp.int8)
                    packed = jnp.concatenate(
                        [q, sb.reshape(slots, cap, 4)], axis=-1)
                    p4 = packed.reshape(ep_total, slots_loc, cap, d + 4)
                    p_recv = jax.lax.all_to_all(p4, ep_axes, 0, 0)
                    q_recv = p_recv[..., :d]
                    s_recv = jax.lax.bitcast_convert_type(
                        p_recv[..., d:].reshape(ep_total, slots_loc, cap, 1, 4),
                        jnp.float32)
                else:
                    q4 = q.reshape(ep_total, slots_loc, cap, d)
                    s4 = scale.reshape(ep_total, slots_loc, cap, 1)
                    q_recv = jax.lax.all_to_all(q4, ep_axes, 0, 0)
                    s_recv = jax.lax.all_to_all(s4, ep_axes, 0, 0)
                recv = q_recv.astype(jnp.float32) * s_recv
                recv = recv.astype(x_loc.dtype)
            else:
                buf4 = buf.reshape(ep_total, slots_loc, cap, d)
                recv = jax.lax.all_to_all(buf4, ep_axes, 0, 0)
            # (ep, slots_loc, C, D) -> (slots_loc, ep*C, D)
            tokens = jnp.moveaxis(recv, 0, 1).reshape(slots_loc, ep_total * cap, d)

            # --- Expert FFN over local slots ---
            if ffn_shard_axis and ffn_gather == "tokens":
                # Beyond-paper (decode-optimized 2-level EP): keep the FFN
                # dim sharded, all-gather the (small) token buffer over the
                # shard axis, compute partial-F FFN, and psum-scatter the
                # partial sums back to token owners. For decode this moves
                # ~2×tokens·D instead of 2×(3·E_loc·D·F) per layer.
                if quantize_gather:
                    # early quantization applied to the second hop too
                    tq, tscale = _quantize_rows(tokens, use_quant_kernel)
                    tq_g = jax.lax.all_gather(tq, ffn_shard_axis, axis=1,
                                              tiled=True)
                    ts_g = jax.lax.all_gather(tscale, ffn_shard_axis, axis=1,
                                              tiled=True)
                    tok_g = (tq_g.astype(jnp.float32) * ts_g).astype(tokens.dtype)
                else:
                    tok_g = jax.lax.all_gather(tokens, ffn_shard_axis, axis=1,
                                               tiled=True)
                g = jnp.einsum("scd,sdf->scf", tok_g, wg_l)
                u = jnp.einsum("scd,sdf->scf", tok_g, wu_l)
                y_part = jnp.einsum("scf,sfd->scd", jax.nn.silu(g) * u, wd_l)
                y = jax.lax.psum_scatter(y_part, ffn_shard_axis,
                                         scatter_dimension=1, tiled=True)
            else:
                if ffn_shard_axis:
                    # ZeRO-3-style: gather the FFN shard of the weights.
                    wg_f = jax.lax.all_gather(wg_l, ffn_shard_axis, axis=2, tiled=True)
                    wu_f = jax.lax.all_gather(wu_l, ffn_shard_axis, axis=2, tiled=True)
                    wd_f = jax.lax.all_gather(wd_l, ffn_shard_axis, axis=1, tiled=True)
                else:
                    wg_f, wu_f, wd_f = wg_l, wu_l, wd_l
                g = jnp.einsum("scd,sdf->scf", tokens, wg_f)
                u = jnp.einsum("scd,sdf->scf", tokens, wu_f)
                y = jnp.einsum("scf,sfd->scd", jax.nn.silu(g) * u, wd_f)

            # --- FusedCombine: BF16 payload back to source ranks ---
            y4 = jnp.moveaxis(y.reshape(slots_loc, ep_total, cap, d), 1, 0)
            y_back = jax.lax.all_to_all(y4, ep_axes, 0, 0)     # (ep, slots_loc, C, D)
            y_flat = y_back.reshape(slots, cap, d)

            gathered = y_flat[flat_slot, flat_pos]
            gathered = jnp.where(in_cap.reshape(-1)[:, None], gathered, 0)
            weighted = gathered.astype(jnp.float32) * top_p.reshape(-1)[:, None]
            out = jnp.zeros((tl, d), jnp.float32).at[tok_of].add(weighted)
            out = out + meta_term

            aux = jax.lax.pmean(aux, mesh_axes)
            dropped = jax.lax.psum(jnp.sum(~in_cap), mesh_axes)
            return out.astype(x_loc.dtype), aux, dropped

        routed, aux, dropped = shard_map(
            body, mesh=mesh,
            in_specs=(tok_spec, P(mesh_axes), P(), w_spec, w_spec, wd_spec),
            out_specs=(tok_spec, P(), P()),
            check_vma=False,
        )(x_pad, valid, p["router"], wg, wu, wd)
        routed = routed[:t]

        # Shared experts: dense, partitioned by the XLA SPMD partitioner
        # (weights F-sharded over "model" via param specs; see sharding.py).
        if "shared_gate" in p:
            routed = routed + swiglu(x, p["shared_gate"], p["shared_up"],
                                     p["shared_down"]).astype(routed.dtype)
        return routed, {"aux_loss": aux, "dropped": dropped}

    return moe_fn


def pick_lep_plan(cfg: ModelConfig, mesh: Mesh, serving: bool = False) -> dict:
    """Choose EP domain / redundancy / FFN sharding for an arch on a mesh.

    Paper-faithful order of preference:
      1. full-mesh EP, one(+) expert per die (the paper's LEP, §4.2)
      2. full-mesh EP via EPLB redundancy (serving only, paper's 32-redundant)
      3. model-axis EP (+ FFN sharding over data when weights cannot be
         replicated — the kimi-k2 1T case)
    """
    axes = tuple(a for a in mesh.axis_names if a != "pod")
    full = tuple(a for a in axes)                      # ("data","model")
    n_full = math.prod(mesh.shape[a] for a in full)
    e = cfg.num_experts
    if e % n_full == 0:
        return dict(ep_axes=full, redundancy=1, ffn_shard_axis=None)
    if serving and n_full % e == 0:
        return dict(ep_axes=full, redundancy=n_full // e, ffn_shard_axis=None)
    # model-axis EP; decide if expert weights fit replicated over data.
    n_model = mesh.shape["model"]
    bytes_per_dev = (cfg.num_layers - cfg.first_k_dense) * (e / n_model) \
        * 3 * cfg.d_model * cfg.d_ff * 2
    ffn_shard = "data" if bytes_per_dev > 4e9 else None
    return dict(ep_axes=("model",), redundancy=1, ffn_shard_axis=ffn_shard)
