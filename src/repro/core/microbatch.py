"""Microbatch-based pipelining (paper §4.2.3 decode, §4.3.2 prefill).

The paper splits each batch into two interleaved microbatches so one stream's
attention overlaps the other's MoE dispatch/combine communication (decode),
and AIC-compute overlaps SDMA-driven all-to-all (prefill). On TPU, stream
assignment is XLA's job: we expose the same *structure* — two data-independent
microbatch computations inside one jitted step — and the latency-hiding
scheduler overlaps µb0's collectives with µb1's compute. On real TPU runs,
enable ``--xla_tpu_enable_latency_hiding_scheduler=true`` (see launch/).

The ablation benchmark (paper Fig. 20/21) compares n_micro=1 vs n_micro=2 by
counting overlappable collective bytes in the compiled HLO schedule.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


def _split_batch(tree: Any, n: int, i: int) -> Any:
    """Slice microbatch i of n along the batch axis of every batched leaf.

    Caches carry a leading layer axis, so batch is axis 1 for rank>=3 leaves
    and axis 0 for rank-2 leaves (tokens). Scalars pass through.
    """
    def f(leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return leaf
        axis = 0 if leaf.ndim <= 2 else 1
        b = leaf.shape[axis]
        if b % n:
            return leaf
        step = b // n
        return jax.lax.dynamic_slice_in_dim(leaf, i * step, step, axis=axis)
    return jax.tree.map(f, tree)


def _concat_batch(trees, axis_fn=None):
    def f(*leaves):
        l0 = leaves[0]
        if not hasattr(l0, "ndim") or l0.ndim == 0:
            return l0
        axis = 0 if l0.ndim <= 2 else 1
        return jnp.concatenate(leaves, axis=axis)
    return jax.tree.map(f, *trees)


def microbatched(step_fn: Callable, n_micro: int = 2):
    """Wrap a (tokens, caches, ...) -> (out, caches) step into n interleaved
    microbatches. The microbatch computations share no data, so the compiler
    may overlap µb_i's MoE collectives with µb_j's attention compute — the
    paper's two-stream decode pipeline, expressed structurally."""
    if n_micro == 1:
        return step_fn

    def wrapped(tokens, caches, *args, **kwargs):
        outs, new_caches = [], []
        for i in range(n_micro):
            t_i = _split_batch(tokens, n_micro, i)
            c_i = _split_batch(caches, n_micro, i)
            o_i, nc_i = step_fn(t_i, c_i, *args, **kwargs)
            outs.append(o_i)
            new_caches.append(nc_i)
        return _concat_batch(outs), _concat_batch(new_caches)

    return wrapped


def microbatched_loss(loss_fn: Callable, n_micro: int = 2):
    """Prefill/training analogue: average loss over interleaved microbatches.
    Structurally exposes per-µb MoE all_to_alls for overlap (paper Fig. 18b)."""
    if n_micro == 1:
        return loss_fn

    def wrapped(params, batch, *args, **kwargs):
        total, metrics = None, None
        for i in range(n_micro):
            b_i = jax.tree.map(
                lambda a: _split_batch(a, n_micro, i) if hasattr(a, "ndim") else a,
                batch)
            l_i, m_i = loss_fn(params, b_i, *args, **kwargs)
            total = l_i if total is None else total + l_i
            metrics = m_i if metrics is None else jax.tree.map(
                lambda x, y: x + y, metrics, m_i)
        inv = 1.0 / n_micro
        return total * inv, jax.tree.map(lambda x: x * inv, metrics)

    return wrapped
