"""HuBERT-XLarge — encoder-only audio transformer. [arXiv:2106.07447]

Frontend carve-out: the conv feature extractor is a stub; ``input_specs``
provides precomputed frame embeddings of shape (batch, frames, d_model).
Encoder-only => no decode shapes (see DESIGN.md / EXPERIMENTS.md skips).
"""
from repro.configs.base import ModelConfig, register


@register
def hubert_xlarge() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        source="arXiv:2106.07447",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        attention_kind="bidirectional",
        rope_theta=10_000.0,
        frontend="audio_frames",
    )
