"""Mamba2-780m — attention-free SSM using SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, register


@register
def mamba2_780m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        source="arXiv:2405.21060",
        num_layers=48,
        d_model=1536,
        num_heads=0,            # attention-free
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,                 # Mamba2 blocks subsume the FFN
        vocab_size=50280,
        attention_kind="none",
        ssm_state=128,
        ssm_conv=4,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=128,
        tie_embeddings=True,
    )
