"""Zamba2-1.2B — hybrid Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig, register


@register
def zamba2_1_2b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        source="arXiv:2411.15242",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,              # FFN of the shared attention block
        vocab_size=32000,
        ssm_state=64,
        ssm_conv=4,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=128,
        attn_every=6,           # one shared attention block every 6 layers
        rope_theta=10_000.0,
        sliding_window=8192,    # attention layers use SWA at 500k; mamba native
        tie_embeddings=True,
    )
