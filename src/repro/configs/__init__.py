"""Architecture configs. Importing this package registers every config."""
from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    get_config,
    get_shape,
    list_configs,
    smoke_variant,
)

# Registration side effects — one module per assigned architecture (+ paper's own).
from repro.configs import qwen3_8b  # noqa: F401
from repro.configs import qwen2_5_3b  # noqa: F401
from repro.configs import olmoe_1b_7b  # noqa: F401
from repro.configs import mamba2_780m  # noqa: F401
from repro.configs import kimi_k2_1t_a32b  # noqa: F401
from repro.configs import hubert_xlarge  # noqa: F401
from repro.configs import zamba2_1_2b  # noqa: F401
from repro.configs import internvl2_2b  # noqa: F401
from repro.configs import phi3_medium_14b  # noqa: F401
from repro.configs import granite_3_2b  # noqa: F401
from repro.configs import deepseek_r1  # noqa: F401

ASSIGNED_ARCHS = [
    "qwen3-8b",
    "qwen2.5-3b",
    "olmoe-1b-7b",
    "mamba2-780m",
    "kimi-k2-1t-a32b",
    "hubert-xlarge",
    "zamba2-1.2b",
    "internvl2-2b",
    "phi3-medium-14b",
    "granite-3-2b",
]
