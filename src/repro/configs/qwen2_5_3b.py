"""Qwen2.5-3B-class — dense GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B family]"""
from repro.configs.base import ModelConfig, register


@register
def qwen2_5_3b() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b",
        family="dense",
        source="hf:Qwen/Qwen2.5-0.5B (family card; assigned 3B-scale variant)",
        num_layers=36,
        d_model=2048,
        num_heads=16,
        num_kv_heads=2,
        head_dim=128,
        d_ff=11008,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        sliding_window=8192,
    )
