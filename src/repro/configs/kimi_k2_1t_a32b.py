"""Kimi K2 — trillion-param MoE, 384 experts top-8 (paper-table scale). [arXiv:2501.kimi2]

Assigned config uses GQA (64H, kv=8) per the public pool table; 1 shared
expert per Kimi K2's card. This is the closest stand-in in the assigned pool
for the paper's DeepSeek-R1 deployment (EP320, one expert per die).
"""
from repro.configs.base import ModelConfig, register


@register
def kimi_k2_1t_a32b() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        source="arXiv:2501.kimi2 (paper-table)",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=2048,              # per-expert FFN width
        vocab_size=163840,
        num_experts=384,
        num_experts_per_tok=8,
        num_shared_experts=1,
        first_k_dense=1,
        rope_theta=50_000.0,
        sliding_window=8192,
    )
