"""Model / input-shape configuration system.

One :class:`ModelConfig` dataclass covers every architecture family assigned to
this paper (dense GQA, MoE, SSM, hybrid, audio-encoder, VLM) plus the paper's
own DeepSeek-R1-style MLA+MoE model. Each ``src/repro/configs/<arch>.py``
registers exactly one full-size config; ``smoke_variant`` derives the reduced
CPU-testable configuration required by the per-arch smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    source: str                      # citation for the config (paper / model card)
    num_layers: int
    d_model: int
    num_heads: int                   # 0 => attention-free
    num_kv_heads: int
    head_dim: int
    d_ff: int                        # dense FFN width (per-expert width for MoE)
    vocab_size: int

    # --- attention options -------------------------------------------------
    attention_kind: str = "causal"   # causal | bidirectional | mla | none
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    # Serving variant for long-context decode of full-attention archs
    # (beyond-paper extension; see DESIGN.md §3). None => full attention only.
    sliding_window: Optional[int] = None

    # --- MLA (DeepSeek-style latent attention) -----------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    first_k_dense: int = 0           # leading dense layers in MoE models
    router_aux_loss_coef: float = 0.001
    # capacity factor for static dispatch buffers (paper Eq. 1-2)
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128             # SSD chunk length

    # --- hybrid (Zamba2-style) ----------------------------------------------
    attn_every: int = 0              # one shared attention block every N ssm layers

    # --- modality frontend stubs --------------------------------------------
    frontend: Optional[str] = None   # audio_frames | vision_patches
    num_prefix_embeddings: int = 0   # patches / frames provided by the stub

    # --- numerics -----------------------------------------------------------
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.ssm_state > 0 and self.attn_every == 0 and self.num_heads == 0

    @property
    def is_hybrid(self) -> bool:
        return self.ssm_state > 0 and self.attn_every > 0

    @property
    def is_encoder_only(self) -> bool:
        return self.attention_kind == "bidirectional"

    @property
    def ssm_heads(self) -> int:
        if self.ssm_state == 0:
            return 0
        return (self.d_model * self.ssm_expand) // self.ssm_head_dim

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder_only

    def supports_long_context(self) -> bool:
        """Sub-quadratic path available for 500k decode?"""
        if self.is_ssm or self.is_hybrid:
            return True
        return self.sliding_window is not None

    # Parameter count (for roofline MODEL_FLOPS = 6*N*D; MoE: active params).
    def param_count(self, active_only: bool = False) -> int:
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        for li in range(self.num_layers):
            total += self._layer_params(li, active_only)
        return total

    def _layer_params(self, layer_idx: int, active_only: bool) -> int:
        d = self.d_model
        p = 2 * d  # two RMSNorm gains
        is_ssm_layer = self.ssm_state > 0 and not (
            self.attn_every and (layer_idx + 1) % self.attn_every == 0
        )
        if self.ssm_state > 0 and is_ssm_layer:
            din = d * self.ssm_expand
            nheads = self.ssm_heads
            # in_proj: z, x, B, C, dt
            p += d * (2 * din + 2 * self.ssm_state + nheads)
            p += din * self.ssm_conv          # conv
            p += 2 * nheads                    # A_log, D
            p += din * d                       # out proj
            p += din                           # gated norm
        elif self.attention_kind == "mla":
            p += d * self.q_lora_rank + self.q_lora_rank * self.num_heads * (
                self.qk_nope_head_dim + self.qk_rope_head_dim)
            p += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            p += self.kv_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)
            p += self.num_heads * self.v_head_dim * d
        elif self.num_heads > 0:
            q = d * self.num_heads * self.head_dim
            kv = 2 * d * self.num_kv_heads * self.head_dim
            o = self.num_heads * self.head_dim * d
            p += q + kv + o
        # FFN
        if self.is_moe and layer_idx >= self.first_k_dense:
            e_active = self.num_experts_per_tok if active_only else self.num_experts
            p += (e_active + self.num_shared_experts) * 3 * d * self.d_ff
            p += d * self.num_experts  # router
        elif not (self.ssm_state > 0 and is_ssm_layer):
            p += 3 * d * self.d_ff
        return p


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # Import side-effect registration.
        from repro import configs as _c  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs() -> List[str]:
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Reduced smoke variant (2 layers, d_model<=512, <=4 experts) per assignment.
# ---------------------------------------------------------------------------


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    d = min(cfg.d_model, 256)
    heads = 0 if cfg.num_heads == 0 else min(cfg.num_heads, 4)
    kv = 0 if cfg.num_heads == 0 else min(cfg.num_kv_heads, heads)
    head_dim = 64 if cfg.num_heads else 0
    upd: dict = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 1024),
        dtype="float32",
    )
    if cfg.is_moe:
        upd.update(
            num_experts=min(cfg.num_experts, 4),
            num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
            num_shared_experts=min(cfg.num_shared_experts, 1),
            first_k_dense=min(cfg.first_k_dense, 1),
        )
    if cfg.attention_kind == "mla":
        upd.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                   qk_rope_head_dim=16, v_head_dim=32, head_dim=48)
    if cfg.ssm_state > 0:
        upd.update(ssm_state=min(cfg.ssm_state, 16), ssm_head_dim=32, ssm_chunk=32)
        if cfg.attn_every:
            upd.update(attn_every=2)
    if cfg.sliding_window:
        upd.update(sliding_window=min(cfg.sliding_window, 64))
    if cfg.num_prefix_embeddings:
        upd.update(num_prefix_embeddings=min(cfg.num_prefix_embeddings, 16))
    return dataclasses.replace(cfg, **upd)
