"""Qwen3-8B — dense GQA with qk-norm. [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import ModelConfig, register


@register
def qwen3_8b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b",
        family="dense",
        source="hf:Qwen/Qwen3-8B",
        num_layers=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=12288,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        sliding_window=8192,  # serving-only SWA variant for long_500k (DESIGN.md §3)
    )
