"""DeepSeek-R1 proxy — the paper's own workload: MLA + 256-expert MoE.

[arXiv:2412.19437 (V3) / arXiv:2501.12948 (R1)] 671B total / 37B active.
This is the reference architecture the paper's CloudMatrix-Infer deployment
(EP320, MLA DP, MTP) targets; included alongside the 10 assigned archs.
"""
from repro.configs.base import ModelConfig, register


@register
def deepseek_r1() -> ModelConfig:
    return ModelConfig(
        name="deepseek-r1",
        family="moe",
        source="arXiv:2412.19437 / arXiv:2501.12948",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,        # MLA: latent cache shared; heads expanded on the fly
        head_dim=192,            # qk_nope(128) + qk_rope(64)
        d_ff=2048,               # per-expert FFN width
        vocab_size=129280,
        attention_kind="mla",
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        num_experts=256,
        num_experts_per_tok=8,
        num_shared_experts=1,
        first_k_dense=3,
        rope_theta=10_000.0,
        sliding_window=8192,
    )
