"""OLMoE-1B-7B — 64-expert top-8 MoE. [arXiv:2409.02060]"""
from repro.configs.base import ModelConfig, register


@register
def olmoe_1b_7b() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        source="arXiv:2409.02060",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1024,              # per-expert FFN width
        vocab_size=50304,
        num_experts=64,
        num_experts_per_tok=8,
        num_shared_experts=0,
        qk_norm=True,
        rope_theta=10_000.0,
        sliding_window=8192,
    )
