"""Phi-3-medium-14B — dense GQA, RoPE + SwiGLU. [arXiv:2404.14219]"""
from repro.configs.base import ModelConfig, register


@register
def phi3_medium_14b() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b",
        family="dense",
        source="arXiv:2404.14219",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=10,
        head_dim=128,
        d_ff=17920,
        vocab_size=100352,
        rope_theta=10_000.0,
        sliding_window=8192,
    )
