"""InternVL2-2B — InternViT + InternLM2 backbone. [arXiv:2404.16821]

Frontend carve-out: the ViT + projector are a stub; ``input_specs`` provides
precomputed patch embeddings prepended to the token stream.
"""
from repro.configs.base import ModelConfig, register


@register
def internvl2_2b() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        source="arXiv:2404.16821",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92553,
        rope_theta=1_000_000.0,
        frontend="vision_patches",
        num_prefix_embeddings=256,   # one 448px tile => 256 visual tokens
        sliding_window=8192,
    )
