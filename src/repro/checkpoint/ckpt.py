"""Sharded npz checkpointing with version metadata.

Feeds EMS Model Caching (§4.4.3): a checkpoint is decomposed into fixed-size
blocks whose keys embed (name, version) — the same block layout ModelCache
registers in the disaggregated pool.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def save_checkpoint(path: str, params: Any, step: int,
                    meta: Optional[Dict] = None, shard_bytes: int = 1 << 28) -> Dict:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree.flatten(params)
    manifest = {"step": step, "meta": meta or {}, "n_leaves": len(leaves),
                "shards": []}
    shard, shard_size, shard_id = {}, 0, 0

    def flush():
        nonlocal shard, shard_size, shard_id
        if shard:
            fn = f"shard_{shard_id:04d}.npz"
            np.savez(os.path.join(path, fn), **shard)
            manifest["shards"].append(fn)
            shard, shard_size, shard_id = {}, 0, shard_id + 1

    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        shard[f"leaf_{i:05d}"] = arr
        shard_size += arr.nbytes
        if shard_size >= shard_bytes:
            flush()
    flush()
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return manifest


def load_checkpoint(path: str, params_template: Any) -> Tuple[Any, int]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = {}
    for fn in manifest["shards"]:
        with np.load(os.path.join(path, fn)) as z:
            leaves.update({k: z[k] for k in z.files})
    tmpl_leaves, treedef = jax.tree.flatten(params_template)
    out = [jax.numpy.asarray(leaves[f"leaf_{i:05d}"]).astype(t.dtype)
           for i, t in enumerate(tmpl_leaves)]
    return jax.tree.unflatten(treedef, out), manifest["step"]
