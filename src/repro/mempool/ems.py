"""Elastic Memory Service (paper §4.4): the shared, tiered, engine-decoupled
prefix-cache service.

:class:`EMSService` lifts :class:`~repro.mempool.context_cache.ContextCache`
from a single-engine, single-tier toy into the paper's EMS shape:

* **Hierarchical tiers** — per-engine *device HBM* tiers (keyed by a string
  tag such as ``"prefill0"`` / ``"decode1"``) in front of the pooled
  host-DRAM → SSD :class:`~repro.mempool.pool.MemoryPool`. An HBM hit is
  free (device-local); a pool hit pays the UB-plane pool read plus an
  RDMA-plane promote into the requesting engine's tier.
* **Async write-back** — ``store`` lands blocks *dirty* in the storing
  engine's HBM tier and queues them for demotion; the queue drains a small
  batch per public op (and fully on :meth:`flush` / :meth:`drop_engine`),
  each demotion charged to the RDMA plane via a
  :class:`~repro.serving.transfer.KVTransferEngine` bound to the pool's
  virtual clock. Prefixes therefore survive engine retire/fail: the pooled
  tier is the system of record.
* **Cost-aware eviction** — HBM victims minimize
  ``(1 + hits) · min(refetch_cost, recompute_cost) / slab_bytes``: a block
  is only worth its cheapest replacement path per byte it pins, not its
  recency. Dirty victims are demoted (never dropped) first.
* **Pool-wide dedup** — the service keeps a *non-mutating* global index
  (key → payload bytes) spanning dirty HBM blocks and pooled blocks, so a
  prefix stored by any engine dedups every other engine's store, and
  residency probes (:meth:`match_prefix` / :meth:`probe_prefix` /
  :meth:`engine_residency`) never perturb the pool's LRU order the way
  ``MemoryPool.contains`` does.

The index is advisory: the pool can still evict a block from both DRAM and
SSD behind it, in which case ``fetch`` degrades to a graceful miss and
repairs the index (the base class's eviction-race semantics).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.mempool.context_cache import ContextCache
from repro.mempool.pool import HUGE_PAGE, MemoryPool


@dataclasses.dataclass
class _HBMEntry:
    """One block resident in an engine's device-HBM tier.

    ``payload is None`` marks a *pin*: the block's KV lives in the engine's
    decode slots (router affinity signal) but the bytes themselves are
    served from the pooled tier."""
    nbytes: int
    payload: Optional[np.ndarray] = None
    dirty: bool = False
    hits: int = 0


def _slab_bytes(nbytes: int) -> int:
    """HBM allocation rounds up to huge-page slabs, like the MP servers."""
    return max(1, -(-max(nbytes, 1) // HUGE_PAGE)) * HUGE_PAGE


class EMSService(ContextCache):
    #: demotions drained per public op (the "async" write-back cadence on
    #: the virtual clock; flush()/drop_engine() drain unconditionally)
    DEMOTE_BATCH = 4

    def __init__(self, pool: Optional[MemoryPool] = None,
                 block_tokens: int = 128, namespace: str = "context",
                 model_tag: str = "model", *,
                 hbm_capacity_bytes: int = 256 * HUGE_PAGE,
                 recompute_cost_per_token_s: float = 2e-4,
                 transfer=None):
        super().__init__(pool if pool is not None else MemoryPool(n_nodes=8),
                         block_tokens, namespace, model_tag)
        if hbm_capacity_bytes < HUGE_PAGE:
            raise ValueError("hbm_capacity_bytes must hold at least one slab")
        if transfer is None:
            # Lazy import: serving.transfer pulls in jax-adjacent modules;
            # the mempool package must stay importable without them resolved
            # first (and this also breaks the package import cycle).
            from repro.serving.transfer import KVTransferEngine
            transfer = KVTransferEngine(clock=self.pool.clock)
        self.transfer = transfer
        self.hbm_capacity_bytes = hbm_capacity_bytes
        self.recompute_cost_per_token_s = recompute_cost_per_token_s
        # key -> payload nbytes; spans pooled AND dirty-HBM blocks. Never
        # consulted through MemoryPool.contains (which mutates LRU order).
        self._index: Dict[str, int] = {}
        self._hbm: Dict[str, "OrderedDict[str, _HBMEntry]"] = {}
        self._hbm_used: Dict[str, int] = {}
        self._demote_q: Deque[Tuple[str, str]] = deque()   # (engine, key)
        self.hbm_hits = 0
        self.pool_hits = 0
        self.promote_blocks = 0
        self.promote_bytes = 0
        self.demote_blocks = 0
        self.demote_bytes = 0
        self.hbm_evictions = 0
        self.index_repairs = 0

    # -- tier bookkeeping ---------------------------------------------------
    def _tier(self, engine: str) -> "OrderedDict[str, _HBMEntry]":
        if engine not in self._hbm:
            self._hbm[engine] = OrderedDict()
            self._hbm_used[engine] = 0
        return self._hbm[engine]

    def _evict_score(self, entry: _HBMEntry) -> float:
        """Retention value per pinned byte: cheapest replacement path
        (RDMA refetch from the pool vs recomputing the block's prefill)
        weighted by observed reuse. Lowest score evicts first."""
        refetch = self.transfer.plane.cost(entry.nbytes)
        recompute = self.block * self.recompute_cost_per_token_s
        return (1 + entry.hits) * min(refetch, recompute) \
            / _slab_bytes(entry.nbytes)

    def _demote_now(self, engine: str, key: str, entry: _HBMEntry) -> None:
        """Write one dirty block back to the pooled tier (RDMA charge +
        pool put); the entry stays resident, now clean."""
        assert entry.dirty and entry.payload is not None
        self.transfer.demote(entry.payload)
        self.pool.put(key, entry.payload, self.ns)
        entry.dirty = False
        self.demote_blocks += 1
        self.demote_bytes += entry.nbytes

    def _drain_demotes(self, limit: Optional[int] = None) -> int:
        """Service the async write-back queue. Entries may have been
        demoted early (eviction under pressure) or dropped with their
        engine — those are skipped, not errors."""
        drained = 0
        budget = len(self._demote_q) if limit is None else limit
        while self._demote_q and budget > 0:
            budget -= 1
            engine, key = self._demote_q.popleft()
            entry = self._hbm.get(engine, {}).get(key)
            if entry is None or not entry.dirty:
                continue
            self._demote_now(engine, key, entry)
            drained += 1
        return drained

    def _hbm_insert(self, engine: str, key: str, entry: _HBMEntry) -> None:
        tier = self._tier(engine)
        old = tier.pop(key, None)
        if old is not None:
            self._hbm_used[engine] -= _slab_bytes(old.nbytes)
            entry.hits = max(entry.hits, old.hits)
        alloc = _slab_bytes(entry.nbytes)
        while self._hbm_used[engine] + alloc > self.hbm_capacity_bytes \
                and tier:
            victim = min(tier, key=lambda k: self._evict_score(tier[k]))
            ve = tier.pop(victim)
            if ve.dirty:            # never drop unwritten bytes
                self._demote_now(engine, victim, ve)
            self._hbm_used[engine] -= _slab_bytes(ve.nbytes)
            self.hbm_evictions += 1
        tier[key] = entry
        self._hbm_used[engine] += alloc

    def _find_dirty(self, key: str) -> Optional[Tuple[str, _HBMEntry]]:
        """Locate a block that exists only as a dirty HBM copy so far."""
        for engine, tier in self._hbm.items():
            entry = tier.get(key)
            if entry is not None and entry.dirty:
                return engine, entry
        return None

    # -- probes (non-mutating: never touch the pool's LRU order) ------------
    def match_prefix(self, tokens: Sequence[int]) -> Tuple[int, List[str]]:
        keys = self._keys(tokens)
        matched: List[str] = []
        for k in keys:
            if k in self._index:
                matched.append(k)
            else:
                break
        return len(matched) * self.block, matched

    def engine_residency(self, engine: str, keys: Sequence[str]) -> int:
        """Hit depth of ``keys`` in one engine's device tier: the number
        of *leading* keys resident there (payload or pin). The decode
        router's affinity signal — derived from the shared service, so it
        cannot drift from reality the way advisory router memory could."""
        tier = self._hbm.get(engine)
        if not tier:
            return 0
        depth = 0
        for k in keys:
            if k not in tier:
                break
            depth += 1
        return depth

    # -- data path ----------------------------------------------------------
    def fetch(self, keys: Sequence[str],
              engine: Optional[str] = None) -> List[np.ndarray]:
        """Resolve blocks through the hierarchy: engine HBM (free) →
        pooled tier (UB pool read + RDMA promote into HBM) → graceful
        miss. Returns the longest resolvable prefix of ``keys``."""
        self._drain_demotes(self.DEMOTE_BATCH)
        tag = engine if engine is not None else "shared"
        tier = self._tier(tag)
        out: List[np.ndarray] = []
        for k in keys:
            entry = tier.get(k)
            if entry is not None and entry.payload is not None:
                entry.hits += 1
                tier.move_to_end(k)
                self.hbm_hits += 1
                out.append(entry.payload)
                continue
            owner = self._find_dirty(k)
            if owner is not None:
                # Another engine holds the only copy, still unwritten:
                # complete the write-back now so the pooled tier can serve.
                self._demote_now(owner[0], k, owner[1])
            v = self.pool.get(k)
            if v is None:
                # Pool evicted behind the index (or the index was stale):
                # graceful miss + repair, caller recomputes the suffix.
                if k in self._index:
                    del self._index[k]
                    self.index_repairs += 1
                self.fetch_misses += 1
                break
            self.pool_hits += 1
            self.transfer.promote(v)
            self.promote_blocks += 1
            self.promote_bytes += v.nbytes
            hits = 1 if entry is None else entry.hits + 1
            self._hbm_insert(tag, k, _HBMEntry(v.nbytes, v, False, hits))
            out.append(v)
        return out

    def store(self, tokens: Sequence[int], kv_blocks: Sequence[np.ndarray],
              engine: Optional[str] = None) -> int:
        """Write-back store: blocks land dirty in the storing engine's HBM
        tier, are indexed (and so dedup'd) pool-wide immediately, and reach
        the pooled tier asynchronously via the demote queue."""
        self._drain_demotes(self.DEMOTE_BATCH)
        tag = engine if engine is not None else "shared"
        keys = self._keys(tokens)
        stored = 0
        for k, payload in zip(keys, kv_blocks):
            if k in self._index:
                self.dedup_skipped += 1
                continue
            arr = np.asarray(payload)
            self._index[k] = arr.nbytes
            self._hbm_insert(tag, k, _HBMEntry(arr.nbytes, arr, True, 0))
            # Capacity pressure inside this very loop can demote the block
            # early; the drain skips entries that are already clean.
            self._demote_q.append((tag, k))
            stored += 1
            self.stored_blocks += 1
        return stored

    # -- engine lifecycle ---------------------------------------------------
    def pin(self, engine: str, keys: Sequence[str]) -> None:
        """Mark ``keys`` device-resident on ``engine`` without moving
        bytes — the decode-admission affinity signal (the engine's slots
        hold this KV for the request's lifetime). Pins are zero-cost,
        pool-backed, and evict like any other entry."""
        tier = self._tier(engine)
        for k in keys:
            if k in tier:
                tier[k].hits += 1
                tier.move_to_end(k)
            else:
                self._hbm_insert(engine, k,
                                 _HBMEntry(self._index.get(k, 0), None,
                                           False, 1))

    def drop_engine(self, engine: str) -> None:
        """Engine retire/fail: write every dirty block back (cached
        prefixes are *not* lost — the pooled tier keeps them), then drop
        the device tier."""
        tier = self._hbm.get(engine)
        if tier is None:
            return
        for key, entry in list(tier.items()):
            if entry.dirty:
                self._demote_now(engine, key, entry)
        del self._hbm[engine]
        del self._hbm_used[engine]

    def flush(self) -> int:
        """Drain the whole write-back queue; returns #blocks demoted."""
        return self._drain_demotes()

    # -- introspection ------------------------------------------------------
    def ems_stats(self) -> Dict[str, float]:
        lookups = self.hbm_hits + self.pool_hits + self.fetch_misses
        return {
            "indexed_blocks": len(self._index),
            "hbm_engines": len(self._hbm),
            "hbm_resident_blocks": sum(len(t) for t in self._hbm.values()),
            "hbm_used_bytes": sum(self._hbm_used.values()),
            "hbm_hits": self.hbm_hits,
            "pool_hits": self.pool_hits,
            "fetch_misses": self.fetch_misses,
            "hit_rate": (self.hbm_hits + self.pool_hits) / max(1, lookups),
            "promote_blocks": self.promote_blocks,
            "promote_bytes": self.promote_bytes,
            "demote_blocks": self.demote_blocks,
            "demote_bytes": self.demote_bytes,
            "pending_demotes": sum(
                1 for eng, k in self._demote_q
                if (e := self._hbm.get(eng, {}).get(k)) is not None
                and e.dirty),
            "hbm_evictions": self.hbm_evictions,
            "index_repairs": self.index_repairs,
            "dedup_skipped": self.dedup_skipped,
            "stored_blocks": self.stored_blocks,
            "hash_calls": self.hash_calls,
        }
