"""UB-driven disaggregated memory pool (paper §4.4.1) — the EMS substrate.

Host-side subsystem (TPU has no CPU-DRAM-over-ICI; see DESIGN.md §5.7) with
the paper's three software roles:

* :class:`MPController` — control plane: DHT view, namespaces, metadata.
* :class:`MPServer`     — one per DRAM-contributing node: slab allocator
  (huge-page-style), DRAM↔SSD tiering with LRU, recovery from the SSD tier.
* :class:`MemoryPool`   — the MP-SDK facade: Put/Get key-value API routed by
  global consistent hashing.

A :class:`SimClock` + :class:`PlaneModel` charge every transfer with the
bandwidth/latency of the plane it crosses (UB vs VPC vs SSD vs OBS), using
the paper's published constants (Table 1, §4.4.3), so benchmarks reproduce
Table 2 / Fig. 23 semantics quantitatively.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Transfer cost model (paper Table 1 / §4.4)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlaneModel:
    name: str
    bandwidth: float   # bytes/s, unidirectional effective
    latency: float     # seconds per operation

    def cost(self, nbytes: int) -> float:
        return self.latency + nbytes / self.bandwidth


# NPU<->CPU-DRAM over UB: ~147-151 GB/s, ~1.7 us (paper Table 1).
UB_PLANE = PlaneModel("ub", 147e9, 1.7e-6)
# VPC plane fallback (Fig. 23 comparison): 400 Gbps nominal, higher latency.
VPC_PLANE = PlaneModel("vpc", 12.5e9, 30e-6)
# EVS SSD tier behind each MP server.
SSD_TIER = PlaneModel("ssd", 3e9, 100e-6)
# OBS bucket: 2.5 GB/s shared (paper §4.4.3).
OBS_STORE = PlaneModel("obs", 2.5e9, 1e-3)


class SimClock:
    """Accumulates simulated transfer seconds (wall-independent)."""

    def __init__(self) -> None:
        self.elapsed = 0.0

    def charge(self, plane: PlaneModel, nbytes: int) -> float:
        dt = plane.cost(nbytes)
        self.elapsed += dt
        return dt


def stable_hash(key: str) -> int:
    return int.from_bytes(hashlib.sha256(key.encode()).digest()[:8], "big")


# ---------------------------------------------------------------------------
# MP Server: slab allocation + DRAM/SSD tiering
# ---------------------------------------------------------------------------

HUGE_PAGE = 2 * 1024 * 1024  # 2 MiB slabs ("huge pages", §4.4.1)


class MPServer:
    def __init__(self, node_id: int, dram_capacity: int, ssd_capacity: int):
        self.node_id = node_id
        self.dram_capacity = dram_capacity
        self.ssd_capacity = ssd_capacity
        self.dram_used = 0
        self.ssd_used = 0
        # key -> (namespace, nbytes, payload); insertion order = LRU order
        self.dram: "OrderedDict[str, Tuple[str, int, np.ndarray]]" = OrderedDict()
        self.ssd: "OrderedDict[str, Tuple[str, int, np.ndarray]]" = OrderedDict()
        self.evictions = 0
        self.recoveries = 0

    @staticmethod
    def _slabs(nbytes: int) -> int:
        """Allocation rounds up to huge-page slabs (fragmentation control)."""
        return max(1, -(-nbytes // HUGE_PAGE)) * HUGE_PAGE

    def put(self, key: str, ns: str, value: np.ndarray) -> None:
        nbytes = value.nbytes
        alloc = self._slabs(nbytes)
        while self.dram_used + alloc > self.dram_capacity and self.dram:
            self._evict_one()
        self.dram[key] = (ns, nbytes, value)
        self.dram.move_to_end(key)
        self.dram_used += alloc
        # Persistence: all data is also written to the EVS/SSD tier (§4.4.1).
        salloc = self._slabs(nbytes)
        while self.ssd_used + salloc > self.ssd_capacity and self.ssd:
            k, (ns2, nb2, _) = self.ssd.popitem(last=False)
            self.ssd_used -= self._slabs(nb2)
        self.ssd[key] = (ns, nbytes, value)
        self.ssd_used += salloc

    def _evict_one(self) -> None:
        """LRU eviction DRAM -> SSD (data persists in the SSD tier)."""
        key, (ns, nbytes, _) = self.dram.popitem(last=False)
        self.dram_used -= self._slabs(nbytes)
        self.evictions += 1

    def get(self, key: str) -> Optional[Tuple[np.ndarray, str]]:
        """Returns (value, tier) or None. Promotes SSD hits to DRAM."""
        if key in self.dram:
            self.dram.move_to_end(key)
            return self.dram[key][2], "dram"
        if key in self.ssd:
            ns, nbytes, value = self.ssd[key]
            self.recoveries += 1
            self.put(key, ns, value)   # promote
            return value, "ssd"
        return None

    def delete_namespace(self, ns: str) -> None:
        for store, used_attr in ((self.dram, "dram_used"), (self.ssd, "ssd_used")):
            doomed = [k for k, v in store.items() if v[0] == ns]
            for k in doomed:
                _, nbytes, _ = store.pop(k)
                setattr(self, used_attr, getattr(self, used_attr) - self._slabs(nbytes))


# ---------------------------------------------------------------------------
# MP Controller: DHT view + namespaces
# ---------------------------------------------------------------------------


class MPController:
    VNODES = 64  # virtual nodes per server for consistent hashing

    def __init__(self, n_nodes: int):
        self.n_nodes = n_nodes
        self.ring: List[Tuple[int, int]] = sorted(
            (stable_hash(f"node{n}#v{v}"), n)
            for n in range(n_nodes) for v in range(self.VNODES))
        self.namespaces: Dict[str, Dict] = {}

    def locate(self, key: str) -> int:
        """Consistent-hash ring lookup: key -> responsible node id."""
        h = stable_hash(key)
        lo, hi = 0, len(self.ring)
        while lo < hi:
            mid = (lo + hi) // 2
            if self.ring[mid][0] < h:
                lo = mid + 1
            else:
                hi = mid
        return self.ring[lo % len(self.ring)][1]

    def create_namespace(self, ns: str, quota_bytes: Optional[int] = None) -> None:
        self.namespaces[ns] = {"quota": quota_bytes, "used": 0}

    def charge_namespace(self, ns: str, nbytes: int) -> bool:
        meta = self.namespaces.setdefault(ns, {"quota": None, "used": 0})
        if meta["quota"] is not None and meta["used"] + nbytes > meta["quota"]:
            return False
        meta["used"] += nbytes
        return True


# ---------------------------------------------------------------------------
# MemoryPool: the MP-SDK facade
# ---------------------------------------------------------------------------


class MemoryPool:
    def __init__(self, n_nodes: int = 32, dram_per_node: int = 1 << 32,
                 ssd_per_node: int = 1 << 36, plane: PlaneModel = UB_PLANE):
        self.controller = MPController(n_nodes)
        self.servers = [MPServer(i, dram_per_node, ssd_per_node)
                        for i in range(n_nodes)]
        self.plane = plane
        self.clock = SimClock()
        self.hits = 0
        self.misses = 0

    # -- KV-store style API (paper §4.4.1 "Put and Get") -------------------
    def put(self, key: str, value: np.ndarray, namespace: str = "default") -> bool:
        if not self.controller.charge_namespace(namespace, value.nbytes):
            return False
        node = self.controller.locate(key)
        self.clock.charge(self.plane, value.nbytes)
        self.servers[node].put(key, namespace, value)
        return True

    def get(self, key: str) -> Optional[np.ndarray]:
        node = self.controller.locate(key)
        res = self.servers[node].get(key)
        if res is None:
            self.misses += 1
            return None
        value, tier = res
        self.hits += 1
        if tier == "ssd":
            self.clock.charge(SSD_TIER, value.nbytes)
        self.clock.charge(self.plane, value.nbytes)
        return value

    def contains(self, key: str) -> bool:
        node = self.controller.locate(key)
        return self.servers[node].get(key) is not None

    # -- introspection ------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / max(1, self.hits + self.misses),
            "sim_seconds": self.clock.elapsed,
            "dram_used": sum(s.dram_used for s in self.servers),
            "evictions": sum(s.evictions for s in self.servers),
            "load_balance": self._balance(),
        }

    def _balance(self) -> float:
        used = np.array([s.dram_used for s in self.servers], dtype=np.float64)
        if used.sum() == 0:
            return 1.0
        return float(used.min() / max(used.max(), 1))
