from repro.mempool.pool import (  # noqa: F401
    MemoryPool,
    MPController,
    MPServer,
    OBS_STORE,
    PlaneModel,
    SSD_TIER,
    UB_PLANE,
    VPC_PLANE,
)
from repro.mempool.context_cache import ContextCache  # noqa: F401
from repro.mempool.ems import EMSService  # noqa: F401
from repro.mempool.model_cache import ModelCache, ModelMeta  # noqa: F401
