"""EMS Model Caching (paper §4.4.3): block-sharded model load + switching.

Models are decomposed into blocks stored as KV entries in the disaggregated
pool; a metadata service maps (model, version) -> block keys. Loading:

* cold (miss): one shared OBS fetch fills the pool (2.5 GB/s bucket), then
  every instance pulls blocks over the UB plane — vs. per-instance OBS
  fetches without EMS (the 8× contention in Table 2).
* warm (hit): DRAM -> NPU over UB (~5 s for 671 GB across the pool).

Versioning: block keys embed the version; stale versions age out via LRU.
The benchmark ``benchmarks/model_caching.py`` reproduces Table 2 from this
cost model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.mempool.pool import MemoryPool, OBS_STORE, UB_PLANE, PlaneModel


@dataclasses.dataclass
class ModelMeta:
    name: str
    version: str
    n_blocks: int
    block_bytes: int

    @property
    def total_bytes(self) -> int:
        return self.n_blocks * self.block_bytes

    def block_key(self, i: int) -> str:
        return f"mc:{self.name}@{self.version}:{i}"


class ModelCache:
    def __init__(self, pool: MemoryPool, namespace: str = "model"):
        self.pool = pool
        self.ns = namespace
        self.registry: Dict[Tuple[str, str], ModelMeta] = {}

    def register(self, name: str, version: str, total_bytes: int,
                 block_bytes: int = 64 * 1024 * 1024) -> ModelMeta:
        n_blocks = max(1, -(-total_bytes // block_bytes))
        meta = ModelMeta(name, version, n_blocks, block_bytes)
        self.registry[(name, version)] = meta
        return meta

    def is_cached(self, meta: ModelMeta) -> bool:
        return all(self.pool.contains(meta.block_key(i))
                   for i in range(meta.n_blocks))

    def prefetch(self, meta: ModelMeta, payload: bool = False) -> float:
        """Async OBS->pool fill for missing blocks. Returns simulated seconds
        (one shared fetch — EMS's key saving vs per-instance loads)."""
        t0 = self.pool.clock.elapsed
        for i in range(meta.n_blocks):
            k = meta.block_key(i)
            if not self.pool.contains(k):
                self.pool.clock.charge(OBS_STORE, meta.block_bytes)
                blk = np.zeros(max(1, meta.block_bytes // 8), np.float64) \
                    if payload else np.zeros(1, np.float64)
                # store metadata-sized payload; accounting uses block_bytes
                self.pool.put(k, blk, self.ns)
        return self.pool.clock.elapsed - t0

    def load_to_npu(self, meta: ModelMeta, n_instances: int = 1,
                    plane: PlaneModel = UB_PLANE) -> float:
        """Pool -> NPU-memory transfer for n instances (shared blocks, no
        duplication — the 1× DRAM footprint of Table 2). Returns sim secs."""
        t0 = self.pool.clock.elapsed
        for _ in range(n_instances):
            for i in range(meta.n_blocks):
                if not self.pool.contains(meta.block_key(i)):
                    self.pool.clock.charge(OBS_STORE, meta.block_bytes)
                self.pool.clock.charge(plane, meta.block_bytes)
        return self.pool.clock.elapsed - t0

    def switch_model(self, target: ModelMeta) -> Tuple[float, bool]:
        """Model switch latency: warm (all blocks cached) ≈ UB load; cold
        adds the OBS fill. Returns (sim seconds, was_warm)."""
        warm = self.is_cached(target)
        t0 = self.pool.clock.elapsed
        if not warm:
            self.prefetch(target)
        self.load_to_npu(target, 1)
        return self.pool.clock.elapsed - t0, warm
