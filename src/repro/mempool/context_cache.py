"""EMS Context Caching (paper §4.4.2): prefix-hashed KV block reuse.

Historical KV caches are organized into paged blocks (default 128 tokens);
each block's key is a content hash chained over the prefix ("augmented with a
prefix hash to enable content-addressable indexing"), so identical prefixes
dedup to one stored copy regardless of which request produced them. The
prefill engine queries the longest cached prefix, loads those blocks over the
UB plane, and computes only the suffix (Fig. 23's reuse-rate mechanics).

Key hashing is memoized per prompt: ``block_keys`` / ``match_prefix`` /
``store`` all resolve through one bounded LRU memo, so a request's sha256
chain is computed once even though the serving loop consults the keys at
routing, admission, reuse, and store time.

:class:`~repro.mempool.ems.EMSService` subclasses this into the shared,
tiered, engine-decoupled cache service; the ``engine=`` keyword on
``fetch``/``store`` is the tier-affinity seam (ignored here).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.mempool.pool import MemoryPool


def _block_keys(tokens: Sequence[int], block: int, model_tag: str) -> List[str]:
    """Prefix-chained content hashes, one per complete block."""
    keys = []
    h = hashlib.sha256(model_tag.encode())
    n_full = len(tokens) // block
    for b in range(n_full):
        chunk = np.asarray(tokens[b * block:(b + 1) * block], np.int32)
        h = hashlib.sha256(h.digest() + chunk.tobytes())
        keys.append("cc:" + h.hexdigest())
    return keys


class ContextCache:
    #: bounded size of the per-prompt key memo (entries, LRU)
    MEMO_ENTRIES = 1024

    def __init__(self, pool: MemoryPool, block_tokens: int = 128,
                 namespace: str = "context", model_tag: str = "model"):
        self.pool = pool
        self.block = block_tokens
        self.ns = namespace
        self.model_tag = model_tag
        self.dedup_skipped = 0
        self.stored_blocks = 0
        self.fetch_misses = 0       # match→fetch eviction races, now graceful
        self.hash_calls = 0         # sha256 chains actually computed
        self._key_memo: "OrderedDict[bytes, List[str]]" = OrderedDict()

    def _keys(self, tokens: Sequence[int]) -> List[str]:
        """Memoized prefix-chained keys: one sha256 chain per distinct
        prompt, however many times the serving loop asks (routing,
        admission probe, match, store)."""
        sig = np.asarray(tokens, np.int32).tobytes()
        hit = self._key_memo.get(sig)
        if hit is not None:
            self._key_memo.move_to_end(sig)
            return hit
        self.hash_calls += 1
        keys = _block_keys(tokens, self.block, self.model_tag)
        self._key_memo[sig] = keys
        if len(self._key_memo) > self.MEMO_ENTRIES:
            self._key_memo.popitem(last=False)
        return keys

    def block_keys(self, tokens: Sequence[int]) -> List[str]:
        """Prefix-chained content keys of every complete block of
        ``tokens`` — the affinity unit for EMS-aware decode-pool routing
        (a request is attracted to the engine whose recent residents
        shared these keys)."""
        return list(self._keys(tokens))

    # -- prefill-side: longest reusable prefix ------------------------------
    def match_prefix(self, tokens: Sequence[int]) -> Tuple[int, List[str]]:
        """Returns (#reusable tokens, keys of matched blocks)."""
        keys = self._keys(tokens)
        matched: List[str] = []
        for k in keys:
            if self.pool.contains(k):
                matched.append(k)
            else:
                break
        return len(matched) * self.block, matched

    def probe_prefix(self, tokens: Sequence[int]) -> int:
        """#tokens a prefill of ``tokens`` could reuse right now — the
        admission-time hit probe (hit-aware gates charge only the
        suffix)."""
        return self.match_prefix(tokens)[0]

    def fetch(self, keys: Sequence[str],
              engine: Optional[str] = None) -> List[np.ndarray]:
        """Payloads of the longest still-resident prefix of ``keys``.

        A block can be evicted between ``match_prefix`` and ``fetch`` (the
        eviction race); rather than asserting, fetch stops at the first
        vanished block and returns what it could load — the caller shrinks
        its reuse to ``len(result) * block`` tokens and recomputes the
        rest. ``engine`` is the device-tier affinity tag, ignored by the
        single-tier base cache."""
        del engine
        out: List[np.ndarray] = []
        for k in keys:
            v = self.pool.get(k)
            if v is None:           # eviction race → graceful miss
                self.fetch_misses += 1
                break
            out.append(v)
        return out

    # -- store computed KV blocks (async in the real system) ----------------
    def store(self, tokens: Sequence[int], kv_blocks: Sequence[np.ndarray],
              engine: Optional[str] = None) -> int:
        """kv_blocks[i] is the KV payload of tokens[i*block:(i+1)*block].
        Deduplicates: already-present blocks are skipped. Returns #stored.
        ``engine`` is the device-tier affinity tag, ignored here."""
        del engine
        keys = self._keys(tokens)
        stored = 0
        for k, payload in zip(keys, kv_blocks):
            if self.pool.contains(k):
                self.dedup_skipped += 1
                continue
            if self.pool.put(k, np.asarray(payload), self.ns):
                stored += 1
                self.stored_blocks += 1
        return stored

    # Decode-side storage policy (paper: reasoning models skip it).
    def should_store_decode(self, is_reasoning_model: bool) -> bool:
        return not is_reasoning_model
