"""EMS Context Caching (paper §4.4.2): prefix-hashed KV block reuse.

Historical KV caches are organized into paged blocks (default 128 tokens);
each block's key is a content hash chained over the prefix ("augmented with a
prefix hash to enable content-addressable indexing"), so identical prefixes
dedup to one stored copy regardless of which request produced them. The
prefill engine queries the longest cached prefix, loads those blocks over the
UB plane, and computes only the suffix (Fig. 23's reuse-rate mechanics).
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.mempool.pool import MemoryPool


def _block_keys(tokens: Sequence[int], block: int, model_tag: str) -> List[str]:
    """Prefix-chained content hashes, one per complete block."""
    keys = []
    h = hashlib.sha256(model_tag.encode())
    n_full = len(tokens) // block
    for b in range(n_full):
        chunk = np.asarray(tokens[b * block:(b + 1) * block], np.int32)
        h = hashlib.sha256(h.digest() + chunk.tobytes())
        keys.append("cc:" + h.hexdigest())
    return keys


class ContextCache:
    def __init__(self, pool: MemoryPool, block_tokens: int = 128,
                 namespace: str = "context", model_tag: str = "model"):
        self.pool = pool
        self.block = block_tokens
        self.ns = namespace
        self.model_tag = model_tag
        self.dedup_skipped = 0
        self.stored_blocks = 0

    def block_keys(self, tokens: Sequence[int]) -> List[str]:
        """Prefix-chained content keys of every complete block of
        ``tokens`` — the affinity unit for EMS-aware decode-pool routing
        (a request is attracted to the engine whose recent residents
        shared these keys)."""
        return _block_keys(tokens, self.block, self.model_tag)

    # -- prefill-side: longest reusable prefix ------------------------------
    def match_prefix(self, tokens: Sequence[int]) -> Tuple[int, List[str]]:
        """Returns (#reusable tokens, keys of matched blocks)."""
        keys = _block_keys(tokens, self.block, self.model_tag)
        matched: List[str] = []
        for k in keys:
            if self.pool.contains(k):
                matched.append(k)
            else:
                break
        return len(matched) * self.block, matched

    def fetch(self, keys: List[str]) -> List[np.ndarray]:
        out = []
        for k in keys:
            v = self.pool.get(k)
            assert v is not None, "matched block vanished (eviction race)"
            out.append(v)
        return out

    # -- store computed KV blocks (async in the real system) ----------------
    def store(self, tokens: Sequence[int], kv_blocks: Sequence[np.ndarray]) -> int:
        """kv_blocks[i] is the KV payload of tokens[i*block:(i+1)*block].
        Deduplicates: already-present blocks are skipped. Returns #stored."""
        keys = _block_keys(tokens, self.block, self.model_tag)
        stored = 0
        for k, payload in zip(keys, kv_blocks):
            if self.pool.contains(k):
                self.dedup_skipped += 1
                continue
            if self.pool.put(k, np.asarray(payload), self.ns):
                stored += 1
                self.stored_blocks += 1
        return stored

    # Decode-side storage policy (paper: reasoning models skip it).
    def should_store_decode(self, is_reasoning_model: bool) -> bool:
        return not is_reasoning_model
