"""Training-free hierarchical INT8 quantization (paper §4.5).

All five components of the paper's scheme:

1. **Mixed-precision strategy** — a policy classifies tensors: large matmuls
   (FFN / attention projections / experts) go INT8; norms, routers, scales
   and other numerically-sensitive small tensors stay BF16/FP32.
2. **Adaptive scale search** (Eq. 3) — offline grid search for the
   weight/activation scale split s* minimizing ‖Q(W·s)(s⁻¹X) − WX‖.
3. **Outlier suppression via structural transformation** — SmoothQuant-style
   diagonal equalization absorbed into adjacent layers (the paper's "simple
   linear transformations ... absorbing scaling factors").
4. **Mixed-granularity kernels** — per-token activation scales × per-channel
   weight scales, executed by kernels/int8_gemm on the MXU.
5. **Block-level clipping + error compensation** (Eq. 4) — per-block clip
   factor search plus an additive bias correcting the systematic
   quantization error, estimated on calibration data.

Everything is calibration-time only; inference uses the produced
:class:`QuantizedLinear` tensors with zero runtime search overhead.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class QuantizedLinear(NamedTuple):
    """Per-channel INT8 weight + scales (+ optional equalization & bias)."""
    w_q: jax.Array          # (K, N) int8
    w_scale: jax.Array      # (1, N) f32
    eq: Optional[jax.Array]          # (K,) f32 activation equalization or None
    bias_corr: Optional[jax.Array]   # (N,) f32 error compensation or None


# ---------------------------------------------------------------------------
# Granular quantizers (component 4)
# ---------------------------------------------------------------------------


def quantize_weight_per_channel(w: jax.Array, clip: Optional[jax.Array] = None
                                ) -> Tuple[jax.Array, jax.Array]:
    """w: (K, N) -> (int8 (K,N), scale (1,N)). Per-output-channel, static."""
    wf = w.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(wf), axis=0, keepdims=True)
    if clip is not None:
        absmax = absmax * clip
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_act_per_token(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (T, K) -> (int8, scale (T,1)). Per-token, dynamic."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# Adaptive scale search (component 2, paper Eq. 3)
# ---------------------------------------------------------------------------


def adaptive_scale_search(w: jax.Array, x_calib: jax.Array,
                          grid=(0.5, 0.7, 0.85, 1.0, 1.2, 1.5, 2.0)
                          ) -> Tuple[float, jax.Array]:
    """Find scalar s* minimizing ‖Q(W·s)(s⁻¹X) − WX‖_F (offline)."""
    ref = x_calib.astype(jnp.float32) @ w.astype(jnp.float32)

    def err(s):
        wq, ws = quantize_weight_per_channel(w * s)
        xq, xs = quantize_act_per_token(x_calib / s)
        approx = (xq.astype(jnp.int32) @ wq.astype(jnp.int32)).astype(jnp.float32)
        approx = approx * xs * ws
        return jnp.linalg.norm(approx - ref)

    errs = jnp.stack([err(s) for s in grid])
    best = int(jnp.argmin(errs))
    return float(grid[best]), errs


# ---------------------------------------------------------------------------
# Outlier suppression (component 3)
# ---------------------------------------------------------------------------


def equalization_scales(w: jax.Array, x_calib: jax.Array,
                        alpha: float = 0.5) -> jax.Array:
    """Diagonal equalization s_k = max|X_k|^α / max|W_k|^(1-α), absorbed as
    x' = x / s, w' = w * s[:, None] — function-preserving, flattens the
    activation outlier channels into the (statically-quantized) weights."""
    xmax = jnp.maximum(jnp.max(jnp.abs(x_calib.astype(jnp.float32)), axis=0), 1e-5)
    wmax = jnp.maximum(jnp.max(jnp.abs(w.astype(jnp.float32)), axis=1), 1e-5)
    return (xmax ** alpha) / (wmax ** (1 - alpha))


# ---------------------------------------------------------------------------
# Block-level clipping + error compensation (component 5, Eq. 4)
# ---------------------------------------------------------------------------


def block_clip_search(w: jax.Array, x_calib: jax.Array, n_blocks: int = 4,
                      grid=(0.8, 0.9, 0.95, 1.0)) -> jax.Array:
    """Per-block clip factor α minimizing the block's output error (Eq. 4).
    Blocks partition output channels. Returns (1, N) clip multipliers."""
    k, n = w.shape
    bs = max(1, n // n_blocks)
    clips = []
    xf = x_calib.astype(jnp.float32)
    for b0 in range(0, n, bs):
        wb = w[:, b0:b0 + bs]
        ref = xf @ wb.astype(jnp.float32)
        errs = []
        for a in grid:
            wq, ws = quantize_weight_per_channel(wb, clip=jnp.float32(a))
            xq, xs = quantize_act_per_token(x_calib)
            approx = (xq.astype(jnp.int32) @ wq.astype(jnp.int32)
                      ).astype(jnp.float32) * xs * ws
            errs.append(jnp.linalg.norm(approx - ref))
        best = grid[int(jnp.argmin(jnp.stack(errs)))]
        clips.append(jnp.full((1, wb.shape[1]), best, jnp.float32))
    return jnp.concatenate(clips, axis=1)


def error_compensation(w: jax.Array, ql: "QuantizedLinear",
                       x_calib: jax.Array) -> jax.Array:
    """Additive bias E[WX − Q(W)Q(X)] over calibration tokens (N,).

    ``w`` / ``x_calib`` are the *original* (un-equalized) tensors; the
    quantized path applies ql.eq internally, so both sides see identical
    inputs.
    """
    ref = x_calib.astype(jnp.float32) @ w.astype(jnp.float32)
    approx = quantized_matmul(x_calib, ql._replace(bias_corr=None))
    return jnp.mean(ref - approx.astype(jnp.float32), axis=0)


# ---------------------------------------------------------------------------
# Calibration driver + runtime apply
# ---------------------------------------------------------------------------


def calibrate_linear(w: jax.Array, x_calib: jax.Array, *,
                     equalize: bool = True, block_clip: bool = True,
                     compensate: bool = True) -> QuantizedLinear:
    """Full §4.5 pipeline for one weight matrix (offline)."""
    eq = equalization_scales(w, x_calib) if equalize else None
    w_eff = w * eq[:, None] if eq is not None else w
    x_eff = x_calib / eq[None, :] if eq is not None else x_calib
    clip = block_clip_search(w_eff, x_eff) if block_clip else None
    w_q, w_scale = quantize_weight_per_channel(w_eff, clip=clip)
    ql = QuantizedLinear(w_q, w_scale, eq, None)
    if compensate:
        bias = error_compensation(w, ql, x_calib)
        ql = ql._replace(bias_corr=bias)
    return ql


def quantized_matmul(x: jax.Array, ql: QuantizedLinear,
                     use_kernel: bool = False,
                     out_dtype=jnp.float32) -> jax.Array:
    """Runtime: per-token quantize -> INT8 GEMM -> rescale (+bias)."""
    if ql.eq is not None:
        x = x / ql.eq[None, :].astype(x.dtype)
    x_q, x_scale = quantize_act_per_token(x)
    if use_kernel:
        from repro.kernels.int8_gemm.ops import int8_matmul
        out = int8_matmul(x_q, ql.w_q, x_scale, ql.w_scale,
                          out_dtype=jnp.float32)
    else:
        out = (x_q.astype(jnp.int32) @ ql.w_q.astype(jnp.int32)
               ).astype(jnp.float32) * x_scale * ql.w_scale
    if ql.bias_corr is not None:
        out = out + ql.bias_corr[None, :]
    return out.astype(out_dtype)


# ---------------------------------------------------------------------------
# Mixed-precision policy (component 1)
# ---------------------------------------------------------------------------

#: path-substring rules: tensors matching INT8_PATHS are quantized; others
#: (norms, routers, biases, scales, dt/A/D of SSM blocks) stay high precision.
INT8_PATHS = ("w_gate", "w_up", "w_down", "wq", "wk", "wv", "wo",
              "wq_a", "wq_b", "wkv_a", "wk_b", "wv_b",
              "shared_gate", "shared_up", "shared_down",
              "in_proj", "out_proj", "lm_head", "mix", "proj")
KEEP_PATHS = ("ln", "norm", "router", "bias", "dt_bias", "A_log", "D",
              "conv", "embed", "q_norm", "k_norm", "q_ln", "kv_ln")


def should_quantize(path: str) -> bool:
    leaf = path.split("/")[-1]
    if any(k in leaf for k in KEEP_PATHS):
        return False
    return any(k == leaf or leaf.startswith(k) for k in INT8_PATHS)


def quantize_param_tree(params: dict) -> Tuple[dict, Dict[str, int]]:
    """Apply the mixed-precision policy over a model param tree.
    2-D+ tensors on INT8 paths -> (int8, scale) dicts; rest untouched.
    Returns (new tree, {quantized: n, kept: m})."""
    stats = {"quantized": 0, "kept": 0}

    def walk(tree, path=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in tree.items()}
        if hasattr(tree, "ndim") and tree.ndim >= 2 and should_quantize(path):
            mat = tree.reshape(-1, tree.shape[-1])
            q, s = quantize_weight_per_channel(mat)
            stats["quantized"] += 1
            return {"__q__": q.reshape(tree.shape),
                    "__scale__": s.astype(jnp.float32)}
        stats["kept"] += 1
        return tree

    return walk(params), stats
