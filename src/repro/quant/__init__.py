from repro.quant.int8 import (  # noqa: F401
    QuantizedLinear,
    adaptive_scale_search,
    block_clip_search,
    calibrate_linear,
    equalization_scales,
    error_compensation,
    quantize_act_per_token,
    quantize_param_tree,
    quantize_weight_per_channel,
    quantized_matmul,
    should_quantize,
)
