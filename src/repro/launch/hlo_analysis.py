"""Compiled-HLO analysis: collective bytes + roofline terms (deliverable g).

cost_analysis() gives HLO FLOPs and bytes-accessed; collective traffic is
extracted by parsing the (per-device SPMD) HLO text and summing the output
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Hardware constants: TPU v5e-class — 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI (system prompt constants).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
                       r"\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every array shape in an HLO type string (incl tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Loop-aware per-op-kind output bytes of collectives in a per-device
    HLO module: collectives inside while-loop bodies (lax.scan layers) are
    multiplied by the loop trip count (parsed from the loop condition's
    comparison constant), so rolled layer stacks are fully accounted."""
    comps = _split_computations(hlo_text)
    # direct collective bytes + call edges per computation
    direct: Dict[str, Dict[str, int]] = {}
    calls: Dict[str, list] = {}
    for name, body in comps.items():
        d = {k: 0 for k in COLLECTIVE_OPS}
        d["count"] = 0
        edges = []
        for line in body:
            s = line.strip()
            m = re.match(r"%?[\w.\-]+\s*=\s*(\([^=]*?\)|[^\s]+)\s+([\w\-]+)", s)
            if m:
                opname = m.group(2)
                for kind in COLLECTIVE_OPS:
                    if opname == kind or opname.startswith(kind + "-start"):
                        d[kind] += _shape_bytes(m.group(1))
                        d["count"] += 1
                        break
            # call edges: while bodies get trip-count multipliers
            wm = re.search(r"\bwhile\(.*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)", s)
            if wm:
                trip = _trip_count(comps.get(wm.group(1), []))
                edges.append((wm.group(2), trip))
                edges.append((wm.group(1), trip))
                continue
            for attr in ("to_apply", "calls"):
                cm = re.search(rf"\b{attr}=%?([\w.\-]+)", s)
                if cm:
                    edges.append((cm.group(1), 1))
            bm = re.search(r"\bbody=%?([\w.\-]+)", s)
            cm2 = re.search(r"\bcondition=%?([\w.\-]+)", s)
            if bm and not wm:
                edges.append((bm.group(1), 1))
            if cm2 and not wm:
                edges.append((cm2.group(1), 1))
        direct[name] = d
        calls[name] = edges

    entry = next((n for n in comps if n.startswith("ENTRY") or n == "__entry__"),
                 None)
    totals = {k: 0 for k in COLLECTIVE_OPS}
    totals["count"] = 0

    def visit(name: str, mult: int, depth: int = 0):
        if name not in direct or depth > 12:
            return
        d = direct[name]
        for k in totals:
            totals[k] += d[k] * mult
        for callee, trip in calls.get(name, []):
            visit(callee, mult * max(1, trip), depth + 1)

    if entry is not None:
        visit(entry, 1)
    else:  # fallback: flat count
        for name in direct:
            for k in totals:
                totals[k] += direct[name][k]
    return totals


def _split_computations(hlo_text: str) -> Dict[str, list]:
    """Map computation name -> its lines. ENTRY gets key 'ENTRY<name>'.

    Computation headers are column-0 lines of the form
    ``[ENTRY ]%name (params...) -> result {`` — params may contain nested
    parens (tuple types), so the name is taken up to the first '(' only.
    """
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                cur = ("ENTRY" + m.group(2)) if m.group(1) else m.group(2)
                comps[cur] = []
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _trip_count(cond_lines: list) -> int:
    """Trip count of a scan/while: the max integer constant in its condition
    (lax.scan lowers to `index < L`)."""
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"\b[su]\d+\[\]\s+constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class Roofline:
    flops: float               # per-device HLO flops
    hbm_bytes: float           # per-device HLO bytes-accessed (unfused bound)
    struct_bytes: float        # args+temps+outputs (fused/TPU-realistic bound)
    coll_bytes: float          # per-device collective bytes
    compute_s: float
    memory_s: float            # from struct_bytes (primary)
    memory_hlo_s: float        # from HLO bytes-accessed (pessimistic)
    collective_s: float
    dominant: str
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def roofline_terms(cost: Dict, coll: Dict[str, int], n_devices: int,
                   model_flops_total: Optional[float] = None,
                   struct_bytes: float = 0.0,
                   ici_links: int = 4) -> Roofline:
    """cost: compiled.cost_analysis() (per-device program).

    compute  = FLOPs / peak ; collective = bytes / (links × link_bw).
    Two memory terms: the primary uses *structural* bytes (arguments + temps
    + outputs — what a fused TPU program actually streams through HBM per
    step); the secondary uses HLO bytes-accessed (counts every op's operands:
    an un-fused upper bound, inflated on the CPU backend). The dry-run runs
    with fully-unrolled layer scans so FLOPs include every layer.
    """
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    cbytes = float(sum(coll[k] for k in COLLECTIVE_OPS))
    compute_s = flops / PEAK_FLOPS
    memory_s = struct_bytes / HBM_BW
    memory_hlo_s = nbytes / HBM_BW
    coll_s = cbytes / (ici_links * ICI_BW)
    dom = max(
        (("compute", compute_s), ("memory", memory_s), ("collective", coll_s)),
        key=lambda kv: kv[1])[0]
    mf = model_flops_total / n_devices if model_flops_total else None
    ratio = (mf / flops) if (mf and flops) else None
    return Roofline(flops, nbytes, struct_bytes, cbytes, compute_s, memory_s,
                    memory_hlo_s, coll_s, dom, mf, ratio)


def model_flops(cfg, n_tokens: int, kind: str) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D for a forward-only pass
    (N = active params for MoE)."""
    n_active = cfg.param_count(active_only=True)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * n_tokens
