"""Sharding rules: param / cache / input PartitionSpecs per (arch × mesh).

Policy (DESIGN.md §4):
* batch over ("pod","data"); TP (heads / FFN columns) over "model".
* training adds FSDP: the d_model dim of big matrices shards over "data"
  (XLA inserts per-layer all-gathers — ZeRO-3 semantics).
* MoE experts: per pick_lep_plan — full-mesh EP when E divides the pod
  (deepseek: one expert per die), else model-axis EP with the FFN dim over
  "data" when replication would blow HBM (kimi-k2 1T).
* decode KV/latent/SSM caches: batch over "data", sequence (or SSM heads)
  over "model" — the TPU analogue of the paper's UB-pooled uniform-access
  cache (softmax over the sharded seq axis lowers to all-reduces).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.lep import pick_lep_plan
from repro.models.attention import KVCache
from repro.models.mamba2 import SSMState
from repro.models.model import build_plan


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _div(n: int, mesh: Mesh, axes) -> bool:
    if not axes:
        return False
    size = math.prod(mesh.shape[a] for a in (axes if isinstance(axes, tuple) else (axes,)))
    return n % size == 0


def _maybe(axis, n, mesh):
    """Use axis only if dimension n divides evenly (else replicate)."""
    return axis if axis and _div(n, mesh, axis) else None


def param_pspecs(cfg: ModelConfig, mesh: Mesh, params_shape: Any,
                 train: bool = False) -> Any:
    """PartitionSpec pytree matching init_params structure."""
    fsdp = "data" if train else None
    lep = pick_lep_plan(cfg, mesh) if cfg.is_moe else None

    def attn_spec(name: str, shape) -> P:
        d = cfg.d_model
        if name in ("wq", "wk", "wv"):
            return P(None, _maybe(fsdp, d, mesh), _maybe("model", shape[-1], mesh))
        if name == "wo":
            return P(None, _maybe("model", shape[1], mesh), _maybe(fsdp, d, mesh))
        if name in ("bq", "bk", "bv"):
            return P(None, _maybe("model", shape[-1], mesh))
        if name in ("wq_a",):
            return P(None, _maybe(fsdp, d, mesh), _maybe("model", shape[-1], mesh))
        if name in ("wq_b", "wk_b", "wv_b"):
            return P(None, None, _maybe("model", shape[-1], mesh))
        if name == "wkv_a":
            return P(None, _maybe(fsdp, d, mesh), None)
        return P()  # norms, gains

    def moe_spec(name: str, shape) -> P:
        ep = lep["ep_axes"]
        ffn = lep["ffn_shard_axis"]
        if name in ("w_gate", "w_up"):
            return P(None, ep, None, _maybe(ffn, shape[-1], mesh))
        if name == "w_down":
            return P(None, ep, _maybe(ffn, shape[2], mesh), None)
        if name in ("shared_gate", "shared_up"):
            return P(None, _maybe(fsdp, shape[1], mesh), _maybe("model", shape[-1], mesh))
        if name == "shared_down":
            return P(None, _maybe("model", shape[1], mesh), _maybe(fsdp, shape[-1], mesh))
        return P()  # router, ln — replicated

    def mamba_spec(name: str, shape) -> P:
        if name == "in_proj":
            return P(None, _maybe(fsdp, shape[1], mesh), _maybe("model", shape[-1], mesh))
        if name == "out_proj":
            return P(None, _maybe("model", shape[1], mesh), _maybe(fsdp, shape[-1], mesh))
        return P()

    def walk(tree, ctx=""):
        if isinstance(tree, dict):
            return {k: walk(v, k if k in ("attn", "mlp", "moe", "mamba")
                            else ctx) for k, v in tree.items()}
        return tree

    # build spec tree mirroring params via path traversal
    def spec_of(path, leaf) -> P:
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        leafname = names[-1]
        shape = leaf.shape
        if leafname == "embed":
            return P(_maybe("model", shape[0], mesh), None)
        if leafname == "lm_head":
            return P(None, _maybe("model", shape[-1], mesh))
        if leafname == "final_norm":
            return P()
        if "moe" in names:
            return moe_spec(leafname, shape)
        if "mamba" in names:
            return mamba_spec(leafname, shape)
        if "attn" in names:
            return attn_spec(leafname, shape)
        if "mlp" in names:
            if leafname in ("w_gate", "w_up"):
                return P(None, _maybe(fsdp, shape[1], mesh),
                         _maybe("model", shape[-1], mesh))
            if leafname == "w_down":
                return P(None, _maybe("model", shape[1], mesh),
                         _maybe(fsdp, shape[-1], mesh))
            return P()
        return P()

    return jax.tree_util.tree_map_with_path(spec_of, params_shape)


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, caches_shape: Any) -> Any:
    """Decode caches: batch over data, sequence / wide dims over model."""
    specs: Dict[str, Any] = {}
    for seg in build_plan(cfg):
        c = caches_shape[seg.name]
        if seg.kind in ("dense", "moe"):
            if cfg.attention_kind == "mla":
                arr = c["mla"]
                specs[seg.name] = {
                    "mla": P(None, _maybe("data", arr.shape[1], mesh),
                             _maybe("model", arr.shape[2], mesh), None),
                    "length": P(),
                }
            else:
                sh = c.k.shape
                spec = P(None, _maybe("data", sh[1], mesh),
                         _maybe("model", sh[2], mesh), None, None)
                specs[seg.name] = KVCache(spec, spec, P())
        elif seg.kind == "mamba_tail":
            hsh = c.h.shape
            csh = c.conv.shape
            specs[seg.name] = SSMState(
                P(None, _maybe("data", hsh[1], mesh),
                  _maybe("model", hsh[2], mesh), None, None),
                P(None, _maybe("data", csh[1], mesh), None,
                  _maybe("model", csh[-1], mesh)),
                P())
        else:
            hsh = c["ssm"]["h"].shape
            csh = c["ssm"]["conv"].shape
            ksh = c["shared_kv"].k.shape
            kvspec = P(None, _maybe("data", ksh[1], mesh),
                       _maybe("model", ksh[2], mesh), None, None)
            specs[seg.name] = {
                "ssm": {
                    "h": P(None, None, _maybe("data", hsh[2], mesh),
                           _maybe("model", hsh[3], mesh), None, None),
                    "conv": P(None, None, _maybe("data", csh[2], mesh),
                              None, _maybe("model", csh[-1], mesh)),
                    "length": P(),
                },
                "length": P(),
                "shared_kv": KVCache(kvspec, kvspec, P()),
            }
    return specs


def batch_pspecs(cfg: ModelConfig, mesh: Mesh, batch_shape: Dict[str, Any]) -> Dict[str, Any]:
    dp = dp_axes(mesh)
    out = {}
    for k, v in batch_shape.items():
        b = v.shape[0]
        ax = dp if _div(b, mesh, dp) else (
            ("data",) if _div(b, mesh, ("data",)) else None)
        out[k] = P(ax, *([None] * (v.ndim - 1)))
    return out


def to_shardings(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))
