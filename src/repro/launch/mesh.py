"""Production mesh construction.

Single pod: 16×16 = 256 chips over ("data", "model") — the CloudMatrix384
supernode analogue (the paper's 320-die decode instance ≈ one pod here).
Multi-pod: (2, 16, 16) = 512 chips with a leading "pod" axis — the paper's
RDMA scale-out plane maps to this axis (DP + KV handoff cross traffic only;
TP/EP stay inside a pod, §6.1.1).

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for CPU multi-device tests (8 forced host devices)."""
    return make_mesh((n_data, n_model), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))
