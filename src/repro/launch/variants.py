import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing harness: lower+compile named VARIANTS of a
(arch × shape) pair and report roofline-term deltas vs baseline.

Each variant is one hypothesis from the EXPERIMENTS.md §Perf log —
paper-faithful baselines (naive Fig-10a MoE, fused LEP) and beyond-paper
changes (token-gather 2-level EP, INT8 weight streaming, microbatch overlap,
sequence-parallel encoder activations) — compiled with the same dry-run
machinery so before/after numbers are directly comparable.

  PYTHONPATH=src python -m repro.launch.variants --arch kimi-k2-1t-a32b \
      --shape decode_32k --variant token_gather
"""
import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_shape
from repro.core.lep import make_lep_moe_fn, pick_lep_plan
from repro.core.microbatch import microbatched
from repro.launch import hlo_analysis as hlo
from repro.launch.dryrun import (OUT_DIR, analytic_flops, input_specs,
                                 train_memory_bytes)
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_pspecs, cache_pspecs, param_pspecs,
                                   to_shardings)
from repro.models import model as model_mod
from repro.quant.int8 import should_quantize

HC_DIR = os.path.join(os.path.dirname(OUT_DIR), "hillclimb")


# ---------------------------------------------------------------------------
# INT8 weight streaming: params stored int8 (+f32 scale), dequantized inline.
# Halves the per-step HBM weight traffic — §4.5's INT8 benefit on the
# memory-bound decode roofline.
# ---------------------------------------------------------------------------


def quantized_param_shapes(params_shape):
    def walk(tree, path=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in tree.items()}
        if hasattr(tree, "ndim") and tree.ndim >= 2 and should_quantize(path):
            return {"__q__": jax.ShapeDtypeStruct(tree.shape, jnp.int8),
                    "__scale__": jax.ShapeDtypeStruct(
                        tree.shape[:-2] + (1, tree.shape[-1]), jnp.float32)}
        return tree
    return walk(params_shape)


def quantized_param_specs(spec_tree, params_shape):
    def walk(spec, shape, path=""):
        if isinstance(shape, dict):
            return {k: walk(spec[k], shape[k], f"{path}/{k}")
                    for k in shape}
        if hasattr(shape, "ndim") and shape.ndim >= 2 and should_quantize(path):
            return {"__q__": spec, "__scale__": P()}
        return spec
    return walk(spec_tree, params_shape)


def dequantize_tree(tree, dtype=jnp.bfloat16):
    if isinstance(tree, dict):
        if "__q__" in tree:
            return (tree["__q__"].astype(jnp.float32)
                    * tree["__scale__"]).astype(dtype)
        return {k: dequantize_tree(v, dtype) for k, v in tree.items()}
    return tree


# ---------------------------------------------------------------------------
# Variant registry
# ---------------------------------------------------------------------------


def build_variant(cfg, shape, mesh, variant: str):
    """Returns (step_fn, args, in_spec)."""
    params_shape = jax.eval_shape(
        functools.partial(model_mod.init_params, cfg=cfg), jax.random.PRNGKey(0))
    p_spec = param_pspecs(cfg, mesh, params_shape, train=(shape.kind == "train"))
    bsh = input_specs(cfg, shape)
    b_spec = batch_pspecs(cfg, mesh, bsh)

    lep_kw: Dict[str, Any] = {}
    if cfg.is_moe:
        lep_kw = dict(pick_lep_plan(cfg, mesh, serving=shape.kind != "train"))

    int8_weights = False
    n_micro = 1
    if variant == "baseline":
        pass
    elif variant == "paper_naive":          # paper's own Fig-10a baseline
        lep_kw.update(naive=True)
    elif variant == "no_early_quant":       # fused ops but BF16 dispatch
        lep_kw.update(quantize=False)
    elif variant == "token_gather":         # beyond-paper 2-level EP
        lep_kw.update(ffn_shard_axis="data", ffn_gather="tokens")
    elif variant == "int8_weights":
        int8_weights = True
    elif variant == "int8_weights_token_gather":
        int8_weights = True
        lep_kw.update(ffn_shard_axis="data", ffn_gather="tokens")
    elif variant == "token_gather_tight":
        # + exact capacity (drop the 8-sublane floor: ~4× fewer buffer rows
        #   at decode token counts) + int8 second-hop gather
        lep_kw.update(ffn_shard_axis="data", ffn_gather="tokens",
                      quantize_gather=True, capacity_align=1)
    elif variant == "full_opt":
        # everything: int8 weights + tight quantized token-gather + donation
        int8_weights = True
        lep_kw.update(ffn_shard_axis="data", ffn_gather="tokens",
                      quantize_gather=True, capacity_align=1)
    elif variant == "donate_cache":
        pass  # handled below (decode only)
    elif variant in ("aligned_decode", "int8_aligned", "best"):
        pass  # handled in the decode step builder
    elif variant == "microbatch2":
        n_micro = 2
    elif variant == "tp_only":
        # train: drop FSDP — weights TP-sharded over model only (trades
        # per-layer weight all-gathers for replicated weight memory)
        p_spec = param_pspecs(cfg, mesh, params_shape, train=False)
    elif variant == "block_skip":
        # beyond-paper: flash-style causal block skipping in prefill
        # (visits only kv blocks <= query block; ~2x fewer executed pairs)
        os.environ["REPRO_BLOCK_SKIP"] = "1"
    elif variant in ("hybrid_a2a", "hybrid_rs"):
        # paper §4.3.1 SP→TP→SP MLA prefill ("a2a" = paper-faithful Fig 17;
        # "rs" = beyond-paper reduce-scatter o_proj)
        os.environ["REPRO_MLA_HYBRID"] = variant.split("_")[1]
    elif variant == "seq_parallel_inputs":  # SP for encoder prefill
        key = "frames" if cfg.frontend == "audio_frames" else "tokens"
        old = b_spec[key]
        b_spec[key] = P(old[0], "model", *([None] * (len(old) - 2)))
    else:
        raise ValueError(f"unknown variant {variant!r}")

    if variant == "int8_aligned":
        int8_weights = True
    if variant == "best":
        int8_weights = True
        ep = lep_kw.get("ep_axes")
        if ep == ("model",):   # 2-level EP possible (kimi-class)
            lep_kw.update(ffn_shard_axis="data", ffn_gather="tokens",
                          quantize_gather=True)
        lep_kw.update(capacity_align=1)

    moe_fn = None
    if cfg.is_moe:
        moe_fn = make_lep_moe_fn(mesh, lep_kw.pop("ep_axes"), **lep_kw)

    if int8_weights:
        q_shapes = quantized_param_shapes(params_shape)
        q_spec = quantized_param_specs(p_spec, params_shape)
        params_shape, p_spec = q_shapes, q_spec

        def adapt(p):
            return dequantize_tree(p, jnp.dtype(cfg.dtype))
    else:
        adapt = lambda p: p

    if shape.kind == "decode":
        caches_shape = jax.eval_shape(
            lambda: model_mod.make_caches(cfg, shape.global_batch, shape.seq_len))
        c_spec = cache_pspecs(cfg, mesh, caches_shape)

        aligned = variant in ("aligned_decode", "int8_aligned", "best")

        def serve_step(params, tokens, caches, cache_len):
            p = adapt(params)
            if aligned:
                # pseudo-synchronous batching (paper §4.1): all requests at
                # one position => scalar length => dynamic-slice cache writes
                # (no per-row scatter; partitioner-friendly on sharded caches)
                cache_len = cache_len[0]

            def base(tt, c):
                return model_mod.decode_step(p, cfg, tt["t"], c, tt["len"],
                                             moe_fn)

            return microbatched(base, n_micro)(
                {"t": tokens, "len": cache_len}, caches)

        args = (params_shape, bsh["tokens"], caches_shape, bsh["cache_len"])
        in_spec = (p_spec, b_spec["tokens"], c_spec, P())
        donate = (2,) if variant in ("donate_cache", "full_opt") else ()
        return serve_step, args, in_spec, donate

    if shape.kind == "prefill":
        def step(params, batch):
            return model_mod.prefill(adapt(params), cfg, batch,
                                     capacity=shape.seq_len, moe_fn=moe_fn)
        return step, (params_shape, bsh), (p_spec, b_spec), ()

    # train
    from repro.train.loop import make_train_step
    from repro.train.optimizer import OptConfig, init_opt_state
    assert not int8_weights, "int8 weights are a serving variant"
    opt_shape = jax.eval_shape(init_opt_state, params_shape)
    o_spec = type(opt_shape)(P(), jax.tree.map(lambda s: s, p_spec),
                             jax.tree.map(lambda s: s, p_spec))
    step = make_train_step(cfg, OptConfig(), moe_fn, n_micro=n_micro)
    return step, (params_shape, opt_shape, bsh), (p_spec, o_spec, b_spec), ()


def run_variant(arch: str, shape_name: str, variant: str,
                multi_pod: bool = False, save: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "variant": variant}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        from repro.core.parallel import set_current_mesh
        set_current_mesh(mesh)
        with mesh:
            step, args, in_spec, donate = build_variant(cfg, shape, mesh, variant)
            lowered = jax.jit(step, in_shardings=to_shardings(mesh, in_spec),
                              donate_argnums=donate).lower(*args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        coll = hlo.collective_bytes(compiled.as_text())
        args_b = float(getattr(mem, "argument_size_in_bytes", 0))
        if shape.kind == "train":
            struct = train_memory_bytes(cfg, shape, args_b, mesh.size)
        else:
            struct = (getattr(mem, "temp_size_in_bytes", 0) + args_b
                      + getattr(mem, "output_size_in_bytes", 0))
        cost = {"flops": analytic_flops(cfg, shape) / mesh.size}
        rl = hlo.roofline_terms(cost, coll, mesh.size, struct_bytes=float(struct))
        rec.update(status="ok", compile_s=round(time.time() - t0, 1),
                   argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
                   temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
                   flops_per_device=rl.flops,
                   collective_bytes_per_device=rl.coll_bytes,
                   collectives=coll,
                   compute_s=rl.compute_s, memory_s=rl.memory_s,
                   memory_hlo_s=rl.memory_hlo_s,
                   collective_s=rl.collective_s, dominant=rl.dominant)
        step_t = max(rl.compute_s, rl.memory_s) + rl.collective_s
        rec["step_s"] = step_t
        print(f"[OK] {arch}×{shape_name}×{variant}: step={step_t*1e3:.1f}ms "
              f"dom={rl.dominant} cmp={rl.compute_s*1e3:.1f} "
              f"mem={rl.memory_s*1e3:.1f} coll={rl.collective_s*1e3:.1f} "
              f"args={rec['argument_bytes']/2**30:.2f}GiB")
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[ERR] {arch}×{shape_name}×{variant}: {rec['error'][:200]}")
    if save:
        os.makedirs(HC_DIR, exist_ok=True)
        with open(os.path.join(
                HC_DIR, f"{arch}__{shape_name}__{variant}.json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    run_variant(args.arch, args.shape, args.variant, args.multi_pod)


if __name__ == "__main__":
    main()
