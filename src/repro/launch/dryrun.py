import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape × mesh) combination with ShapeDtypeStruct
stand-ins — no allocation — and extract memory / cost / collective analysis
for the roofline report (deliverable g).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape decode_32k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all            # full 40-pair sweep
"""
import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import cost_analysis
from repro.configs import ASSIGNED_ARCHS, get_config, get_shape, INPUT_SHAPES
from repro.configs.base import InputShape, ModelConfig
from repro.core.lep import make_lep_moe_fn, pick_lep_plan
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_pspecs, cache_pspecs, dp_axes,
                                   param_pspecs, to_shardings)
from repro.models import model as model_mod
from repro.train.loop import make_train_step
from repro.train.optimizer import OptConfig, init_opt_state

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# ---------------------------------------------------------------------------
# Applicability / skips (DESIGN.md §3)
# ---------------------------------------------------------------------------


def skip_reason(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    if shape.kind == "decode" and not cfg.supports_decode:
        return "encoder-only: no autoregressive decode (DESIGN.md §3)"
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return "full attention at 500k: no sub-quadratic path"
    return None


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins (weak-type-correct, no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio_frames":
            batch = {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)}
        elif cfg.frontend == "vision_patches":
            p = cfg.num_prefix_embeddings
            batch = {"prefix_emb": jax.ShapeDtypeStruct((b, p, cfg.d_model), jnp.bfloat16),
                     "tokens": jax.ShapeDtypeStruct((b, s - p), i32)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if shape.kind == "train":
            # labels align with text tokens (audio: per-frame targets)
            n_lbl = batch.get("tokens", batch.get("frames")).shape[1]
            batch["labels"] = jax.ShapeDtypeStruct((b, n_lbl), i32)
        return batch
    # decode: one token per request + KV cache of seq_len
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "cache_len": jax.ShapeDtypeStruct((b,), i32)}


def _moe_fn_for(cfg: ModelConfig, mesh, serving: bool):
    if not cfg.is_moe:
        return None
    plan = pick_lep_plan(cfg, mesh, serving=serving)
    return make_lep_moe_fn(mesh, plan["ep_axes"], redundancy=plan["redundancy"],
                           ffn_shard_axis=plan["ffn_shard_axis"], quantize=True)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def build_step(cfg: ModelConfig, shape: InputShape, mesh):
    """Returns (jitted_fn, arg_shape_structs, in_shardings) for the combo."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_shape = jax.eval_shape(
        functools.partial(model_mod.init_params, cfg=cfg),
        jax.random.PRNGKey(0))
    p_spec = param_pspecs(cfg, mesh, params_shape, train=(shape.kind == "train"))
    batch_shape = input_specs(cfg, shape)

    if shape.kind == "train":
        moe_fn = _moe_fn_for(cfg, mesh, serving=False)
        opt_shape = jax.eval_shape(init_opt_state, params_shape)
        o_spec = type(opt_shape)(jax.sharding.PartitionSpec(),
                                 jax.tree.map(lambda s: s, p_spec),
                                 jax.tree.map(lambda s: s, p_spec))
        b_spec = batch_pspecs(cfg, mesh, batch_shape)
        step = make_train_step(cfg, OptConfig(), moe_fn)
        args = (params_shape, opt_shape, batch_shape)
        in_spec = (p_spec, o_spec, b_spec)
        return step, args, in_spec

    if shape.kind == "prefill":
        moe_fn = _moe_fn_for(cfg, mesh, serving=True)

        def step(params, batch):
            logits, caches = model_mod.prefill(params, cfg, batch,
                                               capacity=shape.seq_len,
                                               moe_fn=moe_fn)
            return logits, caches

        b_spec = batch_pspecs(cfg, mesh, batch_shape)
        return step, (params_shape, batch_shape), (p_spec, b_spec)

    # decode: serve_step — ONE new token against a seq_len cache
    moe_fn = _moe_fn_for(cfg, mesh, serving=True)
    caches_shape = jax.eval_shape(
        lambda: model_mod.make_caches(cfg, shape.global_batch, shape.seq_len))
    c_spec = cache_pspecs(cfg, mesh, caches_shape)
    b_spec = batch_pspecs(cfg, mesh, input_specs(cfg, shape))

    def serve_step(params, tokens, caches, cache_len):
        return model_mod.decode_step(params, cfg, tokens, caches, cache_len,
                                     moe_fn)

    args = (params_shape, input_specs(cfg, shape)["tokens"], caches_shape,
            input_specs(cfg, shape)["cache_len"])
    in_spec = (p_spec, b_spec["tokens"], c_spec, jax.sharding.PartitionSpec())
    return serve_step, args, in_spec


# ---------------------------------------------------------------------------
# Analytic compute term
#
# XLA's HloCostAnalysis counts a rolled while-loop (lax.scan over layers /
# attention chunks) body ONCE, and fully unrolling 61-layer × 64-chunk graphs
# is intractable to compile on this 1-core container. The compute term is
# therefore computed analytically from the exact architecture math (linear
# layers from active params, EXECUTED attention pairs, SSD chunk algebra) and
# the HLO-reported FLOPs are recorded as a diagnostic. Memory (structural
# bytes) and collectives (loop-aware HLO parsing with trip-count multipliers)
# come from the real compiled artifact. See EXPERIMENTS.md §Methodology.
# ---------------------------------------------------------------------------


def analytic_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Total (all-device) executed FLOPs for one step of this combo."""
    b, s = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    tokens = b if decode else b * s
    fwd_bwd = 3.0 if shape.kind == "train" else 1.0

    # Linear/matmul work: 2 FLOPs per active param per token (includes
    # attention projections, (active) experts, unembedding).
    total = 2.0 * cfg.param_count(active_only=True) * tokens

    # Attention core — EXECUTED pairs (the chunked baseline computes every
    # (q, kv) pair and masks; causal/window block-skipping is a §Perf
    # optimization, not part of the baseline).
    if cfg.num_heads > 0:
        n_attn = (cfg.num_layers // cfg.attn_every if cfg.is_hybrid
                  else cfg.num_layers)
        if decode:
            ring = bool(cfg.sliding_window) and s > cfg.sliding_window \
                and cfg.attention_kind != "mla"
            kv_len = cfg.sliding_window if ring else s
            pairs = float(b) * kv_len
        else:
            from repro.models.attention import _pick_chunk, block_skip_enabled
            if block_skip_enabled() and cfg.attention_kind != "bidirectional":
                chunk = _pick_chunk(s)
                if cfg.sliding_window and cfg.sliding_window < s:
                    pairs = float(b) * s * min(s, cfg.sliding_window + chunk)
                else:
                    pairs = float(b) * s * s / 2 * (1 + chunk / s)
            else:
                pairs = float(b) * s * s
        if cfg.attention_kind == "mla":
            if decode:  # absorbed: scores vs latent + pv in latent space
                per_pair = 2.0 * cfg.num_heads * (
                    2 * cfg.kv_lora_rank + cfg.qk_rope_head_dim)
            else:       # unabsorbed MHA form
                per_pair = 2.0 * cfg.num_heads * (
                    cfg.qk_nope_head_dim + cfg.qk_rope_head_dim + cfg.v_head_dim)
        else:
            per_pair = 4.0 * cfg.num_heads * cfg.head_dim  # qk + pv
        total += n_attn * pairs * per_pair

    # SSD (mamba2 / zamba2)
    if cfg.ssm_state > 0:
        n_ssm = cfg.num_layers if cfg.is_ssm else \
            cfg.num_layers - cfg.num_layers // cfg.attn_every
        h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        if decode:
            total += n_ssm * 6.0 * b * h * p * n
        else:
            q = min(cfg.ssm_chunk, s)
            nc = max(1, s // q)
            per_chunk = (2.0 * b * q * q * n
                         + 2.0 * b * q * q * h * p
                         + 4.0 * b * q * h * p * n)
            total += n_ssm * per_chunk * nc
    return total * fwd_bwd


def train_memory_bytes(cfg: ModelConfig, shape: InputShape, args_bytes: float,
                       n_dev: int) -> float:
    """Per-device HBM traffic model for a train step: optimizer read+write
    of params/moments/grads (~2× argument bytes) + forward-write/backward-
    read of ~12 d_model-wide activations per layer per token."""
    tok_dev = shape.global_batch * shape.seq_len / n_dev
    act = cfg.num_layers * tok_dev * cfg.d_model * 2 * 12
    return 2.0 * args_bytes + act


def _measure(cfg, shape, mesh):
    step, args, in_spec = build_step(cfg, shape, mesh)
    shardings = to_shardings(mesh, in_spec)
    lowered = jax.jit(step, in_shardings=shardings).lower(*args)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = cost_analysis(compiled)
    coll = hlo.collective_bytes(compiled.as_text())
    struct = (getattr(mem, "temp_size_in_bytes", 0)
              + getattr(mem, "argument_size_in_bytes", 0)
              + getattr(mem, "output_size_in_bytes", 0))
    return dict(mem=mem, flops=float(cost.get("flops", 0.0)),
                hbm=float(cost.get("bytes accessed", 0.0)),
                coll=coll,
                coll_total=float(sum(coll[k] for k in hlo.COLLECTIVE_OPS)),
                struct=float(struct))


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            save: bool = True, verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "mesh": mesh_name}

    reason = skip_reason(cfg, shape)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        if verbose:
            print(f"[SKIP] {arch} × {shape_name} × {mesh_name}: {reason}")
        _save(rec, save)
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.size
        with mesh:
            real = _measure(cfg, shape, mesh)
            t_compile = time.time() - t0
            t_lower = 0.0
        mem, coll = real["mem"], real["coll"]
        args_b = float(getattr(mem, "argument_size_in_bytes", 0))
        if shape.kind == "train":
            struct = train_memory_bytes(cfg, shape, args_b, n_dev)
        else:
            struct = real["struct"]
        # compute term: analytic executed FLOPs (see module comment);
        # HLO flops recorded as a diagnostic (loop bodies counted once).
        flops_dev = analytic_flops(cfg, shape) / n_dev
        cost = {"flops": flops_dev, "bytes accessed": real["hbm"]}

        n_tok = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        mf = hlo.model_flops(cfg, n_tok, shape.kind)
        rl = hlo.roofline_terms(cost, coll, n_dev, model_flops_total=mf,
                                struct_bytes=float(struct))
        rec["hlo_flops_per_device"] = real["flops"]

        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            n_devices=n_dev,
            bytes_per_device=int(getattr(mem, "temp_size_in_bytes", 0)
                                 + getattr(mem, "argument_size_in_bytes", 0)
                                 + getattr(mem, "output_size_in_bytes", 0)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
            argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
            output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
            flops_per_device=rl.flops,
            hbm_bytes_per_device=rl.hbm_bytes,
            struct_bytes_per_device=rl.struct_bytes,
            collective_bytes_per_device=rl.coll_bytes,
            collectives=coll,
            compute_s=rl.compute_s, memory_s=rl.memory_s,
            memory_hlo_s=rl.memory_hlo_s,
            collective_s=rl.collective_s, dominant=rl.dominant,
            model_flops_per_device=rl.model_flops,
            useful_ratio=rl.useful_ratio,
        )
        if verbose:
            print(f"[OK]   {arch} × {shape_name} × {mesh_name}: "
                  f"dom={rl.dominant} compute={rl.compute_s*1e3:.1f}ms "
                  f"mem={rl.memory_s*1e3:.1f}ms coll={rl.collective_s*1e3:.1f}ms "
                  f"args={rec['argument_bytes']/2**30:.2f}GiB/dev "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[ERR]  {arch} × {shape_name} × {mesh_name}: {rec['error']}")
    _save(rec, save)
    return rec


def _save(rec: Dict[str, Any], save: bool) -> None:
    if not save:
        return
    os.makedirs(OUT_DIR, exist_ok=True)
    fn = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(OUT_DIR, fn), "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-paper-arch", action="store_true",
                    help="also run deepseek-r1 (the paper's own model)")
    args = ap.parse_args()

    if args.all:
        archs = list(ASSIGNED_ARCHS)
        if args.include_paper_arch:
            archs.append("deepseek-r1")
        for arch in archs:
            for shape in INPUT_SHAPES:
                run_one(arch, shape, multi_pod=args.multi_pod)
        return
    assert args.arch and args.shape, "--arch and --shape (or --all)"
    run_one(args.arch, args.shape, multi_pod=args.multi_pod)


if __name__ == "__main__":
    main()
