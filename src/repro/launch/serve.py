"""Serving launcher: the full PDC pipeline on a batch of synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
      --n-requests 6 --prompt-len 24 --max-new 8 \
      [--mtp [--mtp-fused] [--fit-draft]] [--no-cache] \
      [--hit-aware-admission] \
      [--policy least_loaded|round_robin|queue_depth] \
      [--decode-engines 2 --decode-router least_loaded_slots|round_robin|\
       cache_affinity [--rebalance-every 4]] \
      [--autoscale --min-engines 1 --max-engines 4] \
      [--prefill-engines 2 [--stream-handoff [--stream-chunk 8]]] \
      [--joint-autoscale --min-prefill 1 --max-prefill 4 \
       --ttft-budget-ms 5] \
      [--tpot-budget-ms 15 --admission queue|shed] [--interleave] \
      [--batch-tpot-budget-ms 45 --batch-admission queue|shed \
       --interactive-frac 0.7 [--preempt-batch] [--brownout]] \
      [--decode-chunk 4 [--continuous-batching]] [--prefill-chunk 32] \
      [--poisson-rate 100 [--open-loop]] \
      [--production [--arrival-shape poisson|burst|diurnal]] \
      [--seed 0] [--trace] \
      [--fault-plan random|@plan.json|'[{...}]' [--fault-seed 0] \
       [--degrade-shed-queue-s 0.05]]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core import init_mtp_params
from repro.mempool import EMSService, MemoryPool
from repro.models import init_params
from repro.serving import Request, ServingSystem
from repro.serving.faults import FaultInjector, FaultPlan
from repro.serving.pool import DECODE_ROUTERS
from repro.serving.scheduler import ROUTERS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--n-requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--shared-prefix", type=int, default=16,
                    help="tokens shared across prompts (context-cache reuse)")
    ap.add_argument("--mtp", action="store_true")
    ap.add_argument("--mtp-fused", action="store_true",
                    help="verify base+draft in one fused two-token forward "
                         "(one weight stream per MTP iteration)")
    ap.add_argument("--fit-draft", action="store_true",
                    help="distill the draft head on the model's own greedy "
                         "continuations before serving (realistic MTP "
                         "acceptance at smoke scale)")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--hit-aware-admission", action="store_true",
                    help="admission gate charges only the uncached suffix "
                         "of a request (EMS match_prefix probe at enqueue) "
                         "instead of a full slot")
    ap.add_argument("--decode-batch", type=int, default=4)
    ap.add_argument("--policy", default="least_loaded",
                    choices=sorted(ROUTERS),
                    help="prefill routing policy")
    ap.add_argument("--decode-engines", type=int, default=1,
                    help="decode pool size (independent engines behind a "
                         "routing policy, each with its own slot manager)")
    ap.add_argument("--decode-router", default="least_loaded_slots",
                    choices=sorted(DECODE_ROUTERS),
                    help="decode-pool routing policy (cache_affinity "
                         "prefers the engine holding the request's EMS "
                         "prefix blocks)")
    ap.add_argument("--rebalance-every", type=int, default=0,
                    help="every N decode turns, migrate one request's KV "
                         "from the hottest pool engine to the coldest "
                         "(0 = off)")
    ap.add_argument("--autoscale", action="store_true",
                    help="grow/shrink the decode pool between decode turns "
                         "(deterministic SLO-driven controller; "
                         "--decode-engines is the initial size)")
    ap.add_argument("--min-engines", type=int, default=1,
                    help="autoscaler lower clamp on live decode engines")
    ap.add_argument("--max-engines", type=int, default=4,
                    help="autoscaler upper clamp on live decode engines")
    ap.add_argument("--prefill-engines", type=int, default=2,
                    help="prefill pool size (spawn/park/retire lifecycle "
                         "mirrors the decode pool)")
    ap.add_argument("--joint-autoscale", action="store_true",
                    help="shift engine capacity between the prefill and "
                         "decode roles under one SLO budget (TTFT pressure "
                         "grows prefill, TPOT pressure grows decode)")
    ap.add_argument("--min-prefill", type=int, default=1,
                    help="joint-autoscale lower clamp on live prefill "
                         "instances")
    ap.add_argument("--max-prefill", type=int, default=4,
                    help="joint-autoscale upper clamp on live prefill "
                         "instances")
    ap.add_argument("--ttft-budget-ms", type=float, default=None,
                    help="TTFT SLO budget (virtual ms) driving the joint "
                         "autoscaler's prefill-pressure signal")
    ap.add_argument("--stream-handoff", action="store_true",
                    help="pipelined chunked KV handoff: stream each chunk's "
                         "KV over the RDMA plane while the next chunk "
                         "computes (TTFT charges max(prefill, transfer) + "
                         "the last chunk's wire time; token-identical to "
                         "the synchronous handoff)")
    ap.add_argument("--stream-chunk", type=int, default=None,
                    help="tokens per streamed KV chunk (default 8)")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for the synthetic request stream "
                         "(identical seed => identical trace)")
    ap.add_argument("--tpot-budget-ms", type=float, default=None,
                    help="TPOT SLO budget for the admission gate (virtual "
                         "ms); with SLO classes this is the interactive "
                         "tier's budget")
    ap.add_argument("--admission", default="queue", choices=("queue", "shed"),
                    help="hold or reject prefills that would break the SLO")
    ap.add_argument("--batch-tpot-budget-ms", type=float, default=None,
                    help="relaxed TPOT budget for the batch SLO tier "
                         "(default: share --tpot-budget-ms)")
    ap.add_argument("--batch-admission", default=None,
                    choices=("queue", "shed"),
                    help="admission mode for the batch tier "
                         "(default: share --admission)")
    ap.add_argument("--interactive-frac", type=float, default=1.0,
                    help="fraction of generated requests stamped "
                         "interactive; the rest are batch tier")
    ap.add_argument("--preempt-batch", action="store_true",
                    help="evict the youngest batch-tier decode slot when a "
                         "gate-ready interactive request would otherwise "
                         "wait (replay re-admission, token-identical)")
    ap.add_argument("--brownout", action="store_true",
                    help="climb the deterministic overload ladder under "
                         "sustained interactive pressure: shed batch "
                         "admissions -> preempt batch -> queue-age-shed "
                         "batch -> shed interactive")
    ap.add_argument("--arrival-shape", default="poisson",
                    choices=("poisson", "burst", "diurnal"),
                    help="arrival process for --production streams")
    ap.add_argument("--production", action="store_true",
                    help="production workload suite: heavy-tailed "
                         "prompt/output lengths + --interactive-frac class "
                         "mix under --arrival-shape (requires "
                         "--poisson-rate; --prompt-len/--max-new become "
                         "the length medians)")
    ap.add_argument("--interleave", action="store_true",
                    help="pair two decode microbatches per step (§4.2.3)")
    ap.add_argument("--decode-chunk", type=int, default=1,
                    help="decode iterations per host sync (scanned "
                         "device-resident decode fast path; with --mtp each "
                         "iteration speculates, so up to 2x tokens)")
    ap.add_argument("--continuous-batching", action="store_true",
                    help="adaptive scan widths + mid-scan slot refill on "
                         "the chunked fast path: shrink the next chunk to "
                         "where a finish or gate-held admission lands, and "
                         "refill freed slots between engine chunks (see "
                         "dead_slot_rate / mid_scan_refills in the summary)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="run fresh prompts through chunked prefill_continue "
                         "calls of this width (bounded compile shapes)")
    ap.add_argument("--poisson-rate", type=float, default=None,
                    help="generate Poisson arrivals at this rate (virtual "
                         "req/s) and serve open-loop")
    ap.add_argument("--open-loop", action="store_true",
                    help="arrival-time-driven serving on the virtual clock "
                         "(implied by --poisson-rate)")
    ap.add_argument("--trace", action="store_true",
                    help="dump the structured per-request trace as JSON")
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic fault schedule: 'random' (seeded by "
                         "--fault-seed), '@path/to/plan.json', or inline "
                         "JSON (a list of fault events or {'events': [...]})")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for --fault-plan random and for the "
                         "injector's derived streams")
    ap.add_argument("--degrade-shed-queue-s", type=float, default=None,
                    help="graceful degradation: shed any queued admission "
                         "held longer than this many virtual seconds "
                         "(bounds the backlog when capacity is lost)")
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    cc = None
    if not args.no_cache:
        pool = MemoryPool(n_nodes=8)
        cc = EMSService(pool, block_tokens=8, model_tag=cfg.name)
    mtp_params = init_mtp_params(jax.random.PRNGKey(1), cfg) if args.mtp else None

    rng = np.random.RandomState(args.seed)
    shared = min(args.shared_prefix, args.prompt_len - 1)
    open_loop = args.open_loop or args.poisson_rate is not None
    if args.production:
        if args.poisson_rate is None:
            ap.error("--production requires --poisson-rate")
        from repro.serving import production_requests
        reqs = production_requests(
            args.n_requests, seed=args.seed, vocab_size=cfg.vocab_size,
            rate_rps=args.poisson_rate, arrival_shape=args.arrival_shape,
            prompt_len_median=args.prompt_len, max_new_median=args.max_new,
            interactive_frac=args.interactive_frac)
    elif args.poisson_rate is not None:
        from repro.serving import poisson_requests
        reqs = poisson_requests(args.n_requests, args.poisson_rate,
                                args.prompt_len, args.max_new,
                                cfg.vocab_size, seed=args.seed,
                                shared_prefix=shared)
        for r in reqs:
            if rng.uniform() >= args.interactive_frac:
                r.slo_class = "batch"
    else:
        prefix = list(rng.randint(0, cfg.vocab_size, shared))
        reqs = [Request(i, prefix + list(rng.randint(0, cfg.vocab_size,
                                                     args.prompt_len - shared)),
                        args.max_new,
                        slo_class="interactive"
                        if rng.uniform() < args.interactive_frac
                        else "batch") for i in range(args.n_requests)]

    if args.mtp and args.fit_draft:
        # Distill on the prompts actually served: a random base model's
        # successor map is context-specific, so this is the only
        # distribution the head can meaningfully accept on (the trained-MTP
        # analogue of matching train and serve distributions).
        from repro.core import fit_draft_head
        mtp_params = fit_draft_head(
            params, cfg, mtp_params, jax.random.PRNGKey(2),
            prompts=np.asarray([r.prompt for r in reqs], np.int32),
            gen_len=max(16, 2 * args.max_new))

    injector = None
    if args.fault_plan is not None:
        # Horizon estimate for the seeded random plan: enough virtual time
        # that a mid-decode crash lands while requests are still in flight.
        horizon = max(0.05, args.n_requests * args.max_new * 1.5e-3
                      / max(1, args.decode_engines))
        plan = FaultPlan.load(args.fault_plan, seed=args.fault_seed,
                              n_engines=args.decode_engines,
                              horizon_s=horizon)
        injector = FaultInjector(plan, seed=args.fault_seed)
        print(f"fault plan ({len(plan.events)} events): {plan.to_json()}")

    # Production streams draw heavy-tailed lengths up to the generator's
    # clip (256 prompt + 64 output tokens by default): size the KV slots
    # for the clip, not the medians, so long-tail requests are not all
    # capacity-rejected.
    capacity = 256 + 64 + 8 if args.production \
        else args.prompt_len + args.max_new + 8
    system = ServingSystem(params, cfg,
                           prefill_engines=args.prefill_engines,
                           decode_batch=args.decode_batch,
                           capacity=capacity,
                           decode_engines=args.decode_engines,
                           decode_router=args.decode_router,
                           decode_rebalance_every=args.rebalance_every,
                           autoscale=args.autoscale or None,
                           min_engines=args.min_engines
                           if args.autoscale or args.joint_autoscale
                           else None,
                           max_engines=args.max_engines
                           if args.autoscale or args.joint_autoscale
                           else None,
                           joint_autoscale=args.joint_autoscale or None,
                           min_prefill=args.min_prefill
                           if args.joint_autoscale else None,
                           max_prefill=args.max_prefill
                           if args.joint_autoscale else None,
                           ttft_budget_ms=args.ttft_budget_ms,
                           stream_handoff=args.stream_handoff or None,
                           stream_chunk=args.stream_chunk,
                           context_cache=cc, use_mtp=args.mtp,
                           mtp_params=mtp_params, mtp_fused=args.mtp_fused,
                           policy=args.policy,
                           tpot_budget_ms=args.tpot_budget_ms,
                           admission=args.admission,
                           batch_tpot_budget_ms=args.batch_tpot_budget_ms,
                           batch_admission=args.batch_admission,
                           preempt_batch=args.preempt_batch or None,
                           brownout=args.brownout or None,
                           interleave=args.interleave,
                           decode_chunk=args.decode_chunk,
                           continuous_batching=args.continuous_batching
                           or None,
                           prefill_chunk=args.prefill_chunk,
                           degrade_shed_queue_s=args.degrade_shed_queue_s,
                           hit_aware_admission=args.hit_aware_admission
                           or None,
                           fault_injector=injector)
    t0 = time.time()
    results = system.serve(reqs, open_loop=open_loop)
    dt = time.time() - t0
    total_new = sum(len(r.tokens) for r in results if not r.shed)
    for r in sorted(results, key=lambda r: r.rid):
        flag = " SHED" if r.shed else ""
        print(f"rid={r.rid} prefill@{r.prefill_instance} reused={r.reused_tokens} "
              f"computed={r.computed_tokens} iters={r.decode_iters} "
              f"tokens={r.tokens}{flag}")
    print(f"\n{len(results)} requests, {total_new} tokens in {dt:.2f}s wall "
          f"({total_new/dt:.1f} tok/s on CPU smoke config)")
    summary = system.scheduler.summary()
    classes = summary.pop("classes", None)
    brownout_timeline = summary.pop("brownout_timeline", None)
    print("SLO summary (virtual clock): "
          + ", ".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                      for k, v in summary.items()))
    if classes:
        for cls, cs in sorted(classes.items()):
            print(f"  class {cls}: " + ", ".join(
                f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in cs.items()))
    if args.preempt_batch or args.brownout or summary.get("preemptions"):
        print(f"preemptions: {summary.get('preemptions', 0)} "
              f"(tokens replayed "
              f"{summary.get('preempt_tokens_replayed', 0)})")
    if args.brownout:
        print("brownout: level "
              + (" -> ".join(f"{to}@{t*1e3:.1f}ms"
                             for t, _frm, to in brownout_timeline)
                 if brownout_timeline else "0 throughout")
              + f" (now {summary.get('brownout_level', 0)}, peak "
              f"{summary.get('brownout_peak_level', 0)})")
    if args.decode_engines > 1 or system.pool.n > 1:
        util = summary.get("engine_util", [])
        print("decode pool: " + ", ".join(
            f"engine{st['engine']} active={st['active']} "
            f"iters={st['iters']} util={util[st['engine']] if util else 0}"
            + ("" if st["live"] else
               " (dead)" if st.get("dead") else " (parked)")
            for st in system.pool.engine_stats()))
        print(f"migrations: {system.pool.migrations} "
              f"({system.pool.migrated_bytes/2**20:.2f} MiB over RDMA plane)")
    if args.autoscale:
        sched = system.scheduler
        print("autoscale: "
              + (" -> ".join(f"{n}@{t*1e3:.1f}ms" for t, n
                             in sched.engine_count_timeline)
                 if sched.scale_events else "no scale events")
              + f" ({len(sched.scale_events)} events, live engines "
              f"{system.pool.n_live}/{system.pool.n})")
    if args.joint_autoscale:
        sched = system.scheduler
        shifts = [e for e in sched.scale_events
                  if e["action"].startswith("shift_")]
        print("joint autoscale: "
              + (" -> ".join(f"P{e['prefill_live']}/D{e['engines_live']}"
                             f"@{e['t']*1e3:.1f}ms ({e['action']})"
                             for e in shifts)
                 if shifts else "no shift events")
              + f" (prefill live {system.prefill_pool.n_live}"
              f"/{system.prefill_pool.n}, decode live "
              f"{system.pool.n_live}/{system.pool.n})")
    if args.stream_handoff:
        print(f"streamed handoff: {summary.get('stream_requests', 0)} "
              f"requests in {summary.get('stream_chunks', 0)} chunks, "
              f"{summary.get('stream_overlap_s', 0.0)*1e3:.2f} ms of "
              "transfer hidden behind prefill, max "
              f"{summary.get('stream_max_chunk_bytes', 0)/2**10:.1f} KiB "
              "in flight per chunk")
    if args.prefill_chunk:
        calls = sum(e.continue_calls for e in system.prefills)
        widths = set().union(*(e.continue_widths for e in system.prefills))
        print(f"chunked prefill: {calls} dispatches over {len(widths)} "
              f"compiled widths {sorted(widths)}")
    if cc is not None:
        print("pool:", cc.pool.stats())
        ems = cc.ems_stats()
        print("ems: "
              f"hit_rate={ems['hit_rate']:.3f} "
              f"(hbm {ems['hbm_hits']} / pool {ems['pool_hits']} / "
              f"miss {ems['fetch_misses']}), "
              f"promoted {ems['promote_bytes']/2**20:.2f} MiB, "
              f"demoted {ems['demote_bytes']/2**20:.2f} MiB, "
              f"dedup_skipped={ems['dedup_skipped']} "
              f"evictions={ems['hbm_evictions']}")
    print("transfer:", system.transfer.transfers, "handoffs,",
          f"{system.transfer.bytes_moved/2**20:.1f} MiB over RDMA plane")
    if injector is not None:
        xfer = system.transfer
        print("faults: "
              + ", ".join(f"{k}={v}" for k, v in injector.summary().items())
              + f"; recoveries={summary.get('recoveries', 0)} "
              f"tokens_replayed={summary.get('tokens_replayed', 0)} "
              f"retries={xfer.retries} timeouts={xfer.timeouts} "
              f"corruptions={xfer.corruptions}")
    if args.trace:
        print(json.dumps(system.scheduler.trace_records(), indent=1))


if __name__ == "__main__":
    main()
