"""Training launcher.

CPU (this container): reduced smoke-scale runs. TPU: the same step is pjit'ed
over make_production_mesh() with the sharding rules in sharding.py; enable
``--xla_tpu_enable_latency_hiding_scheduler=true`` for the microbatch overlap
(core/microbatch.py).

  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --steps 50 \
      --batch 8 --seq 64 [--smoke/--full] [--n-micro 2]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.data import make_batch_iter
from repro.models import init_params
from repro.train import OptConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (TPU only)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = smoke_variant(cfg)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.param_count(True)/1e6:.1f}M active)")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batches = make_batch_iter(cfg.vocab_size, args.seq, args.batch)
    opt = OptConfig(lr=args.lr, total_steps=args.steps,
                    warmup_steps=max(1, args.steps // 10))
    params, history = train(params, cfg, batches, args.steps, opt,
                            n_micro=args.n_micro)
    if args.ckpt:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.ckpt, params, args.steps,
                        meta={"arch": cfg.name})
        print(f"checkpoint saved to {args.ckpt}")


if __name__ == "__main__":
    main()
