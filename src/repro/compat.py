"""Version-compat shims over the jax API surface the repo depends on.

The repo targets the modern jax API (``jax.shard_map`` with ``check_vma``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``) but must
also run on jax 0.4.x where those names live under ``jax.experimental`` or do
not exist yet. Every version-sensitive import goes through this module so the
rest of ``src/`` stays on one idiom.

Exports
-------
``shard_map``   — new-style signature (accepts ``check_vma``; translated to
                  the legacy ``check_rep`` kwarg when running on old jax).
``AxisType``    — ``jax.sharding.AxisType`` or a stand-in enum on old jax
                  (old jax meshes are implicitly Auto, so the value is only
                  ever consumed by :func:`make_mesh`, which drops it there).
``make_mesh``   — ``jax.make_mesh`` that tolerates the ``axis_types`` kwarg
                  on versions whose signature predates it.
``TPUCompilerParams`` — ``pallas.tpu.CompilerParams`` (modern name) or the
                  legacy ``pallas.tpu.TPUCompilerParams``.
"""
from __future__ import annotations

import enum
import inspect
from typing import Any

import jax

# --------------------------------------------------------------------------
# shard_map: jax>=0.6 exposes jax.shard_map(check_vma=...); 0.4.x has
# jax.experimental.shard_map.shard_map(check_rep=...).
# --------------------------------------------------------------------------

try:
    from jax import shard_map as _shard_map          # modern jax
except ImportError:                                  # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    """``jax.shard_map`` with the modern keyword surface on any jax version.

    ``check_vma`` (new name) and ``check_rep`` (legacy name) are accepted
    interchangeably and translated to whatever the underlying jax expects;
    kwargs the installed version does not know are dropped rather than
    raising, so call sites can stay on the modern idiom.
    """
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    if "check_rep" in kwargs and "check_rep" not in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    kwargs = {k: v for k, v in kwargs.items() if k in _SHARD_MAP_PARAMS}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


# --------------------------------------------------------------------------
# AxisType / make_mesh: jax.sharding.AxisType + the axis_types kwarg landed
# after 0.4.37. Old meshes are implicitly Auto, so dropping the kwarg there
# preserves semantics for every use in this repo (which only ever passes
# AxisType.Auto).
# --------------------------------------------------------------------------

try:
    from jax.sharding import AxisType                # modern jax
except ImportError:                                  # pragma: no cover - version dependent
    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


_jax_make_mesh = getattr(jax, "make_mesh", None)
_MAKE_MESH_PARAMS = (frozenset(inspect.signature(_jax_make_mesh).parameters)
                     if _jax_make_mesh is not None else frozenset())


def make_mesh(axis_shapes, axis_names, *, axis_types: Any = None, **kwargs):
    """``jax.make_mesh`` accepting ``axis_types`` on every jax version."""
    if _jax_make_mesh is None:      # pre-0.4.35: build the Mesh directly
        import math

        import numpy as np
        from jax.sharding import Mesh

        n = math.prod(axis_shapes)
        devices = np.asarray(jax.devices()[:n]).reshape(axis_shapes)
        return Mesh(devices, axis_names)
    if axis_types is not None and "axis_types" in _MAKE_MESH_PARAMS:
        kwargs["axis_types"] = axis_types
    return _jax_make_mesh(axis_shapes, axis_names, **kwargs)


# --------------------------------------------------------------------------
# Pallas TPU compiler params: renamed TPUCompilerParams -> CompilerParams.
# Call sites in kernels/ only pass ``dimension_semantics``, which both names
# accept. Guarded so compat consumers that never touch Pallas (mesh, LEP)
# stay importable on jax builds without pallas.tpu; the kernel packages
# import pallas themselves and fail on their own terms there.
# --------------------------------------------------------------------------

try:
    from jax.experimental.pallas import tpu as _pltpu

    TPUCompilerParams = getattr(_pltpu, "CompilerParams", None) \
        or _pltpu.TPUCompilerParams
except ImportError:                                  # pragma: no cover - version dependent
    TPUCompilerParams = None


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every jax version
    (0.4.x returned a one-element list of per-computation dicts)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
