"""Model-level equivalence tests: the paper's optimized execution forms must
match their naive counterparts exactly."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, smoke
from repro.models import decode_step, forward, init_params, prefill
from repro.models import mla as mla_mod
from repro.models.mamba2 import ssd_chunked, ssd_reference


def test_mla_absorbed_equals_naive():
    """Absorbed decode (compressed-latent attention) == unabsorbed MHA form
    at the final position — the weight-absorption identity of §4.2.2."""
    cfg = smoke("deepseek-r1")
    p1 = mla_mod.init_mla_params(jax.random.PRNGKey(0), cfg, 1, jnp.float32)
    p = jax.tree.map(lambda a: a[0], p1)
    b, s = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    full_out, latent = mla_mod.mla_prefill(p, x, cfg)
    # decode the last token against the cache of the first s-1
    cache = jnp.zeros((b, s + 4, latent.shape[-1]))
    cache = cache.at[:, : s - 1].set(latent[:, : s - 1])
    out_dec, _ = mla_mod.mla_decode(p, x[:, s - 1:], cache,
                                    jnp.int32(s - 1), cfg)
    np.testing.assert_allclose(np.asarray(out_dec[:, 0]),
                               np.asarray(full_out[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_equals_reference():
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    b, s, h, p, n = 2, 96, 3, 8, 16
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.random.uniform(ks[1], (b, s, h), minval=0.01, maxval=0.2)
    alog = jax.random.normal(ks[2], (h,)) * 0.2
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    for chunk in (8, 32, 96):
        y1, h1 = ssd_chunked(x, dt, alog, bm, cm, chunk)
        y2, h2 = ssd_reference(x, dt, alog, bm, cm)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ["qwen3-8b", "olmoe-1b-7b", "mamba2-780m",
                                  "zamba2-1.2b", "deepseek-r1"])
def test_decode_continuation_matches_forward(arch):
    """prefill(s tokens) + n decode_steps == forward(s+n tokens) logits."""
    # generous expert capacity: token drops depend on total token count and
    # would (legitimately) differ between prefill and full forward.
    cfg = dataclasses.replace(smoke(arch), capacity_factor=16.0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s, n = 2, 16, 4
    batch = make_batch(cfg, b, s + n)
    toks = batch["tokens"]
    logits_full, _ = forward(params, cfg, {"tokens": toks})
    pl, caches = prefill(params, cfg, {"tokens": toks[:, :s]},
                         capacity=s + n + 4, cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(pl), np.asarray(logits_full[:, :s]),
                               rtol=5e-3, atol=5e-3)
    cl = jnp.int32(s)
    for i in range(n):
        dl, caches = decode_step(params, cfg, toks[:, s + i: s + i + 1],
                                 caches, cl)
        np.testing.assert_allclose(
            np.asarray(dl), np.asarray(logits_full[:, s + i]),
            rtol=5e-3, atol=5e-3,
            err_msg=f"{arch}: decode step {i} diverges from forward")
        cl = cl + 1


def test_sliding_window_ring_decode():
    """Ring-buffer decode (window < sequence) matches windowed full forward."""
    cfg = dataclasses.replace(smoke("granite-3-2b"), sliding_window=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, total = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, total), 0,
                              cfg.vocab_size)
    logits_full, _ = forward(params, cfg, {"tokens": toks})
    # prefill 16 (> window 8) then decode the rest through the ring cache
    s = 16
    _, caches = prefill(params, cfg, {"tokens": toks[:, :s]},
                        capacity=total, cache_dtype=jnp.float32)
    cl = jnp.int32(s)
    for i in range(total - s - 1):
        dl, caches = decode_step(params, cfg, toks[:, s + i: s + i + 1],
                                 caches, cl)
        np.testing.assert_allclose(
            np.asarray(dl), np.asarray(logits_full[:, s + i]),
            rtol=5e-3, atol=5e-3, err_msg=f"ring decode step {i}")
        cl = cl + 1


def test_vector_cache_len_equivalence():
    """Per-request (B,) cache_len gives identical results to scalar when all
    requests are aligned (the MTP-aware masking path, §4.2.2-(3))."""
    cfg = smoke("qwen3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 3, 12
    batch = make_batch(cfg, b, s)
    _, caches1 = prefill(params, cfg, {"tokens": batch["tokens"]},
                         capacity=s + 4, cache_dtype=jnp.float32)
    caches2 = jax.tree.map(lambda x: x, caches1)
    tok = jnp.ones((b, 1), jnp.int32)
    d1, _ = decode_step(params, cfg, tok, caches1, jnp.int32(s))
    d2, _ = decode_step(params, cfg, tok, caches2,
                        jnp.full((b,), s, jnp.int32))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-5)
