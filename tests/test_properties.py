"""Property-based tests (hypothesis) on system invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_config, smoke_variant
from repro.mempool.pool import MemoryPool, MPController
from repro.models import moe as moe_mod
from repro.serving.transfer import connection_map, transfer_balance

SET = settings(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# MoE dispatch/combine invariants
# ---------------------------------------------------------------------------


@SET
@given(t=st.integers(4, 48), e=st.sampled_from([4, 8, 16]),
       k=st.integers(1, 4), seed=st.integers(0, 100))
def test_dispatch_indices_conservation(t, e, k, seed):
    """Every (token, expert) assignment gets a unique in-capacity slot when
    capacity is sufficient; no slot collisions (paper Eq. 1-2 buffers)."""
    key = jax.random.PRNGKey(seed)
    top_i = jax.random.randint(key, (t, k), 0, e)
    cap = t * k  # generous: nothing dropped
    slot, valid = moe_mod.dispatch_indices(top_i, e, cap)
    assert bool(jnp.all(valid))
    pairs = set()
    ti, si = np.asarray(top_i).reshape(-1), np.asarray(slot).reshape(-1)
    for eid, s in zip(ti, si):
        assert (eid, s) not in pairs, "slot collision"
        pairs.add((eid, s))
    # slots are dense per expert: 0..count-1
    for eid in range(e):
        slots = sorted(s for x, s in pairs if x == eid)
        assert slots == list(range(len(slots)))


@SET
@given(t=st.integers(4, 32), seed=st.integers(0, 50))
def test_moe_capacity_matches_reference(t, seed):
    """Static-buffer gather/scatter == dense all-experts oracle when nothing
    is dropped (token conservation through dispatch+combine)."""
    cfg = dataclasses.replace(smoke_variant(get_config("olmoe-1b-7b")),
                              capacity_factor=16.0)
    p1 = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg, 1, jnp.float32)
    p = jax.tree.map(lambda a: a[0], p1)
    x = jax.random.normal(jax.random.PRNGKey(seed), (t, cfg.d_model))
    ref, _ = moe_mod.moe_reference(p, x, cfg)
    out, aux = moe_mod.moe_capacity(p, x, cfg)
    assert int(aux["dropped"]) == 0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@SET
@given(seed=st.integers(0, 50))
def test_router_renormalized(seed):
    cfg = smoke_variant(get_config("olmoe-1b-7b"))
    w = jax.random.normal(jax.random.PRNGKey(seed),
                          (cfg.d_model, cfg.num_experts))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, cfg.d_model))
    top_i, top_p, aux = moe_mod.route(w, x, cfg)
    np.testing.assert_allclose(np.asarray(jnp.sum(top_p, -1)), 1.0, rtol=1e-5)
    assert float(aux) >= 1.0 - 1e-5  # load-balance loss lower bound E·Σf·P ≥ 1


# ---------------------------------------------------------------------------
# Quantization invariants
# ---------------------------------------------------------------------------


@SET
@given(t=st.integers(1, 32), d=st.sampled_from([16, 64, 256]),
       scale=st.floats(0.01, 100.0), seed=st.integers(0, 50))
def test_per_token_quant_error_bound(t, d, scale, seed):
    from repro.quant import quantize_act_per_token
    x = jax.random.normal(jax.random.PRNGKey(seed), (t, d)) * scale
    q, s = quantize_act_per_token(x)
    deq = np.asarray(q, np.float32) * np.asarray(s)
    err = np.abs(deq - np.asarray(x))
    assert (err <= np.asarray(s) * 0.5 + 1e-6).all()
    assert (np.abs(np.asarray(q)) <= 127).all()


@SET
@given(seed=st.integers(0, 30))
def test_equalization_preserves_function(seed):
    """x/s @ (s·w) == x @ w exactly (the structural transformation is
    function-preserving before quantization, §4.5)."""
    from repro.quant import equalization_scales
    w = jax.random.normal(jax.random.PRNGKey(seed), (32, 16))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, 32))
    s = equalization_scales(w, x)
    ref = x @ w
    out = (x / s[None, :]) @ (w * s[:, None])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Disaggregated pool invariants
# ---------------------------------------------------------------------------


@SET
@given(n_keys=st.integers(50, 300), seed=st.integers(0, 20))
def test_consistent_hash_stability_and_spread(n_keys, seed):
    ctrl = MPController(n_nodes=8)
    rng = np.random.RandomState(seed)
    keys = [f"key{rng.randint(1 << 30)}:{i}" for i in range(n_keys)]
    locs = [ctrl.locate(k) for k in keys]
    # stability: same key -> same node
    assert locs == [ctrl.locate(k) for k in keys]
    # spread: no node owns everything
    counts = np.bincount(locs, minlength=8)
    assert counts.max() < n_keys  # not degenerate
    assert (counts > 0).sum() >= 4  # most nodes participate


@SET
@given(seed=st.integers(0, 20))
def test_pool_put_get_roundtrip(seed):
    pool = MemoryPool(n_nodes=4)
    rng = np.random.RandomState(seed)
    blobs = {f"k{i}": rng.randn(rng.randint(1, 64)).astype(np.float32)
             for i in range(20)}
    for k, v in blobs.items():
        assert pool.put(k, v)
    for k, v in blobs.items():
        got = pool.get(k)
        np.testing.assert_array_equal(got, v)


def test_pool_lru_eviction_and_ssd_recovery():
    pool = MemoryPool(n_nodes=1, dram_per_node=8 * 2 * 1024 * 1024)
    vals = {f"k{i}": np.full(1024, i, np.float32) for i in range(32)}
    for k, v in vals.items():
        pool.put(k, v)
    srv = pool.servers[0]
    assert srv.evictions > 0, "LRU eviction should have triggered"
    # evicted keys recover from the SSD tier
    for k, v in vals.items():
        np.testing.assert_array_equal(pool.get(k), v)
    assert srv.recoveries > 0


# ---------------------------------------------------------------------------
# Connection-mapping balance (paper §4.3.3)
# ---------------------------------------------------------------------------


@SET
@given(prefill_tp=st.sampled_from([8, 16, 32]),
       decode_tp=st.sampled_from([1, 2, 4]),
       dp_mult=st.integers(1, 8))
def test_connection_map_balanced(prefill_tp, decode_tp, dp_mult):
    ratio = prefill_tp // decode_tp
    decode_dp = ratio * dp_mult
    mapping = connection_map(prefill_tp, decode_tp, decode_dp)
    bal = transfer_balance(mapping, prefill_tp)
    assert bal >= 0.5, f"unbalanced transfer topology: {bal}"


# ---------------------------------------------------------------------------
# Context-cache prefix invariants
# ---------------------------------------------------------------------------


@SET
@given(seed=st.integers(0, 30), plen=st.integers(8, 64))
def test_context_cache_prefix_semantics(seed, plen):
    from repro.mempool import ContextCache
    rng = np.random.RandomState(seed)
    pool = MemoryPool(n_nodes=4)
    cc = ContextCache(pool, block_tokens=8)
    tokens = list(rng.randint(0, 1000, plen))
    n_blocks = plen // 8
    payloads = [np.float32(rng.randn(4)) * 0 + i for i in range(n_blocks)]
    cc.store(tokens, payloads)
    # exact prefix matches all stored blocks
    reuse, keys = cc.match_prefix(tokens)
    assert reuse == n_blocks * 8
    # diverging first token matches nothing
    div = [tokens[0] + 1] + tokens[1:]
    reuse2, _ = cc.match_prefix(div)
    assert reuse2 == 0
    # diverging after the first block matches exactly one block
    if n_blocks >= 2:
        div2 = tokens[:8] + [tokens[8] + 1] + tokens[9:]
        reuse3, _ = cc.match_prefix(div2)
        assert reuse3 == 8
    # storing again is a pure dedup no-op
    before = cc.stored_blocks
    cc.store(tokens, payloads)
    assert cc.stored_blocks == before


# ---------------------------------------------------------------------------
# Sampling invariants (CPU-free in-graph sampling, §4.2.4)
# ---------------------------------------------------------------------------


@SET
@given(seed=st.integers(0, 40))
def test_top_p_support(seed):
    from repro.core.mtp import sample_top_p
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (4, 64)) * 3
    tok = sample_top_p(jax.random.PRNGKey(seed + 1), logits,
                       temperature=0.6, top_p=0.9)
    # sampled tokens must lie in the top-p nucleus
    probs = jax.nn.softmax(logits / 0.6, axis=-1)
    for b in range(4):
        order = np.argsort(-np.asarray(probs[b]))
        cum = np.cumsum(np.asarray(probs[b])[order])
        nucleus_size = int((cum < 0.9).sum()) + 1
        nucleus = set(order[:nucleus_size].tolist())
        assert int(tok[b]) in nucleus
