"""Property-style invariants for the SLO-aware PDC scheduler subsystem.

Covers the pure control-plane pieces (routers, slot manager, admission gate,
cost model) without jax, then the end-to-end SLO behaviour of the live
ServingSystem on the virtual clock: no double slot assignment, cache_len
bounded by capacity, router determinism on a fixed stream, and the admission
gate never letting a recorded trace violate the configured TPOT budget.
"""
import jax
import numpy as np
import pytest

from conftest import smoke
from repro.models import init_params
from repro.serving import Request, ServingSystem
from repro.serving.scheduler import (
    ROUTERS,
    AdmissionGate,
    DecodeCostModel,
    DecodeSlotManager,
    SlotError,
    make_router,
)


@pytest.fixture(scope="module")
def granite():
    cfg = smoke("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def stream_requests(n, prompt_len=12, max_new=3, seed=1):
    rng = np.random.RandomState(seed)
    return [Request(i, list(rng.randint(0, 100, prompt_len)), max_new)
            for i in range(n)]


# ---------------------------------------------------------------------------
# DecodeSlotManager invariants
# ---------------------------------------------------------------------------


def test_slots_never_double_assigned():
    mgr = DecodeSlotManager(n_slots=4, capacity=16)
    slots = [mgr.allocate(rid, cache_len=4) for rid in range(4)]
    assert sorted(slots) == [0, 1, 2, 3]          # each slot used exactly once
    assert mgr.free_slot() is None
    with pytest.raises(SlotError):
        mgr.allocate(99, cache_len=4)             # pool exhausted
    with pytest.raises(SlotError):
        mgr.allocate(99, cache_len=4, slot=2)     # explicit double assign
    mgr.release(2)
    assert mgr.allocate(99, cache_len=4) == 2     # lowest free index reused
    mgr.release(3)
    with pytest.raises(SlotError):
        mgr.release(3)                            # double release


def test_cache_len_never_exceeds_capacity():
    mgr = DecodeSlotManager(n_slots=2, capacity=10)
    s = mgr.allocate(0, cache_len=8)
    assert mgr.advance(s, 2) == 10                # exactly at capacity: fine
    with pytest.raises(SlotError):
        mgr.advance(s, 1)                         # one past capacity: error
    assert mgr.get(s).cache_len == 10             # failed advance is a no-op
    with pytest.raises(SlotError):
        mgr.allocate(1, cache_len=11)             # prompt alone too large
    with pytest.raises(SlotError):
        mgr.advance(1, 1)                         # advance on empty slot


# ---------------------------------------------------------------------------
# Routers: determinism + policy semantics
# ---------------------------------------------------------------------------


def test_router_registry_and_unknown_policy():
    assert set(ROUTERS) == {"least_loaded", "round_robin", "queue_depth"}
    with pytest.raises(ValueError, match="unknown prefill routing policy"):
        make_router("cache_affinity", 2)


@pytest.mark.parametrize("policy", sorted(ROUTERS))
def test_router_deterministic_on_fixed_stream(policy):
    loads_stream = [[0, 0, 0], [5, 0, 3], [5, 7, 3], [1, 1, 1], [9, 0, 0]]

    def run():
        r = make_router(policy, 3)
        picks = []
        for loads in loads_stream:
            i = r.select(loads)
            picks.append(i)
            r.on_complete(i)
        return picks

    a, b = run(), run()
    assert a == b, f"{policy} not deterministic: {a} vs {b}"
    assert all(0 <= i < 3 for i in a)


def test_round_robin_cycles():
    r = make_router("round_robin", 3)
    assert [r.select([0, 0, 0]) for _ in range(6)] == [0, 1, 2, 0, 1, 2]


def test_least_loaded_picks_minimum_with_id_tiebreak():
    r = make_router("least_loaded", 3)
    assert r.select([5, 2, 9]) == 1
    assert r.select([4, 4, 4]) == 0               # tie → lowest id


def test_queue_depth_balances_outstanding_requests():
    r = make_router("queue_depth", 2)
    # loads are irrelevant to this policy; depth counts routed-not-finished
    assert r.select([100, 0]) == 0
    assert r.select([100, 0]) == 1
    assert r.select([100, 0]) == 0
    r.on_complete(1)                              # instance 1 drains
    assert r.select([0, 0]) == 1


# ---------------------------------------------------------------------------
# Admission gate / cost model
# ---------------------------------------------------------------------------


def test_cost_model_batch_cap_math():
    cm = DecodeCostModel(fixed_s=4e-3, per_req_s=1e-3)
    assert cm.max_batch_for(15e-3) == 11
    assert cm.max_batch_for(6e-3) == 2
    assert cm.max_batch_for(5e-3) == 1
    assert cm.max_batch_for(4e-3) == 0            # budget below fixed cost
    assert cm.step_time(cm.max_batch_for(15e-3)) <= 15e-3
    # budgets landing exactly on a step time admit B, not B-1 (float trunc)
    for ms in (5, 6, 9, 11, 44, 45, 46, 47, 50):
        b = cm.max_batch_for(ms * 1e-3)
        assert b == ms - 4, (ms, b)
        assert cm.step_time(b) <= ms * 1e-3 + 1e-12


def test_gate_decisions_and_unsatisfiable_budget():
    cm = DecodeCostModel(fixed_s=4e-3, per_req_s=1e-3)
    gate = AdmissionGate(cm, tpot_budget_s=6e-3, mode="shed")
    assert gate.max_batch == 2
    assert gate.decide(active=0, has_free_slot=True) == "admit"
    assert gate.decide(active=2, has_free_slot=True) == "shed"
    assert gate.decide(active=2, has_free_slot=False) == "wait"
    queue_gate = AdmissionGate(cm, tpot_budget_s=6e-3, mode="queue")
    assert queue_gate.decide(active=2, has_free_slot=True) == "wait"
    with pytest.raises(ValueError, match="no batch size can meet it"):
        AdmissionGate(cm, tpot_budget_s=3e-3, mode="queue")
    with pytest.raises(ValueError, match="queue|shed"):
        AdmissionGate(cm, tpot_budget_s=6e-3, mode="drop")


# ---------------------------------------------------------------------------
# End-to-end SLO behaviour on the live system
# ---------------------------------------------------------------------------


def test_admission_gate_never_violates_budget_in_trace(granite):
    cfg, params = granite
    budget_ms = 6.0                               # cap=2 under default costs
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=4,
                           capacity=32, tpot_budget_ms=budget_ms,
                           admission="queue")
    results = system.serve(stream_requests(6))
    assert len(results) == 6 and not any(r.shed for r in results)
    cap = system.scheduler.gate.max_batch
    assert cap == 2
    for tr in system.scheduler.tracker.finished:
        assert tr.decode_iters > 0
        assert tr.tpot <= budget_ms * 1e-3 + 1e-12, \
            f"rid={tr.rid} tpot={tr.tpot*1e3:.3f}ms > budget {budget_ms}ms"


def test_shed_mode_sheds_when_budget_tightens(granite):
    cfg, params = granite

    def run(budget_ms):
        system = ServingSystem(params, cfg, n_prefill=2, decode_batch=4,
                               capacity=32, tpot_budget_ms=budget_ms,
                               admission="shed")
        results = system.serve(stream_requests(6))
        return results, system.scheduler.summary()

    loose, s_loose = run(None)
    tight, s_tight = run(6.0)
    assert s_loose["shed"] == 0
    assert s_tight["shed"] > 0                    # gate demonstrably sheds
    assert s_tight["completed"] + s_tight["shed"] == 6
    # a gate shed is a rejection, same as a capacity reject: no tokens
    # are delivered (the prefill-produced first token is discarded, not
    # leaked into throughput) and no decode iterations were spent
    for r in tight:
        if r.shed:
            assert r.tokens == [] and r.decode_iters == 0
    # completed requests under the tight budget still meet it
    assert s_tight["tpot_max_s"] <= 6.0e-3 + 1e-12


def test_trace_records_are_complete_and_consistent(granite):
    cfg, params = granite
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=32, policy="round_robin")
    results = system.serve(stream_requests(4, max_new=3))
    recs = system.scheduler.trace_records()
    assert [r["rid"] for r in recs] == [0, 1, 2, 3]
    by_rid = {r.rid: r for r in results}
    for rec in recs:
        assert rec["prefill_instance"] in (0, 1)
        assert rec["prefill_end"] >= rec["prefill_start"] >= rec["arrival"]
        assert rec["transfer_seconds"] > 0        # RDMA plane was charged
        assert rec["decode_end"] >= rec["decode_admit"] >= rec["prefill_end"]
        assert rec["decode_iters"] == by_rid[rec["rid"]].decode_iters == 2
        assert rec["tokens_out"] == 3
        assert rec["ttft"] > 0 and rec["tpot"] > 0
        assert rec["reused_tokens"] + rec["computed_tokens"] \
            == rec["prompt_tokens"]


@pytest.mark.parametrize("policy", ["least_loaded", "round_robin",
                                    "queue_depth"])
def test_routing_spreads_over_instances(granite, policy):
    """With uniform requests every policy must use all prefill instances
    (least_loaded/queue_depth balance on the virtual backlog timeline)."""
    cfg, params = granite
    system = ServingSystem(params, cfg, n_prefill=3, decode_batch=2,
                           capacity=32, policy=policy)
    results = system.serve(stream_requests(6))
    used = {r.prefill_instance for r in results}
    assert used == {0, 1, 2}, f"{policy} routed only to {used}"


def test_policies_all_serve_correctly(granite):
    cfg, params = granite
    ref_tokens = None
    for policy in sorted(ROUTERS):
        system = ServingSystem(params, cfg, n_prefill=3, decode_batch=2,
                               capacity=32, policy=policy)
        results = system.serve(stream_requests(5))
        toks = {r.rid: r.tokens for r in results}
        assert len(toks) == 5
        if ref_tokens is None:
            ref_tokens = toks
        else:          # routing must never change generated tokens
            assert toks == ref_tokens, policy


def test_oversized_request_rejected_without_killing_the_batch(granite):
    """A request whose prompt + max_new exceeds KV capacity is rejected at
    admission (shed=True, no tokens) instead of raising SlotError mid-decode
    and discarding every other in-flight result."""
    cfg, params = granite
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=32)
    reqs = stream_requests(3)
    reqs.append(Request(3, list(np.random.RandomState(9).randint(0, 100, 30)),
                        max_new_tokens=8))      # 30 + 7 > 32
    results = system.serve(reqs)
    assert len(results) == 4
    rejected = {r.rid: r for r in results}[3]
    assert rejected.shed and rejected.tokens == []
    for r in results:
        if r.rid != 3:
            assert not r.shed and len(r.tokens) == 3


def test_max_new_one_with_prompt_filling_slot(granite):
    """max_new=1 is answered entirely by prefill: no decode slot, no dead
    decode iteration — even when the prompt exactly fills KV capacity."""
    cfg, params = granite
    system = ServingSystem(params, cfg, n_prefill=1, decode_batch=2,
                           capacity=16)
    rng = np.random.RandomState(9)
    reqs = [Request(0, list(rng.randint(0, 100, 16)), 1),   # prompt == cap
            Request(1, list(rng.randint(0, 100, 8)), 4)]
    results = {r.rid: r for r in system.serve(reqs)}
    assert len(results[0].tokens) == 1 and results[0].decode_iters == 0
    assert not results[0].shed
    assert len(results[1].tokens) == 4
    tr = system.scheduler.traces[0]
    assert tr.decode_end == tr.decode_admit == tr.ready_at


def test_max_new_zero_returns_no_tokens_and_oversized_prompt_rejected(granite):
    cfg, params = granite
    system = ServingSystem(params, cfg, n_prefill=1, decode_batch=2,
                           capacity=16)
    rng = np.random.RandomState(10)
    reqs = [Request(0, list(rng.randint(0, 100, 10)), 0),    # fits, 0 tokens
            Request(1, list(rng.randint(0, 100, 17)), 0),    # prompt > cap
            Request(2, list(rng.randint(0, 100, 8)), 3)]
    results = {r.rid: r for r in system.serve(reqs)}
    assert results[0].tokens == [] and not results[0].shed
    assert results[1].shed                       # rejected before prefill
    assert len(results[2].tokens) == 3           # batch unaffected


def test_serve_is_reinvokable_with_repeated_rids(granite):
    """Each serve() call is a fresh scheduling epoch: rids may repeat
    across waves and summary/trace reflect the latest wave only."""
    cfg, params = granite
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=32)
    w1 = system.serve(stream_requests(3))
    w2 = system.serve(stream_requests(3, seed=2))   # rids 0..2 again
    assert len(w1) == len(w2) == 3
    assert len(system.scheduler.trace_records()) == 3
    assert system.scheduler.summary()["completed"] == 3


def test_interleave_warns_when_not_applicable(granite):
    cfg, params = granite
    with pytest.warns(UserWarning, match="not divisible"):
        ServingSystem(params, cfg, n_prefill=1, decode_batch=3,
                      capacity=32, interleave=True)


def test_interleaved_decode_matches_plain(granite):
    cfg, params = granite
    plain = ServingSystem(params, cfg, n_prefill=1, decode_batch=4,
                          capacity=32)
    inter = ServingSystem(params, cfg, n_prefill=1, decode_batch=4,
                          capacity=32, interleave=True)
    assert inter.decode.interleaved          # 4 % 2 == 0 → actually paired
    r_plain = {r.rid: r.tokens for r in plain.serve(stream_requests(4))}
    r_inter = {r.rid: r.tokens for r in inter.serve(stream_requests(4))}
    assert r_plain == r_inter
