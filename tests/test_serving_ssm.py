"""PDC serving for the attention-free / hybrid families: the context cache
is inapplicable (no sliceable KV; DESIGN.md §3) but the full PDC flow —
prefill, RDMA handoff, continuous-batched decode on SSM state — must work
and match direct greedy decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke
from repro.mempool import ContextCache, MemoryPool
from repro.models import decode_step, init_params, prefill
from repro.serving import Request, ServingSystem


def test_hybrid_interleave_falls_back_with_warning():
    """Hybrid caches nest SSM state with batch on axis 2, which microbatch
    splitting would mis-slice — interleave must disable itself loudly and
    serve correctly."""
    cfg = smoke("zamba2-1.2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(3)
    reqs = [Request(i, list(rng.randint(0, cfg.vocab_size, 12)), 3)
            for i in range(2)]
    with pytest.warns(UserWarning, match="hybrid"):
        system = ServingSystem(params, cfg, n_prefill=1, decode_batch=2,
                               capacity=32, interleave=True)
    assert not system.decode.interleaved
    results = system.serve(reqs)
    assert len(results) == 2
    assert all(len(r.tokens) == 3 for r in results)


@pytest.mark.parametrize("arch", ["mamba2-780m", "zamba2-1.2b"])
def test_ssm_serving_matches_direct(arch):
    cfg = smoke(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(0, cfg.vocab_size, 16)) for _ in range(3)]

    # pool present but unused for SSM (inapplicability path)
    pool = MemoryPool(n_nodes=2)
    cc = ContextCache(pool, block_tokens=8, model_tag=cfg.name)
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=32, context_cache=cc)
    results = system.serve([Request(i, p, 4) for i, p in enumerate(prompts)])
    assert len(results) == 3
    assert all(r.reused_tokens == 0 for r in results)   # no KV reuse for SSM

    for r in results:
        prompt = prompts[r.rid]
        logits, caches = prefill(params, cfg, {"tokens": jnp.asarray([prompt])},
                                 capacity=32, cache_dtype=jnp.float32)
        toks = [int(jnp.argmax(logits[0, -1]))]
        cl = jnp.int32(len(prompt))
        for _ in range(3):
            lg, caches = decode_step(params, cfg,
                                     jnp.asarray([[toks[-1]]], jnp.int32),
                                     caches, cl)
            toks.append(int(jnp.argmax(lg[0])))
            cl = cl + 1
        assert r.tokens == toks, f"{arch} rid={r.rid}: {r.tokens} != {toks}"
