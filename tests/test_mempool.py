"""Disaggregated memory pool + model caching (paper §4.4, Table 2)."""
import numpy as np
import pytest

from repro.mempool import (MemoryPool, ModelCache, OBS_STORE, UB_PLANE,
                           VPC_PLANE)


def test_namespace_quota():
    pool = MemoryPool(n_nodes=2)
    pool.controller.create_namespace("small", quota_bytes=1000)
    assert pool.put("a", np.zeros(100, np.float32), "small")   # 400 B
    assert pool.put("b", np.zeros(100, np.float32), "small")   # 800 B
    assert not pool.put("c", np.zeros(100, np.float32), "small")  # over quota


def test_namespace_isolation_delete():
    pool = MemoryPool(n_nodes=2)
    pool.put("x1", np.ones(8, np.float32), "ns_a")
    pool.put("x2", np.ones(8, np.float32), "ns_b")
    for s in pool.servers:
        s.delete_namespace("ns_a")
    assert pool.get("x1") is None
    assert pool.get("x2") is not None


def test_plane_cost_model_ub_faster_than_vpc():
    nbytes = 1 << 30
    assert UB_PLANE.cost(nbytes) < VPC_PLANE.cost(nbytes) / 5


def test_model_cache_table2_semantics():
    """EMS vs no-cache loading reproduces Table 2's qualitative structure:
    cold EMS ≈ one OBS fetch (~320s for 671GB at 2.5GB/s shared once +
    fast UB fan-out); warm switch is ~100x faster than cold."""
    total = 671 * 10**9
    # --- no cache: 8 instances each pull from OBS (8x contention) ---
    pool1 = MemoryPool(n_nodes=32)
    mc1 = ModelCache(pool1)
    meta1 = mc1.register("dsr1", "v1", total)
    t_nocache = mc1.load_to_npu(meta1, n_instances=8)  # never cached => OBS each
    # approximately 8 * 671GB / 2.5GB/s, minus pool-assisted reuse
    # --- EMS: one shared OBS fill + UB loads ---
    pool2 = MemoryPool(n_nodes=32, dram_per_node=1 << 38)
    mc2 = ModelCache(pool2)
    meta2 = mc2.register("dsr1", "v1", total)
    t_fill = mc2.prefetch(meta2)
    t_warm = mc2.load_to_npu(meta2, n_instances=8)
    assert 200 < t_fill < 400, f"cold OBS fill {t_fill}s (paper: ~320s)"
    per_instance_warm = t_warm / 8
    assert per_instance_warm < 10, f"warm load {per_instance_warm}s (paper: ~5s)"
    assert t_fill + t_warm < t_nocache / 3

    # --- model switch: warm hit ~5s ---
    t_switch, warm = mc2.switch_model(meta2)
    assert warm and t_switch < 10


def test_model_cache_versioning():
    pool = MemoryPool(n_nodes=4, dram_per_node=1 << 34)
    mc = ModelCache(pool)
    v1 = mc.register("m", "v1", 10 ** 9)
    v2 = mc.register("m", "v2", 10 ** 9)
    mc.prefetch(v1)
    assert mc.is_cached(v1)
    assert not mc.is_cached(v2)  # versions are distinct block sets
