# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device. Multi-device tests (tests/test_multidevice.py) run
# in a subprocess with --xla_force_host_platform_device_count set.
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config, smoke_variant  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "fault_soak: deterministic fault-injection soak over the pool/"
        "injector state machines (fast by default; FAULT_SOAK_ITERS=1000000 "
        "runs the full million-iteration virtual-clock soak)")
    config.addinivalue_line(
        "markers",
        "workload_soak: production workload suite soak through the real "
        "scheduler control plane (fast by default; "
        "WORKLOAD_SOAK_REQUESTS=1000000 runs the full million-request "
        "virtual-clock soak)")


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


def make_batch(cfg, b=2, s=16, seed=0):
    """Batch dict appropriate for the config's modality."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    batch = {}
    if cfg.frontend == "audio_frames":
        batch["frames"] = jax.random.normal(k1, (b, s, cfg.d_model), jnp.float32)
        batch["labels"] = jax.random.randint(k2, (b, s), 0, cfg.vocab_size)
        return batch
    if cfg.frontend == "vision_patches":
        p = cfg.num_prefix_embeddings
        assert s > p, "sequence must exceed patch count"
        batch["prefix_emb"] = jax.random.normal(k1, (b, p, cfg.d_model), jnp.float32)
        batch["tokens"] = jax.random.randint(k2, (b, s - p), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(k2, (b, s - p), 0, cfg.vocab_size)
        return batch
    batch["tokens"] = jax.random.randint(k1, (b, s), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(k2, (b, s), 0, cfg.vocab_size)
    return batch


def smoke(name):
    return smoke_variant(get_config(name))
