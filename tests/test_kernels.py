"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py
oracles (kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dispatch_quant.ops import dispatch_quantize
from repro.kernels.dispatch_quant.ref import dispatch_quantize_ref
from repro.kernels.int8_gemm.ops import int8_matmul
from repro.kernels.int8_gemm.ref import int8_matmul_ref
from repro.kernels.mla_attention.ops import mla_decode_attention
from repro.kernels.mla_attention.ref import mla_decode_attention_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


@pytest.mark.parametrize("t,d", [(8, 64), (64, 256), (128, 128), (32, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dispatch_quant_sweep(t, d, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(t + d), (t, d)) * 5).astype(dtype)
    q, s = dispatch_quantize(x)
    qr, sr = dispatch_quantize_ref(x)
    # XLA may fold x/s into x*(1/s): allow the resulting ±1 code at exact
    # rounding boundaries (value-identical to within half a scale step).
    assert (np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32)) <= 1).all()
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    # quantization error bound: |x - q*s| <= s/2 per element
    deq = np.asarray(q, np.float32) * np.asarray(s)
    err = np.abs(deq - np.asarray(x, np.float32))
    assert (err <= np.asarray(s) * 0.5 + 1e-6).all()


@pytest.mark.parametrize("m,k,n", [(32, 64, 48), (128, 128, 128),
                                   (64, 256, 96), (16, 32, 128)])
@pytest.mark.parametrize("out_dtype", [jnp.bfloat16, jnp.float32])
def test_int8_gemm_sweep(m, k, n, out_dtype):
    kk = jax.random.PRNGKey(m * k + n)
    ks = jax.random.split(kk, 4)
    xq = jax.random.randint(ks[0], (m, k), -127, 128, jnp.int8)
    wq = jax.random.randint(ks[1], (k, n), -127, 128, jnp.int8)
    xs = jax.random.uniform(ks[2], (m, 1)) * 0.1
    ws = jax.random.uniform(ks[3], (1, n)) * 0.1
    out = int8_matmul(xq, wq, xs, ws, out_dtype=out_dtype)
    ref = int8_matmul_ref(xq, wq, xs, ws, out_dtype=out_dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=1e-2)


@pytest.mark.parametrize("b,h,r,dr,s", [(1, 4, 32, 16, 64), (2, 8, 64, 16, 256),
                                        (2, 16, 128, 64, 128)])
@pytest.mark.parametrize("valid_len", [1, 37, None])
def test_mla_attention_sweep(b, h, r, dr, s, valid_len):
    ks = jax.random.split(jax.random.PRNGKey(b * s + h), 3)
    ql = jax.random.normal(ks[0], (b, h, r))
    qr = jax.random.normal(ks[1], (b, h, dr))
    cache = jax.random.normal(ks[2], (b, s, r + dr))
    vl = s if valid_len is None else min(valid_len, s)
    valid = jnp.arange(s) < vl
    out = mla_decode_attention(ql, qr, cache, valid, 0.125, r)
    ref = mla_decode_attention_ref(ql, qr, cache, valid, 0.125, r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 16, 8, 16), (2, 128, 4, 32, 16, 32), (1, 96, 2, 64, 128, 32),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(s + h), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.random.uniform(ks[1], (b, s, h), minval=0.001, maxval=0.1)
    alog = jax.random.normal(ks[2], (h,)) * 0.1
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    y, hf = ssd_scan(x, dt, alog, bm, cm, chunk=chunk)
    yr, hr = ssd_scan_ref(x, dt, alog, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr), rtol=2e-4, atol=2e-4)


def test_ssd_scan_matches_model_chunked():
    """The Pallas kernel and the model's pure-jnp chunked SSD agree."""
    from repro.models.mamba2 import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    b, s, h, p, n = 2, 64, 4, 16, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.random.uniform(ks[1], (b, s, h), minval=0.001, maxval=0.1)
    alog = jax.random.normal(ks[2], (h,)) * 0.1
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    y1, h1 = ssd_scan(x, dt, alog, bm, cm, chunk=16)
    y2, h2 = ssd_chunked(x, dt, alog, bm, cm, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5, atol=1e-5)
