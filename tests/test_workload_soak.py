"""Workload soak: the production request suite driven through the REAL
scheduler control plane (class-indexed admission gate, strict-priority
passes, brownout ladder, slot managers) at scale, on the virtual clock.

Mirrors the fault-soak pattern: ``WORKLOAD_SOAK_REQUESTS`` scales the run
(default 20k requests locally; the scheduled CI soak exports
``WORKLOAD_SOAK_REQUESTS=1000000`` for the full million-request pass).
The stream is generated in seeded chunks (``start``/``rid_base`` keep
rids and timelines disjoint), each chunk served as one scheduler epoch,
and every admission/shed/finish/ladder event is hashed into a sha256
digest; the acceptance bar is *bit-stable determinism* across two runs,
not just absence of crashes. A smaller companion test pushes production
traces through the real ``ServingSystem.serve`` cross-composed with a
fault plan (``FaultPlan`` addition), so the workload suite and the fault
plane are exercised together end to end.
"""
import hashlib
import os

import pytest

from repro.serving import (DecodeSlotManager, Scheduler, SchedulerConfig,
                           production_requests)

WORKLOAD_SOAK_REQUESTS = int(os.environ.get("WORKLOAD_SOAK_REQUESTS",
                                            "20000"))
CHUNK = 2000                      # requests generated per seeded chunk
N_ENGINES = 4
SLOTS = 8
ARRIVAL_ROTATION = ("burst", "diurnal", "poisson")


def _build_scheduler():
    cfg = SchedulerConfig(tpot_budget_ms=6.0, admission="queue",
                          batch_tpot_budget_ms=30.0, brownout=True,
                          brownout_patience=3, brownout_queue_age_s=0.05)
    mgrs = [DecodeSlotManager(SLOTS, 512) for _ in range(N_ENGINES)]
    return Scheduler(2, mgrs, cfg), mgrs


def _drive_wave(sched, mgrs, reqs, digest):
    """Serves one chunk through the scheduler hook surface — prefill
    routing, class-aware admission, decode accounting, brownout ticks —
    without touching jax (the control plane is pure Python on the virtual
    clock). Waiting entries are ``[rid, ready_at, cls, tokens_left]``;
    each turn runs the degrade pass, strict-priority admission, one decode
    iteration per busy engine, then feeds the ladder the real pressure
    signal, exactly the ServingSystem serve-loop shape."""
    waiting = []
    active = {e: [] for e in range(N_ENGINES)}   # engine -> [[rid, left]]
    slot_of = {}                                 # rid -> (engine, slot)
    for req in reqs:
        tr = sched.on_arrival(req.rid, req.arrival, len(req.prompt),
                              slo_class=req.slo_class)
        inst = sched.route_prefill(tr, [0] * sched.n_prefill)
        sched.on_prefill_done(tr, inst, len(req.prompt), 0)
        sched.on_transfer(tr, 1e-5)
        waiting.append([tr.rid, tr.ready_at, tr.slo_class,
                        req.max_new_tokens])
        digest.update(b"A%d,%d,%d" % (tr.rid, len(req.prompt), inst))

    def shed(rid):
        tr = sched.traces[rid]
        sched.on_shed(tr)
        sched.on_finish(tr, 0)
        digest.update(b"S%d" % rid)

    turns = 0
    while waiting or any(active.values()):
        turns += 1
        assert turns < 5_000_000, "soak wave failed to drain"
        now = sched.decode_now + 1e-12
        # Brownout level-3 degrade pass: queue-age-shed batch only.
        if sched.brownout_level >= 3:
            age_cut = sched.config.brownout_queue_age_s
            cut = [w for w in waiting
                   if w[2] == "batch" and now - w[1] > age_cut]
            for rid, _, _, _ in cut:
                shed(rid)
            waiting = [w for w in waiting
                       if not (w[2] == "batch" and now - w[1] > age_cut)]
        # Strict-priority admission: interactive pass first; batch only
        # when no gate-ready interactive request was left blocked.
        ready_blocked = False
        progressed = False
        for want in ("interactive", "batch"):
            if want == "batch" and ready_blocked:
                break
            kept = []
            for w in waiting:
                rid, ready, cls, left = w
                if cls != want or ready > now:
                    kept.append(w)
                    continue
                engine = min(range(N_ENGINES),
                             key=lambda e: (-mgrs[e].free, e))
                tr = sched.traces[rid]
                decision = sched.admission_decision(tr, engine=engine)
                if decision == "admit":
                    slot = mgrs[engine].allocate(rid, tr.prompt_tokens)
                    sched.on_admit(tr, slot, engine=engine)
                    slot_of[rid] = (engine, slot)
                    active[engine].append([rid, left])
                    progressed = True
                    digest.update(b"D%d@%d" % (rid, engine))
                elif decision == "shed":
                    shed(rid)
                    progressed = True
                else:
                    if cls == "interactive":
                        ready_blocked = True
                    kept.append(w)
            waiting = kept
        # One decode iteration per busy engine; idle peers are idle *now*,
        # so their clocks sync to the busy frontier (the serve-loop rule —
        # without it the pool frontier freezes at a stale idle clock).
        stepped = []
        for e in range(N_ENGINES):
            if not active[e]:
                continue
            progressed = True
            stepped.append(e)
            done = []
            for entry in active[e]:
                entry[1] -= 1
                if entry[1] <= 0:
                    done.append(entry[0])
            sched.on_decode_step([rid for rid, _ in active[e]], done,
                                 engine=e)
            for rid in done:
                eng, slot = slot_of.pop(rid)
                mgrs[eng].release(slot)
                tr = sched.traces[rid]
                sched.on_finish(tr, tr.decode_tokens + 1)
                digest.update(b"F%d" % rid)
            active[e] = [x for x in active[e] if x[1] > 0]
        sched.sync_idle_clocks(stepped)
        # Open loop: an idle pool fast-forwards to the next KV-ready event
        # instead of spinning (and the calm turns step the ladder down).
        if not progressed and waiting and not any(active.values()):
            sched.advance_clock(min(w[1] for w in waiting))
        pressured = any(w[2] == "interactive" and w[1] <= now
                        for w in waiting)
        sched.note_overload(pressured)
        digest.update(b"L%d" % sched.brownout_level)
        assert 0 <= sched.brownout_level <= 4


def _soak_digest(n_requests):
    sched, mgrs = _build_scheduler()
    digest = hashlib.sha256()
    totals = {"completed": 0, "shed": 0, "peak_level": 0, "preempt": 0}
    done = 0
    chunk_idx = 0
    first = True
    while done < n_requests:
        if not first:
            sched.begin_epoch()      # one epoch per chunk: bounded traces
        first = False
        n = min(CHUNK, n_requests - done)
        reqs = production_requests(
            n, seed=1000 + chunk_idx, vocab_size=64, rate_rps=400.0,
            arrival_shape=ARRIVAL_ROTATION[chunk_idx % 3],
            interactive_frac=0.7, rid_base=done)
        _drive_wave(sched, mgrs, reqs, digest)
        # Per-chunk invariants: conservation + completeness.
        for mgr in mgrs:
            assert mgr.acquired == mgr.released and mgr.active == 0
        s = sched.summary()
        assert s["completed"] + s["shed"] == n
        totals["completed"] += s["completed"]
        totals["shed"] += s["shed"]
        totals["peak_level"] = max(totals["peak_level"],
                                   s["brownout_peak_level"])
        digest.update(repr((chunk_idx, s["completed"], s["shed"],
                            s["brownout_peak_level"],
                            round(s["decode_virtual_s"], 12))).encode())
        done += n
        chunk_idx += 1
    return digest.hexdigest(), totals


@pytest.mark.workload_soak
def test_production_workload_soak_bit_deterministic():
    """The full-scheduler soak drains WORKLOAD_SOAK_REQUESTS production
    requests (burst/diurnal/poisson chunks, 70/30 class mix) and produces
    a bit-identical event-log digest on a second run."""
    d1, t1 = _soak_digest(WORKLOAD_SOAK_REQUESTS)
    d2, t2 = _soak_digest(WORKLOAD_SOAK_REQUESTS)
    assert d1 == d2
    assert t1 == t2
    assert t1["completed"] + t1["shed"] == WORKLOAD_SOAK_REQUESTS
    assert t1["completed"] > 0
    # The soak must actually exercise the overload machinery.
    assert t1["peak_level"] >= 1


@pytest.mark.workload_soak
def test_workload_soak_through_serving_system_with_faults():
    """A scaled-down production trace through the real ServingSystem.serve,
    cross-composed with a fault plan built by FaultPlan addition — digest
    bit-stable across runs."""
    jax = pytest.importorskip("jax")
    from conftest import smoke
    from repro.models import init_params
    from repro.serving import FaultInjector, FaultPlan, ServingSystem

    cfg = smoke("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    reqs = production_requests(24, seed=7, vocab_size=cfg.vocab_size,
                               rate_rps=400.0, arrival_shape="burst",
                               prompt_len_max=24, max_new_max=8,
                               interactive_frac=0.6)
    plan = (FaultPlan.random(3, n_engines=2, horizon_s=0.05)
            + FaultPlan.parse('[{"kind": "transfer_timeout", "count": 1}]'))

    def run():
        system = ServingSystem(
            params, cfg, n_prefill=2, decode_batch=2, capacity=64,
            decode_engines=2, tpot_budget_ms=9.0, batch_tpot_budget_ms=40.0,
            preempt_batch=True, brownout=True,
            fault_injector=FaultInjector(plan, seed=3))
        results = system.serve(list(reqs), open_loop=True)
        digest = hashlib.sha256()
        for r in sorted(results, key=lambda r: r.rid):
            digest.update(repr((r.rid, r.tokens, r.shed,
                                r.slo_class)).encode())
        for tr in sorted(system.scheduler.traces.values(),
                         key=lambda t: t.rid):
            digest.update(repr((tr.rid, tr.slo_class, tr.recoveries,
                                tr.preemptions, tr.shed,
                                round(tr.decode_end, 12))).encode())
        return digest.hexdigest(), system.scheduler.summary()

    d1, s1 = run()
    d2, s2 = run()
    assert d1 == d2
    assert s1["completed"] + s1["shed"] == len(reqs)
    assert s1 == s2
