"""SLO-class overload control: class-indexed admission gate, strict-priority
ordering, class-ordered graceful degradation, the brownout ladder, per-class
SLO summaries, and the production workload generators."""
import jax
import numpy as np
import pytest

from conftest import smoke
from repro.models import init_params
from repro.serving import (AdmissionGate, BrownoutLadder, DecodeCostModel,
                           Request, RequestTrace, ServingSystem, SLOTracker,
                           multi_turn_sessions, poisson_requests,
                           production_requests)

COST = DecodeCostModel()          # fixed 4 ms + 1 ms/req -> 6 ms budget = B2


@pytest.fixture(scope="module")
def granite():
    cfg = smoke("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# Class-indexed AdmissionGate
# ---------------------------------------------------------------------------


def test_default_gate_is_class_blind_back_compat():
    """Two-argument construction is exactly the pre-class gate: every class
    sees the base budget/mode and decide() is unchanged."""
    gate = AdmissionGate(COST, 6e-3)
    assert gate.max_batch == 2
    assert gate.cap_for() == gate.cap_for("batch") == 2
    assert gate.mode_for() == gate.mode_for("batch") == "queue"
    assert gate.decide(1, True) == "admit"
    assert gate.decide(2, True) == "wait"
    assert gate.decide(2, False) == "wait"


def test_class_budgets_give_per_class_caps_and_modes():
    gate = AdmissionGate(COST, 6e-3,
                         class_budgets={"batch": 30e-3},
                         class_modes={"batch": "shed"})
    assert gate.cap_for("interactive") == 2
    assert gate.cap_for("batch") == COST.max_batch_for(30e-3)
    assert gate.cap_for("batch") > 2
    assert gate.mode_for("interactive") == "queue"
    assert gate.mode_for("batch") == "shed"
    # Unknown classes fall back to the base budget/mode.
    assert gate.cap_for("bulk") == 2 and gate.mode_for("bulk") == "queue"


def test_effective_cap_is_strictest_over_resident_classes():
    """Batch step time is a whole-batch property: a relaxed-budget batch
    request may not inflate the batch past a co-resident interactive
    request's cap."""
    gate = AdmissionGate(COST, 6e-3, class_budgets={"batch": 30e-3})
    # Batch joining a batch-only engine: relaxed cap applies.
    assert gate.admissible(2, "batch", resident_classes=("batch",))
    # Batch joining an engine holding an interactive request: the
    # interactive 2-cap wins.
    assert not gate.admissible(2, "batch",
                               resident_classes=("interactive",))
    assert gate.decide(2, True, "batch",
                       resident_classes=("interactive",)) == "wait"
    # Interactive joining anywhere is capped by its own budget.
    assert not gate.admissible(2, "interactive", resident_classes=("batch",))


def test_class_mode_and_zero_cap_validation():
    with pytest.raises(ValueError, match="queue|shed"):
        AdmissionGate(COST, 6e-3, class_modes={"batch": "drop"})
    # A class budget below the fixed decode cost admits nothing: queue mode
    # would deadlock, so construction must fail just like the base budget.
    with pytest.raises(ValueError, match="below the fixed decode cost"):
        AdmissionGate(COST, 6e-3, class_budgets={"batch": 1e-3})
    # shed mode makes the zero cap legal (reject-all tier).
    gate = AdmissionGate(COST, 6e-3, class_budgets={"batch": 1e-3},
                         class_modes={"batch": "shed"})
    assert gate.cap_for("batch") == 0
    assert gate.decide(0, True, "batch") == "shed"


def test_mode_override_sheds_before_slot_check():
    """A brownout shed-override rejects the class outright — even with a
    free slot and an admissible batch, and without widening admissibility
    for anyone else."""
    gate = AdmissionGate(COST, 6e-3)
    assert gate.decide(0, True, "batch", mode_override="shed") == "shed"
    assert gate.decide(0, False, "batch", mode_override="shed") == "shed"
    assert gate.decide(2, True, "interactive", mode_override="queue") == "wait"


# ---------------------------------------------------------------------------
# Brownout ladder
# ---------------------------------------------------------------------------


def test_brownout_ladder_hysteresis_and_bounds():
    lad = BrownoutLadder(patience=2, cooldown=3)
    assert lad.observe(True) is None                    # 1 pressured turn
    assert lad.observe(True) == {"from": 0, "to": 1}    # patience reached
    assert lad.level == 1
    # Calm turns reset the pressure streak; cooldown steps back down.
    assert lad.observe(False) is None
    assert lad.observe(True) is None                    # streak restarted
    assert lad.observe(True) == {"from": 1, "to": 2}
    for _ in range(2):
        assert lad.observe(False) is None
    assert lad.observe(False) == {"from": 2, "to": 1}
    # Level never leaves [0, MAX_LEVEL].
    for _ in range(20):
        lad.observe(True)
    assert lad.level == BrownoutLadder.MAX_LEVEL == 4
    for _ in range(40):
        lad.observe(False)
    assert lad.level == 0
    assert lad.observe(False) is None                   # floor holds


def test_brownout_ladder_validation():
    with pytest.raises(ValueError, match="patience/cooldown"):
        BrownoutLadder(patience=0)
    with pytest.raises(ValueError, match="patience/cooldown"):
        BrownoutLadder(cooldown=0)


# ---------------------------------------------------------------------------
# Per-class SLO summaries
# ---------------------------------------------------------------------------


def _trace(rid, slo_class, shed=False):
    tr = RequestTrace(rid, arrival=0.0, prompt_tokens=4, slo_class=slo_class,
                      prefill_end=1e-3, decode_admit=2e-3, decode_end=5e-3,
                      decode_iters=3, decode_tokens=3, decode_seconds=3e-3,
                      tokens_out=4)
    tr.shed = shed
    return tr


def test_slo_tracker_per_class_breakdown():
    trk = SLOTracker()
    for t in (_trace(0, "interactive"), _trace(1, "batch"),
              _trace(2, "batch", shed=True)):
        trk.record(t)
    s = trk.summary()
    assert s["completed"] == 2 and s["shed"] == 1
    cls = s["classes"]
    assert set(cls) == {"batch", "interactive"}
    assert cls["interactive"]["completed"] == 1
    assert cls["batch"]["completed"] == 1 and cls["batch"]["shed"] == 1


def test_slo_tracker_single_class_summary_stays_flat():
    trk = SLOTracker()
    trk.record(_trace(0, "interactive"))
    assert "classes" not in trk.summary()


# ---------------------------------------------------------------------------
# Workload generators
# ---------------------------------------------------------------------------


def test_poisson_requests_rejects_degenerate_lengths():
    kw = dict(rate_rps=100.0, vocab_size=64, seed=0)
    with pytest.raises(ValueError, match="prompt_len must be positive"):
        poisson_requests(4, prompt_len=0, max_new=4, **kw)
    with pytest.raises(ValueError, match="max_new must be positive"):
        poisson_requests(4, prompt_len=8, max_new=0, **kw)
    # Existing guards still fire.
    with pytest.raises(ValueError, match="n_requests"):
        poisson_requests(0, prompt_len=8, max_new=4, **kw)
    with pytest.raises(ValueError, match="rate_rps"):
        poisson_requests(4, rate_rps=0.0, prompt_len=8, max_new=4,
                         vocab_size=64, seed=0)


def test_poisson_requests_class_and_rid_base():
    reqs = poisson_requests(3, 100.0, 8, 4, 64, seed=1, slo_class="batch",
                            rid_base=50, start=2.0)
    assert [r.rid for r in reqs] == [50, 51, 52]
    assert all(r.slo_class == "batch" for r in reqs)
    assert all(r.arrival > 2.0 for r in reqs)


@pytest.mark.parametrize("shape", ["poisson", "burst", "diurnal"])
def test_production_requests_deterministic_and_shaped(shape):
    kw = dict(seed=9, vocab_size=64, rate_rps=200.0, arrival_shape=shape,
              interactive_frac=0.6)
    a = production_requests(64, **kw)
    b = production_requests(64, **kw)
    assert [(r.rid, r.arrival, r.prompt, r.max_new_tokens, r.slo_class)
            for r in a] == \
           [(r.rid, r.arrival, r.prompt, r.max_new_tokens, r.slo_class)
            for r in b]
    arrivals = [r.arrival for r in a]
    assert arrivals == sorted(arrivals) and arrivals[0] > 0
    assert {r.slo_class for r in a} == {"interactive", "batch"}
    assert all(1 <= len(r.prompt) <= 256 and 1 <= r.max_new_tokens <= 64
               for r in a)
    # Heavy tail: lengths actually vary.
    assert len({len(r.prompt) for r in a}) > 4


def test_production_requests_validation_and_chunking():
    with pytest.raises(ValueError, match="arrival shape"):
        production_requests(4, seed=0, vocab_size=64, rate_rps=10.0,
                            arrival_shape="flat")
    with pytest.raises(ValueError, match="interactive_frac"):
        production_requests(4, seed=0, vocab_size=64, rate_rps=10.0,
                            interactive_frac=1.5)
    # Chunked generation: disjoint rid ranges and non-overlapping time.
    c0 = production_requests(8, seed=0, vocab_size=64, rate_rps=100.0)
    c1 = production_requests(8, seed=1, vocab_size=64, rate_rps=100.0,
                             start=c0[-1].arrival, rid_base=8)
    assert {r.rid for r in c0}.isdisjoint({r.rid for r in c1})
    assert min(r.arrival for r in c1) > max(r.arrival for r in c0)


def test_multi_turn_sessions_grow_prefixes_deterministically():
    a = multi_turn_sessions(4, seed=3, vocab_size=64, session_rate_rps=50.0,
                            turns=3)
    b = multi_turn_sessions(4, seed=3, vocab_size=64, session_rate_rps=50.0,
                            turns=3)
    assert [(r.rid, r.arrival, r.prompt) for r in a] == \
           [(r.rid, r.arrival, r.prompt) for r in b]
    assert len(a) == 12
    arrivals = [r.arrival for r in a]
    assert arrivals == sorted(arrivals)
    # Each session's later turns re-enter with a strictly grown prefix that
    # starts with the previous turn's full prompt (EMS prefix reuse).
    by_rid = {r.rid: r for r in a}
    for s in range(4):
        t0, t1, t2 = (by_rid[3 * s], by_rid[3 * s + 1], by_rid[3 * s + 2])
        assert len(t0.prompt) < len(t1.prompt) < len(t2.prompt)
        assert t1.prompt[:len(t0.prompt)] == t0.prompt
        assert t2.prompt[:len(t1.prompt)] == t1.prompt


# ---------------------------------------------------------------------------
# End-to-end: strict priority, class-ordered degrade, brownout
# ---------------------------------------------------------------------------


def _mixed_requests(seed=11, n_batch=6, n_interactive=3):
    rng = np.random.RandomState(seed)
    reqs = [Request(i, list(rng.randint(0, 100, 12)), 6,
                    arrival=5e-4 * i, slo_class="batch")
            for i in range(n_batch)]
    reqs += [Request(100 + i, list(rng.randint(0, 100, 12)), 4,
                     arrival=4e-3 + 2e-3 * i, slo_class="interactive")
             for i in range(n_interactive)]
    return reqs


def test_strict_priority_batch_never_delays_ready_interactive(granite):
    """Once an interactive request is KV-ready, no batch-tier request is
    admitted ahead of it — with per-class budgets, the earlier-arrived
    batch flood queues behind the interactive trickle."""
    cfg, params = granite
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=3,
                           capacity=64, tpot_budget_ms=6.0,
                           batch_tpot_budget_ms=30.0)
    results = system.serve(_mixed_requests(), open_loop=True)
    sched = system.scheduler
    assert len(results) == 9 and not any(r.shed for r in results)
    inter = [t for t in sched.traces.values() if t.slo_class == "interactive"]
    batch = [t for t in sched.traces.values() if t.slo_class == "batch"]
    eps = 1e-12
    for it in inter:
        for bt in batch:
            # A batch request admitted after this interactive became ready
            # must not have been admitted before the interactive was.
            if bt.decode_admit > it.ready_at + eps:
                assert bt.decode_admit >= it.decode_admit - eps
    s = sched.summary()
    assert s["classes"]["interactive"]["completed"] == 3
    assert s["classes"]["batch"]["completed"] == 6


def test_degrade_shed_is_class_ordered_at_equal_queue_age(granite):
    """degrade_shed_queue_s composes with class ordering: at equal queue
    age the batch-tier backlog is shed before any interactive request, and
    shed traces stamp their queue time at the shed instant."""
    cfg, params = granite
    rng = np.random.RandomState(5)
    # Interleaved equal-age backlog: all arrive at once, classes alternate.
    reqs = [Request(i, list(rng.randint(0, 100, 12)), 6,
                    slo_class=("batch" if i % 2 == 0 else "interactive"))
            for i in range(8)]
    system = ServingSystem(params, cfg, n_prefill=1, decode_batch=2,
                           capacity=32, degrade_shed_queue_s=1e-4)
    results = system.serve(reqs)
    sched = system.scheduler
    s = sched.summary()
    assert s["shed"] >= 1 and s["completed"] + s["shed"] == len(reqs)
    shed_batch = [t for t in sched.tracker.shed if t.slo_class == "batch"]
    shed_inter = [t for t in sched.tracker.shed
                  if t.slo_class == "interactive"]
    assert shed_batch, "equal-age shedding must cut the batch tier"
    # Class ordering: every interactive shed (if any) happens in a later
    # round than every batch shed.
    if shed_inter:
        assert max(t.decode_admit for t in shed_batch) <= \
            min(t.decode_admit for t in shed_inter)
    # Shed traces stamp queue time at the shed instant.
    for t in sched.tracker.shed:
        assert t.decode_admit == t.decode_end >= t.ready_at
        assert t.queue_seconds > 0
    assert sum(r.shed for r in results) == s["shed"]


def test_brownout_ladder_sheds_batch_under_sustained_pressure(granite):
    """Under a sustained interactive backlog the ladder climbs off level 0
    and brownout-sheds batch admissions that plain class budgets would have
    queued; transitions land in the summary timeline."""
    cfg, params = granite
    rng = np.random.RandomState(17)
    reqs = [Request(i, list(rng.randint(0, 100, 12)), 6,
                    arrival=3e-4 * i, slo_class="interactive")
            for i in range(8)]
    reqs += [Request(100 + i, list(rng.randint(0, 100, 12)), 4,
                     arrival=2e-3 + 2e-3 * i, slo_class="batch")
             for i in range(4)]
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=64, tpot_budget_ms=6.0,
                           batch_tpot_budget_ms=30.0, brownout=True,
                           brownout_patience=4)
    results = system.serve(reqs, open_loop=True)
    sched = system.scheduler
    s = sched.summary()
    assert s["brownout_peak_level"] >= 1
    assert s["brownout_transitions"] >= 1
    assert s["brownout_timeline"], "transitions must be trace events"
    for t, frm, to in s["brownout_timeline"]:
        assert 0 <= frm <= 4 and 0 <= to <= 4 and abs(frm - to) == 1
    # Every interactive request completes; the browned-out batch tier is
    # what pays (shed by the ladder despite its queue-mode config).
    assert s["classes"]["interactive"]["completed"] == 8
    assert s["classes"]["interactive"]["shed"] == 0
    assert s["classes"]["batch"]["shed"] >= 1
    assert len(results) == len(reqs)
