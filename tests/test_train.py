"""Training substrate: optimizer math, loss descent, checkpoints, data."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from conftest import smoke
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import make_batch_iter
from repro.models import init_params
from repro.train import OptConfig, adamw_update, init_opt_state, lr_at, train


def test_lr_schedule():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_at(cfg, jnp.int32(0))) < 2e-4
    assert abs(float(lr_at(cfg, jnp.int32(10))) - 1e-3) < 1.2e-4
    assert float(lr_at(cfg, jnp.int32(99))) <= 1.2e-4 + 1e-3 * cfg.min_lr_frac


def test_adamw_grad_clip():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 1e6)}
    state = init_opt_state(params)
    _, _, m = adamw_update(OptConfig(grad_clip=1.0), params, grads, state)
    assert float(m["grad_norm"]) > 1e6  # reported pre-clip


def test_loss_decreases_and_microbatch_equivalence():
    cfg = smoke("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    it = make_batch_iter(cfg.vocab_size, 32, 8, seed=1)
    p1, hist = train(params, cfg, it, steps=20, log_every=100)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_microbatched_loss_matches_full():
    from repro.core.microbatch import microbatched_loss
    from repro.models import lm_loss
    cfg = smoke("qwen3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    it = make_batch_iter(cfg.vocab_size, 16, 4, seed=2)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    full, _ = lm_loss(params, cfg, batch)
    mb_fn = microbatched_loss(lambda p, b: lm_loss(p, cfg, b), 2)
    mb, _ = mb_fn(params, batch)
    np.testing.assert_allclose(float(full), float(mb), rtol=1e-4)


def test_checkpoint_roundtrip_multi_shard():
    cfg = smoke("olmoe-1b-7b")
    params = init_params(jax.random.PRNGKey(3), cfg)
    with tempfile.TemporaryDirectory() as d:
        man = save_checkpoint(d, params, 7, meta={"arch": cfg.name},
                              shard_bytes=1 << 20)
        assert len(man["shards"]) > 1  # actually sharded
        p2, step = load_checkpoint(d, params)
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic_and_learnable():
    it1 = make_batch_iter(1000, 32, 4, seed=9)
    it2 = make_batch_iter(1000, 32, 4, seed=9)
    b1, b2 = next(it1), next(it2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are the shifted tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
