"""Fault-tolerant serving: deterministic fault injection, engine-failure
recovery via replay re-prefill, transfer retry/backoff semantics, and
graceful degradation under capacity loss.

The load-bearing guarantee tested here end-to-end: a run with injected
faults (mid-decode engine crashes, RDMA timeouts/corruption, stragglers)
emits tokens **bit-identical** to the fault-free run — greedy decode is
deterministic, replay re-prefill is teacher-forced, so failure shows up
only on the virtual clock, never in content."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke
from repro.models import init_params, prefill
from repro.serving import (DecodeEngine, DecodePool, FaultEvent,
                           FaultInjector, FaultPlan, KVTransferEngine,
                           Request, RequestResult, ServingSystem,
                           TransferCorruption, TransferTimeout,
                           make_decode_router)
from repro.serving.transfer import cache_nbytes


@pytest.fixture(scope="module")
def granite():
    cfg = smoke("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def stream_requests(n, prompt_len=12, max_new=6, seed=1):
    rng = np.random.RandomState(seed)
    return [Request(i, list(rng.randint(0, 100, prompt_len)), max_new)
            for i in range(n)]


# ---------------------------------------------------------------------------
# Plan + injector semantics (pure control plane, no jax)
# ---------------------------------------------------------------------------


def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent("engine_on_fire")
    with pytest.raises(ValueError, match="explicit engine id"):
        FaultEvent("engine_crash")                      # engine defaults -1
    with pytest.raises(ValueError, match="unknown transfer op"):
        FaultEvent("transfer_timeout", op="broadcast")
    with pytest.raises(ValueError, match="factor"):
        FaultEvent("slow_engine", factor=0.5)           # speedup forbidden
    with pytest.raises(ValueError, match="count"):
        FaultEvent("transfer_corrupt", count=0)
    with pytest.raises(ValueError, match="duration"):
        FaultEvent("slow_engine", duration=0.0)


def test_fault_plan_parse_and_json_roundtrip():
    plan = FaultPlan.parse(
        '[{"kind": "engine_crash", "engine": 1, "at": 0.01},'
        ' {"kind": "slow_engine", "factor": 2.0, "duration": null}]')
    assert len(plan.events) == 2
    assert plan.events[1].duration == float("inf")      # null => unbounded
    again = FaultPlan.parse(plan.to_json())             # {"events": [...]}
    assert again.events == plan.events


def test_fault_plan_random_is_seed_deterministic():
    a = FaultPlan.random(7, n_engines=3, horizon_s=0.1)
    b = FaultPlan.random(7, n_engines=3, horizon_s=0.1)
    c = FaultPlan.random(8, n_engines=3, horizon_s=0.1)
    assert a.to_json() == b.to_json()
    assert a.to_json() != c.to_json()
    # guaranteed content: >=1 crash, and the first transfer fault is a
    # timeout (the acceptance criterion's minimum fault mix)
    kinds = [e.kind for e in a.events]
    assert "engine_crash" in kinds
    assert next(e for e in a.events
                if e.kind.startswith("transfer")).kind == "transfer_timeout"


def test_fault_plan_load_dispatch(tmp_path):
    inline = FaultPlan.load('[{"kind": "engine_crash", "engine": 0}]')
    assert inline.events[0].kind == "engine_crash"
    fn = tmp_path / "plan.json"
    fn.write_text(inline.to_json())
    assert FaultPlan.load(f"@{fn}").events == inline.events
    assert FaultPlan.load("random", seed=3, n_engines=2).to_json() \
        == FaultPlan.random(3, n_engines=2, horizon_s=0.5).to_json()


def test_injector_crashes_fire_once_by_engine_clock():
    plan = FaultPlan([FaultEvent("engine_crash", engine=1, at=0.01),
                      FaultEvent("engine_crash", engine=5, at=0.0)])
    inj = FaultInjector(plan)
    assert inj.due_crashes([0.0, 0.005]) == []          # not yet due
    # engine 5 is outside this pool: marked fired, never re-armed
    assert inj.due_crashes([0.02, 0.02]) == [1]         # due on OWN clock
    assert inj.crashes_fired == 1
    assert inj.due_crashes([9.9, 9.9]) == []            # fires exactly once


def test_injector_slowdown_windows():
    plan = FaultPlan([
        FaultEvent("slow_engine", engine=0, at=0.01, factor=2.0,
                   duration=0.01),
        FaultEvent("slow_engine", engine=-1, at=0.015, factor=3.0,
                   duration=0.001),
    ])
    inj = FaultInjector(plan)
    assert inj.slowdown(0, 0.005) == 1.0                # before the window
    assert inj.slowdown(0, 0.012) == 2.0
    assert inj.slowdown(0, 0.0155) == 3.0               # overlap: worst wins
    assert inj.slowdown(1, 0.0155) == 3.0               # engine=-1: everyone
    assert inj.slowdown(1, 0.012) == 1.0
    assert inj.slowdown(0, 0.02) == 1.0                 # window closed


def test_injector_transfer_fault_ordinal_addressing():
    plan = FaultPlan([
        FaultEvent("transfer_timeout", op="transfer", after=1, count=2),
        FaultEvent("transfer_corrupt", op="migrate", after=0, count=1),
    ])
    inj = FaultInjector(plan)
    # transfer attempts: #0 clean, #1 and #2 timeout, #3 clean again
    assert inj.transfer_fault("transfer") is None
    assert inj.transfer_fault("transfer") == "timeout"
    assert inj.transfer_fault("transfer") == "timeout"
    assert inj.transfer_fault("transfer") is None
    # migrate attempts are an independent ordinal space
    assert inj.transfer_fault("migrate") == "corrupt"
    assert inj.transfer_fault("migrate") is None
    assert (inj.timeouts_injected, inj.corruptions_injected) == (2, 1)


def test_injector_any_scope_counts_all_rdma_attempts():
    inj = FaultInjector(FaultPlan([
        FaultEvent("transfer_timeout", op="any", after=1, count=1)]))
    assert inj.transfer_fault("migrate") is None        # global attempt #0
    assert inj.transfer_fault("transfer") == "timeout"  # global attempt #1


def test_injector_chunk_scoped_event_hits_exactly_its_chunk():
    """Chunked streaming multiplies transfer attempts per request; a
    (rid, chunk)-scoped event must claim only that chunk's attempts while
    an unscoped event on the same plan keeps counting EVERY attempt in its
    legacy global ordinal space."""
    inj = FaultInjector(FaultPlan([
        FaultEvent("transfer_timeout", op="transfer", rid=7, chunk=2,
                   count=1),
        FaultEvent("transfer_corrupt", op="transfer", after=3, count=1),
    ]))
    # rid 7 streams chunks 0..2: only chunk 2 times out
    assert inj.transfer_fault("transfer", rid=7, chunk=0) is None
    assert inj.transfer_fault("transfer", rid=7, chunk=1) is None
    assert inj.transfer_fault("transfer", rid=7, chunk=2) == "timeout"
    # the unscoped corrupt counted all three attempts above: ordinal 3 is
    # the very next transfer attempt, whatever its rid/chunk
    assert inj.transfer_fault("transfer", rid=8, chunk=0) == "corrupt"
    # another request's chunk 2 is untouched (the scoped event is spent)
    assert inj.transfer_fault("transfer", rid=8, chunk=2) is None
    assert (inj.timeouts_injected, inj.corruptions_injected) == (1, 1)


def test_injector_rid_scope_is_an_independent_ordinal_space():
    """`after` on a rid-scoped event counts that request's own attempts,
    not the global stream — other requests' traffic cannot shift which
    attempt gets hit."""
    inj = FaultInjector(FaultPlan([
        FaultEvent("transfer_timeout", op="any", rid=5, after=1, count=1)]))
    assert inj.transfer_fault("transfer", rid=4, chunk=0) is None  # rid 4
    assert inj.transfer_fault("transfer", rid=4, chunk=1) is None
    assert inj.transfer_fault("transfer", rid=5, chunk=0) is None  # #0 of 5
    assert inj.transfer_fault("migrate", rid=5) == "timeout"       # #1 of 5
    with pytest.raises(ValueError, match="rid/chunk must be >= 0"):
        FaultEvent("transfer_timeout", rid=-2)


def test_chunk_scoped_timeout_under_streaming_changes_no_tokens(granite):
    """End-to-end: a timeout aimed at one stream chunk retries exactly
    that chunk — the pipelined handoff stays bit-identical and only the
    targeted request pays the retry latency."""
    cfg, params = granite
    reqs = stream_requests(3, max_new=4, seed=5)
    kw = dict(n_prefill=2, decode_batch=2, capacity=32,
              stream_handoff=True, stream_chunk=4)
    ref_sys = ServingSystem(params, cfg, **kw)
    ref = {r.rid: list(r.tokens) for r in ref_sys.serve(reqs)}
    ref_chunks = {t.rid: t.transfer_chunks
                  for t in ref_sys.scheduler.traces.values()}

    inj = FaultInjector(FaultPlan([
        FaultEvent("transfer_timeout", op="transfer", rid=1, chunk=1,
                   count=1)]))
    system = ServingSystem(params, cfg, fault_injector=inj, **kw)
    got = {r.rid: list(r.tokens) for r in system.serve(reqs)}
    assert got == ref
    assert inj.timeouts_injected == 1
    sched = system.scheduler
    assert sched.transfer_timeouts == 1 and sched.transfer_retries == 1
    for t in sched.traces.values():
        assert t.transfer_chunks == ref_chunks[t.rid]
        # only rid 1 pays the retry (timeout window + backoff) on the wire
        ref_t = ref_sys.scheduler.traces[t.rid]
        if t.rid == 1:
            assert t.transfer_seconds > ref_t.transfer_seconds
        else:
            assert t.transfer_seconds == pytest.approx(
                ref_t.transfer_seconds)


# ---------------------------------------------------------------------------
# KVTransferEngine: timeout + capped exponential backoff + fingerprints
# ---------------------------------------------------------------------------


def _payload():
    return {"k": jnp.arange(64, dtype=jnp.float32)}


def test_transfer_retries_through_timeouts_with_backoff():
    inj = FaultInjector(FaultPlan([
        FaultEvent("transfer_timeout", op="transfer", count=2)]))
    eng = KVTransferEngine(fault_hook=inj.transfer_fault, timeout_s=1e-3,
                           max_retries=3, backoff_base_s=1e-4,
                           backoff_cap_s=1.5e-4)
    payload = _payload()
    dt = eng.transfer(payload)
    # 2 timeout windows + 2 backoffs (1e-4, then capped 1.5e-4) + the wire
    wire_s = KVTransferEngine().transfer(_payload())
    assert dt == pytest.approx(2 * 1e-3 + 1e-4 + 1.5e-4 + wire_s)
    assert (eng.retries, eng.timeouts, eng.transfers) == (2, 2, 1)
    assert eng.clock.elapsed == pytest.approx(dt)


def test_transfer_exhaustion_raises_with_burned_seconds():
    inj = FaultInjector(FaultPlan([
        FaultEvent("transfer_timeout", op="transfer", count=99)]))
    eng = KVTransferEngine(fault_hook=inj.transfer_fault, timeout_s=1e-3,
                           max_retries=2, backoff_base_s=1e-4,
                           backoff_cap_s=1e-3)
    with pytest.raises(TransferTimeout, match="retries exhausted") as ei:
        eng.transfer(_payload())
    # 3 attempts (1 + 2 retries), each a full timeout window, 2 backoffs
    assert ei.value.attempts == 3
    assert ei.value.seconds == pytest.approx(3 * 1e-3 + 1e-4 + 2e-4)
    assert ei.value.seconds == pytest.approx(eng.clock.elapsed)
    assert eng.transfers == 0                           # never delivered


def test_transfer_corruption_charges_wire_then_retries():
    inj = FaultInjector(FaultPlan([
        FaultEvent("transfer_corrupt", op="migrate", count=1)]))
    eng = KVTransferEngine(fault_hook=inj.transfer_fault,
                           backoff_base_s=1e-4, backoff_cap_s=1e-4)
    payload = _payload()
    clean = KVTransferEngine().migrate(_payload())
    dt = eng.migrate(payload)
    # corrupted delivery pays full wire cost, then backoff, then the clean
    # delivery pays it again
    assert dt == pytest.approx(2 * clean + 1e-4)
    assert (eng.corruptions, eng.retries, eng.migrations) == (1, 1, 1)
    assert eng.fingerprint_checks == 2

    exhausted = KVTransferEngine(
        fault_hook=FaultInjector(FaultPlan([
            FaultEvent("transfer_corrupt", count=99)])).transfer_fault,
        max_retries=1, backoff_base_s=1e-4, backoff_cap_s=1e-4)
    with pytest.raises(TransferCorruption, match="corrupted"):
        exhausted.migrate(_payload())


def test_transfer_fault_free_path_is_cost_identical_to_seed():
    """With no hook — and even WITH a hook that stays silent — transfer
    cost must equal the seed engine's single plane charge exactly."""
    payload = _payload()
    seed = KVTransferEngine()
    base = seed.transfer(payload)
    hooked = KVTransferEngine(fault_hook=lambda op: None)
    assert hooked.transfer(payload) == base
    assert hooked.fingerprint_checks == 1               # verified, found OK
    nbytes = cache_nbytes(payload)
    assert seed.bytes_moved == hooked.bytes_moved == nbytes


# ---------------------------------------------------------------------------
# DecodePool.fail_engine: conservation, dead != parked, router residency
# ---------------------------------------------------------------------------


def test_fail_engine_releases_slots_and_clears_residency(granite):
    cfg, params = granite
    # batch 3: engine 1 keeps a free slot after two admits, so affinity
    # (not the full-engine deprioritization) decides routing below
    pool = DecodePool(
        [DecodeEngine(params, cfg, 3, 24, seed=e) for e in range(2)],
        make_decode_router("cache_affinity", 2))
    logits, caches = prefill(params, cfg,
                             {"tokens": jnp.asarray([[1, 2, 3, 4]],
                                                    jnp.int32)},
                             capacity=24, cache_dtype=jnp.float32)
    first = int(jnp.argmax(logits[0, -1]))
    keys = ("cc:p0", "cc:p1")
    for rid, engine in ((0, 1), (1, 1), (2, 0)):
        res = RequestResult(rid, [])
        pool.add(engine, pool.engines[engine].free_slot(), caches, first,
                 4, res, 5, block_keys=keys if engine == 1 else ())
    assert pool.router.residency(1, keys) == 2
    assert pool.select_engine(keys) == 1                # affinity pins 1

    lost = pool.fail_engine(1)
    assert sorted(rid for rid, _, _ in lost) == [0, 1]
    assert all(cl == 4 for _, _, cl in lost)
    # dead is distinct from parked, and the roster reflects it
    assert pool.dead_ids == [1] and pool.n_dead == 1
    assert pool.live_ids == [0] and pool.failures == 1
    # conservation across the failure: acquired == released + active
    mgr = pool.engines[1].slot_mgr
    assert mgr.acquired == mgr.released + mgr.active == 2
    assert mgr.active == 0
    # stale residency cleared: affinity must not route to the dead engine
    assert pool.router.residency(1, keys) == 0
    assert pool.select_engine(keys) == 0
    with pytest.raises(ValueError, match="already dead"):
        pool.fail_engine(1)

    # revival is a restart over the stable id
    engine, revived = pool.spawn_engine()
    assert (engine, revived) == (1, True)
    assert pool.dead_ids == [] and pool.n_live == 2


def test_spawn_prefers_parked_over_dead(granite):
    """A parked engine (warm state) revives before a dead one (restart)."""
    cfg, params = granite
    pool = DecodePool(
        [DecodeEngine(params, cfg, 2, 24, seed=e) for e in range(3)],
        make_decode_router("round_robin", 3))
    pool.fail_engine(2)
    pool.retire_engine(1)                               # parked, not dead
    engine, revived = pool.spawn_engine()
    assert (engine, revived) == (1, True)               # warm unpark first
    engine, revived = pool.spawn_engine()
    assert (engine, revived) == (2, True)               # then the restart


# ---------------------------------------------------------------------------
# End-to-end: crash mid-decode, recover by replay, tokens identical
# ---------------------------------------------------------------------------


def _fault_free_reference(params, cfg, reqs, **kw):
    system = ServingSystem(params, cfg, **kw)
    return {r.rid: list(r.tokens) for r in system.serve(
        [Request(r.rid, list(r.prompt), r.max_new_tokens, r.arrival)
         for r in reqs])}


def test_engine_crash_recovery_token_identity(granite):
    """The tentpole guarantee: a mid-decode engine crash loses nothing —
    every in-flight request is recovered by re-prefilling its EMS-cached
    prefix + teacher-forced replay of the tokens it had already emitted,
    and the final stream is bit-identical to the fault-free run."""
    cfg, params = granite
    reqs = stream_requests(5, max_new=6)
    kw = dict(n_prefill=2, decode_batch=2, capacity=32, decode_engines=2,
              decode_router="least_loaded_slots", autoscale=True,
              min_engines=2, max_engines=3)
    ref = _fault_free_reference(params, cfg, reqs, **kw)

    inj = FaultInjector(FaultPlan([
        FaultEvent("engine_crash", engine=1, at=0.004)]))
    system = ServingSystem(params, cfg, fault_injector=inj, **kw)
    results = system.serve(reqs)
    got = {r.rid: list(r.tokens) for r in results}
    assert got == ref
    assert not any(r.shed for r in results)

    s = system.scheduler.summary()
    assert inj.crashes_fired == 1
    assert s["engine_failures"] == 1
    assert s["recoveries"] >= 1
    assert s["tokens_replayed"] >= 1
    assert s["recovery_ttft_p50_s"] > 0
    assert s["recovery_ttft_p99_s"] >= s["recovery_ttft_p50_s"]
    # recovery latency is charged to the recovered traces
    recovered = [t for t in system.scheduler.tracker.finished
                 if t.recoveries > 0]
    assert len(recovered) == s["recoveries"]
    assert all(t.recovery_seconds > 0 for t in recovered)
    assert sum(t.tokens_replayed for t in recovered) == s["tokens_replayed"]
    # the autoscaler respawned toward min_engines after the capacity loss
    assert system.pool.n_live >= 2
    assert any(e["action"] == "fail" for e in system.scheduler.scale_events)


def test_transfer_timeouts_do_not_change_tokens(granite):
    cfg, params = granite
    reqs = stream_requests(4, max_new=4, seed=2)
    kw = dict(n_prefill=2, decode_batch=2, capacity=32, decode_engines=2)
    ref = _fault_free_reference(params, cfg, reqs, **kw)
    inj = FaultInjector(FaultPlan([
        FaultEvent("transfer_timeout", op="transfer", after=1, count=2)]))
    system = ServingSystem(params, cfg, fault_injector=inj, **kw)
    got = {r.rid: list(r.tokens) for r in system.serve(reqs)}
    assert got == ref
    s = system.scheduler.summary()
    assert s["retries"] == s["transfer_timeouts"] == 2
    assert s["engine_failures"] == 0 and s["recoveries"] == 0
    assert system.transfer.retries == 2


def test_straggler_slows_clock_but_not_content(granite):
    cfg, params = granite
    reqs = stream_requests(4, max_new=5, seed=3)
    kw = dict(n_prefill=1, decode_batch=2, capacity=32, decode_engines=2)
    ref_sys = ServingSystem(params, cfg, **kw)
    ref = {r.rid: list(r.tokens) for r in ref_sys.serve(
        [Request(r.rid, list(r.prompt), r.max_new_tokens) for r in reqs])}
    ref_busy = ref_sys.scheduler.summary()["engine_busy_s"]

    inj = FaultInjector(FaultPlan([
        FaultEvent("slow_engine", engine=0, at=0.0, factor=3.0)]))
    system = ServingSystem(params, cfg, fault_injector=inj, **kw)
    got = {r.rid: list(r.tokens) for r in system.serve(reqs)}
    assert got == ref                                   # content unchanged
    busy = system.scheduler.summary()["engine_busy_s"]
    # the straggler burned ~3x the virtual time for the same steps
    assert busy[0] == pytest.approx(3.0 * ref_busy[0], rel=1e-6)
    assert busy[1] == pytest.approx(ref_busy[1], rel=1e-6)


def test_total_capacity_loss_sheds_instead_of_hanging(granite):
    """Graceful degradation floor: with the whole pool dead and no
    autoscaler to respawn, the system shed-fails deterministically rather
    than deadlocking with work it can never place."""
    cfg, params = granite
    reqs = stream_requests(3, max_new=6, seed=4)
    inj = FaultInjector(FaultPlan([
        FaultEvent("engine_crash", engine=0, at=0.002)]))
    system = ServingSystem(params, cfg, n_prefill=1, decode_batch=2,
                           capacity=32, fault_injector=inj)
    results = system.serve(reqs)
    assert len(results) == 3
    assert any(r.shed for r in results)                 # degraded, not hung
    s = system.scheduler.summary()
    assert s["engine_failures"] == 1
    assert s["completed"] + s["shed"] == 3
    assert system.pool.n_live == 0


def test_autoscaler_respawns_after_crash_and_completes_all(granite):
    """Same total-loss scenario WITH an autoscaler: the dead engine is
    respawned toward min_engines (bypassing hysteresis) and every request
    completes with fault-free content."""
    cfg, params = granite
    reqs = stream_requests(3, max_new=6, seed=4)
    kw = dict(n_prefill=1, decode_batch=2, capacity=32, autoscale=True,
              min_engines=1, max_engines=2)
    ref = _fault_free_reference(params, cfg, reqs, **kw)
    inj = FaultInjector(FaultPlan([
        FaultEvent("engine_crash", engine=0, at=0.002)]))
    system = ServingSystem(params, cfg, fault_injector=inj, **kw)
    results = system.serve(reqs)
    assert {r.rid: list(r.tokens) for r in results} == ref
    assert not any(r.shed for r in results)
    assert system.pool.n_live >= 1
    events = [e["action"] for e in system.scheduler.scale_events]
    assert "fail" in events and "grow" in events


def test_degrade_shed_queue_bounds_backlog(granite):
    """degrade_shed_queue_s sheds queue-mode admissions held past the
    threshold — the post-failure backlog stays bounded instead of every
    request waiting out the capacity dip."""
    cfg, params = granite
    reqs = stream_requests(8, max_new=6, seed=5)
    inj = FaultInjector(FaultPlan([
        FaultEvent("engine_crash", engine=0, at=0.002)]))
    system = ServingSystem(params, cfg, n_prefill=1, decode_batch=2,
                           capacity=32, decode_engines=2,
                           degrade_shed_queue_s=1e-4, fault_injector=inj)
    results = system.serve(reqs)
    s = system.scheduler.summary()
    assert s["engine_failures"] == 1
    assert s["shed"] >= 1                               # threshold bit
    assert s["completed"] + s["shed"] == len(reqs)
    # shed is recorded on the traces, not silently dropped
    assert sum(r.shed for r in results) == s["shed"]
