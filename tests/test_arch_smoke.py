"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward + one train step on CPU, asserting output
shapes and no NaNs; decode-capable archs additionally run one serve_step."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch, smoke
from repro.configs import ASSIGNED_ARCHS, get_config, smoke_variant
from repro.models import decode_step, forward, init_params, prefill
from repro.train import OptConfig, make_train_step, init_opt_state

ALL_ARCHS = ASSIGNED_ARCHS + ["deepseek-r1"]


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = smoke(name)
            params = init_params(jax.random.PRNGKey(0), cfg)
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nan(arch, arch_state):
    cfg, params = arch_state(arch)
    b, s = 2, 32
    batch = make_batch(cfg, b, s)
    logits, aux = forward(params, cfg, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN logits"
    assert not bool(jnp.isnan(aux["aux_loss"]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch, arch_state):
    cfg, params = arch_state(arch)
    batch = make_batch(cfg, 2, 32)
    step = make_train_step(cfg, OptConfig(total_steps=10, warmup_steps=2))
    opt = init_opt_state(params)
    new_params, opt, metrics = step(params, opt, batch)
    assert not bool(jnp.isnan(metrics["loss"])), f"{arch}: NaN loss"
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    changed = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert changed


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_serve_step(arch, arch_state):
    cfg, params = arch_state(arch)
    if not cfg.supports_decode:
        pytest.skip("encoder-only: decode shapes skipped (DESIGN.md §3)")
    b, s = 2, 32
    batch = make_batch(cfg, b, s)
    batch.pop("labels", None)
    logits, caches = prefill(params, cfg, batch, capacity=s + 8,
                             cache_dtype=jnp.float32)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    dl, caches = decode_step(params, cfg, tok, caches, jnp.int32(s))
    assert dl.shape == (b, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(dl))), f"{arch}: NaN decode logits"
