"""Continuous batching in the scanned decode loop: adaptive chunk widths +
mid-scan slot refill must emit bit-identical tokens to per-step decode
(chunk-split invariance of `model.decode_loop`), never admit later than the
wave-shaped chunked loop, and drive the dead-slot rate — masked iterations
burned on resident-but-finished slots — measurably down. Also covers the
satellite accounting fixes: masked-iteration attribution in the trace,
unified shed semantics (gate shed == capacity reject: no tokens delivered),
shed-inclusive queue percentiles, and full-prompt shared prefixes in the
synthetic workload."""
import jax
import numpy as np
import pytest

from conftest import smoke
from repro.core import init_mtp_params
from repro.models import init_params
from repro.serving import (Request, SchedulerConfig, ServingSystem,
                           poisson_requests)
from repro.serving.scheduler import RequestTrace, SLOTracker

_PARAMS = {}


def model(arch):
    if arch not in _PARAMS:
        cfg = smoke(arch)
        _PARAMS[arch] = (cfg, init_params(jax.random.PRNGKey(0), cfg))
    return _PARAMS[arch]


def _burst(n=8, rate=300.0, plen=10, max_new=6, seed=7):
    return poisson_requests(n, rate, plen, max_new, 100, seed=seed)


def _clone(reqs):
    return [Request(r.rid, list(r.prompt), r.max_new_tokens, r.arrival)
            for r in reqs]


def _serve(params, cfg, reqs, *, chunk, cb, open_loop, **kw):
    kw.setdefault("decode_batch", 2)
    system = ServingSystem(params, cfg, n_prefill=2, capacity=32,
                           decode_chunk=chunk,
                           continuous_batching=cb or None, **kw)
    results = system.serve(_clone(reqs), open_loop=open_loop)
    return {r.rid: r for r in results}, system.scheduler


# ---------------------------------------------------------------------------
# Tentpole: token identity of the continuous path vs per-step decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-8b",        # dense attention
                                  "deepseek-r1",     # MLA latent cache
                                  "olmoe-1b-7b"])    # MoE
def test_cb_token_identical_to_per_step(arch):
    """Adaptive chunks + mid-scan refill emit the same tokens as per-step
    decode, closed AND open loop, with identical per-request decode_iters
    (masked iterations must not leak into the trace)."""
    cfg, params = model(arch)
    reqs = _burst()
    for open_loop in (False, True):
        ref, _ = _serve(params, cfg, reqs, chunk=1, cb=False,
                        open_loop=open_loop)
        out, sched = _serve(params, cfg, reqs, chunk=4, cb=True,
                            open_loop=open_loop)
        assert set(out) == set(ref)
        for rid in ref:
            assert out[rid].tokens == ref[rid].tokens, (arch, open_loop, rid)
            assert out[rid].decode_iters == ref[rid].decode_iters
        # adaptive widths snap down to where the shortest request ends, so
        # the continuous path plans no dead iterations of its own
        assert sched.summary()["dead_slot_rate"] == 0.0


def test_cb_token_identical_with_mtp():
    """MTP speculation on the continuous path: greedy accept/reject is
    PRNG-independent, so chunk-split invariance carries over."""
    cfg, params = model("granite-3-2b")
    mtp = init_mtp_params(jax.random.PRNGKey(2), cfg)
    reqs = _burst(n=6)
    for open_loop in (False, True):
        ref, _ = _serve(params, cfg, reqs, chunk=1, cb=False,
                        open_loop=open_loop, use_mtp=True, mtp_params=mtp)
        out, _ = _serve(params, cfg, reqs, chunk=4, cb=True,
                        open_loop=open_loop, use_mtp=True, mtp_params=mtp)
        for rid in ref:
            assert out[rid].tokens == ref[rid].tokens, (open_loop, rid)
            assert out[rid].decode_iters == ref[rid].decode_iters


def test_cb_mid_scan_refill_on_autoscaled_pool():
    """A refill landing mid-wave on a pooled + autoscaled run: freed slots
    are refilled between engine chunks (mid_scan_refills > 0) and the
    tokens still match a per-step autoscaled serve bit-exactly."""
    cfg, params = model("granite-3-2b")
    reqs = _burst(n=10, rate=400.0, seed=5)
    pool_kw = dict(decode_engines=1, autoscale=True, min_engines=1,
                   max_engines=3)
    ref, _ = _serve(params, cfg, reqs, chunk=1, cb=False, open_loop=True,
                    **pool_kw)
    out, sched = _serve(params, cfg, reqs, chunk=4, cb=True, open_loop=True,
                        **pool_kw)
    for rid in ref:
        assert out[rid].tokens == ref[rid].tokens, rid
    s = sched.summary()
    assert s["mid_scan_refills"] > 0
    assert s["scale_grows"] >= 1                # the burst did scale out
    # per-engine masked-iteration ledgers reconcile with the global one
    assert sum(s["engine_masked_iters"]) == s["masked_slot_iters"]


def test_cb_is_control_plane_flippable():
    """continuous_batching is deliberately NOT baked: widths jit lazily,
    so reconfigure_scheduler can flip it between waves on one system."""
    cfg, params = model("qwen3-8b")
    reqs = _burst(n=4)
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=32, decode_chunk=4)
    off = {r.rid: r.tokens for r in system.serve(_clone(reqs))}
    system.reconfigure_scheduler(SchedulerConfig(decode_chunk=4,
                                                 continuous_batching=True))
    on = {r.rid: r.tokens for r in system.serve(_clone(reqs))}
    assert on == off


# ---------------------------------------------------------------------------
# Tentpole acceptance: dead-slot rate down, admissions never later
# ---------------------------------------------------------------------------


def test_cb_lowers_dead_slot_rate_and_never_admits_later():
    """Identical arrival trace through the wave-shaped chunked loop vs the
    continuous path: same tokens, measurably lower dead-slot rate, no
    request admitted later, and the TPOT gate still holds."""
    cfg, params = model("granite-3-2b")
    # max_new=6 -> 5 decode iters, != 0 mod chunk 4: the wave-shaped loop
    # provably burns masked tail iterations on the shortest slot.
    reqs = _burst(n=8, rate=300.0, max_new=6)
    kw = dict(open_loop=True, decode_batch=3, tpot_budget_ms=9.0,
              admission="queue")
    off, s_off = _serve(params, cfg, reqs, chunk=4, cb=False, **kw)
    on, s_on = _serve(params, cfg, reqs, chunk=4, cb=True, **kw)
    for rid in off:
        assert on[rid].tokens == off[rid].tokens, rid
    so, sn = s_off.summary(), s_on.summary()
    assert so["dead_slot_rate"] > 0.0            # the bug is observable
    assert sn["dead_slot_rate"] < so["dead_slot_rate"]
    assert sn["mid_scan_refills"] > 0
    assert sn["tpot_max_s"] <= 9.0e-3 + 1e-12    # gate never violated
    for rid, tr in s_on.traces.items():
        assert tr.decode_admit <= s_off.traces[rid].decode_admit + 1e-12


# ---------------------------------------------------------------------------
# Satellite 1: masked-iteration attribution in the trace
# ---------------------------------------------------------------------------


def test_masked_iterations_attributed_not_charged():
    """With chunk 4 and max_new 6 the wave-shaped loop dispatches masked
    iterations; they must land in trace.masked_iters — NOT in
    decode_iters, decode_seconds, or the virtual clock."""
    cfg, params = model("granite-3-2b")
    rng = np.random.RandomState(3)
    reqs = [Request(i, list(rng.randint(0, 100, 10)), 6) for i in range(4)]
    out, sched = _serve(params, cfg, reqs, chunk=4, cb=False,
                        open_loop=False, decode_batch=3)
    recs = {r["rid"]: r for r in sched.trace_records()}
    for rid, r in out.items():
        assert recs[rid]["decode_iters"] == r.decode_iters == 5
        assert recs[rid]["tokens_out"] == 6
    s = sched.summary()
    assert s["masked_slot_iters"] > 0
    assert sum(rec["masked_iters"] for rec in recs.values()) \
        == s["masked_slot_iters"]
    # masked iterations charge zero virtual time: total decode time equals
    # the per-iteration charge over live batch sizes only
    assert s["dead_slot_rate"] == pytest.approx(
        s["masked_slot_iters"]
        / (s["masked_slot_iters"] + s["live_slot_iters"]))


# ---------------------------------------------------------------------------
# Satellite 2: unified shed semantics
# ---------------------------------------------------------------------------


def test_gate_shed_and_capacity_reject_deliver_no_tokens():
    """Both rejection paths agree: shed=True, tokens == [], tokens_out == 0
    — the prefill-produced first token of a gate shed is discarded, not
    leaked into throughput."""
    cfg, params = model("granite-3-2b")
    rng = np.random.RandomState(11)
    reqs = [Request(i, list(rng.randint(0, 100, 10)), 4) for i in range(6)]
    reqs.append(Request(6, list(rng.randint(0, 100, 30)), 8))  # 30+7 > 32
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=4,
                           capacity=32, tpot_budget_ms=6.0, admission="shed")
    results = {r.rid: r for r in system.serve(reqs)}
    recs = {r["rid"]: r for r in system.scheduler.trace_records()}
    shed = [r for r in results.values() if r.shed]
    assert results[6].shed                       # capacity reject
    assert any(r.rid != 6 for r in shed)         # gate demonstrably shed
    for r in shed:
        assert r.tokens == [] and r.decode_iters == 0
        assert recs[r.rid]["tokens_out"] == 0
    # throughput counts only delivered tokens
    assert system.scheduler.decode_token_count \
        == sum(len(r.tokens) for r in results.values() if not r.shed) \
        - sum(1 for r in results.values() if not r.shed)  # 1st from prefill
    # gate sheds stamp their queue time; capacity rejects never queued
    assert recs[6]["queue_seconds"] == 0.0
    for r in shed:
        if r.rid != 6:
            assert recs[r.rid]["decode_admit"] >= recs[r.rid]["prefill_end"]


# ---------------------------------------------------------------------------
# Satellite 3: queue percentiles include shed traces
# ---------------------------------------------------------------------------


def test_queue_p99_includes_shed_traces():
    tracker = SLOTracker()
    fin = RequestTrace(0, decode_admit=0.1, decode_end=0.2, decode_iters=1,
                       decode_tokens=1, decode_seconds=0.1, tokens_out=2)
    tracker.record(fin)
    shed = RequestTrace(1, decode_admit=5.0, decode_end=5.0, shed=True)
    tracker.record(shed)
    s = tracker.summary()
    assert fin.queue_seconds == pytest.approx(0.1)
    assert shed.queue_seconds == pytest.approx(5.0)
    # the pooled percentile sees the shed request's 5 s wait
    assert s["queue_p99_s"] > 1.0
    assert s["queue_p99_shed_s"] == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# Satellite 4: shared_prefix == prompt_len in the synthetic workload
# ---------------------------------------------------------------------------


def test_poisson_requests_full_prompt_shared_prefix():
    """shared_prefix == prompt_len models a fully-cached multi-turn
    re-entry stream: every prompt is the same block-aligned prefix."""
    reqs = poisson_requests(4, 100.0, 8, 4, 100, seed=0, shared_prefix=8)
    assert len({tuple(r.prompt) for r in reqs}) == 1
    assert all(len(r.prompt) == 8 for r in reqs)
    with pytest.raises(ValueError, match="shared_prefix"):
        poisson_requests(4, 100.0, 8, 4, 100, seed=0, shared_prefix=9)
    with pytest.raises(ValueError, match="shared_prefix"):
        poisson_requests(4, 100.0, 8, 4, 100, seed=0, shared_prefix=-1)
    # and the stream actually serves; reuse caps at prompt_len - 1 (the
    # last token must be computed for first-token logits) block-aligned,
    # so block 4 under an 8-token fully-shared prompt reuses exactly 4
    from repro.mempool import ContextCache, MemoryPool
    cfg, params = model("qwen3-8b")
    cc = ContextCache(MemoryPool(n_nodes=4), block_tokens=4,
                      model_tag=cfg.name)
    reqs = poisson_requests(4, 100.0, 8, 4, cfg.vocab_size, seed=0,
                            shared_prefix=8)
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=32, context_cache=cc)
    results = system.serve(reqs, open_loop=True)
    assert all(len(r.tokens) == 4 for r in results)
    assert any(r.reused_tokens == 4 for r in results)
    for r in results:
        assert r.reused_tokens + r.computed_tokens == 8
