"""serving/transfer.py: KV handoff exactness + RDMA-plane accounting +
the paper's deterministic group connection mapping (§4.3.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke
from repro.models import decode_step, init_params, make_caches, prefill
from repro.serving import cache_ops
from repro.serving.transfer import (
    KVTransferEngine,
    RDMA_PLANE,
    cache_nbytes,
    connection_map,
    live_connection_map,
    prefill_source_rank,
    transfer_balance,
)

PROMPT_LEN = 16
CAPACITY = 32


@pytest.fixture(scope="module")
def prefilled():
    cfg = smoke("qwen3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompt = list(rng.randint(0, 200, PROMPT_LEN))
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    logits, caches = prefill(params, cfg, batch, capacity=CAPACITY,
                             cache_dtype=jnp.float32)
    return cfg, params, prompt, logits, caches


def _handoff_roundtrip(cfg, caches, length):
    """Serialize the prompt KV region and rebuild it on a fresh 'instance'."""
    payload = cache_ops.seq_slice(cfg, caches, 0, length)
    flat = cache_ops.pack_payload(payload)            # the transferred bytes
    decode_side = make_caches(cfg, 1, CAPACITY, jnp.float32)
    rebuilt_payload = cache_ops.unpack_payload(flat, payload)
    return cache_ops.seq_insert(cfg, decode_side, rebuilt_payload, 0)


def test_kv_handoff_preserves_exact_bytes(prefilled):
    """Pack → (RDMA) → unpack → insert reproduces the KV region bit-exactly."""
    cfg, params, prompt, _, caches = prefilled
    rebuilt = _handoff_roundtrip(cfg, caches, PROMPT_LEN)
    src = cache_ops.seq_slice(cfg, caches, 0, PROMPT_LEN)
    dst = cache_ops.seq_slice(cfg, rebuilt, 0, PROMPT_LEN)
    src_leaves, dst_leaves = jax.tree.leaves(src), jax.tree.leaves(dst)
    assert len(src_leaves) == len(dst_leaves) > 0
    for a, b in zip(src_leaves, dst_leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_from_handed_off_cache_matches_direct(prefilled):
    """Greedy continuation from the transferred cache == continuation from
    the original — the functional definition of a lossless P→D handoff."""
    cfg, params, prompt, logits, caches = prefilled
    rebuilt = _handoff_roundtrip(cfg, caches, PROMPT_LEN)
    tok = int(jnp.argmax(logits[0, PROMPT_LEN - 1]))

    def continue_greedy(cache, n=4):
        toks, cl, t = [], jnp.int32(PROMPT_LEN), tok
        for _ in range(n):
            lg, cache = decode_step(params, cfg,
                                    jnp.asarray([[t]], jnp.int32), cache, cl)
            t = int(jnp.argmax(lg[0]))
            toks.append(t)
            cl = cl + 1
        return toks

    assert continue_greedy(rebuilt) == continue_greedy(caches)


def test_insert_request_roundtrips_across_batched_instance(prefilled):
    """slice_request(insert_request(x)) == x for every decode slot."""
    cfg, params, prompt, _, caches = prefilled
    decode_batch = make_caches(cfg, 3, CAPACITY, jnp.float32)
    for slot in (0, 2):
        inserted = cache_ops.insert_request(cfg, decode_batch, caches, slot)
        back = cache_ops.slice_request(cfg, inserted, slot)
        for a, b in zip(jax.tree.leaves(
                cache_ops.seq_slice(cfg, caches, 0, PROMPT_LEN)),
                jax.tree.leaves(
                cache_ops.seq_slice(cfg, back, 0, PROMPT_LEN))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_transfer_engine_charges_rdma_plane(prefilled):
    cfg, _, _, _, caches = prefilled
    eng = KVTransferEngine()
    nbytes = cache_nbytes(caches)
    assert nbytes > 0
    dt = eng.transfer(caches)
    assert dt == pytest.approx(RDMA_PLANE.latency + nbytes / RDMA_PLANE.bandwidth)
    assert eng.transfers == 1 and eng.bytes_moved == nbytes
    eng.transfer(caches)
    assert eng.transfers == 2 and eng.bytes_moved == 2 * nbytes
    assert eng.clock.elapsed == pytest.approx(2 * dt)


def test_connection_map_deterministic_and_balanced():
    m1 = connection_map(prefill_tp=8, decode_tp=4, decode_dp=4)
    m2 = connection_map(prefill_tp=8, decode_tp=4, decode_dp=4)
    assert m1 == m2                                   # deterministic formula
    assert len(m1) == 16
    assert transfer_balance(m1, prefill_tp=8) == 1.0  # perfectly balanced
    # every decode rank pulls from a valid prefill source
    assert all(0 <= src < 8 for src in m1.values())
    # spot-check the paper formula directly
    assert prefill_source_rank(8, 4, 4, decode_tp_rank=1, decode_dp_rank=3) \
        == m1[(1, 3)]


def test_live_connection_map_tracks_the_roster():
    # the full contiguous roster reduces to the paper's static formula
    assert live_connection_map([0, 1, 2, 3], decode_tp=2, decode_dp=2) \
        == connection_map(prefill_tp=4, decode_tp=2, decode_dp=2)
    # a pooled roster with parked/failed ids: every source is live, the
    # map is deterministic (roster order does not matter), and the balance
    # is recomputed over exactly the live ranks
    roster = [3, 0, 2]                        # instance 1 parked
    m = live_connection_map(roster, decode_tp=2, decode_dp=2)
    assert m == live_connection_map([0, 2, 3], decode_tp=2, decode_dp=2)
    assert set(m.values()) <= {0, 2, 3}
    # pulls land evenly on the ranks the formula selects (min/max over
    # the non-zero pullers; a live rank with no pulls is not an imbalance)
    assert transfer_balance(m, prefill_tp=4, live_ranks=roster) == 1.0
    with pytest.raises(ValueError, match="at least one live rank"):
        live_connection_map([], decode_tp=2, decode_dp=2)


def test_transfer_balance_rejects_stale_mapping():
    """A mapping computed before a retirement still points at the retired
    rank; recomputing the balance against the shrunken roster must fail
    loudly instead of silently folding its pulls onto a live rank."""
    full = connection_map(prefill_tp=4, decode_tp=2, decode_dp=2)
    assert 1 in set(full.values())
    with pytest.raises(ValueError, match="stale connection map"):
        transfer_balance(full, prefill_tp=4, live_ranks=[0, 2, 3])
    # the legacy static-roster call is untouched by the live path
    assert transfer_balance(full, prefill_tp=4) == 1.0
    with pytest.raises(ValueError, match="at least one live rank"):
        transfer_balance(full, prefill_tp=4, live_ranks=[])
