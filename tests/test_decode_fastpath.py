"""Device-resident decode fast path: scanned multi-step decode
(`model.decode_loop`), chunked suffix prefill (`model.prefill_continue`),
batched EMS block packing, single-collective quantized LEP dispatch, and the
chunked serving path end-to-end."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke
from repro.models import (decode_loop, decode_step, init_params, prefill,
                          prefill_continue)
from repro.serving import (DecodeCostModel, MicrobatchInterleaver, Request,
                           SchedulerConfig, ServingSystem,
                           decode_cost_from_roofline)
from repro.serving import cache_ops

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def qwen():
    cfg = smoke("qwen3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prefill_batch(cfg, params, n_req=2, plen=12, capacity=32, seed=0):
    rng = np.random.RandomState(seed)
    prompts = [list(rng.randint(0, 200, plen)) for _ in range(n_req)]
    logits, caches = prefill(params, cfg, {"tokens": jnp.asarray(prompts,
                                                                 jnp.int32)},
                             capacity=capacity, cache_dtype=jnp.float32)
    tok0 = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    cl0 = jnp.full((n_req,), plen, jnp.int32)
    return prompts, tok0, caches, cl0


def _sequential(cfg, params, tok, caches, cl, n, step=None):
    step = step or (lambda t, c, l: decode_step(params, cfg, t, c, l))
    seq = []
    for _ in range(n):
        lg, caches = step(tok[:, None], caches, cl)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        cl = cl + 1
        seq.append(np.asarray(tok))
    return np.stack(seq, 1), caches, cl


def _content_equal(a, b):
    """Bitwise equality of every cache leaf (length bookkeeping leaves may
    legitimately be scalar on one side and per-slot on the other)."""
    oks = jax.tree.leaves(jax.tree.map(
        lambda x, y: bool(jnp.array_equal(jnp.broadcast_to(x, y.shape)
                                          if x.shape != y.shape else x, y)),
        a, b))
    return all(oks)


# ---------------------------------------------------------------------------
# decode_loop(n) == n sequential decode_step calls
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-r1", "olmoe-1b-7b",
                                  "zamba2-1.2b"])
def test_decode_loop_matches_sequential(arch):
    cfg = smoke(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    _, tok0, caches, cl0 = _prefill_batch(cfg, params)
    n = 4
    seq, caches_s, _ = _sequential(cfg, params, tok0, caches, cl0, n)
    em, lv, _, caches_l, clf = decode_loop(params, cfg, tok0, caches, cl0, n)
    assert np.array_equal(np.asarray(em), seq)
    assert np.asarray(lv).all()
    assert np.array_equal(np.asarray(clf), np.asarray(cl0) + n)
    assert _content_equal(caches_s, caches_l)


def test_decode_loop_per_slot_masking(qwen):
    """A slot whose steps_left runs out mid-chunk freezes bit-exactly."""
    cfg, params = qwen
    _, tok0, caches, cl0 = _prefill_batch(cfg, params)
    seq, _, _ = _sequential(cfg, params, tok0, caches, cl0, 5)
    em, lv, _, caches_m, clm = decode_loop(
        params, cfg, tok0, caches, cl0, 5,
        steps_left=jnp.asarray([5, 2], jnp.int32))
    em, lv = np.asarray(em), np.asarray(lv)
    assert np.array_equal(em[0], seq[0])
    assert np.array_equal(em[1, :2], seq[1, :2])
    assert lv.tolist() == [[True] * 5, [True, True, False, False, False]]
    assert np.asarray(clm).tolist() == [17, 14]
    # the frozen slot's cache content must equal a 2-step sequential run
    # (length bookkeeping is global per-batch, so compare batched leaves)
    _, caches_2, _ = _sequential(cfg, params, tok0, caches, cl0, 2)
    sl_m = cache_ops.slice_request(cfg, caches_m, 1)
    sl_2 = cache_ops.slice_request(cfg, caches_2, 1)
    axes = cache_ops.cache_batch_axes(cfg, caches)
    oks = jax.tree.leaves(jax.tree.map(
        lambda x, y, ax: True if ax is None else bool(jnp.array_equal(x, y)),
        sl_2, sl_m, axes))
    assert all(oks)


def test_decode_loop_capacity_masking(qwen):
    """Slots at cache capacity stop advancing instead of corrupting KV."""
    cfg, params = qwen
    _, tok0, caches, cl0 = _prefill_batch(cfg, params, capacity=14)  # 2 free
    em, lv, _, _, clf = decode_loop(params, cfg, tok0, caches, cl0, 5)
    assert np.asarray(clf).tolist() == [14, 14]
    assert np.asarray(lv)[:, :2].all() and not np.asarray(lv)[:, 2:].any()


def test_decode_loop_interleaved_matches_sequential(qwen):
    """Byte-exactness holds when the inner step is microbatch-interleaved."""
    cfg, params = qwen
    _, tok0, caches, cl0 = _prefill_batch(cfg, params)
    wrap = MicrobatchInterleaver(2).wrap(
        lambda t, c, l: decode_step(params, cfg, t, c, l), 2)
    seq, caches_s, _ = _sequential(cfg, params, tok0, caches, cl0, 4,
                                   step=wrap)
    em, lv, _, caches_l, _ = decode_loop(params, cfg, tok0, caches, cl0, 4,
                                         step_fn=wrap)
    assert np.array_equal(np.asarray(em), seq)
    assert _content_equal(caches_s, caches_l)


# ---------------------------------------------------------------------------
# prefill_continue == per-token teacher-forced suffix loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-r1"])
def test_prefill_continue_matches_token_loop(arch):
    cfg = smoke(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(3)
    prompt = list(rng.randint(0, 200, 14))
    reuse = 8
    _, caches = prefill(params, cfg,
                        {"tokens": jnp.asarray([prompt[:reuse]], jnp.int32)},
                        capacity=32, cache_dtype=jnp.float32)
    # reference: per-token decode_step suffix loop
    c_ref, cl, lg = caches, jnp.int32(reuse), None
    for t in prompt[reuse:]:
        lg, c_ref = decode_step(params, cfg, jnp.asarray([[t]], jnp.int32),
                                c_ref, cl)
        cl = cl + 1
    lg2, c_new = prefill_continue(params, cfg,
                                  jnp.asarray([prompt[reuse:]], jnp.int32),
                                  caches, jnp.int32(reuse))
    np.testing.assert_allclose(np.asarray(lg2[0, -1]), np.asarray(lg[0]),
                               rtol=1e-4, atol=1e-4)
    assert int(jnp.argmax(lg2[0, -1])) == int(jnp.argmax(lg[0]))
    # caches agree over the valid region [0, len(prompt))
    sl_ref = cache_ops.seq_slice(cfg, c_ref, 0, len(prompt))
    sl_new = cache_ops.seq_slice(cfg, c_new, 0, len(prompt))
    for a, b in zip(jax.tree.leaves(sl_ref), jax.tree.leaves(sl_new)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_prefill_continue_rejects_unsupported_archs():
    cfg = smoke("mamba2-780m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    from repro.models import make_caches
    caches = make_caches(cfg, 1, 16, jnp.float32)
    with pytest.raises(NotImplementedError):
        prefill_continue(params, cfg, jnp.zeros((1, 4), jnp.int32), caches,
                         jnp.int32(4))


# ---------------------------------------------------------------------------
# Batched EMS block packing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-r1"])
def test_pack_blocks_matches_per_block_pack(arch):
    cfg = smoke(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    _, _, caches, _ = _prefill_batch(cfg, params, n_req=1, plen=16,
                                     capacity=24)
    block, n_blocks = 4, 3
    rows = cache_ops.pack_blocks(cfg, caches, n_blocks, block)
    assert len(rows) == n_blocks
    for bi in range(n_blocks):
        ref = cache_ops.pack_payload(
            cache_ops.seq_slice(cfg, caches, bi * block, block))
        assert np.array_equal(rows[bi], ref), f"block {bi} differs"
    assert cache_ops.pack_blocks(cfg, caches, 0, block) == []


# ---------------------------------------------------------------------------
# Chunked serving end-to-end
# ---------------------------------------------------------------------------


def test_serving_decode_chunk_token_identical(qwen):
    """decode_chunk >= 4 emits token-identical output to per-step decode,
    with identical per-request decode_iters in the trace."""
    cfg, params = qwen
    rng = np.random.RandomState(9)
    prompts = [list(rng.randint(0, 200, 12)) for _ in range(5)]
    reqs = [Request(i, p, 6) for i, p in enumerate(prompts)]
    out = {}
    for chunk in (1, 4):
        system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                               capacity=32, decode_chunk=chunk)
        results = system.serve(list(reqs))
        out[chunk] = {r.rid: r for r in results}
        assert len(results) == len(reqs)
    for rid in out[1]:
        assert out[4][rid].tokens == out[1][rid].tokens, f"rid {rid}"
        assert out[4][rid].decode_iters == out[1][rid].decode_iters
    # virtual decode time must be charged per iteration, not per chunk
    assert not out[4][0].shed


def test_serving_decode_chunk_with_reuse_and_trace(qwen):
    """Chunked decode + EMS reuse (chunked suffix prefill) still accounts
    reused+computed == prompt and keeps the trace consistent."""
    from repro.mempool import ContextCache, MemoryPool

    cfg, params = qwen
    rng = np.random.RandomState(6)
    shared = list(rng.randint(0, 200, 16))
    prompts = [shared + list(rng.randint(0, 200, 8)) for _ in range(4)]
    pool = MemoryPool(n_nodes=4)
    cc = ContextCache(pool, block_tokens=8, model_tag=cfg.name)
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=48, context_cache=cc, decode_chunk=4)
    results = system.serve([Request(i, p, 5) for i, p in enumerate(prompts)])
    assert any(r.reused_tokens > 0 for r in results)
    for r in results:
        assert r.reused_tokens + r.computed_tokens == len(prompts[r.rid])
        assert len(r.tokens) == 5
    for rec in system.scheduler.trace_records():
        assert rec["decode_iters"] == 4          # 5 tokens - 1 from prefill
        assert rec["decode_seconds"] > 0


def test_chunked_engine_raises_on_capacity_frozen_slot(qwen):
    """A slot that hits cache capacity with tokens still requested must
    raise SlotError on the chunked path (like per-step decode via
    DecodeSlotManager.advance), never livelock silently."""
    from repro.serving import DecodeEngine, RequestResult, SlotError
    from repro.serving.cache_ops import slice_request

    cfg, params = qwen
    plen, cap = 10, 12                      # room for only 2 decode writes
    rng = np.random.RandomState(13)
    prompt = list(rng.randint(0, 200, plen))
    logits, caches = prefill(params, cfg,
                             {"tokens": jnp.asarray([prompt], jnp.int32)},
                             capacity=cap, cache_dtype=jnp.float32)
    eng = DecodeEngine(params, cfg, max_batch=1, capacity=cap,
                       decode_chunk=4)
    res = RequestResult(0, [])
    eng.add(0, slice_request(cfg, caches, 0), int(jnp.argmax(logits[0, -1])),
            plen, res, max_new=8)           # wants more than capacity allows
    with pytest.raises(SlotError, match="capacity"):
        while eng.active:
            eng.step_chunk()


def test_admit_with_no_free_slot_requeues_instead_of_crashing(qwen):
    """A stale 'admit' decision (gate says admit, no slot free) must never
    reach DecodeSlotManager.allocate with slot=None."""
    cfg, params = qwen
    rng = np.random.RandomState(11)
    prompts = [list(rng.randint(0, 200, 10)) for _ in range(3)]
    system = ServingSystem(params, cfg, n_prefill=1, decode_batch=1,
                           capacity=24)
    system.scheduler.gate.decide = (lambda active, has_free_slot,
                                *a, **k: "admit")
    results = system.serve([Request(i, p, 4) for i, p in enumerate(prompts)])
    assert len(results) == 3
    for r in results:
        assert len(r.tokens) == 4 and not r.shed


# ---------------------------------------------------------------------------
# Calibrated decode cost model (ROADMAP open item)
# ---------------------------------------------------------------------------


def test_decode_cost_from_roofline_and_fallback():
    rec = {"compute_s": 1e-4, "memory_s": 3e-3, "collective_s": 2e-4}
    kv_bytes = 0.4e9                            # 0.4 GB latent/KV per request
    model = decode_cost_from_roofline(rec, kv_bytes, batch_per_chip=0.5)
    step = max(rec["compute_s"], rec["memory_s"]) + rec["collective_s"]
    per = kv_bytes / 819e9
    assert model.per_req_s == pytest.approx(per)
    assert model.fixed_s == pytest.approx(step - 0.5 * per)
    assert model.step_time(1) == pytest.approx(model.fixed_s + per)
    # fixed-term floor: KV so large the remainder would go negative
    degenerate = decode_cost_from_roofline(rec, 1e13, batch_per_chip=4.0)
    assert degenerate.fixed_s == pytest.approx(0.2 * step)
    # fallbacks -> placeholder defaults
    assert decode_cost_from_roofline(None, kv_bytes, 1.0) == DecodeCostModel()
    assert decode_cost_from_roofline(rec, 0.0, 1.0) == DecodeCostModel()


def test_scheduler_config_decode_chunk_is_baked_in(qwen):
    cfg, params = qwen
    system = ServingSystem(params, cfg, n_prefill=1, decode_batch=2,
                           capacity=24, decode_chunk=2)
    with pytest.raises(ValueError, match="decode_chunk"):
        system.reconfigure_scheduler(SchedulerConfig(decode_chunk=1))
    system.reconfigure_scheduler(SchedulerConfig(decode_chunk=2))


# ---------------------------------------------------------------------------
# Single-collective quantized LEP dispatch (multi-device subprocess)
# ---------------------------------------------------------------------------


def test_quantized_dispatch_single_collective():
    """Packed-scale dispatch compiles to exactly ONE all_to_all per hop
    (dispatch + combine = 2 total vs 3 for the two-collective baseline) and
    is bit-identical to the baseline (the scale bitcast is exact)."""
    code = '''
import dataclasses, jax, jax.numpy as jnp
from repro.launch.mesh import make_debug_mesh
mesh = make_debug_mesh(2, 4)
from repro.configs import get_config, smoke_variant
from repro.core.lep import make_lep_moe_fn
from repro.models import moe as moe_mod
cfg = dataclasses.replace(smoke_variant(get_config("olmoe-1b-7b")),
                          capacity_factor=8.0)
p1 = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg, 1, jnp.float32)
p = jax.tree.map(lambda a: a[0], p1)
x = jax.random.normal(jax.random.PRNGKey(1), (24, cfg.d_model), jnp.float32)
outs, counts = {}, {}
for packed in (True, False):
    fn = make_lep_moe_fn(mesh, ep_axes=("model",), pack_scales=packed)
    with mesh:
        outs[packed], _ = jax.jit(lambda pp, xx: fn(pp, xx, cfg))(p, x)
        counts[packed] = str(jax.make_jaxpr(
            lambda pp, xx: fn(pp, xx, cfg))(p, x)).count("all_to_all")
assert counts[True] == 2, counts    # 1 dispatch + 1 combine
assert counts[False] == 3, counts   # payload + scales + combine
assert jnp.array_equal(outs[True], outs[False])
ref, _ = moe_mod.moe_reference(p, x, cfg)
rel = float(jnp.max(jnp.abs(outs[True] - ref))) / float(jnp.max(jnp.abs(ref)))
assert rel < 0.05, rel              # int8 quantization tolerance
print("SINGLE_COLLECTIVE_OK")
'''
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=520)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "SINGLE_COLLECTIVE_OK" in r.stdout
