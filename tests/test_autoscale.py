"""Decode-pool autoscaling: PoolAutoscaler hysteresis/clamp semantics
(pure control plane), the engine spawn/revive/retire lifecycle against the
scheduler's per-engine views, and the end-to-end guarantee — an open-loop
Poisson burst grows the pool, the tail shrinks it via migration-backed
retirement, and the emitted tokens stay identical to a fixed-size pool at
the max engine count."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke
from repro.models import init_params, prefill
from repro.serving import (DecodeCostModel, DecodeEngine, DecodePool,
                           PoolAutoscaler, Request, RequestResult, Scheduler,
                           SchedulerConfig, ServingSystem, poisson_requests,
                           make_decode_router)
from repro.serving.scheduler import DecodeSlotManager


@pytest.fixture(scope="module")
def granite():
    cfg = smoke("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def make_scaler(**kw):
    kw.setdefault("cost", DecodeCostModel())
    kw.setdefault("n_slots", 2)
    kw.setdefault("min_engines", 1)
    kw.setdefault("max_engines", 4)
    return PoolAutoscaler(kw.pop("cost"), kw.pop("n_slots"),
                          kw.pop("min_engines"), kw.pop("max_engines"), **kw)


# ---------------------------------------------------------------------------
# Controller unit semantics (no jax, fully deterministic)
# ---------------------------------------------------------------------------


def test_autoscaler_validates_configuration():
    with pytest.raises(ValueError, match="min_engines <= max_engines"):
        make_scaler(min_engines=3, max_engines=2)
    with pytest.raises(ValueError, match="min_engines <= max_engines"):
        make_scaler(min_engines=0, max_engines=2)
    with pytest.raises(ValueError, match="n_slots"):
        make_scaler(n_slots=0)
    with pytest.raises(ValueError, match="patience"):
        make_scaler(grow_patience=0)


def test_autoscaler_engine_cap_follows_tpot_budget():
    cost = DecodeCostModel(fixed_s=4e-3, per_req_s=1e-3)
    # no budget: cap = slot count
    assert make_scaler(cost=cost, n_slots=8).engine_cap == 8
    # budget admits batch 5 (4 + 5*1 = 9ms) — the gate's own projection
    s = make_scaler(cost=cost, n_slots=8, tpot_budget_s=9e-3)
    assert s.engine_cap == cost.max_batch_for(9e-3) == 5
    # budget below the fixed cost still leaves a cap of 1 (never 0 — a
    # zero cap would demand infinite engines for any load)
    assert make_scaler(cost=cost, n_slots=8,
                       tpot_budget_s=1e-3).engine_cap == 1
    # slots still clamp from above
    assert make_scaler(cost=cost, n_slots=2, tpot_budget_s=9e-3
                       ).engine_cap == 2


def test_autoscaler_grow_hysteresis_and_cooldown():
    s = make_scaler(grow_patience=2, shrink_patience=2, cooldown=2)
    # demand 5 > 1 engine * cap 2: pressure, but patience=2 delays the grow
    assert s.decide(1, 2, 3) == "hold"
    assert s.decide(1, 2, 3) == "grow"
    # cooldown: two quiet turns even though pressure persists
    assert s.decide(2, 4, 3) == "hold"
    assert s.decide(2, 4, 3) == "hold"
    # streaks were reset by the cooldown — patience counts from zero again
    assert s.decide(2, 4, 3) == "hold"
    assert s.decide(2, 4, 3) == "grow"


def test_autoscaler_grow_streak_resets_when_pressure_clears():
    s = make_scaler(grow_patience=2, cooldown=0)
    assert s.decide(1, 2, 3) == "hold"          # streak 1
    assert s.decide(1, 1, 0) == "hold"          # pressure gone: reset
    assert s.decide(1, 2, 3) == "hold"          # streak must rebuild
    assert s.decide(1, 2, 3) == "grow"


def test_autoscaler_shrink_hysteresis_and_tail():
    s = make_scaler(grow_patience=1, shrink_patience=3, cooldown=0)
    # 3 engines, demand 2 fits in (3-1)*2=4: shrink after 3 quiet turns
    assert s.decide(3, 2, 0) == "hold"
    assert s.decide(3, 2, 0) == "hold"
    assert s.decide(3, 2, 0) == "shrink"
    # queued work vetoes shrink outright (and resets the streak)
    assert s.decide(3, 2, 1) == "hold"
    assert s.decide(3, 2, 0) == "hold"
    # an unabsorbable drain (atomic pre-check failed) also reads as hold
    assert s.decide(3, 2, 0, shrinkable=False) == "hold"


def test_autoscaler_min_max_clamps():
    s = make_scaler(min_engines=2, max_engines=3, grow_patience=1,
                    shrink_patience=1, cooldown=0)
    assert s.decide(3, 99, 99) == "hold"        # at max: never grow
    assert s.decide(2, 0, 0) == "hold"          # at min: never shrink
    assert s.decide(2, 99, 0) == "grow"
    assert s.decide(3, 0, 0) == "shrink"


def test_autoscaler_respawns_below_min_bypassing_hysteresis():
    """Regression (dead-engine demand math): ``n_live`` is the live roster,
    so an engine failure can legitimately present n_live < min_engines —
    and the controller must respawn IMMEDIATELY, through patience and even
    mid-cooldown (hysteresis damps demand noise, not failure recovery)."""
    s = make_scaler(min_engines=2, max_engines=4, grow_patience=3,
                    cooldown=4)
    # zero demand, roster below the floor: grow anyway, no patience
    assert s.decide(1, 0, 0) == "grow"
    # spend a cooldown via a normal grow, then fail below min mid-cooldown
    s = make_scaler(min_engines=2, max_engines=4, grow_patience=1,
                    cooldown=4)
    assert s.decide(2, 99, 0) == "grow"
    assert s.decide(3, 99, 0) == "hold"          # cooling down
    assert s.decide(1, 0, 0) == "grow"           # failure overrides cooldown
    # total capacity loss (n_live=0) is the extreme of the same path
    s = make_scaler(min_engines=1, max_engines=2, grow_patience=5,
                    cooldown=5)
    assert s.decide(0, 0, 3) == "grow"


def test_autoscaler_never_grows_and_shrinks_in_one_turn():
    """A single decide() call emits exactly one action, and the conditions
    are mutually exclusive for any demand/cap — sweep a demand grid."""
    s = make_scaler(min_engines=1, max_engines=4, grow_patience=1,
                    shrink_patience=1, cooldown=0)
    for n_live in (1, 2, 3, 4):
        for active in range(0, 10):
            for queue in range(0, 4):
                d = s.decide(n_live, active, queue)
                assert d in ("grow", "hold", "shrink")
                s.reset()
    # and a grow is never chased by a shrink inside the cooldown window
    s = make_scaler(grow_patience=1, shrink_patience=1, cooldown=1)
    assert s.decide(1, 2, 3) == "grow"
    assert s.decide(2, 0, 0) == "hold"          # cooldown, not shrink


# ---------------------------------------------------------------------------
# Engine spawn / revive / retire lifecycle
# ---------------------------------------------------------------------------


def test_spawn_revive_retire_lifecycle(granite):
    cfg, params = granite
    built = []

    def factory(seed):
        built.append(seed)
        return DecodeEngine(params, cfg, 2, 24, seed=seed)

    pool = DecodePool([factory(0)], make_decode_router("round_robin", 1),
                      engine_factory=factory)
    assert (pool.n, pool.n_live) == (1, 1)
    e, revived = pool.spawn_engine()
    assert (e, revived) == (1, False) and built == [0, 1]
    assert pool.router.n == 2 and pool.live_ids == [0, 1]
    pool.retire_engine(1)                        # idle: nothing to drain
    assert pool.n_live == 1 and pool.live_mask == [True, False]
    # a parked engine is invisible to routing and cannot take migrations
    assert pool.select_engine() == 0
    # grow again: the parked engine revives — no new construction
    e, revived = pool.spawn_engine()
    assert (e, revived) == (1, True) and built == [0, 1]
    assert pool.n_live == 2
    pool.retire_engine(0)
    with pytest.raises(ValueError, match="last live engine"):
        pool.retire_engine(1)
    with pytest.raises(ValueError, match="already parked"):
        pool.retire_engine(0)


def test_spawn_without_factory_raises(granite):
    cfg, params = granite
    pool = DecodePool([DecodeEngine(params, cfg, 2, 24)],
                      make_decode_router("round_robin", 1))
    with pytest.raises(RuntimeError, match="engine_factory"):
        pool.spawn_engine()


def test_retire_engine_drains_atomically_into_peers(granite):
    cfg, params = granite
    engines = [DecodeEngine(params, cfg, 2, 24, seed=e) for e in range(2)]
    pool = DecodePool(engines, make_decode_router("least_loaded_slots", 2))
    logits, caches = prefill(params, cfg,
                             {"tokens": jnp.asarray([[5, 6, 7]], jnp.int32)},
                             capacity=24, cache_dtype=jnp.float32)
    first = int(jnp.argmax(logits[0, -1]))
    for rid in (0, 1):
        res = RequestResult(rid, [])
        pool.add(0, pool.engines[0].free_slot(), caches, first, 3, res, 4)
    assert pool.can_drain(0)
    moved = pool.retire_engine(0)
    assert len(moved) == 2 and pool.engines[1].active == 2
    assert pool.live_mask == [False, True]
    # retired means parked: routing and migration both refuse it
    assert pool.select_engine() == 1
    with pytest.raises(Exception, match="parked"):
        pool.migrate(0, 0)


def test_scheduler_register_engine_warms_clock_to_frontier():
    sched = Scheduler(1, DecodeSlotManager(2, 64), SchedulerConfig())
    tr = sched.on_arrival(0, 0.0, 8)
    sched.on_prefill_done(tr, 0, 8, 0)
    sched.on_transfer(tr, 0.0)
    sched.slot_mgrs[0].allocate(0, 8)
    sched.on_admit(tr, 0, engine=0)
    for _ in range(3):
        sched.on_decode_step([0], [], engine=0)
    frontier = sched.decode_now
    assert frontier > 0
    e = sched.register_engine(DecodeSlotManager(2, 64))
    assert e == 1 and sched.n_decode == 2
    # the new engine joins *now*, not at virtual t=0
    assert sched._decode_now[e] == pytest.approx(frontier)
    assert sched.decode_now == pytest.approx(frontier)
    # parking an engine removes its stale clock from the frontier
    sched.set_engine_live(e, False)
    for _ in range(2):
        sched.on_decode_step([0], [], engine=0)
    assert sched.decode_now > frontier
    # ...and reviving warms it up to the current frontier again
    sched.set_engine_live(e, True)
    assert sched._decode_now[e] == pytest.approx(sched.decode_now)


def test_scale_events_recorded_on_virtual_timeline():
    sched = Scheduler(1, DecodeSlotManager(2, 64), SchedulerConfig())
    sched.register_engine(DecodeSlotManager(2, 64))
    sched.record_scale_event("grow", 1)
    sched.set_engine_live(1, False)
    sched.record_scale_event("shrink", 1)
    assert [e["action"] for e in sched.scale_events] == ["grow", "shrink"]
    assert [e["engines_live"] for e in sched.scale_events] == [2, 1]
    assert [n for _, n in sched.engine_count_timeline] == [1, 2, 1]
    s = sched.summary()
    assert s["scale_events"] == 2
    assert (s["scale_grows"], s["scale_shrinks"]) == (1, 1)
    # a fresh epoch clears the events but keeps the live mask
    sched.begin_epoch()
    assert sched.scale_events == []
    assert sched.engine_count_timeline == [(0.0, 1)]


# ---------------------------------------------------------------------------
# End-to-end: burst grows, tail shrinks, tokens identical to fixed pool
# ---------------------------------------------------------------------------


def _burst(cfg, n=10, rate=400.0, max_new=8, seed=5):
    return poisson_requests(n, rate, 10, max_new, 100, seed=seed)


def assert_monotone(records):
    for rec in records:
        if rec["shed"]:
            continue
        assert rec["arrival"] <= rec["prefill_start"] <= rec["prefill_end"]
        ready = rec["prefill_end"] + rec["transfer_seconds"]
        assert rec["decode_admit"] >= ready - 1e-12
        assert rec["decode_end"] >= rec["decode_admit"]


def test_autoscale_e2e_burst_grows_tail_shrinks_token_identical(granite):
    cfg, params = granite
    reqs = _burst(cfg)
    fixed = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                          capacity=32, decode_engines=3)
    ref = {r.rid: r.tokens for r in fixed.serve(
        [Request(r.rid, list(r.prompt), r.max_new_tokens, r.arrival)
         for r in reqs], open_loop=True)}
    auto = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                         capacity=32, decode_engines=1, autoscale=True,
                         min_engines=1, max_engines=3)
    results = auto.serve(reqs, open_loop=True)
    assert {r.rid: r.tokens for r in results} == ref
    sched = auto.scheduler
    s = sched.summary()
    assert s["scale_grows"] >= 1 and s["scale_shrinks"] >= 1
    counts = [n for _, n in sched.engine_count_timeline]
    assert max(counts) == 3                     # the burst hit the clamp
    assert counts[-1] < max(counts)             # the tail shrank the pool
    # grow precedes shrink and the timeline never rewinds
    times = [t for t, _ in sched.engine_count_timeline]
    assert times == sorted(times)
    first_shrink = next(e for e in sched.scale_events
                        if e["action"] == "shrink")
    assert all(e["t"] <= first_shrink["t"] for e in sched.scale_events
               if e["action"] == "grow" and e["t"] < first_shrink["t"])
    # shrink-migrated requests are stamped on the trace
    assert_monotone(sched.trace_records())
    # slot conservation holds across spawned engines
    for mgr in auto.pool.slot_mgrs:
        assert mgr.acquired == mgr.released + mgr.active
        assert mgr.active == 0


def test_autoscale_respects_max_clamp_and_budget_cap(granite):
    """With a TPOT budget the controller sizes engines by the gate's batch
    cap, and never exceeds max_engines however hard the burst."""
    cfg, params = granite
    cost = DecodeCostModel(fixed_s=4e-3, per_req_s=1e-3)
    auto = ServingSystem(params, cfg, n_prefill=2, decode_batch=4,
                         capacity=32, decode_engines=1, autoscale=True,
                         min_engines=1, max_engines=2,
                         tpot_budget_ms=6.0, admission="queue",
                         scheduler_config=SchedulerConfig(decode_cost=cost))
    assert auto.scheduler.gate.max_batch == 2
    results = auto.serve(_burst(cfg, n=8, max_new=6, seed=7),
                         open_loop=True)
    assert len(results) == 8 and not any(r.shed for r in results)
    sched = auto.scheduler
    assert max(n for _, n in sched.engine_count_timeline) == 2
    # the per-engine gate held: no admitted batch ever exceeded the cap,
    # so every trace TPOT is within budget
    s = sched.summary()
    assert s["tpot_max_s"] * 1e3 <= 6.0 + 1e-9


def test_autoscale_second_wave_revives_parked_engines(granite):
    """Across serve() waves the pool keeps its engines: wave 2's burst
    revives parked engines instead of constructing (re-jitting) new ones,
    and per-wave scale events start fresh."""
    cfg, params = granite
    auto = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                         capacity=32, decode_engines=1, autoscale=True,
                         min_engines=1, max_engines=3)
    auto.serve(_burst(cfg), open_loop=True)
    n_after_wave1 = auto.pool.n
    assert n_after_wave1 > 1
    auto.serve(_burst(cfg, seed=6), open_loop=True)
    assert auto.pool.n == n_after_wave1          # revived, not re-built
    assert auto.scheduler.summary()["scale_grows"] >= 1
    for mgr in auto.pool.slot_mgrs:
        assert mgr.acquired == mgr.released + mgr.active


def test_autoscale_requires_initial_size_inside_clamp(granite):
    cfg, params = granite
    with pytest.raises(ValueError, match="autoscale clamp"):
        ServingSystem(params, cfg, decode_batch=2, capacity=32,
                      decode_engines=5, autoscale=True,
                      min_engines=1, max_engines=4)


def test_reconfigure_scheduler_preserves_parked_engines(granite):
    cfg, params = granite
    auto = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                         capacity=32, decode_engines=1, autoscale=True,
                         min_engines=1, max_engines=3)
    auto.serve(_burst(cfg), open_loop=True)
    parked = [e for e, live in enumerate(auto.pool.live_mask) if not live]
    assert parked                                # the tail parked someone
    auto.reconfigure_scheduler(SchedulerConfig(autoscale=True,
                                               min_engines=1, max_engines=3))
    assert auto.scheduler._live == auto.pool.live_mask
    # a non-autoscale wave on the same system still serves correctly on
    # the remaining live engines
    auto.reconfigure_scheduler(SchedulerConfig())
    rng = np.random.RandomState(3)
    reqs = [Request(i, list(rng.randint(0, 100, 10)), 4) for i in range(4)]
    results = auto.serve(reqs)
    assert len(results) == 4 and not any(r.shed for r in results)
    assert all(t.decode_engine not in parked
               for t in auto.scheduler.tracker.finished)
