"""Fused MTP speculative decoding fast path (`model.decode_loop_mtp`), the
one-forward base+draft verification, the MTP-aware scheduler accounting,
the open-loop Poisson serving mode, fresh-prompt chunked prefill, and the
`sample_top_p` cutoff regressions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke
from repro.core import mtp as mtp_mod
from repro.models import decode_step, init_params, prefill
from repro.models.model import cache_batch_axes, decode_loop_mtp
from repro.serving import (DecodeCostModel, PrefillEngine, Request,
                           SchedulerConfig, ServingSystem, poisson_requests)
from repro.serving import cache_ops


@pytest.fixture(scope="module")
def qwen():
    cfg = smoke("qwen3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    mtp = mtp_mod.init_mtp_params(jax.random.PRNGKey(7), cfg)
    return cfg, params, mtp


def _prefill_batch(cfg, params, n_req=3, plen=10, capacity=40, seed=2):
    rng = np.random.RandomState(seed)
    prompts = [list(rng.randint(0, 200, plen)) for _ in range(n_req)]
    logits, caches = prefill(params, cfg,
                             {"tokens": jnp.asarray(prompts, jnp.int32)},
                             capacity=capacity, cache_dtype=jnp.float32)
    tok0 = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    cl0 = jnp.full((n_req,), plen, jnp.int32)
    return prompts, tok0, caches, cl0


def _mtp_sequential(cfg, params, mtp, tok, drf, caches, cl, n, key,
                    fused=False):
    """Reference: n per-step mtp_step calls with the scan's key schedule."""
    ems, accs = [], []
    for _ in range(n):
        key, sub = jax.random.split(key)
        em, acc, tok, drf, caches, cl = mtp_mod.mtp_step(
            params, mtp, cfg, tok, drf, caches, cl, sub,
            fused_verify=fused)
        ems.append(np.asarray(em))
        accs.append(np.asarray(acc))
    return np.stack(ems, 1), np.stack(accs, 1), tok, drf, caches, cl


def _content_equal(cfg, a, b):
    """Bitwise equality of every batched cache leaf (the `length`
    bookkeeping leaves are excluded: per-step mtp_step leaves them at the
    speculative write position regardless of acceptance, while the scanned
    loop normalizes them to the committed per-slot cache_len)."""
    axes = cache_batch_axes(cfg)
    oks = jax.tree.leaves(jax.tree.map(
        lambda x, y, ax: True if ax is None else bool(jnp.array_equal(x, y)),
        a, b, axes))
    return all(oks)


# ---------------------------------------------------------------------------
# decode_loop_mtp(n) == n sequential mtp_step calls
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-r1", "olmoe-1b-7b"])
def test_decode_loop_mtp_matches_per_step(arch):
    """Token-identical and bitwise cache-equal across dense/MLA/MoE."""
    cfg = smoke(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    mtp = mtp_mod.init_mtp_params(jax.random.PRNGKey(1), cfg)
    _, tok0, caches, cl0 = _prefill_batch(cfg, params)
    key0 = jax.random.PRNGKey(5)
    n = 4
    ref_em, ref_acc, tok_s, _, caches_s, cl_s = _mtp_sequential(
        cfg, params, mtp, tok0, mtp_mod.propose_draft(params, mtp, cfg, tok0),
        caches, cl0, n, key0)
    em, acc, lv, tok_l, _, caches_l, cl_l = decode_loop_mtp(
        params, mtp, cfg, tok0, mtp_mod.propose_draft(params, mtp, cfg, tok0),
        caches, cl0, n, key=key0)
    assert np.array_equal(np.asarray(em), ref_em)
    assert np.array_equal(np.asarray(acc), ref_acc)
    assert np.asarray(lv).all()
    assert np.array_equal(np.asarray(cl_l), np.asarray(cl_s))
    assert np.array_equal(np.asarray(tok_l), np.asarray(tok_s))
    assert _content_equal(cfg, caches_s, caches_l)


def test_decode_loop_mtp_accept_reject_divergence(qwen):
    """Forced accept/reject divergence within one batch: slot 0 starts with
    the oracle draft (guaranteed accept), slot 1 with a wrong one."""
    cfg, params, mtp = qwen
    _, tok0, caches, cl0 = _prefill_batch(cfg, params, n_req=2)
    # oracle successor of tok0 per slot
    lg, c2 = decode_step(params, cfg, tok0[:, None], caches, cl0)
    oracle = jnp.argmax(lg, -1).astype(jnp.int32)
    d0 = jnp.stack([oracle[0], (oracle[1] + 1) % cfg.vocab_size])
    key0 = jax.random.PRNGKey(3)
    ref_em, ref_acc, _, _, caches_s, cl_s = _mtp_sequential(
        cfg, params, mtp, tok0, d0, caches, cl0, 3, key0)
    assert ref_acc[0, 0] and not ref_acc[1, 0]      # the divergence is real
    em, acc, lv, _, _, caches_l, cl_l = decode_loop_mtp(
        params, mtp, cfg, tok0, d0, caches, cl0, 3, key=key0)
    assert np.array_equal(np.asarray(em), ref_em)
    assert np.array_equal(np.asarray(acc), ref_acc)
    assert np.array_equal(np.asarray(cl_l), np.asarray(cl_s))
    # accepted slot advanced 2 on iteration one, rejected slot advanced 1
    assert int(cl_l[0]) >= int(cl0[0]) + 4
    assert _content_equal(cfg, caches_s, caches_l)


def test_decode_loop_mtp_steps_left_freezes(qwen):
    """A slot whose token budget drains mid-chunk freezes bit-exactly."""
    cfg, params, mtp = qwen
    _, tok0, caches, cl0 = _prefill_batch(cfg, params, n_req=2)
    d0 = mtp_mod.propose_draft(params, mtp, cfg, tok0)
    key0 = jax.random.PRNGKey(4)
    n = 4
    em, acc, lv, _, _, caches_m, cl_m = decode_loop_mtp(
        params, mtp, cfg, tok0, d0, caches, cl0, n, key=key0,
        steps_left=jnp.asarray([2 * n, 2], jnp.int32))
    lv = np.asarray(lv)
    k = int(lv[1].sum())                 # live iterations of the frozen slot
    assert k < n and lv[1, :k].all() and not lv[1, k:].any()
    # the frozen slot's cache/emissions equal a k-iteration per-step run
    ref_em, ref_acc, _, _, caches_k, cl_k = _mtp_sequential(
        cfg, params, mtp, tok0, d0, caches, cl0, k, key0)
    assert np.array_equal(np.asarray(em)[1, :k], ref_em[1, :k])
    assert int(cl_m[1]) == int(cl_k[1])
    axes = cache_batch_axes(cfg)
    sl_m = cache_ops.slice_request(cfg, caches_m, 1)
    sl_k = cache_ops.slice_request(cfg, caches_k, 1)
    oks = jax.tree.leaves(jax.tree.map(
        lambda x, y, ax: True if ax is None else bool(jnp.array_equal(x, y)),
        sl_k, sl_m, axes))
    assert all(oks)


def test_decode_loop_mtp_capacity_freeze(qwen):
    """Slots freeze (instead of corrupting KV) when both speculative writes
    no longer fit: live requires cache_len + 2 <= capacity."""
    cfg, params, mtp = qwen
    plen, cap = 10, 13                  # 3 free cells
    _, tok0, caches, cl0 = _prefill_batch(cfg, params, n_req=2, plen=plen,
                                          capacity=cap)
    d0 = mtp_mod.propose_draft(params, mtp, cfg, tok0)
    em, acc, lv, _, _, _, cl_f = decode_loop_mtp(
        params, mtp, cfg, tok0, d0, caches, cl0, 5, key=jax.random.PRNGKey(0))
    lv, acc = np.asarray(lv), np.asarray(acc)
    cl_f = np.asarray(cl_f)
    assert (cl_f <= cap).all()
    assert not lv[:, -1].any()          # everyone froze by the end
    # the mask must have stopped exactly when the speculative write would
    # no longer fit
    for i in range(2):
        cl = int(cl0[i])
        for j in range(5):
            expect_live = cl + 2 <= cap
            assert bool(lv[i, j]) == expect_live
            if expect_live:
                cl += 1 + int(acc[i, j])


def test_fused_verify_matches_two_step_tokens(qwen):
    """One-forward verification emits the same tokens/acceptance as the
    two-decode-step form (not bitwise: different reduction order)."""
    cfg, params, mtp = qwen
    _, tok0, caches, cl0 = _prefill_batch(cfg, params)
    d0 = mtp_mod.propose_draft(params, mtp, cfg, tok0)
    key0 = jax.random.PRNGKey(6)
    outs = {}
    for fused in (False, True):
        em, acc, lv, _, _, _, cl = decode_loop_mtp(
            params, mtp, cfg, tok0, d0, caches, cl0, 4, key=key0,
            fused_verify=fused)
        outs[fused] = (np.asarray(em), np.asarray(acc), np.asarray(cl))
    assert np.array_equal(outs[True][0], outs[False][0])
    assert np.array_equal(outs[True][1], outs[False][1])
    assert np.array_equal(outs[True][2], outs[False][2])


def test_can_fuse_verify_gating():
    assert mtp_mod.can_fuse_verify(smoke("qwen3-8b"), 32)
    assert mtp_mod.can_fuse_verify(smoke("deepseek-r1"), 32)
    assert not mtp_mod.can_fuse_verify(smoke("mamba2-780m"), 32)
    assert not mtp_mod.can_fuse_verify(smoke("zamba2-1.2b"), 32)
    phi = smoke("phi3-medium-14b")
    if phi.sliding_window:              # ring cache at long capacity
        assert not mtp_mod.can_fuse_verify(phi, phi.sliding_window + 1)


# ---------------------------------------------------------------------------
# Serving end-to-end: chunked MTP == per-step MTP
# ---------------------------------------------------------------------------


def test_serving_mtp_chunked_token_identical(qwen):
    """use_mtp + decode_chunk=4 emits token-identical output (and identical
    per-request iteration counts) to per-step MTP serving."""
    cfg, params, mtp = qwen
    rng = np.random.RandomState(9)
    prompts = [list(rng.randint(0, 200, 12)) for _ in range(5)]
    reqs = [Request(i, p, 6) for i, p in enumerate(prompts)]
    out = {}
    for chunk in (1, 4):
        system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                               capacity=32, use_mtp=True, mtp_params=mtp,
                               decode_chunk=chunk)
        results = system.serve(list(reqs))
        assert len(results) == len(reqs)
        out[chunk] = {r.rid: r for r in results}
    for rid in out[1]:
        assert out[4][rid].tokens == out[1][rid].tokens, f"rid {rid}"
        assert out[4][rid].decode_iters == out[1][rid].decode_iters
    # scheduler ran with the MTP cost model and credited real tokens
    sched = system.scheduler
    assert sched.cost.mtp_iter_factor == DecodeCostModel.MTP_ITER_FACTOR
    for rec in sched.trace_records():
        assert rec["decode_tokens"] == rec["tokens_out"] - 1
        assert rec["decode_iters"] <= rec["decode_tokens"]


def test_serving_mtp_fused_token_identical(qwen):
    """The fused one-forward verify serves the same tokens end-to-end."""
    cfg, params, mtp = qwen
    rng = np.random.RandomState(12)
    prompts = [list(rng.randint(0, 200, 12)) for _ in range(4)]
    reqs = [Request(i, p, 6) for i, p in enumerate(prompts)]
    out = {}
    for fused in (False, True):
        system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                               capacity=32, use_mtp=True, mtp_params=mtp,
                               decode_chunk=4, mtp_fused=fused)
        out[fused] = {r.rid: r.tokens for r in system.serve(list(reqs))}
    assert out[True] == out[False]


def test_mtp_cost_model_terms():
    cm = DecodeCostModel(fixed_s=4e-3, per_req_s=1e-3)
    m = cm.with_mtp()
    assert m.mtp_iter_factor == 1.44 and m.mtp_accept == 0.70
    assert m.step_time(8) == pytest.approx(cm.step_time(8) * 1.44)
    assert m.token_time(8) == pytest.approx(m.step_time(8) / 1.7)
    # the budget buys more batch under MTP: slower iterations, 1+α credit
    b = m.max_batch_for(15e-3)
    assert b > 0
    assert m.token_time(b) <= 15e-3 + 1e-12
    assert m.token_time(b + 1) > 15e-3
    # defaults (no MTP terms) keep the PR 1 semantics bit-for-bit
    assert cm.step_time(8) == 4e-3 + 8e-3
    assert cm.max_batch_for(15e-3) == 11
    # a measured acceptance overrides the paper default
    m2 = cm.with_mtp(accept=0.25)
    assert m2.tokens_per_iter == pytest.approx(1.25)


def test_scheduler_config_use_mtp_is_baked_in(qwen):
    cfg, params, mtp = qwen
    system = ServingSystem(params, cfg, n_prefill=1, decode_batch=2,
                           capacity=24, use_mtp=True, mtp_params=mtp)
    with pytest.raises(ValueError, match="use_mtp"):
        system.reconfigure_scheduler(SchedulerConfig(use_mtp=False))
    system.reconfigure_scheduler(SchedulerConfig(use_mtp=True))


# ---------------------------------------------------------------------------
# sample_top_p cutoff regressions
# ---------------------------------------------------------------------------


def test_sample_top_p_keeps_at_least_one_token():
    """top_p >= 1.0 must keep the whole vocabulary (no OOB cutoff index)
    and a top token whose mass alone exceeds top_p must still be
    sampleable."""
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[10.0, 0.0, -1.0, -2.0],
                          [0.1, 0.2, 0.3, 0.4]], jnp.float32)
    for top_p in (1.0, 1.5):
        out = mtp_mod.sample_top_p(key, logits, temperature=1.0, top_p=top_p)
        assert out.shape == (2,)
        assert ((out >= 0) & (out < 4)).all()
        # keeping everything == pure temperature+gumbel sampling
        g = -jnp.log(-jnp.log(jax.random.uniform(key, logits.shape) + 1e-20)
                     + 1e-20)
        ref = jnp.argmax(logits + g, axis=-1).astype(jnp.int32)
        assert jnp.array_equal(out, ref), top_p
    # peaked row: p(top) ≈ 1 > top_p=0.5 — must deterministically keep it
    peaked = jnp.asarray([[30.0, 0.0, 0.0, 0.0]], jnp.float32)
    for seed in range(8):
        out = mtp_mod.sample_top_p(jax.random.PRNGKey(seed), peaked,
                                   temperature=1.0, top_p=0.5)
        assert int(out[0]) == 0


# ---------------------------------------------------------------------------
# Open-loop Poisson serving
# ---------------------------------------------------------------------------


def test_poisson_requests_generator():
    reqs = poisson_requests(32, 100.0, 12, 4, 200, seed=1, shared_prefix=4)
    arr = [r.arrival for r in reqs]
    assert all(b > a for a, b in zip(arr, arr[1:]))
    assert all(len(r.prompt) == 12 for r in reqs)
    assert all(r.prompt[:4] == reqs[0].prompt[:4] for r in reqs)
    # mean inter-arrival ~ 1/rate (loose: 32 samples)
    gaps = np.diff([0.0] + arr)
    assert 0.2 / 100 < gaps.mean() < 5.0 / 100
    with pytest.raises(ValueError):
        poisson_requests(4, 0.0, 12, 4, 200, seed=0)
    # shared_prefix == prompt_len is legal (fully-cached re-entry stream);
    # only a prefix longer than the prompt is rejected
    full = poisson_requests(4, 10.0, 12, 4, 200, seed=0, shared_prefix=12)
    assert all(r.prompt == full[0].prompt for r in full)
    with pytest.raises(ValueError):
        poisson_requests(4, 10.0, 12, 4, 200, seed=0, shared_prefix=13)


def test_open_loop_burst_queues_and_matches_greedy(qwen):
    """An open-loop burst completes with token-identical output to closed
    loop, and actually queues (decode busy when later arrivals land)."""
    cfg, params, _ = qwen
    reqs = poisson_requests(6, 300.0, 10, 4, 200, seed=3)
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=32)
    res_open = {r.rid: r.tokens for r in system.serve(list(reqs),
                                                      open_loop=True)}
    open_summary = system.scheduler.summary()
    res_closed = {r.rid: r.tokens
                  for r in system.serve(list(reqs), open_loop=False)}
    assert res_open == res_closed
    assert open_summary["completed"] == 6
    assert open_summary["queue_p99_s"] > 0
    # arrival-ordered admission: nobody decodes before arriving
    for rec in system.scheduler.trace_records():
        assert rec["decode_admit"] >= rec["arrival"]


def test_open_loop_tight_budget_sheds(qwen):
    """Burst + tight TPOT budget + shedding gate: load is actually shed."""
    cfg, params, _ = qwen
    reqs = poisson_requests(8, 500.0, 10, 4, 200, seed=4)
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=4,
                           capacity=32, tpot_budget_ms=5.5, admission="shed")
    results = system.serve(reqs, open_loop=True)
    s = system.scheduler.summary()
    assert s["completed"] + s["shed"] == 8
    assert s["shed"] > 0
    assert s["tpot_max_s"] * 1e3 <= 5.5 + 1e-9


# ---------------------------------------------------------------------------
# Fresh-prompt chunked prefill (bounded compile shapes)
# ---------------------------------------------------------------------------


def test_fresh_chunked_prefill_matches_full(qwen):
    """Chunked fresh prefill produces the same first token + equivalent
    caches as full prefill, from ONE compiled program per chunk width."""
    cfg, params, _ = qwen
    rng = np.random.RandomState(21)
    eng_full = PrefillEngine(params, cfg, capacity=48)
    eng_chunk = PrefillEngine(params, cfg, capacity=48, prefill_chunk=8)
    for i, plen in enumerate((24, 17, 9)):      # varied lengths, one program
        prompt = list(rng.randint(0, 200, plen))
        f1, c1, r1 = eng_full.run(Request(i, prompt, 1))
        f2, c2, r2 = eng_chunk.run(Request(i, prompt, 1))
        assert f1 == f2, plen
        assert r2.computed_tokens == plen
        sl1 = cache_ops.seq_slice(cfg, c1, 0, plen)
        sl2 = cache_ops.seq_slice(cfg, c2, 0, plen)
        for a, b in zip(jax.tree.leaves(sl1), jax.tree.leaves(sl2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
    assert eng_chunk.continue_widths == {8}
    assert eng_chunk.continue_cache_hit_rate > 0.8


def test_chunked_prefill_overflow_fails_fast(qwen):
    """A prompt that cannot fit the prefill cache raises instead of
    spinning forever once the chunk width clamps to zero."""
    cfg, params, _ = qwen
    eng = PrefillEngine(params, cfg, capacity=16, prefill_chunk=8)
    prompt = list(np.random.RandomState(0).randint(0, 200, 24))
    with pytest.raises(ValueError, match="capacity"):
        eng.run(Request(0, prompt, 1))


def test_scheduler_config_cannot_flip_use_mtp_at_construction(qwen):
    """The scheduler's MTP cost accounting always matches the engine: a
    scheduler_config with use_mtp=True cannot attach MTP charging to a
    non-MTP decode engine."""
    cfg, params, _ = qwen
    system = ServingSystem(params, cfg, n_prefill=1, decode_batch=2,
                           capacity=24,
                           scheduler_config=SchedulerConfig(use_mtp=True))
    assert system.scheduler.config.use_mtp is False
    assert system.scheduler.cost.mtp_iter_factor == 1.0


def test_serving_with_fresh_chunked_prefill_token_identical(qwen):
    """End-to-end serving with prefill_chunk set matches default serving."""
    cfg, params, _ = qwen
    rng = np.random.RandomState(22)
    prompts = [list(rng.randint(0, 200, 14)) for _ in range(4)]
    reqs = [Request(i, p, 5) for i, p in enumerate(prompts)]
    base = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                         capacity=32)
    chunked = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                            capacity=32, prefill_chunk=8)
    out_b = {r.rid: r.tokens for r in base.serve(list(reqs))}
    out_c = {r.rid: r.tokens for r in chunked.serve(list(reqs))}
    assert out_b == out_c
    assert all(e.continue_widths <= {8} for e in chunked.prefills)
