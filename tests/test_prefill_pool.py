"""Prefill-pool invariants for the peer-to-peer PDC plane: instance
lifecycle (spawn/park/retire/fail over stable ids), routed-token
conservation across every prefill policy (the least_loaded in-flight load
must drain to zero on ALL completion paths, including shed and fault
recovery), bit-identity of the pipelined chunked KV handoff vs the
synchronous whole-request path across dense/MLA/MoE, the streamed-TTFT
monotonicity property, and the joint P/D autoscaler's capacity see-saw."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke
from repro.models import decode_step, init_params, prefill
from repro.serving import (FaultEvent, FaultInjector, FaultPlan,
                           JointAutoscaler, PrefillPool, Request,
                           SchedulerConfig, ServingSystem)
from repro.serving.scheduler import ROUTERS, make_router


@pytest.fixture(scope="module")
def granite():
    cfg = smoke("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def stream_requests(n, prompt_len=12, max_new=4, seed=1):
    rng = np.random.RandomState(seed)
    return [Request(i, list(rng.randint(0, 100, prompt_len)), max_new)
            for i in range(n)]


def greedy_reference(cfg, params, prompt, n_new):
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    logits, caches = prefill(params, cfg, batch,
                             capacity=len(prompt) + n_new + 4,
                             cache_dtype=jnp.float32)
    toks = [int(jnp.argmax(logits[0, -1]))]
    cl = jnp.int32(len(prompt))
    for _ in range(n_new - 1):
        lg, caches = decode_step(params, cfg,
                                 jnp.asarray([[toks[-1]]], jnp.int32),
                                 caches, cl)
        toks.append(int(jnp.argmax(lg[0])))
        cl = cl + 1
    return toks


# ---------------------------------------------------------------------------
# PrefillPool lifecycle (pure control plane, no jax)
# ---------------------------------------------------------------------------


class _FakeCfg:
    name = "fake-arch"


class _FakePrefill:
    """Shape-compatible stand-in: the pool reads capacity/cfg.name/load."""

    def __init__(self, instance_id, capacity=32):
        self.instance_id = instance_id
        self.capacity = capacity
        self.cfg = _FakeCfg()
        self.load = 0


def test_prefill_pool_lifecycle_stable_ids():
    built = []

    def factory(i):
        built.append(i)
        return _FakePrefill(i)

    pool = PrefillPool([_FakePrefill(0), _FakePrefill(1)],
                       engine_factory=factory)
    assert (pool.n, pool.n_live, pool.live_ids) == (2, 2, [0, 1])

    # retire parks (id survives); reviving prefers the parked id
    pool.retire_engine(1)
    assert pool.live_ids == [0] and pool.n == 2
    inst, revived = pool.spawn_engine()
    assert (inst, revived) == (1, True) and built == []

    # failure marks dead; a spawn restarts over the same stable id
    pool.fail_engine(1)
    assert pool.dead_ids == [1] and pool.live_ids == [0]
    inst, revived = pool.spawn_engine()
    assert (inst, revived) == (1, True) and pool.dead_ids == []

    # full live roster: a spawn extends through the factory
    inst, revived = pool.spawn_engine()
    assert (inst, revived) == (2, False) and built == [2]
    assert pool.live_ids == [0, 1, 2]
    assert (pool.spawns, pool.retires, pool.failures) == (3, 1, 1)


def test_prefill_pool_lifecycle_errors():
    pool = PrefillPool([_FakePrefill(0), _FakePrefill(1)])
    pool.retire_engine(1)
    with pytest.raises(ValueError, match="already parked"):
        pool.retire_engine(1)
    with pytest.raises(ValueError, match="last live prefill instance"):
        pool.retire_engine(0)
    pool.spawn_engine()                      # revive 1
    pool.fail_engine(1)
    with pytest.raises(ValueError, match="already dead"):
        pool.fail_engine(1)
    with pytest.raises(ValueError, match="last live prefill instance"):
        pool.retire_engine(0)
    # no factory and nothing parked/dead left to revive after restarting 1
    pool.spawn_engine()
    with pytest.raises(RuntimeError, match="no engine_factory"):
        pool.spawn_engine()
    with pytest.raises(ValueError, match="at least one prefill instance"):
        PrefillPool([])
    with pytest.raises(ValueError, match="must share model config"):
        PrefillPool([_FakePrefill(0, capacity=32),
                     _FakePrefill(1, capacity=64)])


def test_prefill_router_resize_grows_never_shrinks():
    for policy in sorted(ROUTERS):
        r = make_router(policy, 2)
        r.resize(3)
        assert r.n == 3
        # routing reaches the new id once it is the best candidate
        assert r.select([5, 5, 0], candidates=[2]) == 2
        with pytest.raises(ValueError, match="never disappear"):
            r.resize(2)
        with pytest.raises(ValueError, match="no live prefill instance"):
            r.select([0, 0, 0], candidates=[])


def test_router_candidates_exclude_parked_instances():
    ll = make_router("least_loaded", 3)
    assert ll.select([9, 0, 4], candidates=[0, 2]) == 2   # 1 parked
    rr = make_router("round_robin", 3)
    assert rr.select([0, 0, 0], candidates=[0, 2]) == 0
    assert rr.select([0, 0, 0], candidates=[0, 2]) == 2   # cursor skips 1
    assert rr.select([0, 0, 0], candidates=[0, 2]) == 0   # wrapped
    qd = make_router("queue_depth", 2)
    assert qd.select([0, 0], candidates=[0, 1]) == 0
    assert qd.select([0, 0], candidates=[0, 1]) == 1      # depth-balanced
    qd.on_complete(0)
    assert qd.select([0, 0], candidates=[0, 1]) == 0


# ---------------------------------------------------------------------------
# JointAutoscaler decision semantics (pure control plane, no jax)
# ---------------------------------------------------------------------------


def test_joint_autoscaler_decisions_and_hysteresis():
    j = JointAutoscaler(None, 4, min_prefill=1, max_prefill=2,
                        min_decode=1, max_decode=2, ttft_budget_s=1e-3,
                        patience=1, cooldown=1)
    # TTFT pressure + sparable decode engine -> d2p, then cooldown holds
    assert j.decide(1, 2, 0, 0, 5e-3) == "shift_d2p"
    assert j.decide(1, 2, 0, 0, 5e-3) == "hold"
    # decode at min_decode can never donate
    assert j.decide(1, 1, 0, 0, 5e-3) == "hold"
    # TPOT pressure (demand 9 > 1 engine * 4 slots) + idle prefill -> p2d
    assert j.decide(2, 1, 4, 5, 0.0) == "shift_p2d"
    j.reset()
    # an undrainable victim blocks the shift
    assert j.decide(1, 2, 0, 0, 5e-3, decode_shrinkable=False) == "hold"
    # queued decode work vetoes donating a decode engine to prefill
    assert j.decide(1, 2, 0, 1, 5e-3) == "hold"

    slow = JointAutoscaler(None, 4, min_prefill=1, max_prefill=2,
                           min_decode=1, max_decode=2, ttft_budget_s=1e-3,
                           patience=2, cooldown=0)
    assert slow.decide(1, 2, 0, 0, 5e-3) == "hold"        # streak 1 < 2
    assert slow.decide(1, 2, 0, 0, 5e-3) == "shift_d2p"
    with pytest.raises(ValueError, match="min_prefill"):
        JointAutoscaler(None, 4, min_prefill=0, max_prefill=2,
                        min_decode=1, max_decode=2)


# ---------------------------------------------------------------------------
# Routed-token conservation (the satellite-1 accounting fix)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", sorted(ROUTERS))
def test_routed_load_conserved_across_lifecycle(granite, policy):
    """Token-weighted in-flight routed load drains to exactly zero when a
    wave completes — per policy, and across spawn/park/retire/fail roster
    churn between waves. Routing only ever targets live instances."""
    cfg, params = granite
    reqs = stream_requests(6)
    system = ServingSystem(params, cfg, prefill_engines=3, decode_batch=4,
                           capacity=64, policy=policy)
    ref = {r.rid: list(r.tokens) for r in system.serve(reqs)}
    sched = system.scheduler
    assert sched.prefill_inflight_tokens == [0.0, 0.0, 0.0]
    assert sched._routed_load == {}
    assert ref[0] == greedy_reference(cfg, params, reqs[0].prompt, 4)

    # park 2, crash 1: the wave must route only to instance 0
    system.prefill_pool.retire_engine(2)
    sched.set_prefill_live(2, False)
    system.prefill_pool.fail_engine(1)
    sched.set_prefill_live(1, False)
    got = {r.rid: list(r.tokens) for r in system.serve(reqs)}
    assert got == ref
    sched = system.scheduler
    assert sched.prefill_inflight_tokens == [0.0, 0.0, 0.0]
    assert all(t.prefill_instance == 0 for t in sched.traces.values())

    # revive: spawn prefers the parked id (2), then restarts the dead (1)
    assert system.prefill_pool.spawn_engine() == (2, True)
    sched.set_prefill_live(2, True)
    assert system.prefill_pool.spawn_engine() == (1, True)
    sched.set_prefill_live(1, True)
    got = {r.rid: list(r.tokens) for r in system.serve(reqs)}
    assert got == ref
    sched = system.scheduler
    assert sched.prefill_inflight_tokens == [0.0, 0.0, 0.0]
    assert {t.prefill_instance for t in sched.traces.values()} > {0}


def test_shed_requests_release_routed_load(granite):
    """Regression for the pre-fix leak: gate sheds and capacity rejects
    left their token-weighted load on the routed instance forever, skewing
    least_loaded away from it for the rest of the epoch."""
    cfg, params = granite
    rng = np.random.RandomState(11)
    reqs = [Request(i, list(rng.randint(0, 100, 10)), 4) for i in range(6)]
    reqs.append(Request(6, list(rng.randint(0, 100, 30)), 8))  # 30+7 > 32
    system = ServingSystem(params, cfg, prefill_engines=2, decode_batch=4,
                           capacity=32, policy="least_loaded",
                           tpot_budget_ms=6.0, admission="shed")
    results = system.serve(reqs)
    assert any(r.shed for r in results)          # the leak path exercised
    sched = system.scheduler
    assert sched.prefill_inflight_tokens == [0.0, 0.0]
    assert sched._routed_load == {}


def test_fault_recovery_releases_routed_load(granite):
    """The recover-then-finish (and recover-then-shed) path releases the
    routed load exactly once — idempotent by rid."""
    cfg, params = granite
    reqs = stream_requests(5, max_new=6)
    inj = FaultInjector(FaultPlan([
        FaultEvent("engine_crash", engine=1, at=0.004)]))
    system = ServingSystem(params, cfg, prefill_engines=2, decode_batch=2,
                           capacity=32, decode_engines=2,
                           policy="least_loaded", fault_injector=inj)
    results = system.serve(reqs)
    assert inj.crashes_fired == 1
    assert system.scheduler.summary()["recoveries"] >= 1
    assert not any(r.shed for r in results)
    assert system.scheduler.prefill_inflight_tokens == [0.0, 0.0]
    assert system.scheduler._routed_load == {}


# ---------------------------------------------------------------------------
# Pipelined chunked KV handoff: bit-identity + TTFT monotonicity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["granite-3-2b",     # dense GQA
                                  "deepseek-r1",      # MLA
                                  "olmoe-1b-7b"])     # MoE
def test_streamed_handoff_tokens_bit_identical(arch):
    """The streamed path rebuilds the decode cache from the bytes that
    crossed the wire, chunk by chunk — emitted tokens must match the
    synchronous whole-request handoff exactly, for every cache layout."""
    cfg = smoke(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    reqs = stream_requests(3, prompt_len=10, max_new=4)
    system = ServingSystem(params, cfg, prefill_engines=2, decode_batch=2,
                           capacity=32)
    sync = {r.rid: list(r.tokens) for r in system.serve(reqs)}
    sync_ttft = {r: system.scheduler.traces[r].ttft for r in sync}
    system.reconfigure_scheduler(SchedulerConfig(stream_handoff=True,
                                                 stream_chunk=4))
    strm_res = system.serve(reqs)
    assert {r.rid: list(r.tokens) for r in strm_res} == sync
    sched = system.scheduler
    s = sched.summary()
    assert s["stream_requests"] == 3
    assert s["stream_chunks"] == 3 * 3           # 10 tokens = 2 full + tail
    assert s["stream_bytes"] > 0 and s["stream_max_chunk_bytes"] > 0
    for t in sched.traces.values():
        assert t.transfer_chunks == 3
        assert t.overlap_seconds >= 0.0
        assert t.transfer_seconds > 0.0          # last chunk's wire time
        assert t.ready_at == pytest.approx(t.prefill_end
                                           + t.transfer_seconds)
        assert t.ttft <= sync_ttft[t.rid] + 1e-12


def test_streamed_ttft_monotonically_better(granite):
    """Open-loop burst: per-request virtual-clock TTFT under streaming is
    never worse than synchronous handoff, and strictly better somewhere
    (the hidden transfer time is real)."""
    cfg, params = granite
    rng = np.random.RandomState(7)
    reqs = [Request(i, list(rng.randint(0, 100, 16)), 3, arrival=2e-4 * i)
            for i in range(6)]
    system = ServingSystem(params, cfg, prefill_engines=2, decode_batch=4,
                           capacity=48)
    sync_res = system.serve(reqs, open_loop=True)
    sync = {r.rid: system.scheduler.traces[r.rid].ttft for r in sync_res}
    system.reconfigure_scheduler(SchedulerConfig(stream_handoff=True,
                                                 stream_chunk=4))
    strm_res = system.serve(reqs, open_loop=True)
    strm = {r.rid: system.scheduler.traces[r.rid].ttft for r in strm_res}
    assert [r.tokens for r in strm_res] == [r.tokens for r in sync_res]
    assert all(strm[r] <= sync[r] + 1e-12 for r in sync)
    assert any(strm[r] < sync[r] - 1e-12 for r in sync)
    assert system.scheduler.summary()["stream_overlap_s"] > 0


def test_hybrid_arch_falls_back_to_synchronous_handoff():
    """Ring-buffer SSM state has no per-position KV to stream: the gate
    keeps hybrids on the synchronous path even when streaming is on."""
    cfg = smoke("zamba2-1.2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    reqs = stream_requests(2, prompt_len=10, max_new=3)
    system = ServingSystem(params, cfg, prefill_engines=1, decode_batch=2,
                           capacity=32, stream_handoff=True, stream_chunk=4)
    ref = {r.rid: greedy_reference(cfg, params, r.prompt, r.max_new_tokens)
           for r in reqs}
    got = {r.rid: list(r.tokens) for r in system.serve(reqs)}
    assert got == ref
    s = system.scheduler.summary()
    assert s["stream_requests"] == 0 and s["stream_chunks"] == 0


# ---------------------------------------------------------------------------
# Joint P/D autoscaler end-to-end: the capacity see-saw
# ---------------------------------------------------------------------------


def _phase_skewed_burst(cfg):
    """Prefill-heavy opening (long prompts, 2-token generations), then a
    decode-heavy phase (short prompts, long generations)."""
    rng = np.random.RandomState(3)
    reqs = [Request(i, list(rng.randint(0, cfg.vocab_size, 48)), 2,
                    arrival=5e-4 * i) for i in range(8)]
    reqs += [Request(100 + i, list(rng.randint(0, cfg.vocab_size, 6)), 24,
                     arrival=0.15 + 2e-4 * i) for i in range(8)]
    return reqs


def test_joint_autoscaler_shifts_both_ways_tokens_identical(granite):
    cfg, params = granite
    reqs = _phase_skewed_burst(cfg)
    kw = dict(prefill_engines=1, decode_batch=2, capacity=96,
              decode_engines=2)
    ref_sys = ServingSystem(params, cfg, **kw)
    ref = {r.rid: list(r.tokens) for r in ref_sys.serve(reqs,
                                                        open_loop=True)}
    system = ServingSystem(params, cfg, joint_autoscale=True,
                           min_prefill=1, max_prefill=3,
                           min_engines=1, max_engines=3,
                           ttft_budget_ms=2.0, tpot_budget_ms=6.0,
                           admission="queue", **kw)
    got = {r.rid: list(r.tokens) for r in system.serve(reqs,
                                                       open_loop=True)}
    assert got == ref                      # the see-saw never alters tokens
    s = system.scheduler.summary()
    assert s["shifts_d2p"] >= 1 and s["shifts_p2d"] >= 1
    counts = [n for _, n in s["prefill_count_timeline"]]
    assert max(counts) >= 2 and min(counts) == 1
    shifts = [e for e in system.scheduler.scale_events
              if e["action"].startswith("shift_")]
    assert all(e["role"] == "joint" for e in shifts)
    # the prefill phase pulls capacity d2p before decode pulls it back
    first_d2p = min(e["t"] for e in shifts if e["action"] == "shift_d2p")
    last_p2d = max(e["t"] for e in shifts if e["action"] == "shift_p2d")
    assert first_d2p < last_p2d
    # conservation inside the clamp: every event stamps both role counts
    for e in shifts:
        assert 1 <= e["prefill_live"] <= 3
        assert 1 <= e["engines_live"] <= 3
    assert system.scheduler.prefill_inflight_tokens \
        == [0.0] * system.prefill_pool.n
