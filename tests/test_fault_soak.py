"""Fault soak: a long deterministic sweep of seeded fault plans driven
against the pure-control-plane state machines (FaultInjector +
DecodeSlotManager roster + PoolAutoscaler) on a virtual clock, checking
conservation and roster invariants every iteration.

Fast by default (CI runs it via the ``fault_soak`` marker); the full
million-iteration soak from the issue is the same harness env-gated:

    FAULT_SOAK_ITERS=1000000 PYTHONPATH=src pytest -m fault_soak \\
        tests/test_fault_soak.py

No jax in the loop — the soak exercises scheduling/failure logic, not
compute, so a million virtual-clock iterations stay tractable."""
import hashlib
import os
import random

import pytest

from repro.serving import DecodeCostModel, FaultInjector, PoolAutoscaler
from repro.serving.faults import FaultPlan
from repro.serving.scheduler import DecodeSlotManager

SOAK_ITERS = int(os.environ.get("FAULT_SOAK_ITERS", "20000"))
ITERS_PER_PLAN = 2000
N_SLOTS = 2
STEP_S = 1e-3


class _SoakPool:
    """A decode pool reduced to its accounting: slot managers, a live/dead
    roster, per-engine virtual clocks, and an autoscaler — everything the
    fault plane mutates, nothing that computes."""

    def __init__(self, n_engines: int, injector: FaultInjector, seed: int):
        self.mgrs = [DecodeSlotManager(N_SLOTS, 64) for _ in range(n_engines)]
        self.live = [True] * n_engines
        self.dead = [False] * n_engines
        self.clocks = [0.0] * n_engines
        self.inj = injector
        self.rng = random.Random(seed ^ 0x5f5f)
        self.scaler = PoolAutoscaler(DecodeCostModel(), N_SLOTS,
                                     min_engines=1, max_engines=n_engines + 2,
                                     grow_patience=2, shrink_patience=3,
                                     cooldown=2)
        self.queue = 0              # requests waiting for a slot
        self.next_rid = 0
        self.recovered = 0
        self.log = hashlib.sha256()

    @property
    def n_live(self):
        return sum(self.live)

    @property
    def active(self):
        return sum(m.active for m, lv in zip(self.mgrs, self.live) if lv)

    def tick(self):
        # arrivals (seeded, bounded)
        self.queue += self.rng.randrange(3)
        # admissions to live engines with free slots
        for e, mgr in enumerate(self.mgrs):
            while self.live[e] and self.queue and mgr.free_slot() is not None:
                mgr.allocate(self.next_rid, cache_len=8)
                self.next_rid += 1
                self.queue -= 1
        # decode progress: clocks advance under the straggler multiplier,
        # and each busy engine finishes a request with seeded probability
        for e, mgr in enumerate(self.mgrs):
            if not self.live[e]:
                continue
            factor = self.inj.slowdown(e, self.clocks[e])
            assert factor >= 1.0
            if mgr.active:
                self.clocks[e] += STEP_S * factor
                if self.rng.random() < 0.25:
                    slot = next(iter(mgr.active_slots()))[0]
                    mgr.release(slot)
        # crashes fire on per-engine clocks; lost requests requeue
        # (the real system replays them — accounting-wise: back to queue)
        for e in self.inj.due_crashes(self.clocks):
            if not self.live[e]:
                continue
            lost = [s for s, _ in self.mgrs[e].active_slots()]
            for slot in lost:
                self.mgrs[e].release(slot)
            self.live[e] = False
            self.dead[e] = True
            self.queue += len(lost)
            self.recovered += len(lost)
            self.log.update(f"crash:{e}@{self.clocks[e]:.6f}:"
                            f"{len(lost)}".encode())
        # a seeded share of RDMA attempts consults the transfer hook
        if self.rng.random() < 0.3:
            fault = self.inj.transfer_fault(
                self.rng.choice(("transfer", "migrate")))
            assert fault in (None, "timeout", "corrupt")
            if fault:
                self.log.update(fault.encode())
        # controller: dead engines are NOT in n_live; below-min respawns
        decision = self.scaler.decide(self.n_live, self.active, self.queue,
                                      shrinkable=self.n_live > 1)
        if decision == "grow":
            for e in range(len(self.live)):          # revive lowest non-live
                if not self.live[e]:
                    self.live[e] = True
                    self.dead[e] = False
                    break
            else:
                self.mgrs.append(DecodeSlotManager(N_SLOTS, 64))
                self.live.append(True)
                self.dead.append(False)
                self.clocks.append(max(self.clocks))
            self.log.update(b"grow")
        elif decision == "shrink" and self.n_live > 1:
            victims = [e for e in range(len(self.live))
                       if self.live[e] and self.mgrs[e].active == 0]
            if victims:                              # only empty engines park
                self.live[victims[-1]] = False
                self.log.update(b"shrink")

    def check_invariants(self):
        for e, mgr in enumerate(self.mgrs):
            assert mgr.acquired == mgr.released + mgr.active
            if not self.live[e]:
                assert mgr.active == 0, "non-live engine holds work"
        assert self.n_live >= 0 and self.queue >= 0
        assert all(c >= 0.0 for c in self.clocks)
        assert self.inj.crashes_fired <= sum(
            1 for ev in self.inj.plan.events if ev.kind == "engine_crash")


def _run_plan(seed: int, iters: int):
    n_engines = 2 + seed % 3
    plan = FaultPlan.random(seed, n_engines=n_engines,
                            horizon_s=iters * STEP_S * 0.1,
                            n_crashes=1 + seed % 2, n_transfer_faults=2,
                            n_stragglers=2)
    pool = _SoakPool(n_engines, FaultInjector(plan, seed=seed), seed)
    for i in range(iters):
        pool.tick()
        if i % 100 == 0 or i == iters - 1:
            pool.check_invariants()
    pool.check_invariants()
    # exact firing semantics: per-engine clocks are monotone, so a crash
    # event fired iff its engine's final clock crossed the scheduled
    # instant (an engine that sat parked below its crash time is the one
    # legitimate never-fire) — no more, no less, no double-fires
    expected = sum(1 for ev in plan.events if ev.kind == "engine_crash"
                   and ev.engine < len(pool.clocks)
                   and pool.clocks[ev.engine] >= ev.at)
    assert pool.inj.crashes_fired == expected
    assert pool.n_live >= 1
    return pool.log.hexdigest(), pool.inj.crashes_fired


@pytest.mark.fault_soak
def test_fault_soak_invariants_hold_across_seeded_plans():
    iters = max(ITERS_PER_PLAN, SOAK_ITERS // max(1, SOAK_ITERS
                                                  // ITERS_PER_PLAN))
    n_plans = max(1, SOAK_ITERS // iters)
    total_fired = 0
    for seed in range(n_plans):
        _, fired = _run_plan(seed, iters)
        total_fired += fired
    # the sweep as a whole must actually exercise the crash plane
    assert total_fired >= 1


@pytest.mark.fault_soak
def test_fault_soak_is_bit_deterministic():
    """The same seed drives the identical crash/fault/scale event log —
    the soak (and any failure it finds) is replayable from one integer."""
    assert _run_plan(3, ITERS_PER_PLAN) == _run_plan(3, ITERS_PER_PLAN)
