"""Multi-device tests: LEP modes, hybrid parallelism, dry-run path.

These need >1 XLA device, so each runs in a subprocess with
--xla_force_host_platform_device_count=8 (the main pytest process must keep
seeing exactly ONE device per the assignment)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, n_dev: int = 8, timeout: int = 520) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_lep_all_modes_match_reference():
    out = run_py('''
import dataclasses, jax, jax.numpy as jnp
from repro.launch.mesh import make_debug_mesh
mesh = make_debug_mesh(2, 4)
from repro.configs import get_config, smoke_variant
from repro.core.lep import make_lep_moe_fn
from repro.models import moe as moe_mod
cfg = dataclasses.replace(smoke_variant(get_config("olmoe-1b-7b")), capacity_factor=8.0)
p1 = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg, 1, jnp.float32)
p = jax.tree.map(lambda a: a[0], p1)
x = jax.random.normal(jax.random.PRNGKey(1), (24, cfg.d_model), jnp.float32)
ref, _ = moe_mod.moe_reference(p, x, cfg)
for kw in [dict(ep_axes=("model",)), dict(ep_axes=("data","model"), redundancy=2),
           dict(ep_axes=("model",), ffn_shard_axis="data"),
           dict(ep_axes=("model",), ffn_shard_axis="data", ffn_gather="tokens"),
           dict(ep_axes=("model",), naive=True), dict(ep_axes=("model",), quantize=False)]:
    fn = make_lep_moe_fn(mesh, **kw)
    with mesh:
        out, aux = jax.jit(lambda pp, xx: fn(pp, xx, cfg))(p, x)
    tol = 0.05 if kw.get("quantize", True) and not kw.get("naive") else 1e-4
    rel = float(jnp.max(jnp.abs(out - ref))) / float(jnp.max(jnp.abs(ref)))
    assert rel < tol, (kw, rel)
    assert int(aux["dropped"]) == 0
print("LEP_OK")
''')
    assert "LEP_OK" in out


def test_lep_uneven_tokens_padding():
    out = run_py('''
import dataclasses, jax, jax.numpy as jnp
from repro.launch.mesh import make_debug_mesh
mesh = make_debug_mesh(2, 4)
from repro.configs import get_config, smoke_variant
from repro.core.lep import make_lep_moe_fn
from repro.models import moe as moe_mod
cfg = dataclasses.replace(smoke_variant(get_config("olmoe-1b-7b")), capacity_factor=8.0)
p1 = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg, 1, jnp.float32)
p = jax.tree.map(lambda a: a[0], p1)
for t in (3, 7, 13):   # not divisible by 8 devices -> padding path
    x = jax.random.normal(jax.random.PRNGKey(t), (t, cfg.d_model), jnp.float32)
    ref, _ = moe_mod.moe_reference(p, x, cfg)
    fn = make_lep_moe_fn(mesh, ep_axes=("model",), quantize=False)
    with mesh:
        out, _ = jax.jit(lambda pp, xx: fn(pp, xx, cfg))(p, x)
    rel = float(jnp.max(jnp.abs(out - ref))) / float(jnp.max(jnp.abs(ref)))
    assert rel < 1e-4, (t, rel)
print("PAD_OK")
''')
    assert "PAD_OK" in out


def test_hybrid_parallel_mla_prefill():
    out = run_py('''
import jax, jax.numpy as jnp
from repro.launch.mesh import make_debug_mesh
mesh = make_debug_mesh(2, 4)
from repro.configs import get_config, smoke_variant
from repro.models import mla as M
from repro.core.hybrid_parallel import mla_prefill_hybrid
cfg = smoke_variant(get_config("deepseek-r1"))
p1 = M.init_mla_params(jax.random.PRNGKey(0), cfg, 1, jnp.float32)
p = jax.tree.map(lambda a: a[0], p1)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
ref, lat_ref = M.mla_prefill(p, x, cfg)
for mode in ("a2a", "rs"):
    with mesh:
        out, lat = jax.jit(lambda pp, xx: mla_prefill_hybrid(pp, xx, cfg, mesh, oproj_mode=mode))(p, x)
    e = float(jnp.max(jnp.abs(out - ref))) / float(jnp.max(jnp.abs(ref)))
    assert e < 1e-4, (mode, e)
print("HYBRID_OK")
''')
    assert "HYBRID_OK" in out


def test_hybrid_prefill_integrated_in_model():
    """REPRO_MLA_HYBRID routes the model's MLA prefill through the §4.3.1
    SP→TP→SP path; logits must match the plain path."""
    out = run_py('''
import os, jax, jax.numpy as jnp
from repro.launch.mesh import make_debug_mesh
mesh = make_debug_mesh(2, 4)
from repro.configs import get_config, smoke_variant
from repro.core.parallel import set_current_mesh
from repro.models import init_params, prefill
cfg = smoke_variant(get_config("deepseek-r1"))
params = init_params(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
ref, _ = prefill(params, cfg, {"tokens": toks}, 40, cache_dtype=jnp.float32)
set_current_mesh(mesh)
os.environ["REPRO_MLA_HYBRID"] = "a2a"
with mesh:
    hy, _ = jax.jit(lambda p, b: prefill(p, cfg, b, 40, cache_dtype=jnp.float32))(params, {"tokens": toks})
e = float(jnp.max(jnp.abs(hy - ref))) / float(jnp.max(jnp.abs(ref)))
assert e < 5e-3, e
print("HYBRID_MODEL_OK")
''')
    assert "HYBRID_MODEL_OK" in out


def test_sharded_train_step_runs():
    """A real (executed, not just lowered) sharded train step on a 2x4 mesh."""
    out = run_py('''
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_debug_mesh
mesh = make_debug_mesh(2, 4)
from repro.configs import get_config, smoke_variant
from repro.core.lep import make_lep_moe_fn
from repro.models import init_params
from repro.train import OptConfig, make_train_step, init_opt_state
import numpy as np
cfg = smoke_variant(get_config("olmoe-1b-7b"))
params = init_params(jax.random.PRNGKey(0), cfg)
moe_fn = make_lep_moe_fn(mesh, ep_axes=("model",))
step = make_train_step(cfg, OptConfig(total_steps=5, warmup_steps=1), moe_fn)
opt = init_opt_state(params)
batch = {"tokens": jnp.ones((8, 16), jnp.int32),
         "labels": jnp.ones((8, 16), jnp.int32)}
with mesh:
    p2, opt, m = jax.jit(step)(params, opt, batch)
assert not bool(jnp.isnan(m["loss"]))
print("TRAIN_OK", float(m["loss"]))
''')
    assert "TRAIN_OK" in out


@pytest.mark.parametrize("arch,shape", [
    ("granite-3-2b", "decode_32k"),
    ("qwen2.5-3b", "train_4k"),
])
def test_dryrun_production_mesh(arch, shape):
    """The real dry-run entry point (512 placeholder devices) lowers and
    compiles for a representative (arch × shape) on the 16×16 mesh."""
    out = run_py(f'''
from repro.launch.dryrun import run_one
rec = run_one("{arch}", "{shape}", multi_pod=False, save=False)
assert rec["status"] == "ok", rec
print("DRYRUN_OK", rec["dominant"])
''', n_dev=512, timeout=560)
    assert "DRYRUN_OK" in out
