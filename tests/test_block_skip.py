"""Block-skipped flash prefill (beyond-paper §Perf optimization) must match
the masked full-S chunked baseline exactly."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke
from repro.models import attention as A
from repro.models import forward, init_params


@pytest.fixture(autouse=True)
def _restore_env():
    yield
    os.environ["REPRO_BLOCK_SKIP"] = "0"


@pytest.mark.parametrize("window", [None, 16, 24])
def test_flash_matches_baseline(window):
    cfg = dataclasses.replace(smoke("qwen3-8b"), sliding_window=window)
    p1 = A.init_attention_params(jax.random.PRNGKey(0), cfg, 1, jnp.float32)
    p = jax.tree.map(lambda a: a[0], p1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    os.environ["REPRO_BLOCK_SKIP"] = "0"
    ref, (k1, v1) = A.attention_prefill(p, x, cfg)
    os.environ["REPRO_BLOCK_SKIP"] = "1"
    out, (k2, v2) = A.attention_prefill(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))


def test_mla_flash_matches_baseline():
    from repro.models import mla as M
    cfg = smoke("deepseek-r1")
    p1 = M.init_mla_params(jax.random.PRNGKey(2), cfg, 1, jnp.float32)
    p = jax.tree.map(lambda a: a[0], p1)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model))
    os.environ["REPRO_BLOCK_SKIP"] = "0"
    ref, lat_ref = M.mla_prefill(p, x, cfg)
    os.environ["REPRO_BLOCK_SKIP"] = "1"
    out, lat = M.mla_prefill(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(lat), np.asarray(lat_ref))


def test_flash_full_model_forward():
    cfg = smoke("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    os.environ["REPRO_BLOCK_SKIP"] = "0"
    ref, _ = forward(params, cfg, {"tokens": toks})
    os.environ["REPRO_BLOCK_SKIP"] = "1"
    out, _ = forward(params, cfg, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
