"""INT8 quantization subsystem (paper §4.5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke
from repro.quant import (adaptive_scale_search, calibrate_linear,
                         quantize_param_tree, quantized_matmul,
                         should_quantize)


@pytest.fixture(scope="module")
def calib_data():
    k = jax.random.PRNGKey(0)
    w = jax.random.normal(k, (128, 96)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 128))
    x = x.at[:, 5].mul(30.0)  # activation outlier channel
    return w, x


def _rel_err(w, x, **kwargs):
    ref = x @ w
    ql = calibrate_linear(w, x, **kwargs)
    out = quantized_matmul(x, ql)
    return float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))


def test_equalization_suppresses_outliers(calib_data):
    w, x = calib_data
    plain = _rel_err(w, x, equalize=False, block_clip=False, compensate=False)
    eq = _rel_err(w, x, equalize=True, block_clip=False, compensate=False)
    assert eq < plain * 0.6, f"equalization should cut error: {plain} -> {eq}"


def test_full_pipeline_monotone(calib_data):
    w, x = calib_data
    plain = _rel_err(w, x, equalize=False, block_clip=False, compensate=False)
    full = _rel_err(w, x, equalize=True, block_clip=True, compensate=True)
    assert full <= plain
    assert full < 0.02  # accuracy-preserving (paper Table 6 spirit)


def test_adaptive_scale_search_improves_or_matches(calib_data):
    w, x = calib_data
    s, errs = adaptive_scale_search(w, x)
    assert float(jnp.min(errs)) <= float(errs[3]) + 1e-6  # grid[3] == 1.0


def test_kernel_path_matches_jnp_path(calib_data):
    w, x = calib_data
    ql = calibrate_linear(w, x, equalize=True, block_clip=False,
                          compensate=False)
    out_j = quantized_matmul(x, ql, use_kernel=False)
    out_k = quantized_matmul(x, ql, use_kernel=True)
    np.testing.assert_allclose(np.asarray(out_j), np.asarray(out_k),
                               rtol=1e-3, atol=1e-3)


def test_mixed_precision_policy():
    assert should_quantize("segments/moe/moe/w_gate")
    assert should_quantize("segments/dense/attn/wq")
    assert should_quantize("segments/moe/attn/wkv_a")
    assert not should_quantize("segments/dense/attn/ln")
    assert not should_quantize("segments/moe/moe/router")
    assert not should_quantize("segments/mamba/mamba/A_log")
    assert not should_quantize("segments/mamba/mamba/conv_w")
    assert not should_quantize("embed")


@pytest.mark.parametrize("arch", ["qwen3-8b", "olmoe-1b-7b", "mamba2-780m",
                                  "deepseek-r1"])
def test_quantize_param_tree_coverage(arch):
    from repro.models import init_params
    cfg = smoke(arch)
    p = init_params(jax.random.PRNGKey(0), cfg)
    qp, stats = quantize_param_tree(p)
    assert stats["quantized"] > 0
    assert stats["kept"] > 0
    # quantized leaves carry scales
    flat = jax.tree_util.tree_flatten_with_path(qp)[0]
    q_leaves = [p for p, _ in flat if any(
        getattr(k, "key", "") == "__q__" for k in p)]
    assert len(q_leaves) == stats["quantized"]
