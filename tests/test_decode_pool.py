"""Decode-pool invariants: routing-policy semantics, pool-wide slot
conservation, token identity of pooled vs single-engine decode, and
bitwise cache equality across forced cross-engine KV migrations
(dense/MLA/MoE)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke
from repro.mempool import ContextCache, MemoryPool
from repro.models import decode_step, init_params, prefill
from repro.models.model import cache_batch_axes
from repro.serving import (DECODE_ROUTERS, DecodeEngine, DecodePool,
                           KVTransferEngine, Request, RequestResult,
                           SchedulerConfig, ServingSystem, SlotError,
                           make_decode_router)
from repro.serving import cache_ops


@pytest.fixture(scope="module")
def granite():
    cfg = smoke("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def stream_requests(n, prompt_len=12, max_new=4, seed=1):
    rng = np.random.RandomState(seed)
    return [Request(i, list(rng.randint(0, 100, prompt_len)), max_new)
            for i in range(n)]


def greedy_reference(cfg, params, prompt, n_new):
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    logits, caches = prefill(params, cfg, batch,
                             capacity=len(prompt) + n_new + 4,
                             cache_dtype=jnp.float32)
    toks = [int(jnp.argmax(logits[0, -1]))]
    cl = jnp.int32(len(prompt))
    for _ in range(n_new - 1):
        lg, caches = decode_step(params, cfg,
                                 jnp.asarray([[toks[-1]]], jnp.int32),
                                 caches, cl)
        toks.append(int(jnp.argmax(lg[0])))
        cl = cl + 1
    return toks


def slices_bitwise_equal(cfg, a, b):
    """Bitwise equality of every batched leaf of two request slices."""
    axes = cache_batch_axes(cfg)
    oks = jax.tree.leaves(jax.tree.map(
        lambda x, y, ax: True if ax is None else
        bool(np.array_equal(np.asarray(x), np.asarray(y))),
        a, b, axes))
    return all(oks)


# ---------------------------------------------------------------------------
# Router policy semantics (pure control plane, no jax)
# ---------------------------------------------------------------------------


def test_decode_router_registry_and_unknown_policy():
    assert set(DECODE_ROUTERS) == {"least_loaded_slots", "round_robin",
                                   "cache_affinity"}
    with pytest.raises(ValueError, match="unknown decode routing policy"):
        make_decode_router("least_loaded", 2)    # prefill policy, not pool
    with pytest.raises(ValueError, match="at least one"):
        make_decode_router("round_robin", 0)


def test_router_select_is_pure_until_commit():
    """select() never mutates router state: a gated/waiting request that
    retries gets the same answer; the cursor/affinity map moves only on
    on_admit (the actual placement)."""
    rr = make_decode_router("round_robin", 3)
    assert [rr.select([0, 0, 0], [2, 2, 2]) for _ in range(4)] == [0] * 4
    rr.on_admit(0)
    assert rr.select([1, 0, 0], [1, 2, 2]) == 1
    rr.on_admit(1)
    rr.on_admit(2)
    assert rr.select([1, 1, 1], [1, 1, 1]) == 0   # wrapped

    aff = make_decode_router("cache_affinity", 2)
    keys = ["cc:a", "cc:b"]
    assert aff.select([0, 0], [2, 2], keys) == 0   # no residency: least id
    aff.on_admit(1, keys)
    assert aff.select([0, 5], [2, 2], keys) == 1   # blocks live on engine 1
    assert aff.select([0, 5], [2, 2], keys) == 1   # …and select stays pure
    # a full engine is deprioritized even when affinity points at it
    assert aff.select([0, 5], [2, 0], keys) == 0


def test_least_loaded_slots_prefers_free_engines():
    r = make_decode_router("least_loaded_slots", 3)
    assert r.select([5, 2, 9], [1, 1, 1]) == 1
    assert r.select([4, 4, 4], [1, 1, 1]) == 0          # tie → lowest id
    assert r.select([0, 3, 4], [0, 1, 1]) == 1          # engine 0 is full


def test_pool_rejects_heterogeneous_engines(granite):
    cfg, params = granite
    a = DecodeEngine(params, cfg, 2, 32)
    b = DecodeEngine(params, cfg, 2, 48)                # different capacity
    with pytest.raises(ValueError, match="identical cache layout"):
        DecodePool([a, b], make_decode_router("round_robin", 2))
    with pytest.raises(ValueError, match="router sized"):
        DecodePool([a], make_decode_router("round_robin", 2))


# ---------------------------------------------------------------------------
# Pool-wide slot conservation
# ---------------------------------------------------------------------------


def test_pool_slot_conservation_across_waves(granite):
    """Slots acquired == released + active, per engine and pool-wide,
    after every serve() wave — including a wave that sheds."""
    cfg, params = granite
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=32, decode_engines=2,
                           decode_router="least_loaded_slots")

    def check():
        for mgr in system.pool.slot_mgrs:
            assert mgr.acquired == mgr.released + mgr.active
            assert mgr.active == 0          # wave fully drained
        total_acq = sum(m.acquired for m in system.pool.slot_mgrs)
        total_rel = sum(m.released for m in system.pool.slot_mgrs)
        assert total_acq == total_rel + system.pool.active

    results = system.serve(stream_requests(5))
    assert len(results) == 5
    check()
    results = system.serve(stream_requests(4, seed=2))
    check()
    # shedding wave: shed requests never acquire a slot, so conservation
    # still balances
    system.reconfigure_scheduler(
        SchedulerConfig(tpot_budget_ms=5.0, admission="shed",
                        decode_policy="least_loaded_slots"))
    results = system.serve(stream_requests(6, seed=3))
    assert any(r.shed for r in results)
    check()


# ---------------------------------------------------------------------------
# Token identity: pooled == single-engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("router", ["round_robin", "least_loaded_slots"])
def test_pooled_decode_token_identical_to_single_engine(granite, router):
    cfg, params = granite
    reqs = stream_requests(5)
    single = ServingSystem(params, cfg, n_prefill=2, decode_batch=4,
                           capacity=32)
    ref = {r.rid: r.tokens for r in single.serve(
        [Request(r.rid, list(r.prompt), r.max_new_tokens) for r in reqs])}
    pooled = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=32, decode_engines=2,
                           decode_router=router)
    got = {r.rid: r.tokens for r in pooled.serve(reqs)}
    assert got == ref
    # both engines actually decoded something
    s = pooled.scheduler.summary()
    assert s["decode_engines"] == 2
    assert all(t > 0 for t in s["engine_decode_tokens"])


def test_pooled_decode_composes_with_chunked_fast_path(granite):
    """decode_chunk > 1 inside each pool engine stays token-identical."""
    cfg, params = granite
    reqs = stream_requests(4, max_new=6)
    single = ServingSystem(params, cfg, n_prefill=1, decode_batch=2,
                           capacity=32)
    ref = {r.rid: r.tokens for r in single.serve(
        [Request(r.rid, list(r.prompt), r.max_new_tokens) for r in reqs])}
    pooled = ServingSystem(params, cfg, n_prefill=1, decode_batch=2,
                           capacity=32, decode_engines=2,
                           decode_router="round_robin", decode_chunk=3)
    got = {r.rid: r.tokens for r in pooled.serve(reqs)}
    assert got == ref


# ---------------------------------------------------------------------------
# Cross-engine KV migration: bitwise cache equality, dense/MLA/MoE
# ---------------------------------------------------------------------------


def _manual_pool(cfg, params, capacity, n=2, batch=2):
    engines = [DecodeEngine(params, cfg, batch, capacity, seed=e)
               for e in range(n)]
    return DecodePool(engines, make_decode_router("round_robin", n))


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-r1", "olmoe-1b-7b"])
def test_forced_migration_bitwise_cache_equality(arch):
    """Mid-stream drain into a peer engine: the migrated request's cache
    rows are bit-identical on the destination, and the continued decode is
    token-identical to an unmigrated greedy reference."""
    cfg = smoke(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(3)
    prompt = list(rng.randint(0, 200, 10))
    max_new = 6
    ref = greedy_reference(cfg, params, prompt, max_new)

    pool = _manual_pool(cfg, params, capacity=len(prompt) + max_new + 4)
    logits, caches = prefill(params, cfg,
                             {"tokens": jnp.asarray([prompt], jnp.int32)},
                             capacity=pool.capacity, cache_dtype=jnp.float32)
    first = int(jnp.argmax(logits[0, -1]))
    res = RequestResult(0, [])
    slot = pool.engines[0].free_slot()
    pool.add(0, slot, caches, first, len(prompt), res, max_new)

    # decode two tokens on engine 0, then migrate mid-stream
    for _ in range(2):
        pool.engines[0].step()
    src_snapshot = cache_ops.slice_request(cfg, pool.engines[0].caches, slot)
    src_len = int(pool.engines[0].cache_len[slot])
    transfer = KVTransferEngine()
    src_e, dst_slot, seconds = pool.migrate(0, 1, transfer)
    assert (src_e, pool.migrations) == (0, 1)
    assert seconds > 0 and transfer.migrations == 1
    assert transfer.bytes_migrated == pool.migrated_bytes > 0

    dst = pool.engines[1]
    dst_slice = cache_ops.slice_request(cfg, dst.caches, dst_slot)
    assert slices_bitwise_equal(cfg, src_snapshot, dst_slice)
    assert int(dst.cache_len[dst_slot]) == src_len
    assert pool.engines[0].active == 0 and dst.active == 1

    # finish on the destination engine: tokens must match the reference
    while dst.active:
        dst.step()
    assert res.tokens == ref


def test_migration_error_paths(granite):
    cfg, params = granite
    pool = _manual_pool(cfg, params, capacity=24, batch=1)
    logits, caches = prefill(params, cfg,
                             {"tokens": jnp.asarray([[1, 2, 3, 4]],
                                                    jnp.int32)},
                             capacity=24, cache_dtype=jnp.float32)
    first = int(jnp.argmax(logits[0, -1]))
    for rid, engine in ((0, 0), (1, 1)):
        res = RequestResult(rid, [])
        pool.add(engine, 0, caches, first, 4, res, 4)
    with pytest.raises(SlotError, match="not resident"):
        pool.migrate(99, 1)
    with pytest.raises(ValueError, match="already decodes"):
        pool.migrate(0, 0)
    with pytest.raises(SlotError, match="no free slot"):
        pool.migrate(0, 1)                       # engine 1 is full
    with pytest.raises(SlotError, match="all-or-nothing"):
        pool.drain_engine(0)


def test_drain_engine_is_atomic_when_peers_cannot_absorb(granite):
    """Regression (half-drain bug): drain used to migrate slot by slot and
    raise only when the peers filled mid-drain — leaving some requests
    moved and some stranded. The aggregate-capacity pre-check makes drain
    all-or-nothing: on failure, *nothing* has migrated."""
    cfg, params = granite
    pool = _manual_pool(cfg, params, capacity=24, n=2, batch=2)
    logits, caches = prefill(params, cfg,
                             {"tokens": jnp.asarray([[1, 2, 3, 4]],
                                                    jnp.int32)},
                             capacity=24, cache_dtype=jnp.float32)
    first = int(jnp.argmax(logits[0, -1]))
    # engine 0 fully loaded (2 active); engine 1 has 1 active, 1 free —
    # the old code migrated one request, then raised on the second.
    for rid, engine in ((0, 0), (1, 0), (2, 1)):
        res = RequestResult(rid, [])
        pool.add(engine, pool.engines[engine].free_slot(), caches, first,
                 4, res, 4)
    with pytest.raises(SlotError, match="all-or-nothing"):
        pool.drain_engine(0)
    assert pool.engines[0].active == 2          # nothing moved
    assert pool.engines[1].active == 1
    assert pool.migrations == 0
    # free a peer slot: the same drain now moves everything
    pool.engines[1].slot_mgr.release(
        next(iter(pool.engines[1].slot_mgr.active_slots()))[0])
    moved = pool.drain_engine(0)
    assert len(moved) == 2 and pool.engines[0].active == 0


def test_drain_with_zero_live_peers_raises_and_moves_nothing(granite):
    """Edge case: draining when every peer is parked/dead. peer_free_slots
    must count LIVE peers only, so the all-or-nothing pre-check fails
    cleanly instead of migrating onto a non-live engine."""
    cfg, params = granite
    pool = _manual_pool(cfg, params, capacity=24, n=3, batch=2)
    logits, caches = prefill(params, cfg,
                             {"tokens": jnp.asarray([[1, 2, 3, 4]],
                                                    jnp.int32)},
                             capacity=24, cache_dtype=jnp.float32)
    first = int(jnp.argmax(logits[0, -1]))
    pool.add(0, 0, caches, first, 4, RequestResult(0, []), 4)
    pool.retire_engine(2)                       # parked
    pool.fail_engine(1)                         # dead
    assert pool.peer_free_slots(0) == 0 and not pool.can_drain(0)
    with pytest.raises(SlotError, match="all-or-nothing"):
        pool.drain_engine(0)
    assert pool.engines[0].active == 1 and pool.migrations == 0
    with pytest.raises(ValueError, match="last live engine"):
        pool.retire_engine(0)


def test_drain_failure_mid_drain_surfaces_moves_and_conserves_slots(granite):
    """Edge case: the capacity pre-check passes but the RDMA plane gives
    out mid-drain. DrainError must carry the completed moves and the
    failed rid; the failed request stays whole on the source with slot
    accounting conserved (acquired == released + active pool-wide)."""
    from repro.serving import (DrainError, FaultEvent, FaultInjector,
                               FaultPlan)

    cfg, params = granite
    pool = _manual_pool(cfg, params, capacity=24, n=2, batch=2)
    logits, caches = prefill(params, cfg,
                             {"tokens": jnp.asarray([[1, 2, 3, 4]],
                                                    jnp.int32)},
                             capacity=24, cache_dtype=jnp.float32)
    first = int(jnp.argmax(logits[0, -1]))
    for rid in (0, 1):
        pool.add(0, rid, caches, first, 4, RequestResult(rid, []), 4)
    # first migrate clean, second exhausts its retries
    inj = FaultInjector(FaultPlan([
        FaultEvent("transfer_timeout", op="migrate", after=1, count=99)]))
    transfer = KVTransferEngine(fault_hook=inj.transfer_fault, max_retries=2)
    with pytest.raises(DrainError, match="after 1 completed moves") as ei:
        pool.drain_engine(0, transfer)
    assert [m[0] for m in ei.value.moved] == [0]        # rid 0 landed
    assert ei.value.failed_rid == 1
    # rid 1 is intact on the source engine; nothing half-moved
    assert pool.locate(1) == (0, 1)
    assert pool.engines[0].active == 1 and pool.engines[1].active == 1
    assert pool.migrations == 1
    total_acq = sum(m.acquired for m in pool.slot_mgrs)
    total_rel = sum(m.released for m in pool.slot_mgrs)
    assert total_acq == total_rel + pool.active
    assert transfer.timeouts == 3                       # 1 + 2 retries


def test_rebalance_prefers_victim_without_cache_affinity(granite):
    """Regression (affinity-thrash bug): the rebalancer used to migrate
    the hottest engine's lowest-numbered slot, which under cache_affinity
    could be a request whose cached prefix blocks live on that very
    engine — the router would route the next shared-prefix admission right
    back, fighting the move. The victim must be a request *without* block
    residency on the source engine when one exists."""
    cfg, params = granite
    pool = DecodePool(
        [DecodeEngine(params, cfg, 4, 24, seed=e) for e in range(2)],
        make_decode_router("cache_affinity", 2))
    logits, caches = prefill(params, cfg,
                             {"tokens": jnp.asarray([[1, 2, 3, 4]],
                                                    jnp.int32)},
                             capacity=24, cache_dtype=jnp.float32)
    first = int(jnp.argmax(logits[0, -1]))
    shared = ("cc:prefix0", "cc:prefix1")
    # slots 0/1 on engine 0 hold shared-prefix requests (resident blocks);
    # slot 2 holds an affinity-free request. Engine 1 idles.
    for rid, keys in ((0, shared), (1, shared), (2, ())):
        res = RequestResult(rid, [])
        pool.add(0, pool.engines[0].free_slot(), caches, first, 4, res, 6,
                 block_keys=keys)
    moved = pool.rebalance()
    assert moved is not None
    rid, src, dst, _ = moved
    assert (src, dst) == (0, 1)
    assert rid == 2                # the non-resident request moved…
    assert pool.router.residency(0, shared) == 2   # …residency unperturbed
    # with only resident requests left (release the migrated one), the
    # fallback is the old deterministic choice: lowest active slot moves
    pool.engines[1].slot_mgr.release(
        next(iter(pool.engines[1].slot_mgr.active_slots()))[0])
    moved = pool.rebalance()
    assert moved is not None and moved[0] == 0


def test_drain_engine_retires_all_slots(granite):
    """Engine retirement: every active slot migrates to peers and decode
    completes correctly on the new engines."""
    cfg, params = granite
    pool = _manual_pool(cfg, params, capacity=24, n=3, batch=2)
    rng = np.random.RandomState(4)
    prompts = [list(rng.randint(0, 100, 8)) for _ in range(2)]
    refs, ress = [], []
    for rid, p in enumerate(prompts):
        refs.append(greedy_reference(cfg, params, p, 5))
        logits, caches = prefill(params, cfg,
                                 {"tokens": jnp.asarray([p], jnp.int32)},
                                 capacity=24, cache_dtype=jnp.float32)
        res = RequestResult(rid, [])
        ress.append(res)
        pool.add(0, pool.engines[0].free_slot(), caches,
                 int(jnp.argmax(logits[0, -1])), len(p), res, 5)
    pool.engines[0].step()
    moved = pool.drain_engine(0, KVTransferEngine())
    assert len(moved) == 2 and pool.engines[0].active == 0
    assert {dst for _, dst, _ in moved} <= {1, 2}
    while pool.active:
        for _, eng in enumerate(pool.engines):
            if eng.active:
                eng.step()
    for res, ref in zip(ress, refs):
        assert res.tokens == ref


def test_serving_system_forced_migration_in_trace(granite):
    """ServingSystem.migrate_request charges the RDMA plane and records the
    move on the scheduler trace (engine + migration counters)."""
    cfg, params = granite
    system = ServingSystem(params, cfg, n_prefill=1, decode_batch=2,
                           capacity=32, decode_engines=2,
                           decode_router="round_robin")
    req = Request(0, list(np.random.RandomState(5).randint(0, 100, 10)), 6)
    sched = system.scheduler
    sched.begin_epoch()
    tr = sched.on_arrival(0, 0.0, 10)
    first, caches, res = system.prefills[0].run(req)
    sched.on_prefill_done(tr, 0, res.computed_tokens, res.reused_tokens)
    sched.on_transfer(tr, system.transfer.transfer(caches))
    slot = system.pool.engines[0].free_slot()
    system.pool.add(0, slot, caches, first, 10, res, 6)
    sched.on_admit(tr, slot, 0)
    for e, _, il in system.pool.step_all():
        for entry in il:
            sched.on_decode_step(*entry, engine=e)
    seconds = system.migrate_request(0, 1)
    assert seconds > 0
    assert tr.decode_engine == 1 and tr.migrations == 1
    assert tr.migration_seconds == pytest.approx(seconds)
    assert system.transfer.migrations == 1
    # destination clock >= source clock: per-request timeline stays monotone
    assert sched._decode_now[1] >= sched._decode_now[0] + seconds


# ---------------------------------------------------------------------------
# Rebalancing + EMS-aware routing end-to-end
# ---------------------------------------------------------------------------


def test_auto_rebalance_migrates_and_preserves_tokens(granite):
    """Uneven drain (short requests on one engine) triggers the pool
    rebalancer, which must not change any generated token."""
    cfg, params = granite
    rng = np.random.RandomState(6)
    # rids 0,2 decode long on engine 0; rids 1,3 finish fast on engine 1
    # (least_loaded_slots alternates admissions), leaving a >=2 imbalance.
    reqs = [Request(i, list(rng.randint(0, 100, 10)),
                    10 if i % 2 == 0 else 2) for i in range(4)]
    single = ServingSystem(params, cfg, n_prefill=1, decode_batch=4,
                           capacity=32)
    ref = {r.rid: r.tokens for r in single.serve(
        [Request(r.rid, list(r.prompt), r.max_new_tokens) for r in reqs])}
    pooled = ServingSystem(params, cfg, n_prefill=1, decode_batch=2,
                           capacity=32, decode_engines=2,
                           decode_router="least_loaded_slots",
                           decode_rebalance_every=1)
    got = {r.rid: r.tokens for r in pooled.serve(reqs)}
    assert got == ref
    s = pooled.scheduler.summary()
    assert s["migrations"] >= 1
    assert pooled.pool.migrations == s["migrations"]
    assert pooled.transfer.migrations == s["migrations"]
    migrated = [t for t in pooled.scheduler.tracker.finished
                if t.migrations > 0]
    assert migrated and all(t.migration_seconds > 0 for t in migrated)


def test_cache_affinity_routes_shared_prefix_to_resident_engine(granite):
    """EMS-aware routing: requests sharing a cached prefix land on the
    engine already holding those blocks; round_robin spreads them."""
    cfg, params = granite
    rng = np.random.RandomState(7)
    prefix = list(rng.randint(0, 100, 8))
    reqs = [Request(i, prefix + list(rng.randint(0, 100, 4)), 3)
            for i in range(2)]

    def run(router):
        cc = ContextCache(MemoryPool(n_nodes=4), block_tokens=4,
                          model_tag=cfg.name)
        system = ServingSystem(params, cfg, n_prefill=1, decode_batch=2,
                               capacity=32, decode_engines=2,
                               decode_router=router, context_cache=cc)
        system.serve([Request(r.rid, list(r.prompt), r.max_new_tokens)
                      for r in reqs])
        return [system.scheduler.traces[i].decode_engine for i in range(2)]

    assert run("cache_affinity") == [0, 0]       # prefix blocks pin engine 0
    assert run("round_robin") == [0, 1]
