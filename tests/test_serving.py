"""Serving-system behaviour: PDC flow, cache reuse exactness, MTP greedy
equivalence, continuous batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke
from repro.core import init_mtp_params
from repro.core.mtp import mtp_step, propose_draft
from repro.mempool import ContextCache, MemoryPool
from repro.models import decode_step, init_params, prefill
from repro.serving import Request, ServingSystem


@pytest.fixture(scope="module")
def qwen():
    cfg = smoke("qwen3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def greedy_reference(cfg, params, prompt, n_new):
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    logits, caches = prefill(params, cfg, batch, capacity=len(prompt) + n_new + 4,
                             cache_dtype=jnp.float32)
    toks = [int(jnp.argmax(logits[0, -1]))]
    cl = jnp.int32(len(prompt))
    for _ in range(n_new - 1):
        lg, caches = decode_step(params, cfg,
                                 jnp.asarray([[toks[-1]]], jnp.int32), caches, cl)
        toks.append(int(jnp.argmax(lg[0])))
        cl = cl + 1
    return toks


def test_serving_matches_direct_greedy(qwen):
    cfg, params = qwen
    rng = np.random.RandomState(1)
    prompts = [list(rng.randint(0, 200, 20)) for _ in range(3)]
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=48)
    results = system.serve([Request(i, p, 5) for i, p in enumerate(prompts)])
    for r in results:
        ref = greedy_reference(cfg, params, prompts[r.rid], 5)
        assert r.tokens == ref, f"rid {r.rid}: {r.tokens} != {ref}"


def test_cache_reuse_is_exact(qwen):
    """Outputs with context-cache reuse == outputs without (bit-level)."""
    cfg, params = qwen
    rng = np.random.RandomState(2)
    shared = list(rng.randint(0, 200, 16))
    prompts = [shared + list(rng.randint(0, 200, 8)) for _ in range(3)]

    pool = MemoryPool(n_nodes=4)
    cc = ContextCache(pool, block_tokens=8, model_tag=cfg.name)
    sys_cached = ServingSystem(params, cfg, n_prefill=1, decode_batch=3,
                               capacity=48, context_cache=cc)
    res_c = sys_cached.serve([Request(i, p, 4) for i, p in enumerate(prompts)])
    assert any(r.reused_tokens > 0 for r in res_c), "no reuse happened"

    sys_plain = ServingSystem(params, cfg, n_prefill=1, decode_batch=3,
                              capacity=48)
    res_p = sys_plain.serve([Request(i, p, 4) for i, p in enumerate(prompts)])
    for rc, rp in zip(sorted(res_c, key=lambda r: r.rid),
                      sorted(res_p, key=lambda r: r.rid)):
        assert rc.tokens == rp.tokens


def test_pdc_end_to_end_reuse_accounting(qwen):
    """Full PDC run under prefix reuse: reused + computed tokens must account
    for exactly the prompt, in both RequestResult and the scheduler trace."""
    cfg, params = qwen
    rng = np.random.RandomState(6)
    shared = list(rng.randint(0, 200, 16))
    prompts = [shared + list(rng.randint(0, 200, 8)) for _ in range(4)]
    pool = MemoryPool(n_nodes=4)
    cc = ContextCache(pool, block_tokens=8, model_tag=cfg.name)
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=48, context_cache=cc)
    results = system.serve([Request(i, p, 4) for i, p in enumerate(prompts)])
    assert len(results) == 4
    assert any(r.reused_tokens > 0 for r in results), "no reuse happened"
    for r in results:
        assert r.reused_tokens + r.computed_tokens == len(prompts[r.rid])
        assert len(r.tokens) == 4
    for rec in system.scheduler.trace_records():
        assert rec["reused_tokens"] + rec["computed_tokens"] \
            == rec["prompt_tokens"]
        # EMS reuse directly buys TTFT: only computed tokens cost prefill time
        assert rec["prefill_end"] - rec["prefill_start"] == pytest.approx(
            rec["computed_tokens"]
            * system.scheduler.config.prefill_token_cost_s)


def test_mtp_greedy_equals_plain_greedy(qwen):
    """Speculative decoding must not change greedy outputs — the fundamental
    correctness property of MTP (§4.2.4)."""
    cfg, params = qwen
    mtp = init_mtp_params(jax.random.PRNGKey(7), cfg)
    rng = np.random.RandomState(3)
    prompt = list(rng.randint(0, 200, 20))
    n_new = 9
    ref = greedy_reference(cfg, params, prompt, n_new)

    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    logits, caches = prefill(params, cfg, batch, capacity=64,
                             cache_dtype=jnp.float32)
    x = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    d = propose_draft(params, mtp, cfg, x)
    cl = jnp.full((1,), len(prompt), jnp.int32)
    got = [int(x[0])]
    key = jax.random.PRNGKey(0)
    accepts = 0
    while len(got) < n_new:
        key, sub = jax.random.split(key)
        em, acc, x, d, caches, cl = mtp_step(params, mtp, cfg, x, d, caches,
                                             cl, sub, greedy=True)
        got.append(int(em[0, 0]))
        if bool(acc[0]) and len(got) < n_new:
            got.append(int(em[0, 1]))
            accepts += 1
    assert got[:n_new] == ref, f"MTP diverged: {got[:n_new]} != {ref}"


def test_mtp_mixed_acceptance_batch(qwen):
    """Batched MTP with diverging per-request lengths still matches
    per-request greedy references (the §4.2.2-(3) misaligned-batch case)."""
    cfg, params = qwen
    mtp = init_mtp_params(jax.random.PRNGKey(8), cfg)
    rng = np.random.RandomState(4)
    prompts = [list(rng.randint(0, 200, 16)) for _ in range(3)]
    n_new = 7
    refs = [greedy_reference(cfg, params, p, n_new) for p in prompts]

    batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
    logits, caches = prefill(params, cfg, batch, capacity=48,
                             cache_dtype=jnp.float32)
    x = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    d = propose_draft(params, mtp, cfg, x)
    cl = jnp.full((3,), 16, jnp.int32)
    got = [[int(x[i])] for i in range(3)]
    key = jax.random.PRNGKey(1)
    for _ in range(n_new):
        key, sub = jax.random.split(key)
        em, acc, x, d, caches, cl = mtp_step(params, mtp, cfg, x, d, caches,
                                             cl, sub, greedy=True)
        for i in range(3):
            if len(got[i]) < n_new:
                got[i].append(int(em[i, 0]))
                if bool(acc[i]) and len(got[i]) < n_new:
                    got[i].append(int(em[i, 1]))
    for i in range(3):
        assert got[i][:n_new] == refs[i], f"req {i}: {got[i][:n_new]} != {refs[i]}"


def test_continuous_batching_more_requests_than_slots(qwen):
    cfg, params = qwen
    rng = np.random.RandomState(5)
    prompts = [list(rng.randint(0, 200, 12)) for _ in range(5)]
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=32)
    results = system.serve([Request(i, p, 4) for i, p in enumerate(prompts)])
    assert len(results) == 5
    for r in results:
        assert len(r.tokens) == 4
        ref = greedy_reference(cfg, params, prompts[r.rid], 4)
        assert r.tokens == ref
