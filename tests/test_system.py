"""End-to-end behaviour tests for the paper's system: the full PDC pipeline
(train a tiny model → checkpoint → model-cache deploy → serve with context
caching + MTP) exercised as one workflow."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from conftest import smoke
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import init_mtp_params
from repro.data import make_batch_iter
from repro.mempool import ContextCache, MemoryPool, ModelCache
from repro.models import init_params
from repro.serving import Request, ServingSystem
from repro.train import train


def test_full_lifecycle_train_deploy_serve():
    cfg = smoke("qwen2.5-3b")

    # 1. train briefly (substrate: data pipeline + optimizer + loop)
    params = init_params(jax.random.PRNGKey(0), cfg)
    it = make_batch_iter(cfg.vocab_size, 32, 4, seed=0)
    params, hist = train(params, cfg, it, steps=8, log_every=100)
    assert not np.isnan(hist[-1]["loss"])

    # 2. checkpoint + register in the EMS model cache
    pool = MemoryPool(n_nodes=8, dram_per_node=1 << 34)
    mc = ModelCache(pool)
    with tempfile.TemporaryDirectory() as d:
        man = save_checkpoint(d, params, 8, meta={"arch": cfg.name})
        nbytes = sum(np.prod(x.shape) * x.dtype.itemsize
                     for x in jax.tree.leaves(params))
        meta = mc.register(cfg.name, f"step{man['step']}", int(nbytes),
                           block_bytes=1 << 20)
        mc.prefetch(meta)
        t_switch, warm = mc.switch_model(meta)
        assert warm
        params2, step = load_checkpoint(d, params)
    assert step == 8

    # 3. serve through the peer-to-peer PDC system with context caching + MTP
    cc = ContextCache(pool, block_tokens=8, model_tag=cfg.name)
    mtp = init_mtp_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.RandomState(0)
    shared = list(rng.randint(0, 500, 16))
    reqs = [Request(i, shared + list(rng.randint(0, 500, 8)), 5)
            for i in range(3)]
    system = ServingSystem(params2, cfg, n_prefill=2, decode_batch=2,
                           capacity=48, context_cache=cc, use_mtp=True,
                           mtp_params=mtp)
    results = system.serve(reqs)
    assert len(results) == 3
    assert all(len(r.tokens) == 5 for r in results)
    assert any(r.reused_tokens > 0 for r in results)       # context cache hit
    assert system.transfer.transfers == 3                  # P→D handoffs
    # identical prompts prefix ⇒ identical first blocks stored once (dedup)
    assert cc.dedup_skipped > 0 or cc.stored_blocks <= 9


def test_scheduler_is_stateless_and_load_balanced():
    """Prefill routing ignores data locality (peer-to-peer property): with
    equal loads, requests spread across instances."""
    cfg = smoke("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    system = ServingSystem(params, cfg, n_prefill=3, decode_batch=4,
                           capacity=32)
    rng = np.random.RandomState(1)
    reqs = [Request(i, list(rng.randint(0, 100, 12)), 2) for i in range(6)]
    results = system.serve(reqs)
    used = {r.prefill_instance for r in results}
    assert len(results) == 6
    assert used == {0, 1, 2}  # virtual-backlog balancing spreads the load
