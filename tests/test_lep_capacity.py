"""Regression tests for core/lep.py capacity math (paper Eq. 2).

These pin the *behaviour* of the static-buffer sizing — zero-token edge
cases, capacity-factor rounding, sublane alignment, and the drop accounting
of capacity-bounded dispatch — so the shard_map compat fix stays anchored to
semantics rather than to imports alone.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lep import _cdiv, lep_capacity
from repro.models.moe import dispatch_indices


# ---------------------------------------------------------------------------
# lep_capacity (Eq. 2): cap = ceil(int(t_loc·k·factor) / slots) + 1,
# rounded up to `align` with an `align` floor.
# ---------------------------------------------------------------------------


def test_zero_tokens_still_allocates_aligned_floor():
    # An empty local shard must still produce a valid (non-zero) static
    # buffer: the TPU sublane floor dominates.
    assert lep_capacity(0, 2, 8, 1.0) == 8           # default align=8
    assert lep_capacity(0, 2, 8, 1.0, align=1) == 1  # decode path floor
    assert lep_capacity(0, 8, 256, 4.0, align=4) == 4


def test_exact_values_and_alignment_rounding():
    # cdiv(16·1·1.0, 4) + 1 = 5 → padded to the next multiple of align
    assert lep_capacity(16, 1, 4, 1.0, align=1) == 5
    assert lep_capacity(16, 1, 4, 1.0, align=4) == 8
    assert lep_capacity(16, 1, 4, 1.0, align=8) == 8
    # paper-scale EP320-ish shape: 128 tokens/rank, k=8, 256 slots
    assert lep_capacity(128, 8, 256, 1.0, align=1) == 5
    assert lep_capacity(128, 8, 256, 1.0) == 8
    # decode single-token path: t_loc=1
    assert lep_capacity(1, 8, 256, 1.0, align=1) == 2


def test_capacity_factor_rounding_truncates_product_first():
    # 3·2·1.25 = 7.5 → int() truncation to 7 BEFORE cdiv: cdiv(7,4)+1 = 3.
    assert lep_capacity(3, 2, 4, 1.25, align=1) == 3
    # if the product were ceil'd first this would be cdiv(8,4)+1 = 3 too;
    # distinguish with a case where truncation changes the bucket count:
    # 5·1·1.5 = 7.5 → int → 7 → cdiv(7,8)+1 = 2 (ceil'd 8 would give 2 as
    # well, so use slots=7: trunc 7→cdiv=1+1=2; ceil 8→cdiv=2+1=3)
    assert lep_capacity(5, 1, 7, 1.5, align=1) == 2


def test_capacity_monotone_in_factor_and_tokens():
    caps_f = [lep_capacity(32, 4, 16, f, align=1)
              for f in (0.5, 1.0, 1.5, 2.0, 4.0)]
    assert caps_f == sorted(caps_f)
    caps_t = [lep_capacity(t, 4, 16, 1.0, align=1) for t in (0, 8, 64, 512)]
    assert caps_t == sorted(caps_t)


def test_alignment_is_respected_for_all_aligns():
    for align in (1, 2, 4, 8, 16):
        for t in (0, 1, 7, 33, 100):
            cap = lep_capacity(t, 2, 8, 1.0, align=align)
            assert cap % align == 0 and cap >= align
            # never below the unaligned requirement
            assert cap >= _cdiv(int(t * 2 * 1.0), 8) + 1 or t == 0


# ---------------------------------------------------------------------------
# Drop accounting: dispatch_indices valid-mask under capacity pressure
# ---------------------------------------------------------------------------


def test_dispatch_drops_exactly_the_overflow():
    top_i = jnp.zeros((8, 1), jnp.int32)            # all tokens → expert 0
    slot, valid = dispatch_indices(top_i, num_experts=4, capacity=8)
    np.testing.assert_array_equal(np.asarray(slot[:, 0]), np.arange(8))
    assert bool(valid.all())                        # capacity fits: no drops
    _, valid6 = dispatch_indices(top_i, num_experts=4, capacity=6)
    assert int(valid6.sum()) == 6                   # exactly 2 dropped
    # arrival order is preserved: the dropped ones are the LAST arrivals
    np.testing.assert_array_equal(np.asarray(valid6[:, 0]),
                                  [1, 1, 1, 1, 1, 1, 0, 0])


def test_lep_capacity_prevents_drops_under_uniform_routing():
    """cap from Eq. 2 with factor>=1 never drops uniformly-routed tokens."""
    t, k, slots = 24, 2, 8
    top_i = jnp.asarray(
        (np.arange(t * k) % slots).reshape(t, k), jnp.int32)
    cap = lep_capacity(t, k, slots, 1.0, align=1)
    _, valid = dispatch_indices(top_i, slots, cap)
    assert bool(valid.all())
