"""Scheduler trace invariants that previously went unchecked: per-request
virtual-clock monotonicity, decode_tokens == emitted tokens under chunked
decode and MTP, seed-determinism of the Poisson workload and of
admission-gate decisions, and the MTP acceptance-rate feedback loop."""
import jax
import numpy as np
import pytest

from conftest import smoke
from repro.core import init_mtp_params
from repro.models import init_params
from repro.serving import (DecodeCostModel, DecodeSlotManager, Request,
                           Scheduler, SchedulerConfig, ServingSystem,
                           poisson_requests)


@pytest.fixture(scope="module")
def granite():
    cfg = smoke("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def granite_mtp_system(granite):
    cfg, params = granite
    mtp = init_mtp_params(jax.random.PRNGKey(2), cfg)
    return ServingSystem(params, cfg, n_prefill=1, decode_batch=2,
                         capacity=40, use_mtp=True, mtp_params=mtp)


def stream_requests(n, prompt_len=12, max_new=4, seed=1):
    rng = np.random.RandomState(seed)
    return [Request(i, list(rng.randint(0, 100, prompt_len)), max_new)
            for i in range(n)]


def assert_monotone(records):
    """arrival -> prefill -> KV-ready -> admit -> decode-end never rewinds."""
    for rec in records:
        if rec["shed"]:
            continue
        assert rec["arrival"] <= rec["prefill_start"] <= rec["prefill_end"]
        ready = rec["prefill_end"] + rec["transfer_seconds"]
        assert rec["decode_admit"] >= ready - 1e-12
        assert rec["decode_end"] >= rec["decode_admit"]
        assert rec["decode_seconds"] >= 0 and rec["queue_seconds"] >= 0


# ---------------------------------------------------------------------------
# Virtual-clock monotonicity per request
# ---------------------------------------------------------------------------


def test_clock_monotone_closed_loop_pooled_chunked(granite):
    cfg, params = granite
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=32, decode_engines=2,
                           decode_router="least_loaded_slots",
                           decode_chunk=2, decode_rebalance_every=1)
    results = system.serve(stream_requests(5, max_new=6))
    assert len(results) == 5
    assert_monotone(system.scheduler.trace_records())


def test_clock_monotone_open_loop_poisson(granite):
    cfg, params = granite
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=32)
    reqs = poisson_requests(8, 300.0, 10, 4, 100, seed=11)
    system.serve(reqs, open_loop=True)
    recs = system.scheduler.trace_records()
    assert_monotone(recs)
    for rec in recs:                  # open loop: nothing precedes arrival
        assert rec["prefill_start"] >= rec["arrival"]


# ---------------------------------------------------------------------------
# decode_tokens in the trace == tokens the engine actually emitted
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("decode_chunk", [1, 3])
def test_trace_decode_tokens_sum_matches_emitted(granite, decode_chunk):
    cfg, params = granite
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=32, decode_chunk=decode_chunk)
    results = system.serve(stream_requests(4, max_new=5))
    sched = system.scheduler
    for r in results:
        # prefill produced tokens[0]; every other token was a decode commit
        assert sched.traces[r.rid].decode_tokens == len(r.tokens) - 1
    assert sched.decode_token_count == sum(len(r.tokens) - 1
                                           for r in results)


def test_trace_decode_tokens_sum_matches_emitted_mtp(granite_mtp_system):
    """Under MTP an iteration may commit 2 tokens; the per-iteration credit
    must still sum exactly to what each request received."""
    system = granite_mtp_system
    results = system.serve(stream_requests(4, max_new=5, seed=9))
    sched = system.scheduler
    for r in results:
        tr = sched.traces[r.rid]
        assert tr.decode_tokens == len(r.tokens) - 1
        assert tr.decode_iters <= tr.decode_tokens    # speculation credits
    assert sched.decode_token_count == sum(len(r.tokens) - 1
                                           for r in results)


def test_open_loop_pool_decodes_concurrently(granite):
    """Idle engines' clocks track the busy frontier: an arrival landing
    while engine 0 decodes a long request must be admitted to idle
    engine 1 at its arrival time, not after the pool drains (the pool
    would otherwise serialize into bulk-synchronous waves open-loop)."""
    cfg, params = granite
    rng = np.random.RandomState(19)
    reqs = [Request(0, list(rng.randint(0, 100, 8)), 12, arrival=0.0),
            Request(1, list(rng.randint(0, 100, 8)), 3, arrival=5e-3)]
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=1,
                           capacity=32, decode_engines=2,
                           decode_router="least_loaded_slots")
    results = {r.rid: r for r in system.serve(reqs, open_loop=True)}
    assert len(results) == 2
    tr0, tr1 = system.scheduler.traces[0], system.scheduler.traces[1]
    assert (tr0.decode_engine, tr1.decode_engine) == (0, 1)
    # rid 1 decodes DURING rid 0's residency, not after it
    assert tr1.decode_admit < tr0.decode_end
    assert_monotone(system.scheduler.trace_records())


def test_open_loop_advances_to_fifo_head_ready_at(granite):
    """Livelock regression: with the decode pool idle, the clock must
    fast-forward to the FIFO *head's* KV-ready time. A later-arriving
    request with a shorter prompt (idler prefill instance) gets an earlier
    ready_at; advancing only to min-over-waiting left the head gated and
    the serve loop spinning on the same instant forever."""
    import signal

    cfg, params = granite
    rng = np.random.RandomState(17)
    reqs = [Request(0, list(rng.randint(0, 100, 60)), 3, arrival=0.0),
            Request(1, list(rng.randint(0, 100, 4)), 3, arrival=4e-4)]
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=2,
                           capacity=64)
    signal.alarm(120)              # fail loudly instead of hanging CI
    try:
        results = system.serve(reqs, open_loop=True)
    finally:
        signal.alarm(0)
    assert sorted(r.rid for r in results) == [0, 1]
    assert all(len(r.tokens) == 3 for r in results)
    recs = system.scheduler.trace_records()
    assert_monotone(recs)
    # the head (long prefill) really was the later-ready request
    assert recs[0]["prefill_end"] + recs[0]["transfer_seconds"] > \
        recs[1]["prefill_end"] + recs[1]["transfer_seconds"]


# ---------------------------------------------------------------------------
# Determinism given a seed
# ---------------------------------------------------------------------------


def test_poisson_requests_seed_determinism():
    a = poisson_requests(16, 250.0, 12, 4, 500, seed=42, shared_prefix=4)
    b = poisson_requests(16, 250.0, 12, 4, 500, seed=42, shared_prefix=4)
    assert [r.arrival for r in a] == [r.arrival for r in b]
    assert [r.prompt for r in a] == [r.prompt for r in b]
    c = poisson_requests(16, 250.0, 12, 4, 500, seed=43, shared_prefix=4)
    assert [r.arrival for r in a] != [r.arrival for r in c]
    assert [r.prompt for r in a] != [r.prompt for r in c]


def test_admission_decisions_deterministic_given_seed(granite):
    """Replaying the same seeded Poisson burst through the same system
    yields byte-identical traces — shed/queue decisions included."""
    cfg, params = granite
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=4,
                           capacity=32, tpot_budget_ms=5.5,
                           admission="shed")

    def run():
        reqs = poisson_requests(10, 400.0, 10, 4, 100, seed=21)
        results = system.serve(reqs, open_loop=True)
        shed = sorted(r.rid for r in results if r.shed)
        return shed, system.scheduler.trace_records()

    shed_a, recs_a = run()
    shed_b, recs_b = run()
    assert shed_a == shed_b and shed_a     # the gate actually shed
    assert recs_a == recs_b                # floats equal: same ops, same seed


# ---------------------------------------------------------------------------
# Acceptance-rate feedback into DecodeCostModel.mtp_accept
# ---------------------------------------------------------------------------


def _simulated_wave(sched, accept_tokens_per_iter, iters=4):
    tr = sched.on_arrival(0, 0.0, 8)
    sched.on_prefill_done(tr, 0, 8, 0)
    sched.on_transfer(tr, 0.0)
    sched.slot_mgr.allocate(0, 8)
    sched.on_admit(tr, 0)
    for i in range(iters):
        fin = [0] if i == iters - 1 else []
        sched.on_decode_step([0], fin, {0: accept_tokens_per_iter})
    sched.slot_mgr.release(0)


def test_mtp_feedback_expands_gate_after_high_acceptance_wave():
    cfg = SchedulerConfig(use_mtp=True, tpot_budget_ms=10.0,
                          admission="queue")
    sched = Scheduler(1, DecodeSlotManager(8, 64), cfg)
    cap0 = sched.gate.max_batch            # sized for the paper's α = 0.70
    assert sched.cost.mtp_accept == DecodeCostModel.MTP_ACCEPT
    _simulated_wave(sched, accept_tokens_per_iter=2)   # perfect acceptance
    assert sched.feedback_mtp_acceptance() == pytest.approx(1.0)
    assert sched.cost.mtp_accept == pytest.approx(1.0)
    assert sched.gate.max_batch > cap0     # more tokens/iter => bigger batch

    # and a dismal wave shrinks it below the paper default
    sched.begin_epoch()
    _simulated_wave(sched, accept_tokens_per_iter=1)   # nothing accepted
    assert sched.feedback_mtp_acceptance() == pytest.approx(0.0)
    assert sched.gate.max_batch < cap0


def test_mtp_feedback_noop_without_mtp_or_data():
    sched = Scheduler(1, DecodeSlotManager(4, 64),
                      SchedulerConfig(tpot_budget_ms=10.0))
    assert sched.feedback_mtp_acceptance() is None     # not an MTP system
    sched_mtp = Scheduler(1, DecodeSlotManager(4, 64),
                          SchedulerConfig(use_mtp=True))
    assert sched_mtp.feedback_mtp_acceptance() is None  # no finished trace


def test_mtp_feedback_applied_end_to_end(granite_mtp_system):
    """ServingSystem folds the measured acceptance back into the cost model
    after each wave: cost.mtp_accept equals the trace-derived rate."""
    system = granite_mtp_system
    results = system.serve(stream_requests(3, max_new=5, seed=13))
    sched = system.scheduler
    iters = sum(t.decode_iters for t in sched.tracker.finished)
    toks = sum(t.decode_tokens for t in sched.tracker.finished)
    assert iters > 0
    measured = min(1.0, max(0.0, toks / iters - 1.0))
    assert sched.cost.mtp_accept == pytest.approx(measured)
    assert len(results) == 3


# ---------------------------------------------------------------------------
# Preempt-then-resume invariants (SLO-class overload control)
# ---------------------------------------------------------------------------


def _overload_requests(seed=7, n_batch=6, n_interactive=4):
    """Batch flood first, interactive arriving mid-decode: forces the gate
    to preempt batch slots when preemption is enabled."""
    rng = np.random.RandomState(seed)
    reqs = [Request(i, list(rng.randint(0, 100, 12)), 6,
                    arrival=5e-4 * i, slo_class="batch")
            for i in range(n_batch)]
    reqs += [Request(100 + i, list(rng.randint(0, 100, 12)), 4,
                     arrival=4e-3 + 2e-3 * i, slo_class="interactive")
             for i in range(n_interactive)]
    return reqs


def test_preempt_resume_token_identical_and_monotone(granite):
    """A preempted-then-resumed batch request finishes token-identical to
    the uncontended run, its per-request clock stays monotone through the
    preemption, and DecodeSlotManager acquired/released conservation holds
    across every evict/re-admit cycle."""
    cfg, params = granite
    reqs = _overload_requests()

    def run(class_aware):
        kw = dict(n_prefill=2, decode_batch=3, capacity=64)
        if class_aware:
            kw.update(tpot_budget_ms=6.0, batch_tpot_budget_ms=30.0,
                      preempt_batch=True)
        system = ServingSystem(params, cfg, **kw)
        results = system.serve(list(reqs), open_loop=True)
        return system, results

    controlled, res_c = run(class_aware=True)
    reference, res_r = run(class_aware=False)
    sched = controlled.scheduler
    preempted = [t.rid for t in sched.traces.values() if t.preemptions > 0]
    assert preempted, "scenario must actually preempt"
    assert sched.preemptions >= len(preempted)
    assert all(sched.traces[rid].slo_class == "batch" for rid in preempted)
    # Token identity: every preempted request's tokens match the
    # uncontended (class-blind) reference run exactly.
    tok_c = {r.rid: r.tokens for r in res_c if not r.shed}
    tok_r = {r.rid: r.tokens for r in res_r if not r.shed}
    for rid in preempted:
        assert not tok_c[rid] == [] and tok_c[rid] == tok_r[rid]
    # Monotone per-request clocks through the preemption; the preemption
    # latency is charged to the trace.
    assert_monotone(sched.trace_records())
    for rid in preempted:
        tr = sched.traces[rid]
        assert tr.preempt_seconds > 0
        assert tr.decode_end >= tr.decode_admit
    # Slot conservation: every acquire (admission + re-admission) has a
    # matching release (preemption eviction + finish) once the wave drains.
    for mgr in controlled.pool.slot_mgrs:
        assert mgr.acquired == mgr.released
        assert mgr.active == 0
    # Preemption must not have shed anyone in queue mode.
    assert sched.tracker.summary()["shed"] == 0


def test_preempt_composes_with_continuous_batching_and_chunk(granite):
    """Preemption through the chunked continuous-batching fast path keeps
    the same invariants: conservation, monotone traces, completion."""
    cfg, params = granite
    system = ServingSystem(params, cfg, n_prefill=2, decode_batch=3,
                           capacity=64, decode_chunk=2,
                           continuous_batching=True,
                           tpot_budget_ms=6.0, batch_tpot_budget_ms=30.0,
                           preempt_batch=True)
    results = system.serve(_overload_requests(seed=23), open_loop=True)
    sched = system.scheduler
    assert len(results) == 10 and not any(r.shed for r in results)
    assert_monotone(sched.trace_records())
    for mgr in system.pool.slot_mgrs:
        assert mgr.acquired == mgr.released and mgr.active == 0
